"""Table XI: SA runtime and the simulation-cache speedup.

The paper reports convergence under 2 hours per workload with the cache
(WL5: 363 min -> 73 min without/with = ~5x). Our ScaleSim-equivalent is
analytical, so absolute runtimes are seconds; the asserted claim is the
CACHE EFFECT: hit-rate dominates and wall-clock improves when the cache
is shared across the anneal.
"""
from __future__ import annotations

import time

from repro.core import SAConfig, SimCache, TEMPLATES, workload
from repro.core import scalesim
from repro.pathfinding import Pathfinder, SimulatedAnnealing
from benchmarks.common import row, timed


class _NoCache(scalesim.SimCache):
    """A cache that never hits (paper's 'without caching' flow)."""

    def simulate(self, tiles, core, dataflow):
        self.misses += 1
        return scalesim.simulate_assignment(tiles, core, dataflow)


def run(out=print) -> str:
    cfg = SAConfig(t_initial=400.0, t_final=0.05, cooling=0.93,
                   moves_per_temp=25, norm_samples=800, seed=1)
    # frontier collection off: this benchmark isolates the Sec V-D cache
    # mitigation, and per-move archive feeding is identical fixed
    # overhead on both arms (it would only dilute the measured ratio)
    sa = SimulatedAnnealing(cfg, frontier_size=0)

    def flow(wl, cache):
        pf = Pathfinder(wl, TEMPLATES["T1"], cache=cache)
        pf.fit_normalizer(samples=800, method="scalar")
        pf.search(strategy=sa)

    def compute():
        results = []
        for wl_idx in range(1, 7):
            wl = workload(wl_idx)
            cache = SimCache()
            t0 = time.perf_counter()
            flow(wl, cache)
            with_cache = time.perf_counter() - t0
            nocache = _NoCache()
            t0 = time.perf_counter()
            flow(wl, nocache)
            without = time.perf_counter() - t0
            hit_rate = cache.hits / max(1, cache.hits + cache.misses)
            results.append((wl_idx, with_cache, without, hit_rate))
        return results

    results, us = timed(compute)
    out("# Table XI: SA runtime per workload (T1), cache on/off")
    out("wl,with_cache_s,without_cache_s,speedup,hit_rate")
    speedups = []
    for wl_idx, w, wo, hr in results:
        out(f"WL{wl_idx},{w:.2f},{wo:.2f},{wo/w:.2f},{hr:.3f}")
        speedups.append(wo / w)
    avg = sum(speedups) / len(speedups)
    hr_min = min(hr for *_, hr in results)
    derived = f"avg_cache_speedup={avg:.2f}x;min_hit_rate={hr_min:.2f}"
    assert hr_min > 0.5, "cache must absorb most simulations"
    assert avg > 1.2, f"cache must speed up the anneal (got {avg:.2f}x)"
    return row("table11_runtime", us, derived)


if __name__ == "__main__":
    print(run())
