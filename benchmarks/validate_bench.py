"""Validate the committed perf trajectory (``BENCH_pathfinder.json``).

Schema (one entry per benchmark measurement at a commit)::

    {"schema": 1,
     "entries": [{"benchmark": "<name>", "commit": "<sha>",
                  "metrics": {...non-empty...}}, ...]}

Checks enforced so a malformed bench point fails the PR instead of
landing silently:

  * top level is an object with ``schema == 1`` and an ``entries`` list;
  * every entry has non-empty string ``benchmark`` / ``commit`` keys and
    a non-empty dict ``metrics``, with no unknown keys;
  * the trajectory is monotone: no duplicate (benchmark, commit) pairs —
    re-measuring a commit must *replace* its entries, never double-count
    them (``benchmarks.run --trajectory`` does this).

No third-party imports: runnable before any dependency install.

Usage: ``python -m benchmarks.validate_bench [BENCH_pathfinder.json]``
"""
from __future__ import annotations

import json
import sys
from typing import List

ALLOWED_KEYS = {"benchmark", "commit", "metrics"}


def validate(doc) -> List[str]:
    """Return a list of human-readable problems (empty = valid)."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"top level must be an object, got {type(doc).__name__}"]
    if doc.get("schema") != 1:
        errors.append(f"schema must be 1, got {doc.get('schema')!r}")
    entries = doc.get("entries")
    if not isinstance(entries, list):
        errors.append("missing/invalid 'entries' list")
        return errors
    seen = set()
    for i, e in enumerate(entries):
        where = f"entries[{i}]"
        if not isinstance(e, dict):
            errors.append(f"{where}: not an object")
            continue
        unknown = set(e) - ALLOWED_KEYS
        if unknown:
            errors.append(f"{where}: unknown keys {sorted(unknown)}")
        for key in ("benchmark", "commit"):
            v = e.get(key)
            if not isinstance(v, str) or not v.strip():
                errors.append(f"{where}: {key!r} must be a non-empty "
                              f"string, got {v!r}")
        m = e.get("metrics")
        if not isinstance(m, dict) or not m:
            errors.append(f"{where}: 'metrics' must be a non-empty "
                          f"object, got {type(m).__name__}")
        pair = (e.get("benchmark"), e.get("commit"))
        if all(isinstance(x, str) for x in pair):
            if pair in seen:
                errors.append(
                    f"{where}: duplicate (benchmark, commit) pair "
                    f"{pair} — trajectory must be monotone (one "
                    "measurement per benchmark per commit)")
            seen.add(pair)
    return errors


def main(argv: List[str]) -> int:
    path = argv[0] if argv else "BENCH_pathfinder.json"
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable ({e})", file=sys.stderr)
        return 1
    errors = validate(doc)
    for err in errors:
        print(f"{path}: {err}", file=sys.stderr)
    if errors:
        return 1
    n = len(doc["entries"])
    benches = {e["benchmark"] for e in doc["entries"]}
    commits = {e["commit"] for e in doc["entries"]}
    print(f"{path}: OK ({n} entries, {len(benches)} benchmarks, "
          f"{len(commits)} commits)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
