"""Fig. 13: embodied CFP vs dollar cost — decorrelation.

Claims: cost is NOT a proxy for carbon (no tight linear relationship);
EMIB-based designs carry high embodied CFP (dense silicon-bridge wiring).
"""
from __future__ import annotations

import math

from repro.core import evaluate, workload
from repro.core.chiplet import different_chiplet_system, identical_chiplet_system
from benchmarks.common import CACHE, all_43_systems, row, timed


def _pearson(xs, ys):
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    sx = math.sqrt(sum((x - mx) ** 2 for x in xs))
    sy = math.sqrt(sum((y - my) ** 2 for y in ys))
    if sx == 0 or sy == 0:
        return 0.0
    return sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / (sx * sy)


def run(out=print) -> str:
    def compute():
        results = {}
        for tag, chips in (("identical", identical_chiplet_system(4)),
                           ("different", different_chiplet_system())):
            for wl_idx in (1, 2):
                rows = []
                for name, sys in all_43_systems(chips, mapping="0-OS-1"):
                    m = evaluate(sys, workload(wl_idx), cache=CACHE)
                    rows.append((name, m.emb_cfp_kg, m.dollar))
                results[(tag, wl_idx)] = rows
        return results

    results, us = timed(compute)
    rs = []
    emib_high = []
    for (tag, wl_idx), rows in results.items():
        base = next(r for r in rows if r[0] == "2.5D-RDL-UCIe-S")
        out(f"# Fig13({tag}, WL{wl_idx}): CFP vs cost norm. 2.5D-RDL-UCS")
        out("combo,emb_cfp,cost")
        for name, e, c in rows:
            out(f"{name},{e/base[1]:.3f},{c/base[2]:.3f}")
        rs.append(_pearson([c for _, _, c in rows],
                           [e for _, e, _ in rows]))
        emib = [e for n, e, _ in rows if "EMIB" in n]
        non = [e for n, e, _ in rows if "EMIB" not in n]
        emib_high.append(sum(emib) / len(emib) > sum(non) / len(non))
    r_max = max(abs(r) for r in rs)
    derived = (f"max_pearson_r={r_max:.2f};"
               f"emib_high_cfp={all(emib_high)}")
    assert r_max < 0.9, f"cost must not be a carbon proxy (r={r_max:.2f})"
    assert all(emib_high), "EMIB designs must carry high embodied CFP"
    return row("fig13_cfp_vs_cost", us, derived)


if __name__ == "__main__":
    print(run())
