"""Fig. 13: embodied CFP vs dollar cost — decorrelation + the frontier.

Claims: cost is NOT a proxy for carbon (no tight linear relationship);
EMIB-based designs carry high embodied CFP (dense silicon-bridge wiring).

The per-combo metrics come from one batched evaluation per (chiplet set,
workload) and the CFP-vs-cost frontier is read from the Pareto archive
every :class:`~repro.pathfinding.GridSweep` search now returns
(``SearchResult.frontier``) — no per-system scalar rescans.
"""
from __future__ import annotations

import math

from repro.core import workload
from repro.core.chiplet import different_chiplet_system, identical_chiplet_system
from repro.core.templates import IDENTITY_NORMALIZER, TEMPLATES
from repro.core.workload import Mapping
from repro.pathfinding import GridSweep, Pathfinder, non_dominated_mask
from benchmarks.common import CACHE, row, timed

MAPPING = Mapping.parse("0-OS-1")


def _pearson(xs, ys):
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    sx = math.sqrt(sum((x - mx) ** 2 for x in xs))
    sy = math.sqrt(sum((y - my) ** 2 for y in ys))
    if sx == 0 or sy == 0:
        return 0.0
    return sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / (sx * sy)


def _combo_name(s) -> str:
    parts = [s.style]
    if s.pkg_25d:
        parts += [s.pkg_25d, s.proto_25d]
    if s.pkg_3d:
        parts.append(s.pkg_3d)
    return "-".join(parts)


def run(out=print) -> str:
    def compute():
        results = {}
        fronts = {}
        for tag, chips in (("identical", identical_chiplet_system(4)),
                           ("different", different_chiplet_system())):
            sweep = GridSweep(chiplets=tuple(chips), memories=("DDR5",),
                              mappings=(MAPPING,))
            for wl_idx in (1, 2):
                pf = Pathfinder(workload(wl_idx), TEMPLATES["T1"],
                                norm=IDENTITY_NORMALIZER, cache=CACHE,
                                device=False)
                # the search evaluates the grid once; the stats table
                # reuses the same rows through one batched call (stage-2
                # topology descriptors come out of the evaluator's memo,
                # so no per-system rescan happens)
                res = pf.search(strategy=sweep)
                systems = sweep.systems(pf.db)
                mb = pf.evaluate_batch(pf.space.encode_many(systems))
                results[(tag, wl_idx)] = [
                    (_combo_name(s), float(mb.emb_cfp_kg[i]),
                     float(mb.dollar[i]), float(mb.total_cfp[i]))
                    for i, s in enumerate(systems)]
                # the CFP-vs-cost frontier is the archive's (dollar,
                # total_cfp) projection — a first-class search output
                fronts[(tag, wl_idx)] = res.frontier.project((1, 2))
        return results, fronts

    (results, fronts), us = timed(compute)
    rs = []
    emib_high = []
    front_ok = []
    for (tag, wl_idx), rows in results.items():
        base = next(r for r in rows if r[0] == "2.5D-RDL-UCIe-S")
        out(f"# Fig13({tag}, WL{wl_idx}): CFP vs cost norm. 2.5D-RDL-UCS")
        out("combo,emb_cfp,cost")
        for name, e, c, _ in rows:
            out(f"{name},{e/base[1]:.3f},{c/base[2]:.3f}")
        front = fronts[(tag, wl_idx)]
        out(f"# Fig13({tag}, WL{wl_idx}) frontier (dollar, total_cfp)")
        out("cost,total_cfp")
        for c, f in front:
            out(f"{c:.4f},{f:.4f}")
        # every sampled combo must be weakly dominated by the frontier
        front_ok.append(all(
            any(fc <= c + 1e-9 and ff <= f + 1e-9 for fc, ff in front)
            for _, _, c, f in rows))
        # the frontier itself must be non-dominated
        front_ok.append(bool(non_dominated_mask(front).all()))
        rs.append(_pearson([c for _, _, c, _ in rows],
                           [e for _, e, _, _ in rows]))
        emib = [e for n, e, _, _ in rows if "EMIB" in n]
        non = [e for n, e, _, _ in rows if "EMIB" not in n]
        emib_high.append(sum(emib) / len(emib) > sum(non) / len(non))
    r_max = max(abs(r) for r in rs)
    n_front = sum(len(f) for f in fronts.values())
    derived = (f"max_pearson_r={r_max:.2f};"
               f"emib_high_cfp={all(emib_high)};"
               f"frontier_pts={n_front};frontier_dominates={all(front_ok)}")
    assert r_max < 0.9, f"cost must not be a carbon proxy (r={r_max:.2f})"
    assert all(emib_high), "EMIB designs must carry high embodied CFP"
    assert all(front_ok), "archive frontier must dominate every combo"
    return row("fig13_cfp_vs_cost", us, derived)


if __name__ == "__main__":
    print(run())
