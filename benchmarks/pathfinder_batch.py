"""Pathfinder v2: batched vs scalar evaluation throughput + parity.

Claims asserted:
  (a) ``evaluate_batch`` matches scalar ``evaluate`` within 1e-6 relative
      tolerance on every metric field over a 1000-system random
      population (the v2 parity guarantee);
  (b) batched ``fit_normalizer`` (sample + evaluate + fit as arrays) is
      >= 5x faster than the seed scalar loop at 2000 samples, measured in
      steady state (tables and jax op caches warm — the one-time build is
      reported separately in the derived column).
"""
from __future__ import annotations

import os
import random
import time

from repro.core import evaluate, workload
from repro.core.sa import fit_normalizer, random_system
from repro.core.templates import METRIC_FIELDS
from repro.pathfinding import DesignSpace, evaluate_batch, fit_normalizer_batched
from benchmarks.common import row, timed

PARITY_SYSTEMS = 1000
FIT_SAMPLES = 2000
RTOL = 1e-6
# wall-clock ratio bound: >= 5x is the claim on an unloaded machine
# (typically ~10x); shared CI runners set a lower catastrophic-regression
# floor via the env var since timing ratios are environment-dependent
MIN_SPEEDUP = float(os.environ.get("PATHFINDER_BENCH_MIN_SPEEDUP", "5.0"))


def run(out=print) -> str:
    wl = workload(1)
    space = DesignSpace()

    def compute():
        # -- (a) parity on a 1000-system random population ----------------
        rng = random.Random(2026)
        systems = [random_system(rng) for _ in range(PARITY_SYSTEMS)]
        enc = space.encode_many(systems)
        mb = evaluate_batch(enc, wl, space=space)  # build tables, warm jax
        t0 = time.perf_counter()
        mb = evaluate_batch(enc, wl, space=space)
        t_batch = time.perf_counter() - t0
        t0 = time.perf_counter()
        ms = [evaluate(s, wl) for s in systems]
        t_scalar = time.perf_counter() - t0
        worst = 0.0
        for i, m in enumerate(ms):
            for f in METRIC_FIELDS:
                ref = getattr(m, f)
                got = float(mb.fields()[f][i])
                worst = max(worst, abs(got - ref) / max(abs(ref), 1e-300))

        # -- (b) normalizer-fit throughput at 2000 samples ----------------
        # best-of-N on both sides: a fair steady-state ratio that is
        # robust to transient load on shared runners
        fit_scalar = min(
            timed(lambda: fit_normalizer(wl, samples=FIT_SAMPLES))[1] / 1e6
            for _ in range(2))
        t0 = time.perf_counter()
        fit_normalizer_batched(wl, samples=FIT_SAMPLES, space=space)
        fit_cold = time.perf_counter() - t0          # includes jax warmup
        fit_batched = min(
            timed(lambda: fit_normalizer_batched(
                wl, samples=FIT_SAMPLES, space=space))[1] / 1e6
            for _ in range(3))
        return worst, t_batch, t_scalar, fit_scalar, fit_cold, fit_batched

    (worst, t_batch, t_scalar, fit_scalar, fit_cold,
     fit_batched), us = timed(compute)
    speedup = fit_scalar / fit_batched
    out("# Pathfinder v2: batched evaluator parity + throughput")
    out("metric,value")
    out(f"parity_worst_rel_err,{worst:.3e}")
    out(f"eval1000_scalar_s,{t_scalar:.4f}")
    out(f"eval1000_batched_s,{t_batch:.4f}")
    out(f"fit2000_scalar_s,{fit_scalar:.4f}")
    out(f"fit2000_batched_cold_s,{fit_cold:.4f}")
    out(f"fit2000_batched_s,{fit_batched:.4f}")
    out(f"fit_speedup,{speedup:.2f}")
    derived = (f"parity={worst:.1e};fit_speedup={speedup:.2f}x;"
               f"cold_s={fit_cold:.2f}")
    assert worst < RTOL, (
        f"batch-vs-scalar parity violated: {worst:.3e} > {RTOL}")
    assert speedup >= MIN_SPEEDUP, (
        f"batched fit_normalizer speedup {speedup:.2f}x < {MIN_SPEEDUP}x")
    return row("pathfinder_batch", us, derived)


if __name__ == "__main__":
    print(run())
