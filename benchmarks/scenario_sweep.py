"""One-compile scenario sweeps: the stacked grid engine vs per-cell
rebuilds.

Claims asserted:
  (a) the full 5-region x 2-workload :class:`ScenarioSweep` compiles the
      fused scenario program exactly **once** (counted via the jit trace
      hook ``repro.pathfinding.device.trace_count``), with zero per-cell
      fused-program compiles;
  (b) at *equal evaluation budget* it beats the PR-3 per-cell path — a
      fresh ``Pathfinder``/``DeviceEvaluator`` (fresh normalizer fit,
      full program retrace) per (workload, region) cell — by >= 5x
      wall-clock on an unloaded machine (shared CI runners set a lower
      catastrophic-regression floor via ``SCENARIO_SWEEP_MIN_SPEEDUP``);
  (c) per-cell frontier hypervolume under the fixed per-cell keys is no
      worse than the per-cell path's on average (shared per-cell
      reference points; floor via ``SCENARIO_SWEEP_MIN_HV_RATIO``);
  (d) the *lifecycle* grid — every region upgraded to a full
      :class:`repro.core.regions.Region` with a distinct 24h diurnal
      grid-intensity profile, electricity price and embodied factor —
      runs on the same warm engine with exactly **zero** additional
      fused compiles: the three new axes are runtime columns of the one
      stacked program, not trace-time constants.

The derived summary carries cells/sec for both arms, the compile count,
the speedup, the mean hypervolume ratio, and the lifecycle-grid compile
count and timing.

Standalone: ``python -m benchmarks.scenario_sweep [--json out.json]``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import numpy as np

from repro.core import TEMPLATES, workload
from repro.core.techdb import DEFAULT_DB
from repro.pathfinding import (
    ParetoArchive,
    Pathfinder,
    ScalarizationSweep,
    ScenarioSweep,
    fold_cell_key,
    hypervolume,
)
from repro.core.regions import Region, measured_profile
from repro.pathfinding.device import trace_count
from repro.pathfinding.pareto import REGION_INTENSITIES
from benchmarks.common import row, timed

DIRECTIONS = 4
N_CHAINS = 2
# 8 chains x 100 sweeps = 808 evaluations per cell: enough budget that
# per-cell hypervolume is stable across keys (at ~200 evals/cell the
# key-to-key ratio swings 0.6x-1.8x and the (c) gate would be noise)
SWEEPS = 100
NORM_SAMPLES = 400
BASE_KEY = 1
MIN_SPEEDUP = float(os.environ.get("SCENARIO_SWEEP_MIN_SPEEDUP", "5.0"))
MIN_HV_RATIO = float(os.environ.get("SCENARIO_SWEEP_MIN_HV_RATIO", "0.95"))


def _per_cell_baseline(wls, strat, cell_budget):
    """The PR-3 path, reconstructed faithfully: every (workload, region)
    cell builds a fresh region TechDB -> fresh Pathfinder -> fresh
    normalizer fit -> fresh DeviceEvaluator (full fused-program retrace,
    since only the db *instance* changed). Keys are the same per-cell
    folds the stacked path uses, so the two arms are stream-comparable."""
    results = {}
    idx = 0
    for wl in wls:
        for region, ci in REGION_INTENSITIES.items():
            db_s = dataclasses.replace(DEFAULT_DB, carbon_intensity=ci)
            pf = Pathfinder(wl, TEMPLATES["T1"], db=db_s)
            pf.fit_normalizer(samples=NORM_SAMPLES, seed=1234)
            res = pf.search(strategy=strat, budget=cell_budget,
                            key=fold_cell_key(BASE_KEY, idx))
            results[(wl.name, region)] = res
            idx += 1
    return results


def _lifecycle_regions() -> dict:
    """The scalar-CI regions upgraded to full lifecycle cells: each
    gets its *measured* ElectricityMaps-style 24h grid trace
    (``repro.core.regions.measured_profile``, replacing the synthetic
    sinusoid), a distinct electricity price and a distinct embodied
    factor — five regions, no two sharing any axis value."""
    return {
        name: Region(
            carbon_intensity=ci,
            electricity_price=0.04 + 0.03 * i,
            emb_factor=0.90 + 0.06 * i,
            grid_profile=measured_profile(name))
        for i, (name, ci) in enumerate(REGION_INTENSITIES.items())
    }


def run(out=print) -> str:
    wls = [workload(1), workload(6)]
    strat = ScalarizationSweep(directions=DIRECTIONS, n_chains=N_CHAINS,
                               sweeps=SWEEPS)
    n_cells = len(wls) * len(REGION_INTENSITIES)
    nc = strat.weight_rows().shape[0] * strat.n_chains
    cell_budget = nc * (1 + SWEEPS)
    budget = n_cells * cell_budget
    sweep = ScenarioSweep(strategy=strat, norm_samples=NORM_SAMPLES)

    def compute():
        # -- (a) one compile for the whole grid ---------------------------
        before = {k: trace_count(k)
                  for k in ("scenario_pt", "pt", "eval_cost")}
        t0 = time.perf_counter()
        sf_cold = sweep.run(wls, budget=budget, key=BASE_KEY)
        t_cold = time.perf_counter() - t0  # includes the one compile
        compiles = trace_count("scenario_pt") - before["scenario_pt"]
        per_cell_compiles = (trace_count("pt") - before["pt"]
                             + trace_count("eval_cost")
                             - before["eval_cost"])
        t_warm = timed(
            lambda: sweep.run(wls, budget=budget, key=BASE_KEY))[1] / 1e6

        # -- (b) the per-cell rebuild path at equal budget ----------------
        t0 = time.perf_counter()
        base_results = _per_cell_baseline(wls, strat, cell_budget)
        t_base = time.perf_counter() - t0

        evals_new = sum(sf_cold.results[s.key].evaluations
                        for s in sf_cold.scenarios)
        evals_base = sum(r.evaluations for r in base_results.values())

        # -- (c) per-cell hypervolume, shared reference per cell ----------
        ratios = []
        for s in sf_cold.scenarios:
            a = sf_cold.results[s.key].frontier
            b = base_results[s.key].frontier
            union = ParetoArchive(max_size=2 * strat.frontier_size)
            union.merge(a)
            union.merge(b)
            ref = union.reference_point(margin=0.1)
            hv_a, hv_b = a.hypervolume(ref), b.hypervolume(ref)
            if hv_b > 0:
                ratios.append(hv_a / hv_b)

        # -- (d) lifecycle axes as data: zero extra compiles --------------
        # same workloads + db -> same warm ScenarioEngine; the profile /
        # price / embodied columns only change the runtime inputs of the
        # already-compiled program
        sweep_lc = ScenarioSweep(strategy=strat,
                                 regions=_lifecycle_regions(),
                                 norm_samples=NORM_SAMPLES)
        before_lc = trace_count("scenario_pt")
        t0 = time.perf_counter()
        sf_lc = sweep_lc.run(wls, budget=budget, key=BASE_KEY)
        t_lc = time.perf_counter() - t0
        lc_compiles = trace_count("scenario_pt") - before_lc
        evals_lc = sum(sf_lc.results[s.key].evaluations
                       for s in sf_lc.scenarios)
        return (sf_cold, compiles, per_cell_compiles, t_cold, t_warm,
                t_base, evals_new, evals_base, float(np.mean(ratios)),
                lc_compiles, t_lc, evals_lc)

    (sf, compiles, per_cell_compiles, t_cold, t_warm, t_base, evals_new,
     evals_base, hv_ratio, lc_compiles, t_lc, evals_lc), us = \
        timed(compute)
    speedup = t_base / t_cold
    out("# Scenario sweep: stacked one-compile grid vs per-cell rebuilds "
        f"({len(wls)} workloads x {len(REGION_INTENSITIES)} regions)")
    out("metric,value")
    out(f"cells,{len(sf.scenarios)}")
    out(f"budget_total,{evals_new}")
    out(f"fused_compiles,{compiles}")
    out(f"per_cell_compiles,{per_cell_compiles}")
    out(f"stacked_cold_s,{t_cold:.3f}")
    out(f"stacked_warm_s,{t_warm:.3f}")
    out(f"per_cell_s,{t_base:.3f}")
    out(f"cells_per_s_cold,{len(sf.scenarios) / t_cold:.3f}")
    out(f"cells_per_s_warm,{len(sf.scenarios) / t_warm:.3f}")
    out(f"speedup_cold,{speedup:.2f}")
    out(f"speedup_warm,{t_base / t_warm:.2f}")
    out(f"hv_ratio_mean,{hv_ratio:.4f}")
    out(f"lifecycle_compiles,{lc_compiles}")
    out(f"lifecycle_s,{t_lc:.3f}")
    out(f"lifecycle_evals,{evals_lc}")
    derived = (f"compiles={compiles};speedup={speedup:.2f}x;"
               f"warm_speedup={t_base / t_warm:.2f}x;"
               f"cells_per_s={len(sf.scenarios) / t_warm:.2f};"
               f"hv_ratio={hv_ratio:.3f};evals={evals_new};"
               f"lifecycle_compiles={lc_compiles};"
               f"lifecycle_s={t_lc:.2f}")
    assert compiles == 1, (
        f"stacked sweep compiled the fused scenario program {compiles}x "
        "(expected exactly 1)")
    assert per_cell_compiles == 0, (
        f"stacked sweep triggered {per_cell_compiles} per-cell "
        "fused-program compiles (expected 0)")
    assert evals_new == evals_base == budget, (
        f"budget accounting broke: stacked {evals_new}, per-cell "
        f"{evals_base}, budget {budget}")
    assert speedup >= MIN_SPEEDUP, (
        f"stacked sweep speedup {speedup:.2f}x < {MIN_SPEEDUP}x at "
        f"budget {budget}")
    assert hv_ratio >= MIN_HV_RATIO, (
        f"mean per-cell hypervolume ratio {hv_ratio:.3f} < "
        f"{MIN_HV_RATIO} vs the per-cell path")
    assert lc_compiles == 0, (
        f"the lifecycle (profile/price/embodied) grid retraced the "
        f"fused scenario program {lc_compiles}x on the warm engine "
        "(expected 0 — the axes are runtime columns)")
    assert evals_lc == budget, (
        f"lifecycle-grid budget accounting broke: {evals_lc} != {budget}")
    return row("scenario_sweep", us, derived)


def main() -> None:
    args = sys.argv[1:]
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        try:
            json_path = args[i + 1]
        except IndexError:
            sys.exit("--json requires a path argument")
    lines = []
    summary = run(out=lines.append)
    print("\n".join(lines))
    print(summary)
    if json_path:
        name, us, derived = summary.split(",", 2)
        with open(json_path, "w") as f:
            json.dump({"rows": [{"name": name, "us_per_call": float(us),
                                 "derived": derived}]}, f, indent=2)
        print(f"# wrote {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
