"""Fig. 6: normalized energy per package-protocol combination.

Claims: 3D-HB-UC3 has the lowest energy (fast low-pitch bonding); the
ChipletGym MAC-only model under-reports energy vs CarbonPATH's
DRAM+SRAM+compute+D2D model.
"""
from __future__ import annotations

from repro.core import evaluate, evaluate_chipletgym, workload
from repro.core.chiplet import different_chiplet_system, identical_chiplet_system
from benchmarks.common import CACHE, all_43_systems, row, timed


def run(out=print) -> str:
    wl = workload(1)

    def compute():
        results = {}
        for tag, chips in (("identical", identical_chiplet_system(4)),
                           ("different", different_chiplet_system())):
            rows = []
            for name, sys in all_43_systems(chips):
                m = evaluate(sys, wl, cache=CACHE)
                g = evaluate_chipletgym(sys, wl, cache=CACHE)
                rows.append((name, m.energy_j, g.energy_j))
            results[tag] = rows
        return results

    results, us = timed(compute)
    checks = []
    for tag, rows in results.items():
        base = next(e for n, e, _ in rows if n == "3D-TSV-UCIe-3D")
        out(f"# Fig6({tag}): energy normalized to 3D-TSV-UC3")
        out("combo,carbonpath,chipletgym")
        for name, e, g in rows:
            out(f"{name},{e/base:.3f},{g/base:.3f}")
        pure = [(n, e) for n, e, _ in rows if not n.startswith("2.5D+3D")]
        lowest = min(pure, key=lambda r: r[1])
        checks.append(lowest[0] == "3D-HybBond-UCIe-3D")
        checks.append(all(g < e for _, e, g in rows))
    derived = f"hb_lowest={checks[0] and checks[2]};gym_lower={checks[1] and checks[3]}"
    assert checks[1] and checks[3], "ChipletGym must under-report energy"
    assert checks[0] and checks[2], "3D-HB-UC3 must be lowest-energy"
    return row("fig06_energy_pkg", us, derived)


if __name__ == "__main__":
    print(run())
