"""Fig. 11: Perf-SI vs dollar cost over all 43 package-protocol pairs.

Claims: varying Perf-SI at the same cost (cost is not a proxy for carbon
efficiency); 2.5D advanced packages (Active/Passive + UCIe-A/BoW) land in
the good (high Perf-SI, low cost) region.
"""
from __future__ import annotations

from repro.core import evaluate, workload
from repro.core.chiplet import different_chiplet_system
from benchmarks.common import CACHE, all_43_systems, row, timed


def run(out=print) -> str:
    wl = workload(1)

    def compute():
        rows = []
        for name, sys in all_43_systems(different_chiplet_system(),
                                        mapping="0-OS-1"):
            m = evaluate(sys, wl, cache=CACHE)
            rows.append((name, m.perf_si, m.dollar))
        return rows

    rows, us = timed(compute)
    base = next(r for r in rows if r[0] == "2.5D-RDL-UCIe-S")
    out("# Fig11: Perf-SI vs cost normalized to 2.5D-RDL-UCS")
    out("combo,perf_si,cost")
    for name, p, c in rows:
        out(f"{name},{p/base[1]:.3f},{c/base[2]:.3f}")

    # spread of Perf-SI within a narrow cost band -> not cost-determined
    costs = sorted(c for _, _, c in rows)
    lo, hi = costs[len(costs)//4], costs[3*len(costs)//4]
    band = [p for _, p, c in rows if lo <= c <= hi]
    band_spread = max(band) / min(band) if band else 1.0
    adv = [p for n, p, _ in rows
           if n.startswith(("2.5D-Active", "2.5D-Passive"))]
    med = sorted(p for _, p, _ in rows)[len(rows)//2]
    adv_good = sum(p >= med for p in adv) >= len(adv) / 2
    derived = f"same_cost_perf_spread={band_spread:.2f}x;adv_25d_good={adv_good}"
    assert band_spread > 1.3, "Perf-SI must vary at similar cost"
    return row("fig11_perfsi_cost_scatter", us, derived)


if __name__ == "__main__":
    print(run())
