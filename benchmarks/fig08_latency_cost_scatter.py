"""Fig. 8: latency vs dollar-cost scatter over all 43 pairs.

Claims: 3D/hybrid occupy the low-latency (higher-cost) region; 2.5D the
low-cost side; ~10x latency spread between min and max points.
"""
from __future__ import annotations

from repro.core import evaluate, workload
from repro.core.chiplet import different_chiplet_system
from benchmarks.common import CACHE, all_43_systems, row, timed


def run(out=print) -> str:
    wl = workload(1)

    def compute():
        rows = []
        for name, sys in all_43_systems(different_chiplet_system()):
            m = evaluate(sys, wl, cache=CACHE)
            rows.append((name, m.latency_s, m.dollar))
        return rows

    rows, us = timed(compute)
    base_l = next(l for n, l, _ in rows if n == "2.5D-RDL-UCIe-S")
    base_c = next(c for n, _, c in rows if n == "2.5D-RDL-UCIe-S")
    out("# Fig8: latency vs cost, normalized to 2.5D-RDL-UCS")
    out("combo,latency,cost")
    for name, l, c in rows:
        out(f"{name},{l/base_l:.3f},{c/base_c:.3f}")

    lats = [l for _, l, _ in rows]
    spread = max(lats) / min(lats)
    lat_25d = [l for n, l, _ in rows if n.startswith("2.5D-")]
    lat_3d = [l for n, l, _ in rows if n.startswith("3D-")]
    cost_25d = [c for n, _, c in rows if n.startswith("2.5D-")]
    cost_3d = [c for n, _, c in rows if n.startswith("3D-")]
    ok_3d_fast = (sum(lat_3d) / len(lat_3d)) < (sum(lat_25d) / len(lat_25d))
    ok_3d_costly = (sum(cost_3d) / len(cost_3d)) > (sum(cost_25d)
                                                    / len(cost_25d))
    derived = (f"latency_spread={spread:.1f}x;3d_faster_avg={ok_3d_fast};"
               f"3d_pricier_avg={ok_3d_costly}")
    # The paper reports ~10x; the spread is calibration-dependent (it
    # grows with the D2D share of total latency). We assert the direction
    # and record the magnitude (see EXPERIMENTS.md for the discussion).
    assert spread > 1.5, f"packaging must matter: got {spread:.1f}x"
    assert ok_3d_fast and ok_3d_costly
    return row("fig08_latency_cost_scatter", us, derived)


if __name__ == "__main__":
    print(run())
