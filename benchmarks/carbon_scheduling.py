"""Carbon-aware temporal scheduling at equal budget: fixed vs window.

Claims asserted:
  (a) the window-schedule scenario grid — per-design start-hour and
      duty-window-shape live as two extra encoded axes — compiles its
      fused program exactly **once** for the whole 5-region
      measured-profile grid, same as the fixed arm: schedules are
      runtime data (a [n_shapes, 24] duty table gathered and rolled per
      slot), never trace-time constants;
  (b) re-running either arm on its warm engine adds exactly **zero**
      fused compiles;
  (c) at *equal evaluation budget* the schedule-axis search reduces the
      best achievable operational CFP on at least one region with a
      non-flat measured grid trace: picking *when* to run concentrates
      the same lifetime energy into low-carbon hours, an axis the fixed
      arm cannot express. The fixed schedule is the exact neutral
      element, so the window space strictly contains the fixed space and
      the min-operational-CFP frontier point can only improve.

Both arms run through the unified
:class:`repro.pathfinding.scenario.ScenarioSpec` API over regions whose
24h grid-intensity profiles are the checked-in measured traces
(:func:`repro.core.regions.measured_profile`).

The derived summary carries both arms' warm wall times, the compile
counts, the per-region operational-CFP reductions and the shared budget.

Standalone: ``python -m benchmarks.carbon_scheduling``.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from benchmarks.common import row, timed
from repro.core import workload
from repro.core.regions import Region, measured_profile
from repro.core.techdb import DEFAULT_DB
from repro.pathfinding import (
    DesignSpace,
    ScalarizationSweep,
    ScenarioSpec,
    ScenarioSweep,
    evaluate_batch,
)
from repro.pathfinding.device import trace_count
from repro.pathfinding.pareto import REGION_INTENSITIES

DIRECTIONS = 4
N_CHAINS = 2
SWEEPS = 80
NORM_SAMPLES = 400
BASE_KEY = 1
MIN_REDUCTION = float(os.environ.get("CARBON_SCHED_MIN_REDUCTION", "0.0"))


def _regions() -> dict:
    """The five scalar-CI regions, each carrying its measured
    ElectricityMaps-style 24h grid trace."""
    return {name: Region(carbon_intensity=ci,
                         grid_profile=measured_profile(name))
            for name, ci in REGION_INTENSITIES.items()}


def _arm(schedule, wls, strat, budget):
    """One schedule-model arm, driven through the unified ScenarioSpec:
    cold run (traces its own fused program), warm rerun (must replay),
    frontiers + compile deltas."""
    spec = ScenarioSpec(workloads=tuple(wls), regions=_regions(),
                        schedule=schedule, budget=budget)
    sweep = ScenarioSweep(strategy=strat, norm_samples=NORM_SAMPLES)
    before = trace_count("scenario_pt")
    t0 = time.perf_counter()
    sf = sweep.run(spec, key=BASE_KEY)
    t_cold = time.perf_counter() - t0
    cold_compiles = trace_count("scenario_pt") - before
    before = trace_count("scenario_pt")
    t_warm = timed(lambda: sweep.run(spec, key=BASE_KEY))[1] / 1e6
    warm_compiles = trace_count("scenario_pt") - before
    evals = sum(sf.results[s.key].evaluations for s in sf.scenarios)
    return sf, t_cold, t_warm, cold_compiles, warm_compiles, evals


def _min_ope(sf, schedule) -> dict:
    """Best operational CFP across each cell's frontier, re-evaluated
    through the host batch path under the region's own TechDB (grid
    profile included) — the window arm's rows carry their searched
    (start, shape) schedules in the encoding."""
    out = {}
    for s in sf.scenarios:
        db_s = dataclasses.replace(DEFAULT_DB, **s.spec.db_overrides())
        space = DesignSpace(db_s, schedule=schedule)
        arch = sf.results[s.key].frontier
        mb = evaluate_batch(arch.encoded, s.workload, db_s, space=space)
        out[s.region] = float(np.min(mb.ope_cfp_kg))
    return out


def run(out=print) -> str:
    wls = [workload(1)]
    strat = ScalarizationSweep(directions=DIRECTIONS, n_chains=N_CHAINS,
                               sweeps=SWEEPS)
    nc = strat.weight_rows().shape[0] * strat.n_chains
    n_cells = len(wls) * len(REGION_INTENSITIES)
    budget = n_cells * nc * (1 + SWEEPS)

    def compute():
        fixed = _arm("fixed", wls, strat, budget)
        window = _arm("window", wls, strat, budget)
        ope_f = _min_ope(fixed[0], "fixed")
        ope_w = _min_ope(window[0], "window")
        return fixed, window, ope_f, ope_w

    (fixed, window, ope_f, ope_w), us = timed(compute)
    _, tf_cold, tf_warm, cf_cold, cf_warm, ev_f = fixed
    _, tw_cold, tw_warm, cw_cold, cw_warm, ev_w = window
    regions = _regions()
    nonflat = {name for name, reg in regions.items()
               if np.ptp(reg.profile_array()) > 0.0}
    reductions = {name: 1.0 - ope_w[name] / ope_f[name]
                  for name in ope_f if ope_f[name] > 0}
    best_region = max(reductions, key=reductions.get)
    out("# Carbon-aware scheduling at equal budget: fixed vs window "
        f"({n_cells}-cell measured-profile grid, budget {budget})")
    out("metric,fixed,window")
    out(f"cold_s,{tf_cold:.3f},{tw_cold:.3f}")
    out(f"warm_s,{tf_warm:.3f},{tw_warm:.3f}")
    out(f"cold_compiles,{cf_cold},{cw_cold}")
    out(f"warm_compiles,{cf_warm},{cw_warm}")
    out(f"evals,{ev_f},{ev_w}")
    out("region,min_ope_fixed_kg,min_ope_window_kg,reduction")
    for name in ope_f:
        out(f"{name},{ope_f[name]:.4f},{ope_w[name]:.4f},"
            f"{reductions.get(name, 0.0):.4f}")
    derived = (f"fixed_warm_s={tf_warm:.2f};window_warm_s={tw_warm:.2f};"
               f"window_compiles={cw_cold};warm_compiles={cw_warm};"
               f"best_ope_cut={reductions[best_region]:.3f}"
               f"@{best_region};evals={ev_w}")
    assert cf_cold == 1 and cw_cold == 1, (
        f"each arm must trace its fused program exactly once, got "
        f"fixed {cf_cold} / window {cw_cold}")
    assert cf_warm == 0 and cw_warm == 0, (
        f"warm reruns retraced: fixed {cf_warm} / window {cw_warm} "
        "(expected 0 — schedules are runtime data)")
    assert ev_f == ev_w == budget, (
        f"equal-budget accounting broke: fixed {ev_f}, window {ev_w}, "
        f"budget {budget}")
    nonflat_cuts = {n: r for n, r in reductions.items() if n in nonflat}
    assert any(r > MIN_REDUCTION for r in nonflat_cuts.values()), (
        "schedule-axis search found no operational-CFP reduction on any "
        f"non-flat measured region at equal budget: {nonflat_cuts}")
    return row("carbon_scheduling", us, derived)


def main() -> None:
    run()


if __name__ == "__main__":
    main()
