"""Device-resident pathfinding: fused lax.scan ParallelTempering vs the
PR-1 host sweep loop, plus jitted-path parity vs the scalar evaluator.

Claims asserted:
  (a) the jitted fused evaluator matches scalar ``evaluate`` within 1e-6
      relative tolerance on every Eq. 17 metric field over a 512-system
      random population (in practice ~1e-15);
  (b) the device ParallelTempering engine (propose + evaluate + accept +
      replica exchange fused into one ``jax.lax.scan``) sustains >= 10x
      the sweep throughput of the host path at 64 chains x 500 sweeps,
      measured steady-state (the one-time scan compile is reported
      separately in the derived column).
"""
from __future__ import annotations

import os
import random
import time


from repro.core import TEMPLATES, workload
from repro.core.evaluate import evaluate
from repro.core.sa import random_system
from repro.core.scalesim import SimCache
from repro.core.templates import METRIC_FIELDS
from repro.pathfinding import (
    DesignSpace,
    ParallelTempering,
    Pathfinder,
    fit_normalizer_batched,
    get_device_evaluator,
)
from benchmarks.common import row, timed

N_CHAINS = 64
SWEEPS = 500
PARITY_SYSTEMS = 512
RTOL = 1e-6
# wall-clock ratio bound: >= 10x is the claim on an unloaded machine;
# shared CI runners set a lower catastrophic-regression floor via the env
# var since timing ratios are environment-dependent
MIN_SPEEDUP = float(os.environ.get("PATHFINDER_DEVICE_MIN_SPEEDUP", "10.0"))


def run(out=print) -> str:
    wl = workload(1)
    space = DesignSpace()
    norm = fit_normalizer_batched(wl, samples=2000, seed=1234, space=space)

    def compute():
        # -- (a) jitted-path parity vs scalar evaluate --------------------
        dev = get_device_evaluator(wl, space=space)
        rng = random.Random(2026)
        systems = [random_system(rng) for _ in range(PARITY_SYSTEMS)]
        mb = dev.metrics(space.encode_many(systems))
        cache = SimCache()
        worst = 0.0
        for i, sys in enumerate(systems):
            m = evaluate(sys, wl, cache=cache)
            for f in METRIC_FIELDS:
                ref = getattr(m, f)
                got = float(mb.fields()[f][i])
                worst = max(worst,
                            abs(got - ref) / max(abs(ref), 1e-300))

        # -- (b) 64-chain x 500-sweep ParallelTempering throughput --------
        # frontier collection off on both arms: the claim is the fused
        # engine's sweep throughput (as in the PR-2 baseline numbers);
        # the Pareto-archive cost rides on top identically for both and
        # is measured by benchmarks/pareto_frontier.py
        strat = ParallelTempering(n_chains=N_CHAINS, sweeps=SWEEPS,
                                  frontier_size=0)
        pf_dev = Pathfinder(wl, TEMPLATES["T1"], norm=norm, space=space)
        pf_host = Pathfinder(wl, TEMPLATES["T1"], norm=norm, space=space,
                             device=False)
        t0 = time.perf_counter()
        res_cold = pf_dev.search(strategy=strat, key=1)
        t_compile = time.perf_counter() - t0  # includes the scan compile
        t_dev = min(timed(lambda: pf_dev.search(strategy=strat, key=1)
                          )[1] / 1e6 for _ in range(2))
        t0 = time.perf_counter()
        res_host = pf_host.search(strategy=strat, key=1)
        t_host = time.perf_counter() - t0
        return worst, t_compile, t_dev, t_host, res_cold, res_host

    (worst, t_compile, t_dev, t_host, res_dev,
     res_host), us = timed(compute)
    speedup = t_host / t_dev
    evals = res_dev.evaluations
    out("# Device pathfinding: fused PT scan vs host sweep loop")
    out("metric,value")
    out(f"parity_worst_rel_err,{worst:.3e}")
    out(f"pt_chains,{N_CHAINS}")
    out(f"pt_sweeps,{SWEEPS}")
    out(f"device_cold_s,{t_compile:.3f}")
    out(f"device_s,{t_dev:.4f}")
    out(f"host_s,{t_host:.4f}")
    out(f"device_sweeps_per_s,{SWEEPS / t_dev:.1f}")
    out(f"host_sweeps_per_s,{SWEEPS / t_host:.1f}")
    out(f"device_evals_per_s,{evals / t_dev:.0f}")
    out(f"speedup,{speedup:.2f}")
    out(f"device_best_cost,{res_dev.best_cost:.6f}")
    out(f"host_best_cost,{res_host.best_cost:.6f}")
    derived = (f"parity={worst:.1e};pt_speedup={speedup:.2f}x;"
               f"dev_s={t_dev:.2f};host_s={t_host:.2f};"
               f"cold_s={t_compile:.1f}")
    assert worst < RTOL, (
        f"jitted-path parity violated: {worst:.3e} > {RTOL}")
    assert speedup >= MIN_SPEEDUP, (
        f"device PT speedup {speedup:.2f}x < {MIN_SPEEDUP}x "
        f"({N_CHAINS} chains x {SWEEPS} sweeps)")
    return row("pathfinder_device", us, derived)


if __name__ == "__main__":
    print(run())
