"""Fig. 12: Perf-SI across workload mappings per HI type.

Claims: split-K is asymmetric — it *hurts* 2.5D (partial-sum traffic over
limited interposer bandwidth) and helps / does not hurt 3D; with split-K
off, OS is the best dataflow; 3D achieves the best overall Perf-SI.
"""
from __future__ import annotations

from repro.core import evaluate, workload
from repro.core.chiplet import different_chiplet_system, identical_chiplet_system
from repro.core.workload import ALL_MAPPINGS
from benchmarks.common import CACHE, row, sys_25d, sys_3d, sys_hybrid, timed


def run(out=print) -> str:
    wl = workload(1)

    def compute():
        results = {}
        for tag, chips in (("identical", identical_chiplet_system(4)),
                           ("different", different_chiplet_system())):
            per_type = {}
            for m in ALL_MAPPINGS:
                per_type.setdefault("2.5D-EMIB", {})[m.name] = evaluate(
                    sys_25d(chips, "EMIB", "UCIe-A", mapping=m.name), wl,
                    cache=CACHE).perf_si
                per_type.setdefault("3D-HB", {})[m.name] = evaluate(
                    sys_3d(chips, "HybBond", mapping=m.name), wl,
                    cache=CACHE).perf_si
                per_type.setdefault("2.5D+3D", {})[m.name] = evaluate(
                    sys_hybrid(chips, "EMIB", "UCIe-A", "HybBond",
                               mapping=m.name), wl, cache=CACHE).perf_si
            results[tag] = per_type
        return results

    results, us = timed(compute)
    checks = {"splitk_hurts_25d": 0, "splitk_total": 0,
              "os_best_nok": 0, "os_total": 0, "3d_best": 0}
    for tag, per_type in results.items():
        base = results[tag]["2.5D-EMIB"]["0-IS-0"]
        out(f"# Fig12({tag}): Perf-SI normalized to 2.5D-EMIB 0-IS-0")
        out("hi_type,mapping,perf_si")
        for t, vals in per_type.items():
            for m, v in vals.items():
                out(f"{t},{m},{v/base:.3f}")
        # split-K asymmetry on 2.5D
        for o in (0, 1):
            for d in ("OS", "WS", "IS"):
                off = per_type["2.5D-EMIB"][f"{o}-{d}-0"]
                on = per_type["2.5D-EMIB"][f"{o}-{d}-1"]
                checks["splitk_total"] += 1
                checks["splitk_hurts_25d"] += int(on <= off)
        # OS best among split-K-off per HI type
        for t, vals in per_type.items():
            nok = {m: v for m, v in vals.items() if m.endswith("-0")}
            best = max(nok, key=nok.get)
            checks["os_total"] += 1
            checks["os_best_nok"] += int("OS" in best)
        # 3D best overall
        best_overall = max(per_type, key=lambda t: max(per_type[t].values()))
        checks["3d_best"] += int(best_overall == "3D-HB")

    frac_hurt = checks["splitk_hurts_25d"] / checks["splitk_total"]
    frac_os = checks["os_best_nok"] / checks["os_total"]
    derived = (f"splitk_hurts_25d={frac_hurt:.2f};os_best_frac={frac_os:.2f};"
               f"3d_best_in={checks['3d_best']}/2")
    assert frac_hurt >= 0.8, "split-K must hurt 2.5D (bandwidth-starved)"
    assert frac_os >= 0.8, "OS must win with split-K off"
    assert checks["3d_best"] == 2, "3D packaging must have top Perf-SI"
    return row("fig12_perfsi_mapping", us, derived)


if __name__ == "__main__":
    print(run())
