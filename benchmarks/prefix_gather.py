"""Stacked prefix-gather kernel: fused gather + split-select + segment
reduce (``prefix_select_gather``) vs the plain jnp reference path, on
the real 2-workload stacked engine tables.

Claims asserted:
  (a) the kernel (interpret mode on CPU, compiled on TPU) matches the
      jnp reference bit-for-bit on every chain count — the tables are
      int64 prefix sums and both paths subtract them exactly;
  (b) on TPU backends, the compiled kernel sustains >= the jnp gather
      throughput at 4096 chains (``PREFIX_GATHER_MIN_SPEEDUP`` floor,
      default 1.0). Off-TPU the gate is skipped: interpret mode is a
      correctness vehicle, not a fast path, and its timing is reported
      for the record only.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import workload
from repro.kernels.prefix_gather import prefix_select_gather, prefix_select_ref
from repro.pathfinding.device import ScenarioEngine
from benchmarks.common import row, timed

CHAINS = (256, 1024, 4096)
GATE_CHAINS = 4096
REPEATS = 5
MIN_SPEEDUP = float(os.environ.get("PREFIX_GATHER_MIN_SPEEDUP", "1.0"))


def _inputs(rng, tb, cfg, P):
    """Random but in-contract gather operands for P chains: rows inside
    the stacked table, segments clipped like the tempering step's."""
    import jax.numpy as jnp

    R = tb["pref0_flatw"].shape[1]
    C = cfg.C
    wi = rng.integers(0, 2, (P,))
    rows = (rng.integers(0, R // 2, (P, C))
            + (wi * (R // 2))[:, None]).astype(np.int32)
    start = rng.integers(0, cfg.T0, (P, C)).astype(np.int32)
    end = np.minimum(start + rng.integers(0, 16, (P, C)),
                     cfg.T0).astype(np.int32)
    split = rng.integers(0, 2, (P,)).astype(np.int32)
    t0 = np.full((P,), cfg.T0, np.int32)
    t1 = np.full((P,), cfg.T1, np.int32)
    return tuple(jnp.asarray(a) for a in
                 (rows, start, end, split, t0, t1))


def run(out=print) -> str:
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    on_tpu = jax.default_backend() == "tpu"

    def compute():
        eng = ScenarioEngine([workload(1), workload(6)], use_pallas=True)
        tb, cfg = eng.tables, eng.cfg
        ref_fn = jax.jit(prefix_select_ref)
        kern = lambda *a: prefix_select_gather(   # noqa: E731
            *a, interpret=not on_tpu)
        rng = np.random.default_rng(2026)
        stats = {}
        with enable_x64():
            # int64 tables, converted under x64 like the engine does —
            # an int32 truncation would overflow the slot-sum totals
            p0 = jnp.asarray(tb["pref0_flatw"])
            p1 = jnp.asarray(tb["pref1_flatw"])
            for P in CHAINS:
                args = _inputs(rng, tb, cfg, P)
                sel_r, tot_r = ref_fn(p0, p1, *args)
                sel_k, tot_k = kern(p0, p1, *args)
                assert (np.asarray(sel_r) == np.asarray(sel_k)).all()
                assert (np.asarray(tot_r) == np.asarray(tot_k)).all()

                def bench(fn):
                    fn(p0, p1, *args)[0].block_until_ready()  # warm
                    return min(
                        timed(lambda: fn(p0, p1, *args)[0]
                              .block_until_ready())[1]
                        for _ in range(REPEATS))
                stats[P] = (bench(ref_fn), bench(kern))
        return stats

    stats, us = timed(compute)
    out("# Stacked prefix-gather kernel vs jnp reference")
    out("chains,jnp_us,kernel_us,kernel_mode,speedup")
    mode = "compiled" if on_tpu else "interpret"
    for P, (t_ref, t_k) in stats.items():
        out(f"{P},{t_ref:.0f},{t_k:.0f},{mode},{t_ref / t_k:.3f}")
    t_ref, t_k = stats[GATE_CHAINS]
    speedup = t_ref / t_k
    derived = (f"parity=bitwise;mode={mode};"
               f"speedup@{GATE_CHAINS}={speedup:.2f}x;"
               f"jnp_us={t_ref:.0f};kernel_us={t_k:.0f}")
    if on_tpu:
        assert speedup >= MIN_SPEEDUP, (
            f"compiled prefix-gather kernel {speedup:.2f}x < "
            f"{MIN_SPEEDUP}x the jnp path at {GATE_CHAINS} chains")
    else:
        derived += ";gate=skipped-non-tpu"
    return row("prefix_gather", us, derived)


if __name__ == "__main__":
    print(run())
