"""Fig. 5: normalized D2D latency vs #chiplets, 2.5D-RDL vs 3D packages.

Claims reproduced: (a) 3D achieves lower D2D latency than 2.5D at every
chiplet count (higher bandwidth, more I/Os); (b) D2D latency grows with
chiplet count (more reduction traffic over shared links).
"""
from __future__ import annotations

from repro.core import Chiplet, evaluate, workload
from benchmarks.common import CACHE, row, sys_25d, sys_3d, timed


def run(out=print) -> str:
    wl = workload(1)
    counts = range(2, 9)
    chips = lambda n: [Chiplet(128, 7, 1024)] * n

    def compute():
        rdl = [evaluate(sys_25d(chips(n), "RDL", "UCIe-S"), wl,
                        cache=CACHE).l_d2d_s for n in counts]
        ub = [evaluate(sys_3d(chips(n), "uBump"), wl,
                       cache=CACHE).l_d2d_s for n in counts]
        hb_hbm = [evaluate(sys_3d(chips(n), "HybBond", memory="HBM3"), wl,
                           cache=CACHE).l_d2d_s for n in counts]
        rdl_hbm = [evaluate(sys_25d(chips(n), "RDL", "UCIe-S",
                                    memory="HBM3"), wl,
                            cache=CACHE).l_d2d_s for n in counts]
        return rdl, ub, rdl_hbm, hb_hbm

    (rdl, ub, rdl_hbm, hb_hbm), us = timed(compute)
    base = rdl[0]
    out("# Fig5(a): normalized D2D latency (base = 2.5D-RDL-DDR5 @2)")
    out("n,2.5D-RDL-DDR5,3D-uB-DDR5")
    for i, n in enumerate(counts):
        out(f"{n},{rdl[i]/base:.3f},{ub[i]/base:.3f}")
    base_b = rdl_hbm[0]
    out("# Fig5(b): normalized D2D latency (base = 2.5D-RDL-HBM3 @2)")
    out("n,2.5D-RDL-HBM3,3D-HB-HBM3")
    for i, n in enumerate(counts):
        out(f"{n},{rdl_hbm[i]/base_b:.3f},{hb_hbm[i]/base_b:.3f}")

    ok_3d_faster = all(u < r for u, r in zip(ub, rdl))
    ok_grows = rdl[-1] > rdl[0] and ub[-1] > ub[0]
    derived = (f"3d_faster={ok_3d_faster};d2d_grows={ok_grows};"
               f"spread_2.5D={rdl[-1]/rdl[0]:.2f}x")
    assert ok_3d_faster, "paper claim: 3D D2D latency < 2.5D"
    assert ok_grows, "paper claim: D2D latency grows with chiplet count"
    return row("fig05_latency_vs_chiplets", us, derived)


if __name__ == "__main__":
    print(run())
