"""Shared helpers for the per-figure/table benchmarks."""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core import HISystem, Mapping, SimCache
from repro.core.system import validate
from repro.core.techdb import valid_pairs_25d, valid_pairs_3d, valid_pairs_hybrid

CACHE = SimCache()


def sys_25d(chips, pkg, proto, memory="DDR5", mapping="1-OS-0"):
    s = HISystem(chiplets=tuple(chips), style="2.5D", memory=memory,
                 mapping=Mapping.parse(mapping), pkg_25d=pkg, proto_25d=proto)
    validate(s, max_chiplets=max(6, len(chips)))
    return s


def sys_3d(chips, pkg, memory="DDR5", mapping="1-OS-0"):
    s = HISystem(chiplets=tuple(chips), style="3D", memory=memory,
                 mapping=Mapping.parse(mapping), pkg_3d=pkg,
                 proto_3d="UCIe-3D")
    validate(s, max_chiplets=max(6, len(chips)))
    return s


def sys_hybrid(chips, pkg25, proto25, pkg3, memory="DDR5",
               mapping="1-OS-0", stack=(1, 2)):
    s = HISystem(chiplets=tuple(chips), style="2.5D+3D", memory=memory,
                 mapping=Mapping.parse(mapping), pkg_25d=pkg25,
                 proto_25d=proto25, pkg_3d=pkg3, proto_3d="UCIe-3D",
                 stack=stack)
    validate(s, max_chiplets=max(6, len(chips)))
    return s


def all_43_systems(chips, memory="DDR5", mapping="1-OS-0"
                   ) -> List[Tuple[str, HISystem]]:
    """Every package-protocol combination (Sec V-A: 10 + 3 + 30 = 43)."""
    out = []
    for pkg, proto in valid_pairs_25d():
        out.append((f"2.5D-{pkg}-{proto}",
                    sys_25d(chips, pkg, proto, memory, mapping)))
    for pkg, proto in valid_pairs_3d():
        out.append((f"3D-{pkg}-{proto}", sys_3d(chips, pkg, memory, mapping)))
    for p25, pr25, p3, pr3 in valid_pairs_hybrid():
        out.append((f"2.5D+3D-{p25}-{pr25}-{p3}",
                    sys_hybrid(chips, p25, pr25, p3, memory, mapping)))
    assert len(out) == 43
    return out


def timed(fn) -> Tuple[object, float]:
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.0f},{derived}"
