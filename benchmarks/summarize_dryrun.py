"""Render dryrun_report.json into the EXPERIMENTS.md summary tables.

    PYTHONPATH=src python -m benchmarks.summarize_dryrun [report] [--patch]

Prints two markdown tables (dry-run memory/collectives + roofline terms);
with --patch, splices them into EXPERIMENTS.md at the
<!-- DRYRUN_SUMMARY --> / <!-- ROOFLINE_SUMMARY --> markers.
"""
from __future__ import annotations

import json
import sys

from repro.analysis.roofline import from_record
from repro.configs import get_config, get_shape


def gib(x):
    return f"{(x or 0)/2**30:.2f}"


def dryrun_table(records) -> str:
    lines = [
        "| arch | shape | mesh | params+opt GiB/dev | temp GiB/dev | "
        "all-gather | all-reduce | reduce-scatter | all-to-all | "
        "compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"],
                                            r["mesh"])):
        if r["status"] == "skipped":
            continue
        if r["status"] == "error":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR: {r['error'][:60]} | | | | | | |")
            continue
        c = r.get("collectives", {})
        mesh = "single" if "single" in r["mesh"] else "multi"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | "
            f"{gib(r.get('argument_size_in_bytes'))} | "
            f"{gib(r.get('temp_size_in_bytes'))} | "
            f"{gib(c.get('all-gather'))} | {gib(c.get('all-reduce'))} | "
            f"{gib(c.get('reduce-scatter'))} | {gib(c.get('all-to-all'))} | "
            f"{r.get('compile_s', 0):.0f} |")
    skips = [r for r in records if r["status"] == "skipped"]
    if skips:
        lines.append("")
        lines.append(f"Skipped cells ({len(skips)}): " + "; ".join(
            f"{r['arch']}×{r['shape']}×"
            f"{'single' if 'single' in r['mesh'] else 'multi'}"
            for r in sorted(skips, key=lambda r: (r['arch'], r['shape']))))
    return "\n".join(lines)


def roofline_table(records) -> str:
    lines = [
        "| arch | shape | mesh | t_compute | t_memory | t_collective | "
        "bottleneck | useful frac | MFU bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(records, key=lambda x: (x["arch"], x["shape"],
                                            x["mesh"])):
        if r["status"] != "ok":
            continue
        rl = from_record(r, get_config(r["arch"]), get_shape(r["shape"]))
        mesh = "single" if "single" in r["mesh"] else "multi"
        lines.append(
            f"| {rl.arch} | {rl.shape} | {mesh} | {rl.t_compute:.2e} | "
            f"{rl.t_memory:.2e} | {rl.t_collective:.2e} | "
            f"**{rl.bottleneck}** | {rl.useful_flops_fraction:.2f} | "
            f"{rl.mfu_upper_bound:.2f} |")
    return "\n".join(lines)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 and not sys.argv[1].startswith(
        "--") else "dryrun_report.json"
    with open(path) as f:
        records = json.load(f)
    dt = dryrun_table(records)
    rt = roofline_table(records)
    if "--patch" in sys.argv:
        with open("EXPERIMENTS.md") as f:
            doc = f.read()
        doc = doc.replace("<!-- DRYRUN_SUMMARY -->",
                          "<!-- DRYRUN_SUMMARY -->\n\n" + dt, 1) \
            if "<!-- DRYRUN_SUMMARY -->\n\n|" not in doc else doc
        doc = doc.replace("<!-- ROOFLINE_SUMMARY -->",
                          "<!-- ROOFLINE_SUMMARY -->\n\n" + rt, 1) \
            if "<!-- ROOFLINE_SUMMARY -->\n\n|" not in doc else doc
        with open("EXPERIMENTS.md", "w") as f:
            f.write(doc)
        print("EXPERIMENTS.md patched")
    else:
        print(dt)
        print()
        print(rt)


if __name__ == "__main__":
    main()
