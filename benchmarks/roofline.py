"""Roofline table from the dry-run report (EXPERIMENTS.md SRoofline).

Reads dryrun_report.json (produced by ``python -m repro.launch.dryrun``)
and emits, per (arch x shape x mesh) cell: the three roofline terms in
seconds, the dominant bottleneck, MODEL_FLOPS, the useful-FLOPs ratio and
the roofline MFU upper bound.
"""
from __future__ import annotations

import json
import os
import sys

from repro.analysis.roofline import HEADER, format_row, from_record
from repro.configs import get_config, get_shape
from benchmarks.common import row, timed

DEFAULT_REPORT = os.environ.get("DRYRUN_REPORT", "dryrun_report.json")


def run(out=print, report_path: str = DEFAULT_REPORT) -> str:
    def compute():
        try:
            with open(report_path) as f:
                records = json.load(f)
        except FileNotFoundError:
            return None
        rows = []
        for rec in records:
            if rec.get("status") != "ok":
                continue
            cfg = get_config(rec["arch"])
            shape = get_shape(rec["shape"])
            r = from_record(rec, cfg, shape)
            if r:
                rows.append(r)
        return rows

    rows, us = timed(compute)
    if rows is None:
        out(f"# roofline: no {report_path}; run "
            "`python -m repro.launch.dryrun` first")
        return row("roofline", us, "skipped=no_dryrun_report")
    out("# SRoofline: three terms per (arch x shape x mesh)")
    out(HEADER)
    bottlenecks = {"compute": 0, "memory": 0, "collective": 0}
    for r in sorted(rows, key=lambda r: (r.arch, r.shape, r.mesh)):
        out(format_row(r))
        bottlenecks[r.bottleneck] += 1
    derived = (f"cells={len(rows)};" + ";".join(
        f"{k}_bound={v}" for k, v in bottlenecks.items()))
    return row("roofline", us, derived)


if __name__ == "__main__":
    print(run(report_path=sys.argv[1] if len(sys.argv) > 1
              else DEFAULT_REPORT))
