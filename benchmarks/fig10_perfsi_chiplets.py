"""Fig. 10: normalized Perf-SI vs chiplet count across packages/workloads.

Claims: Perf-SI shows an inflection (throughput gains vs rising embodied
CFP + communication overheads); high-bandwidth packages sustain gains to
larger counts; small workloads (WL6) do not benefit from more chiplets.
"""
from __future__ import annotations

from repro.core import Chiplet, evaluate, workload
from benchmarks.common import CACHE, row, sys_25d, sys_3d, timed

COUNTS = range(2, 9)


def run(out=print) -> str:
    chips = lambda n: [Chiplet(128, 7, 1024)] * n

    def compute():
        data = {}
        # (a) WL1 across 3D interconnects / (b) 2.5D interconnects
        for pkg in ("TSV", "uBump", "HybBond"):
            data[f"3D-{pkg}"] = [
                evaluate(sys_3d(chips(n), pkg, mapping="0-OS-1"), workload(1),
                         cache=CACHE).perf_si for n in COUNTS]
        for pkg, proto in (("RDL", "UCIe-S"), ("Active", "UCIe-A"),
                           ("Passive", "UCIe-A"), ("EMIB", "UCIe-A")):
            data[f"2.5D-{pkg}"] = [
                evaluate(sys_25d(chips(n), pkg, proto, mapping="0-OS-1"),
                         workload(1), cache=CACHE).perf_si for n in COUNTS]
        # (c)/(d): all workloads on 3D-HB and 2.5D-Active
        for wl_idx in (1, 2, 5, 6):
            data[f"WL{wl_idx}-3D-HB"] = [
                evaluate(sys_3d(chips(n), "HybBond", mapping="0-OS-1"),
                         workload(wl_idx), cache=CACHE).perf_si
                for n in COUNTS]
        return data

    data, us = timed(compute)
    out("# Fig10: Perf-SI normalized to 2-chiplet baseline")
    out("series," + ",".join(str(n) for n in COUNTS))
    for name, vals in data.items():
        out(name + "," + ",".join(f"{v/vals[0]:.3f}" for v in vals))

    # claims
    wl1_hb = data["WL1-3D-HB"]
    peak_at = COUNTS[wl1_hb.index(max(wl1_hb))]
    wl6 = data["WL6-3D-HB"]
    wl6_peak = COUNTS[wl6.index(max(wl6))]
    # higher-bandwidth 3D package sustains/beats lower-bandwidth at high n
    hb_gain = data["3D-HybBond"][-1] / data["3D-HybBond"][0]
    tsv_gain = data["3D-TSV"][-1] / data["3D-TSV"][0]
    derived = (f"wl1_peak_n={peak_at};wl6_peak_n={wl6_peak};"
               f"hb_tail_gain={hb_gain:.2f};tsv_tail_gain={tsv_gain:.2f}")
    assert wl6_peak <= peak_at, (
        "small workloads must peak at fewer chiplets (WL6 claim)")
    assert hb_gain >= tsv_gain, (
        "higher-bandwidth packages must sustain gains longer")
    return row("fig10_perfsi_chiplets", us, derived)


if __name__ == "__main__":
    print(run())
