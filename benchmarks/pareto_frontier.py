"""Pareto-frontier pathfinding: hypervolume vs evaluation budget.

Claims asserted:
  (a) the vectorized ``jax.numpy`` non-dominated filter matches the exact
      host reference on 1,000 random fronts (duplicates and axis ties
      included) — *exactly*, not approximately;
  (b) one :class:`~repro.pathfinding.pareto.ScalarizationSweep` batched
      device program (64 scalarization directions x 4 tempering chains)
      reaches frontier hypervolume >= 64 independent single-objective
      parallel-tempering runs at the *same total evaluation budget*
      (the PR-2 engine with all chains scalarizing the T1 template and
      replica exchange blocked across runs — identical program shape, so
      the comparison is apples-to-apples down to the jit cache).

The hypervolume-vs-budget trajectory (both arms, shared reference point)
goes to the data table; the derived summary carries the final ratio.

Standalone: ``python -m benchmarks.pareto_frontier [--json out.json]``.
"""
from __future__ import annotations

import json
import random
import sys

import numpy as np

from repro.core import TEMPLATES, workload
from repro.core.sa import random_system
from repro.pathfinding import (
    DesignSpace,
    ParetoArchive,
    fit_normalizer_batched,
    get_device_evaluator,
    hypervolume,
    non_dominated_mask,
    non_dominated_mask_jnp,
    simplex_directions,
)
from repro.pathfinding.pareto import directions_to_weights
from benchmarks.common import row, timed

N_FRONTS = 1000          # random fronts for the filter-parity claim
FRONT_SIZE = 32
N_DIRECTIONS = 64
N_CHAINS = 4
SWEEPS = 300
SWAP_EVERY = 5
CHECKPOINTS = (75, 150, 300)   # sweep prefixes for the budget trajectory
ARCHIVE_SIZE = 512
# exploitative ladder: Eq. 17-normalized costs are O(1), see
# ScalarizationSweep's defaults
T_MAX, T_MIN = 5.0, 0.005


def _random_fronts(rng: np.random.Generator) -> np.ndarray:
    """[N_FRONTS, FRONT_SIZE, 3] with exact duplicates and axis ties."""
    pts = rng.random((N_FRONTS, FRONT_SIZE, 3))
    pts[:, ::7] = pts[:, 1::7]            # exact duplicate rows
    pts[:, 2::5, 0] = pts[:, 3::5, 0]     # single-axis ties
    pts[:, -1] = pts[:, 0]                # duplicate of the first row
    return pts


def _ladder(k: int, n: int, t_max=T_MAX, t_min=T_MIN):
    ratio = (t_min / t_max) ** (1.0 / max(1, n - 1))
    return np.tile([t_max * ratio ** i for i in range(n)], k)


def _hv_trajectory(samples, ref) -> dict:
    """Archive hypervolume at each sweep-prefix checkpoint."""
    out = {}
    enc, vec = samples["enc"], samples["vec"]
    n = enc.shape[1]
    for cp in CHECKPOINTS:
        arch = ParetoArchive(max_size=ARCHIVE_SIZE)
        arch.insert(enc[:cp + 1].reshape(-1, enc.shape[-1]),
                    vec[:cp + 1].reshape(-1, 3))
        out[(cp + 1) * n] = arch.hypervolume(ref)
    return out


def run(out=print) -> str:
    wl = workload(1)
    space = DesignSpace()
    norm = fit_normalizer_batched(wl, samples=2000, seed=1234, space=space)
    tpl = TEMPLATES["T1"]

    def compute():
        # -- (a) jnp filter == host reference on 1k random fronts --------
        fronts = _random_fronts(np.random.default_rng(13))
        host = np.stack([non_dominated_mask(f) for f in fronts])
        dev_mask = non_dominated_mask_jnp(fronts)   # one batched call
        mismatches = int((host != dev_mask).sum())

        # -- (b) sweep vs 64 independent PT runs at equal budget ---------
        dev = get_device_evaluator(wl, space=space)
        n_total = N_DIRECTIONS * N_CHAINS
        temps = _ladder(N_DIRECTIONS, N_CHAINS)
        pair_ok = (np.arange(n_total - 1) + 1) % N_CHAINS != 0

        rng = random.Random(7)
        v0 = space.encode_many(
            [random_system(rng, space.db, space.max_chiplets)
             for _ in range(n_total)])

        w_sweep = np.repeat(
            directions_to_weights(simplex_directions(N_DIRECTIONS)),
            N_CHAINS, axis=0)
        res_sweep = dev.parallel_tempering(
            v0, temps, SWEEPS, SWAP_EVERY, seed=11, norm=norm,
            template=tpl, weights=w_sweep, pair_mask=pair_ok)

        # baseline: same program shape, every chain on the single T1
        # scalarization; blocked pairs make the 64 ladders independent
        rng_b = random.Random(8)
        v0_b = space.encode_many(
            [random_system(rng_b, space.db, space.max_chiplets)
             for _ in range(n_total)])
        res_pt = dev.parallel_tempering(
            v0_b, temps, SWEEPS, SWAP_EVERY, seed=12, norm=norm,
            template=tpl, weights=None, pair_mask=pair_ok)

        # reference point: nadir of the *combined final frontiers* + 10%
        # margin. Anchoring at the union of all raw samples would let the
        # random-init outliers dominate the measure and flatten the
        # difference between the arms into noise.
        combined = ParetoArchive(max_size=2 * ARCHIVE_SIZE)
        for r in (res_sweep, res_pt):
            combined.insert(r.samples["enc"].reshape(-1, space.width),
                            r.samples["vec"].reshape(-1, 3))
        ref = combined.reference_point(margin=0.1)
        traj_sweep = _hv_trajectory(res_sweep.samples, ref)
        traj_pt = _hv_trajectory(res_pt.samples, ref)
        assert res_sweep.evaluations == res_pt.evaluations
        return (mismatches, traj_sweep, traj_pt, ref,
                res_sweep.evaluations)

    (mismatches, traj_sweep, traj_pt, ref, evals), us = timed(compute)

    out("# Pareto frontier: hypervolume vs evaluation budget "
        f"(ref={np.round(ref, 4).tolist()})")
    out("budget,hv_scalarization_sweep,hv_independent_pt")
    for budget in sorted(traj_sweep):
        out(f"{budget},{traj_sweep[budget]:.6g},{traj_pt[budget]:.6g}")

    hv_s, hv_p = traj_sweep[max(traj_sweep)], traj_pt[max(traj_pt)]
    ratio = hv_s / hv_p if hv_p > 0 else float("inf")
    derived = (f"filter_mismatches={mismatches}/{N_FRONTS};"
               f"hv_sweep={hv_s:.4g};hv_pt={hv_p:.4g};"
               f"hv_ratio={ratio:.3f};evals={evals}")
    assert mismatches == 0, (
        f"jnp filter deviated from host reference on {mismatches} fronts")
    assert hv_s >= hv_p, (
        f"scalarization sweep hypervolume {hv_s:.4g} < independent-PT "
        f"baseline {hv_p:.4g} at equal budget {evals}")
    return row("pareto_frontier", us, derived)


def main() -> None:
    args = sys.argv[1:]
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        try:
            json_path = args[i + 1]
        except IndexError:
            sys.exit("--json requires a path argument")
    lines = []
    summary = run(out=lines.append)
    print("\n".join(lines))
    print(summary)
    if json_path:
        name, us, derived = summary.split(",", 2)
        with open(json_path, "w") as f:
            json.dump({"rows": [{"name": name, "us_per_call": float(us),
                                 "derived": derived}]}, f, indent=2)
        print(f"# wrote {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
