"""Tables VI-X: three optimization flows across all WLs x templates.

Flows: (1) ChipletGym-models SA, (2) CarbonPATH w/o carbon (zeta=eta=0),
(3) full CarbonPATH. Reports per-(WL, template) metrics of each flow's
solution normalized to CarbonPATH's (Table VI convention) and the
converged architectures (Tables VII-X convention).

All three flows run through the Pathfinder v2 facade with the
:class:`SimulatedAnnealing` strategy — the ChipletGym flow is the
``objective="chipletgym"`` backend, replacing the seed ``evaluate_fn``
swap. Normalizers use the scalar fitting loop (``method="scalar"``) so
runs stay bit-comparable with the seed annealer.

Claim asserted: CarbonPATH achieves lower (or equal) embodied CFP than
CarbonPATH-w/o-carbon on average, with a meaningful improvement factor
(paper: 1.9x average, up to 3.16x on T4).

Default schedule is reduced for CI speed; --full uses the paper's
(T0=4000, Tf=0.001, cooling 0.99, 50 moves/temp).
"""
from __future__ import annotations

import sys as _sys

from repro.core import (
    SAConfig,
    SimCache,
    TEMPLATES,
    evaluate,
    workload,
)
from repro.pathfinding import Pathfinder, SimulatedAnnealing
from benchmarks.common import row, timed

REDUCED = SAConfig(t_initial=400.0, t_final=0.01, cooling=0.93,
                   moves_per_temp=25, norm_samples=1500, seed=0)
FULL = SAConfig()  # the paper's schedule


def run(out=print, full: bool = False) -> str:
    cfg = FULL if full else REDUCED
    cache = SimCache()

    def compute():
        rows = []
        # frontier collection off: the table compares scalar SA flows and
        # never reads the archive
        sa = SimulatedAnnealing(cfg, frontier_size=0)
        for wl_idx in range(1, 7):
            wl = workload(wl_idx)
            pf = Pathfinder(wl, TEMPLATES["T1"], cache=cache)
            norm = pf.fit_normalizer(samples=cfg.norm_samples,
                                     method="scalar")
            pf_gym = Pathfinder(wl, TEMPLATES["T1"], objective="chipletgym",
                                cache=cache)
            norm_gym = pf_gym.fit_normalizer(samples=cfg.norm_samples,
                                             method="scalar")
            for tname, template in TEMPLATES.items():
                res_cp = Pathfinder(wl, template, norm=norm,
                                    cache=cache).search(strategy=sa)
                res_noc = Pathfinder(wl, template.without_carbon(),
                                     norm=norm, cache=cache).search(
                    strategy=sa)
                res_gym = Pathfinder(wl, template.without_carbon(),
                                     objective="chipletgym", norm=norm_gym,
                                     cache=cache).search(strategy=sa)
                # re-evaluate every solution under the FULL CarbonPATH
                # models so the comparison is apples-to-apples
                m_cp = res_cp.best_metrics
                m_noc = res_noc.best_metrics
                m_gym = evaluate(res_gym.best, wl, cache=cache)
                rows.append((wl_idx, tname,
                             (res_cp.best, m_cp),
                             (res_noc.best, m_noc),
                             (res_gym.best, m_gym)))
        return rows

    rows, us = timed(compute)
    out("# Tables VI-X: metrics normalized to CarbonPATH; architectures")
    out("wl,template,flow,n_chiplets,system,mapping,"
        "energy,area,dollar,latency,emb_cfp,ope_cfp")
    emb_ratios = []
    emb_ratios_by_t = {t: [] for t in TEMPLATES}
    for wl_idx, tname, cp, noc, gym in rows:
        base = cp[1]
        for flow, (sol, m) in (("CarbonPATH", cp),
                               ("CarbonPATH-w/o-C", noc),
                               ("ChipletGym", gym)):
            out(f"WL{wl_idx},{tname},{flow},{sol.n_chiplets},"
                f"{sol.describe()},{sol.mapping.name},"
                f"{m.energy_j/base.energy_j:.3f},"
                f"{m.area_mm2/base.area_mm2:.3f},"
                f"{m.dollar/base.dollar:.3f},"
                f"{m.latency_s/base.latency_s:.3f},"
                f"{(m.emb_cfp_kg/base.emb_cfp_kg) if base.emb_cfp_kg else 0:.3f},"
                f"{(m.ope_cfp_kg/base.ope_cfp_kg) if base.ope_cfp_kg else 0:.3f}")
        r = noc[1].emb_cfp_kg / cp[1].emb_cfp_kg
        emb_ratios.append(r)
        emb_ratios_by_t[tname].append(r)

    avg = sum(emb_ratios) / len(emb_ratios)
    by_t = {t: sum(v) / len(v) for t, v in emb_ratios_by_t.items()}
    derived = (f"avg_emb_improvement={avg:.2f}x;"
               + ";".join(f"{t}={v:.2f}x" for t, v in by_t.items()))
    assert avg >= 1.0, (
        f"carbon-aware flow must not increase embodied CFP (avg {avg:.2f})")
    return row("table06_sa_flows", us, derived)


if __name__ == "__main__":
    print(run(full="--full" in _sys.argv))
