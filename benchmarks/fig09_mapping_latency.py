"""Fig. 9: latency across all 12 workload mappings on the hybrid system.

Claims: OS dataflow lowest-latency for WL1/WL2 (partial sums stay local);
the best assigning order is workload-dependent; 3.5x / 2.9x min-max
latency variation for WL1 / WL2.
"""
from __future__ import annotations

from repro.core import evaluate, workload
from repro.core.chiplet import different_chiplet_system
from repro.core.workload import ALL_MAPPINGS
from benchmarks.common import CACHE, row, sys_hybrid, timed


def run(out=print) -> str:
    chips = different_chiplet_system()

    def compute():
        results = {}
        for wl_idx in (1, 2):
            wl = workload(wl_idx)
            rows = []
            for m in ALL_MAPPINGS:
                sys = sys_hybrid(chips, "RDL", "UCIe-S", "HybBond",
                                 mapping=m.name, stack=(1, 2))
                rows.append((m.name, evaluate(sys, wl, cache=CACHE).latency_s))
            results[wl_idx] = rows
        return results

    results, us = timed(compute)
    derived_parts = []
    for wl_idx, rows in results.items():
        base = next(l for n, l in rows if n == "0-IS-0")
        out(f"# Fig9 WL{wl_idx}: latency normalized to 0-IS-0")
        out("mapping,latency")
        for name, l in rows:
            out(f"{name},{l/base:.3f}")
        spread = max(l for _, l in rows) / min(l for _, l in rows)
        best = min(rows, key=lambda r: r[1])[0]
        # claim: OS dataflow is the fastest family (with split-K off)
        no_k = [(n, l) for n, l in rows if n.endswith("-0")]
        best_nok = min(no_k, key=lambda r: r[1])[0]
        derived_parts.append(
            f"WL{wl_idx}:spread={spread:.2f}x,best={best}")
        assert "OS" in best_nok, f"paper: OS wins split-K-off; got {best_nok}"
        assert spread > 1.3, f"mapping must matter: spread {spread:.2f}"
    return row("fig09_mapping_latency", us, ";".join(derived_parts))


if __name__ == "__main__":
    print(run())
