"""Fig. 7: normalized dollar cost per package-protocol combination.

Claims: 2.5D-RDL-UCS cheapest (mature, highest yield); 3D hybrid bonding
most expensive (lowest bonding yield); TSV cheapest 3D; ChipletGym's
constant 0.99 bonding yield under-reports cost.
"""
from __future__ import annotations

from repro.core import evaluate, evaluate_chipletgym, workload
from repro.core.chiplet import different_chiplet_system, identical_chiplet_system
from benchmarks.common import CACHE, all_43_systems, row, timed


def run(out=print) -> str:
    wl = workload(1)

    def compute():
        results = {}
        for tag, chips in (("identical", identical_chiplet_system(4)),
                           ("different", different_chiplet_system())):
            rows = []
            for name, sys in all_43_systems(chips):
                m = evaluate(sys, wl, cache=CACHE)
                g = evaluate_chipletgym(sys, wl, cache=CACHE)
                rows.append((name, m.dollar, g.dollar))
            results[tag] = rows
        return results

    results, us = timed(compute)
    checks = []
    for tag, rows in results.items():
        base = next(c for n, c, _ in rows if n == "3D-TSV-UCIe-3D")
        out(f"# Fig7({tag}): cost normalized to 3D-TSV-UC3")
        out("combo,carbonpath,chipletgym")
        for name, c, g in rows:
            out(f"{name},{c/base:.3f},{g/base:.3f}")
        cheapest = min(rows, key=lambda r: r[1])
        checks.append(cheapest[0] == "2.5D-RDL-UCIe-S")
        three_d = [(n, c) for n, c, _ in rows if n.startswith("3D-")]
        checks.append(min(three_d, key=lambda r: r[1])[0] == "3D-TSV-UCIe-3D")
        checks.append(max(three_d, key=lambda r: r[1])[0]
                      == "3D-HybBond-UCIe-3D")
    derived = (f"rdl_cheapest={checks[0] and checks[3]};"
               f"tsv_cheapest_3d={checks[1] and checks[4]};"
               f"hb_priciest_3d={checks[2] and checks[5]}")
    assert all(checks), f"cost-ordering claims failed: {checks}"
    return row("fig07_cost_pkg", us, derived)


if __name__ == "__main__":
    print(run())
