"""Communication models at equal budget: legacy links vs mesh-NoC + NoI.

Claims asserted:
  (a) the mesh_noc scenario grid — per-chiplet mesh dims and NoI entry
      placements live as two extra encoded axes per chiplet — compiles
      its fused program exactly **once** for the whole 5-region
      lifecycle grid, same as legacy: the NoC axes are runtime data
      (closed-form Manhattan hop tables gathered per slot), never
      trace-time constants;
  (b) re-running either arm on its warm engine adds exactly **zero**
      fused compiles, and the warm wall-clock of the mesh arm stays
      within ``COMM_MODELS_MAX_SLOWDOWN`` of legacy (the NoC terms are
      a handful of elementwise gathers on top of the same program);
  (c) at *equal evaluation budget* the mesh arm's per-cell frontier
      hypervolume (union reference per cell) is no worse than
      ``COMM_MODELS_MIN_HV_RATIO`` of legacy's on average — the mesh
      space strictly contains the legacy space (the neutral 1x1 mesh is
      bit-identical to no NoC at all), so searching the larger space at
      the same budget must not collapse the frontier.

The derived summary carries both arms' warm wall times, the compile
counts, the hypervolume ratio and the shared budget.

Standalone: ``python -m benchmarks.comm_models``.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import row, timed
from benchmarks.scenario_sweep import _lifecycle_regions
from repro.core import workload
from repro.pathfinding import ScalarizationSweep, ScenarioSweep
from repro.pathfinding.device import trace_count

DIRECTIONS = 4
N_CHAINS = 2
SWEEPS = 80
NORM_SAMPLES = 400
BASE_KEY = 1
MAX_SLOWDOWN = float(os.environ.get("COMM_MODELS_MAX_SLOWDOWN", "2.0"))
MIN_HV_RATIO = float(os.environ.get("COMM_MODELS_MIN_HV_RATIO", "0.6"))


def _arm(comm, wls, strat, budget):
    """One comm-model arm: cold run (traces its own fused program), warm
    rerun (must replay), per-cell frontiers + compile deltas."""
    sweep = ScenarioSweep(strategy=strat, regions=_lifecycle_regions(),
                          norm_samples=NORM_SAMPLES, comm=comm)
    before = trace_count("scenario_pt")
    t0 = time.perf_counter()
    sf = sweep.run(wls, budget=budget, key=BASE_KEY)
    t_cold = time.perf_counter() - t0
    cold_compiles = trace_count("scenario_pt") - before
    before = trace_count("scenario_pt")
    t_warm = timed(
        lambda: sweep.run(wls, budget=budget, key=BASE_KEY))[1] / 1e6
    warm_compiles = trace_count("scenario_pt") - before
    evals = sum(sf.results[s.key].evaluations for s in sf.scenarios)
    return sf, t_cold, t_warm, cold_compiles, warm_compiles, evals


def run(out=print) -> str:
    wls = [workload(1)]
    strat = ScalarizationSweep(directions=DIRECTIONS, n_chains=N_CHAINS,
                               sweeps=SWEEPS)
    nc = strat.weight_rows().shape[0] * strat.n_chains
    n_cells = len(wls) * len(_lifecycle_regions())
    budget = n_cells * nc * (1 + SWEEPS)

    def compute():
        legacy = _arm("legacy", wls, strat, budget)
        mesh = _arm("mesh_noc", wls, strat, budget)
        sf_l, sf_m = legacy[0], mesh[0]
        ratios = []
        for s in sf_l.scenarios:
            a = sf_m.results[s.key].frontier
            b = sf_l.results[s.key].frontier
            # encoded rows differ in width across comm models, so the
            # shared reference comes from the stacked objective vectors
            # (nadir + 10% span, the ParetoArchive default)
            v = np.vstack([a.vectors, b.vectors])
            lo, hi = v.min(axis=0), v.max(axis=0)
            span = np.where(hi > lo, hi - lo, np.maximum(np.abs(hi), 1.0))
            ref = hi + 0.1 * span
            hv_m, hv_l = a.hypervolume(ref), b.hypervolume(ref)
            if hv_l > 0:
                ratios.append(hv_m / hv_l)
        return legacy, mesh, float(np.mean(ratios))

    (legacy, mesh, hv_ratio), us = timed(compute)
    _, tl_cold, tl_warm, cl_cold, cl_warm, ev_l = legacy
    _, tm_cold, tm_warm, cm_cold, cm_warm, ev_m = mesh
    slowdown = tm_warm / tl_warm
    out("# Comm models at equal budget: legacy vs mesh_noc "
        f"({n_cells}-cell lifecycle grid, budget {budget})")
    out("metric,legacy,mesh_noc")
    out(f"cold_s,{tl_cold:.3f},{tm_cold:.3f}")
    out(f"warm_s,{tl_warm:.3f},{tm_warm:.3f}")
    out(f"cold_compiles,{cl_cold},{cm_cold}")
    out(f"warm_compiles,{cl_warm},{cm_warm}")
    out(f"evals,{ev_l},{ev_m}")
    out(f"hv_ratio_mean,{hv_ratio:.4f},")
    out(f"warm_slowdown,{slowdown:.2f},")
    derived = (f"legacy_warm_s={tl_warm:.2f};mesh_warm_s={tm_warm:.2f};"
               f"warm_slowdown={slowdown:.2f}x;"
               f"mesh_compiles={cm_cold};warm_compiles={cm_warm};"
               f"hv_ratio={hv_ratio:.3f};evals={ev_m}")
    assert cl_cold == 1 and cm_cold == 1, (
        f"each arm must trace its fused program exactly once, got "
        f"legacy {cl_cold} / mesh {cm_cold}")
    assert cl_warm == 0 and cm_warm == 0, (
        f"warm reruns retraced: legacy {cl_warm} / mesh {cm_warm} "
        "(expected 0 — mesh dims and entry placements are runtime data)")
    assert ev_l == ev_m == budget, (
        f"equal-budget accounting broke: legacy {ev_l}, mesh {ev_m}, "
        f"budget {budget}")
    assert slowdown <= MAX_SLOWDOWN, (
        f"mesh_noc warm pass {slowdown:.2f}x slower than legacy "
        f"(cap {MAX_SLOWDOWN}x)")
    assert hv_ratio >= MIN_HV_RATIO, (
        f"mesh_noc mean per-cell hypervolume ratio {hv_ratio:.3f} < "
        f"{MIN_HV_RATIO} vs legacy at equal budget")
    return row("comm_models", us, derived)


def main() -> None:
    run()


if __name__ == "__main__":
    main()
