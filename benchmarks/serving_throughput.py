"""Serving throughput: continuous batching on a warm engine vs cold runs.

Claims asserted:
  (a) after the one-time bucket warmup, a service drains 8 concurrent
      jobs with ZERO retraces (``device.trace_count`` flat across the
      timed run — the pre-compiled programs are only ever replayed);
  (b) one warm multiplexed service beats 8 sequential cold runs (each
      paying its own engine build + trace, as 8 separate processes
      would) by >= ``SERVING_MIN_SPEEDUP`` on jobs/sec (default 3x;
      override on noisy/cache-warm runners);
  (c) at equal total sweep budget, adaptive per-cell budgets reach
      >= ``SERVING_MIN_HV_RATIO`` of fixed-budget mean per-cell
      hypervolume (default 1.0: donation only ever extends
      still-improving frontiers) while consuming no more sweeps.

The derived summary carries jobs/sec for both paths, the speedup, the
trace counts, and the adaptive/fixed hypervolume ratio.

Standalone: ``python -m benchmarks.serving_throughput [--json out.json]``.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import row, timed

N_JOBS = 8
SWEEPS = 16
SEGMENT = 2
SLOTS = 4
NORM_SAMPLES = 60
MIN_SPEEDUP = float(os.environ.get("SERVING_MIN_SPEEDUP", "3.0"))
MIN_HV_RATIO = float(os.environ.get("SERVING_MIN_HV_RATIO", "1.0"))
TRACE_KEYS = ("scenario_pt", "scenario_init")


def _specs(prefix: str):
    from repro.pathfinding import ScalarizationSweep
    from repro.serving import JobSpec

    from repro.core import workload

    wls = [workload(1), workload(6)]
    specs = []
    for i in range(N_JOBS):
        specs.append(JobSpec(
            job_id=f"{prefix}-{i}", workload=wls[i % 2].name,
            strategy=ScalarizationSweep(directions=2, n_chains=2,
                                        sweeps=SWEEPS),
            carbon_intensity=[0.024, 0.3, 0.475, 0.82][i % 4]))
    return wls, specs


def _service(wls, adaptive=False):
    from repro.serving import PathfinderService

    # two consecutive flat boundaries before a job is declared
    # converged: a single zero-gain segment on a small search is noise,
    # and donating on it trades real tail improvements away
    return PathfinderService(
        wls, slots=SLOTS, segment=SEGMENT, norm_samples=NORM_SAMPLES,
        adaptive=adaptive, stall_segments=2, stall_tol=0.0)


def _drain(svc, specs):
    for sp in specs:
        svc.submit(sp)
    svc.drain()
    return [svc.result(sp.job_id) for sp in specs]


def run(out=print) -> str:
    from repro.pathfinding import hypervolume
    from repro.pathfinding.device import (
        _SCENARIO_ENGINES,
        trace_count,
    )

    def compute():
        wls, specs = _specs("warmup")
        _drain(_service(wls), specs)      # one-time warmup (compiles)

        # -- (a) warm multiplexed drain: 8 jobs, zero retraces ------------
        wls, specs = _specs("warm")
        svc = _service(wls)
        before = {k: trace_count(k) for k in TRACE_KEYS}
        t0 = time.perf_counter()
        _drain(svc, specs)
        t_warm = time.perf_counter() - t0
        warm_traces = sum(trace_count(k) - before[k] for k in TRACE_KEYS)

        # adaptive-vs-fixed on the still-warm engine, same total budget
        wls, fixed_specs = _specs("fixed")
        fixed = _drain(_service(wls), fixed_specs)
        wls, adapt_specs = _specs("adapt")
        adapt = _drain(_service(wls, adaptive=True), adapt_specs)
        hv_f, hv_a = [], []
        for rf, ra in zip(fixed, adapt):
            ref = np.maximum(rf.frontier.reference_point(),
                             ra.frontier.reference_point())
            hv_f.append(hypervolume(rf.frontier.vectors, ref))
            hv_a.append(hypervolume(ra.frontier.vectors, ref))
        hv_ratio = float(np.mean(hv_a) / max(np.mean(hv_f), 1e-300))
        sweeps_a = sum(r.sweeps for r in adapt)
        sweeps_f = sum(r.sweeps for r in fixed)

        # -- (b) 8 sequential cold runs: every job pays its own engine ----
        # (dropping the module-level engine cache before each job is what
        # 8 separate processes would do; with a persistent XLA cache the
        # retrace still costs tracing time, just not XLA compile time)
        wls, specs = _specs("cold")
        before = {k: trace_count(k) for k in TRACE_KEYS}
        t0 = time.perf_counter()
        for sp in specs:
            _SCENARIO_ENGINES.clear()
            svc = _service(wls)
            svc.submit(sp)
            svc.drain()
            svc.result(sp.job_id)
        t_cold = time.perf_counter() - t0
        cold_traces = sum(trace_count(k) - before[k] for k in TRACE_KEYS)
        return (t_warm, warm_traces, t_cold, cold_traces,
                hv_ratio, sweeps_a, sweeps_f)

    (t_warm, warm_traces, t_cold, cold_traces,
     hv_ratio, sweeps_a, sweeps_f), us = timed(compute)
    warm_jps = N_JOBS / t_warm
    cold_jps = N_JOBS / t_cold
    speedup = t_cold / t_warm
    out(f"# Serving throughput: {N_JOBS} jobs x {SWEEPS} sweeps, "
        f"{SLOTS} slots, segment={SEGMENT}")
    out("metric,value")
    out(f"warm_s,{t_warm:.3f}")
    out(f"cold_s,{t_cold:.3f}")
    out(f"warm_jobs_per_s,{warm_jps:.2f}")
    out(f"cold_jobs_per_s,{cold_jps:.2f}")
    out(f"speedup,{speedup:.2f}")
    out(f"warm_traces,{warm_traces}")
    out(f"cold_traces,{cold_traces}")
    out(f"hv_ratio_adaptive_vs_fixed,{hv_ratio:.4f}")
    out(f"sweeps_adaptive,{sweeps_a}")
    out(f"sweeps_fixed,{sweeps_f}")
    assert warm_traces == 0, (
        f"warm service retraced {warm_traces} programs — continuous "
        "batching must only replay the warmed bucket programs")
    assert cold_traces > 0, "cold baseline unexpectedly reused programs"
    assert speedup >= MIN_SPEEDUP, (
        f"warm serving speedup {speedup:.2f}x < {MIN_SPEEDUP}x over "
        "sequential cold runs")
    assert sweeps_a <= sweeps_f, (
        f"adaptive consumed {sweeps_a} sweeps > fixed {sweeps_f}")
    assert hv_ratio >= MIN_HV_RATIO - 1e-9, (
        f"adaptive/fixed mean hypervolume ratio {hv_ratio:.4f} < "
        f"{MIN_HV_RATIO}")
    derived = (f"warm_jps={warm_jps:.2f};speedup={speedup:.1f}x;"
               f"warm_traces={warm_traces};cold_traces={cold_traces};"
               f"hv_ratio={hv_ratio:.3f};"
               f"sweeps={sweeps_a}/{sweeps_f}")
    return row("serving_throughput", us, derived)


def main() -> None:
    args = sys.argv[1:]
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        try:
            json_path = args[i + 1]
        except IndexError:
            sys.exit("--json requires a path argument")
    lines = []
    summary = run(out=lines.append)
    print("\n".join(lines))
    print(summary)
    if json_path:
        name, us, derived = summary.split(",", 2)
        with open(json_path, "w") as f:
            json.dump({"rows": [{"name": name, "us_per_call": float(us),
                                 "derived": derived}]}, f, indent=2)
        print(f"# wrote {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
