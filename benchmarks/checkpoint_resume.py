"""Checkpoint overhead of the segmented tempering engine.

Claims asserted:
  (a) segmentation itself is bit-invisible: the segmented run's history
      / best / final population equal the monolithic run's exactly;
  (b) checkpointing a production-shaped sweep (512 chains, segment=50)
      costs < 5% wall over the monolithic un-checkpointed engine
      (``CHECKPOINT_MAX_OVERHEAD`` overrides the gate on noisy shared
      runners);
  (c) resuming a finished run restores state without re-running any
      segment (reported as ``resume_ms``).

The derived summary carries the per-save cost, both overheads and the
number of boundary snapshots.

Standalone: ``python -m benchmarks.checkpoint_resume [--json out.json]``.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

from repro.core import TEMPLATES, workload
from repro.pathfinding import (
    DesignSpace,
    ParetoArchive,
    SearchCheckpointer,
    fit_normalizer_batched,
)
from repro.pathfinding.device import get_device_evaluator
from benchmarks.common import row, timed

N_CHAINS = 512
SWEEPS = 100
SEGMENT = 50
SEED = 11
REPEATS = 3
MAX_OVERHEAD = float(os.environ.get("CHECKPOINT_MAX_OVERHEAD", "0.05"))


def run(out=print) -> str:
    wl = workload(1)
    space = DesignSpace()
    norm = fit_normalizer_batched(wl, samples=2000, seed=1234, space=space)
    dev = get_device_evaluator(wl, space=space)
    tpl = TEMPLATES["T1"]
    v0 = space.sample(N_CHAINS, key=3)
    ratio = (1.0 / 4000.0) ** (1.0 / (N_CHAINS - 1))
    temps = np.array([4000.0 * ratio ** i for i in range(N_CHAINS)])

    def sweep(segment=None, checkpoint=None):
        archive = ParetoArchive(max_size=256)
        res = dev.parallel_tempering(
            v0, temps, SWEEPS, 5, seed=SEED, norm=norm, template=tpl,
            archive=archive, segment=segment, checkpoint=checkpoint)
        return res, archive

    def best_wall(fn):
        walls = []
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            fn()
            walls.append(time.perf_counter() - t0)
        return min(walls)

    def compute():
        # warm both program shapes (monolithic 100-sweep scan, 50-sweep
        # segment scan) out of the timed region
        res_mono, _ = sweep()
        res_seg, _ = sweep(segment=SEGMENT)
        # -- (a) segmentation is bit-invisible ----------------------------
        assert res_seg.history == res_mono.history, \
            "segmented scan diverged from the monolithic trajectory"
        assert np.array_equal(res_seg.best_enc, res_mono.best_enc)
        assert np.array_equal(res_seg.final_enc, res_mono.final_enc)

        t_mono = best_wall(lambda: sweep())
        t_seg = best_wall(lambda: sweep(segment=SEGMENT))

        walls, resumes = [], []
        n_saves = SWEEPS // SEGMENT + (SWEEPS % SEGMENT > 0)
        for _ in range(REPEATS):
            with tempfile.TemporaryDirectory() as d:
                t0 = time.perf_counter()
                res_ck, _ = sweep(segment=SEGMENT,
                                  checkpoint=SearchCheckpointer(d))
                walls.append(time.perf_counter() - t0)
                assert res_ck.history == res_mono.history, \
                    "checkpointed run diverged"
                # -- (c) resume of a finished run runs zero segments ------
                t0 = time.perf_counter()
                res_r, _ = sweep(segment=SEGMENT,
                                 checkpoint=SearchCheckpointer(d))
                resumes.append(time.perf_counter() - t0)
                assert res_r.history == res_mono.history
        t_ck = min(walls)
        return (t_mono, t_seg, t_ck, min(resumes), n_saves)

    (t_mono, t_seg, t_ck, t_resume, n_saves), us = timed(compute)
    seg_overhead = t_seg / t_mono - 1.0
    ck_overhead = t_ck / t_mono - 1.0
    save_ms = max(0.0, (t_ck - t_seg) / n_saves * 1e3)
    out(f"# Checkpoint overhead: {N_CHAINS} chains x {SWEEPS} sweeps, "
        f"segment={SEGMENT} ({n_saves} boundary snapshots)")
    out("metric,value")
    out(f"monolithic_s,{t_mono:.3f}")
    out(f"segmented_s,{t_seg:.3f}")
    out(f"checkpointed_s,{t_ck:.3f}")
    out(f"resume_finished_s,{t_resume:.3f}")
    out(f"segment_overhead,{seg_overhead:.4f}")
    out(f"checkpoint_overhead,{ck_overhead:.4f}")
    out(f"per_save_ms,{save_ms:.2f}")
    derived = (f"ckpt_overhead={ck_overhead * 100:.1f}%;"
               f"seg_overhead={seg_overhead * 100:.1f}%;"
               f"save_ms={save_ms:.1f};saves={n_saves};"
               f"resume_ms={t_resume * 1e3:.0f}")
    assert ck_overhead <= MAX_OVERHEAD, (
        f"checkpoint overhead {ck_overhead * 100:.1f}% > "
        f"{MAX_OVERHEAD * 100:.0f}% at segment={SEGMENT} "
        f"({N_CHAINS} chains)")
    return row("checkpoint_resume", us, derived)


def main() -> None:
    args = sys.argv[1:]
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        try:
            json_path = args[i + 1]
        except IndexError:
            sys.exit("--json requires a path argument")
    lines = []
    summary = run(out=lines.append)
    print("\n".join(lines))
    print(summary)
    if json_path:
        name, us, derived = summary.split(",", 2)
        with open(json_path, "w") as f:
            json.dump({"rows": [{"name": name, "us_per_call": float(us),
                                 "derived": derived}]}, f, indent=2)
        print(f"# wrote {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
