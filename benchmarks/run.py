"""Benchmark orchestrator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark) to stdout;
per-benchmark data tables go to ``benchmarks/out/<name>.csv``.

Usage:
    PYTHONPATH=src python -m benchmarks.run           # everything
    PYTHONPATH=src python -m benchmarks.run fig05 t11 # substring filter
    PYTHONPATH=src python -m benchmarks.run --json perf.json  # + summary

``--json <path>`` additionally writes the summary rows as a JSON perf
snapshot: {"rows": [{"name", "us_per_call", "derived"}, ...]}.

``--trajectory <path> [--commit <sha>]`` appends the measured rows to
the committed perf *trajectory* (``BENCH_pathfinder.json``): one entry
per (benchmark, commit) with ``{"benchmark", "commit", "metrics"}``
keys. Re-measuring the same commit replaces its entries; the file is
validated in CI by ``benchmarks/validate_bench.py``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import traceback
from typing import Optional

from benchmarks import (
    carbon_scheduling,
    checkpoint_resume,
    comm_models,
    fig05_latency_vs_chiplets,
    fig06_energy_pkg,
    fig07_cost_pkg,
    fig08_latency_cost_scatter,
    fig09_mapping_latency,
    fig10_perfsi_chiplets,
    fig11_perfsi_cost_scatter,
    fig12_perfsi_mapping,
    fig13_cfp_vs_cost,
    pareto_frontier,
    pathfinder_batch,
    pathfinder_device,
    prefix_gather,
    roofline,
    scenario_sweep,
    serving_throughput,
    table06_sa_flows,
    table11_runtime,
)

ALL = [
    ("fig05", fig05_latency_vs_chiplets),
    ("fig06", fig06_energy_pkg),
    ("fig07", fig07_cost_pkg),
    ("fig08", fig08_latency_cost_scatter),
    ("fig09", fig09_mapping_latency),
    ("fig10", fig10_perfsi_chiplets),
    ("fig11", fig11_perfsi_cost_scatter),
    ("fig12", fig12_perfsi_mapping),
    ("fig13", fig13_cfp_vs_cost),
    ("table06", table06_sa_flows),
    ("table11", table11_runtime),
    ("roofline", roofline),
    ("pathfinder_batch", pathfinder_batch),
    ("pathfinder_device", pathfinder_device),
    ("prefix_gather", prefix_gather),
    ("pareto_frontier", pareto_frontier),
    ("scenario_sweep", scenario_sweep),
    ("comm_models", comm_models),
    ("carbon_scheduling", carbon_scheduling),
    ("checkpoint_resume", checkpoint_resume),
    ("serving_throughput", serving_throughput),
]

OUT_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "out")


def _take_flag(args, flag):
    if flag not in args:
        return None
    i = args.index(flag)
    try:
        value = args[i + 1]
    except IndexError:
        sys.exit(f"{flag} requires an argument")
    del args[i:i + 2]
    return value


def append_trajectory(path: str, rows, commit: Optional[str]) -> None:
    """Append measured rows to the committed perf trajectory, replacing
    any existing entries for the same (benchmark, commit)."""
    if commit is None:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, check=True).stdout.strip()
    doc = {"schema": 1, "entries": []}
    if os.path.exists(path):
        with open(path) as f:
            loaded = json.load(f)
        # keep only a well-formed trajectory; a foreign layout (e.g. a
        # --json snapshot's {"rows": ...}) must not leak stale top-level
        # keys into the file the bench-file CI gate validates
        if isinstance(loaded, dict) and isinstance(loaded.get("entries"),
                                                   list):
            doc["entries"] = loaded["entries"]
    names = {r["name"] for r in rows}
    doc["entries"] = [e for e in doc["entries"]
                      if not (e.get("commit") == commit
                              and e.get("benchmark") in names)]
    for r in rows:
        doc["entries"].append({
            "benchmark": r["name"], "commit": commit,
            "metrics": {"us_per_call": r["us_per_call"],
                        "derived": r["derived"]},
        })
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")


def main() -> None:
    args = sys.argv[1:]
    json_path = _take_flag(args, "--json")
    traj_path = _take_flag(args, "--trajectory")
    commit = _take_flag(args, "--commit")
    filters = [a for a in args if not a.startswith("-")]
    os.makedirs(OUT_DIR, exist_ok=True)
    print("name,us_per_call,derived")
    failures = 0
    summaries = []
    for name, mod in ALL:
        if filters and not any(f in name for f in filters):
            continue
        lines = []
        try:
            summary = mod.run(out=lines.append)
            print(summary, flush=True)
        except AssertionError as e:
            failures += 1
            summary = f"{name},0,ASSERT_FAIL:{e}"
            print(summary, flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc(file=sys.stderr)
            summary = f"{name},0,ERROR:{type(e).__name__}"
            print(summary, flush=True)
        summaries.append(summary)
        with open(os.path.join(OUT_DIR, f"{name}.csv"), "w") as f:
            f.write("\n".join(lines) + "\n")
    rows = []
    for s in summaries:
        bname, us, derived = s.split(",", 2)
        try:
            us_val = float(us)
        except ValueError:
            us_val = us  # keep the raw field rather than lose the dump
        rows.append({"name": bname, "us_per_call": us_val,
                     "derived": derived})
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"rows": rows}, f, indent=2)
        print(f"# wrote {json_path}", file=sys.stderr)
    if traj_path:
        if failures:
            print("# trajectory NOT updated: benchmark failures",
                  file=sys.stderr)
        else:
            append_trajectory(traj_path, rows, commit)
            print(f"# appended {len(rows)} entries to {traj_path}",
                  file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
