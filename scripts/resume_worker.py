#!/usr/bin/env python
"""Kill-and-resume proof for interruptible scenario sweeps.

Two entry points:

``run``
    Execute a small, fixed ScenarioSweep (2 regions x 1 workload,
    2 directions x 2 chains, 8 sweeps advanced in 2-sweep segments) and
    optionally write its final per-cell frontiers/histories to an
    ``.npz``. With ``--checkpoint-dir`` the sweep snapshots every
    segment boundary and resumes from the newest valid snapshot.
    ``--max-segments N`` hard-exits the process (code 3) right after the
    N-th snapshot — a deterministic boundary preemption used by the
    pytest variant; ``--sleep S`` sleeps after each snapshot to widen
    the window for a real SIGTERM.

``check``
    The full CI lane: run an uninterrupted reference, launch a live
    worker and SIGTERM it mid-run (after its first checkpoint appears),
    rerun the worker to resume, and assert the resumed frontiers are
    **bit-identical** to the reference. The three subprocesses share a
    JAX persistent compilation cache so only the first pays the XLA
    compile.

Usage::

    PYTHONPATH=src python scripts/resume_worker.py check
    PYTHONPATH=src python scripts/resume_worker.py run --out ref.npz
"""
from __future__ import annotations

import argparse
import glob
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

# the fixed tiny sweep: big enough for 4 boundaries, small enough for CI
KEY = 5
SEGMENT = 2
SWEEPS = 8
REGIONS = {"hydro": 0.024, "coal-heavy": 0.82}
NORM_SAMPLES = 80


def _build_sweep():
    from repro.pathfinding import ScalarizationSweep, ScenarioSweep

    return ScenarioSweep(
        strategy=ScalarizationSweep(directions=2, n_chains=2,
                                    sweeps=SWEEPS),
        regions=dict(REGIONS), norm_samples=NORM_SAMPLES)


def cmd_run(args: argparse.Namespace) -> int:
    if args.max_segments or args.sleep:
        from repro.pathfinding.resume import SearchCheckpointer

        orig_save = SearchCheckpointer.save
        state = {"saves": 0}

        def save(self, *a, **kw):
            path = orig_save(self, *a, **kw)
            state["saves"] += 1
            if args.sleep:
                time.sleep(args.sleep)
            if args.max_segments and state["saves"] >= args.max_segments:
                # hard exit: no cleanup, exactly like a preemption
                os._exit(3)
            return path

        SearchCheckpointer.save = save

    from repro.core import workload

    sweep = _build_sweep()
    sf = sweep.run(workload(1), key=KEY, segment=SEGMENT,
                   checkpoint_dir=args.checkpoint_dir)
    if args.out:
        payload = {}
        for i, s in enumerate(sf.scenarios):
            res = sf.results[s.key]
            payload[f"enc_{i}"] = res.frontier.encoded
            payload[f"vec_{i}"] = res.frontier.vectors
            payload[f"hist_{i}"] = np.asarray(res.history)
            payload[f"best_cost_{i}"] = np.float64(res.best_cost)
        np.savez(args.out, **payload)
    print(f"sweep done: {len(sf.scenarios)} cells, "
          f"{sum(len(sf.results[s.key].frontier) for s in sf.scenarios)} "
          "frontier points")
    return 0


def _finished_steps(directory: str):
    """Completed snapshot dirs only — a torn ``step_N.tmp`` from a save
    interrupted mid-write must satisfy neither the SIGTERM wait nor the
    survived-the-kill assertion (restore ignores it too)."""
    return [d for d in glob.glob(os.path.join(directory, "step_*"))
            if not d.endswith(".tmp")
            and os.path.exists(os.path.join(d, "checkpoint.json"))]


def _wait_for_checkpoint(directory: str, proc: subprocess.Popen,
                         timeout: float) -> bool:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            return False  # finished (or died) before any snapshot
        if _finished_steps(directory):
            return True
        time.sleep(0.05)
    return False


def cmd_check(args: argparse.Namespace) -> int:
    workdir = args.workdir or tempfile.mkdtemp(prefix="kill-resume-")
    os.makedirs(workdir, exist_ok=True)
    env = dict(os.environ)
    # all three subprocesses share one persistent XLA cache: only the
    # first pays the compile, and the lane doubles as a cache smoke test
    env.setdefault("JAX_COMPILATION_CACHE_DIR",
                   os.path.join(workdir, "jax-cache"))
    env.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
    me = os.path.abspath(__file__)

    def worker(*extra: str) -> subprocess.Popen:
        return subprocess.Popen([sys.executable, me, "run", *extra],
                                env=env)

    ref_npz = os.path.join(workdir, "reference.npz")
    res_npz = os.path.join(workdir, "resumed.npz")
    ckpt = os.path.join(workdir, "ckpt")

    print("[1/4] uninterrupted reference run", flush=True)
    assert worker("--out", ref_npz).wait() == 0, "reference run failed"

    print("[2/4] live run + SIGTERM after first checkpoint", flush=True)
    killed = False
    for attempt, sleep_s in enumerate((1.0, 3.0), 1):
        # a fresh directory per attempt: stale snapshots from an attempt
        # that finished before its SIGTERM must not satisfy the wait (the
        # lane would then "resume" a completed run and prove nothing)
        shutil.rmtree(ckpt, ignore_errors=True)
        proc = worker("--checkpoint-dir", ckpt, "--sleep", str(sleep_s))
        if _wait_for_checkpoint(ckpt, proc, timeout=args.timeout):
            proc.send_signal(signal.SIGTERM)
            rc = proc.wait()
            print(f"    SIGTERM delivered (attempt {attempt}), "
                  f"worker exit code {rc}", flush=True)
            assert rc != 0, "worker survived SIGTERM?"
            killed = True
            break
        proc.wait()
        print(f"    attempt {attempt}: run finished before SIGTERM "
              "window; widening sleep", flush=True)
    assert killed, "could not interrupt the worker mid-run"
    steps = _finished_steps(ckpt)
    assert steps, "no checkpoint survived the kill"
    print(f"    checkpoints on disk: {sorted(os.path.basename(s) for s in steps)}",
          flush=True)

    print("[3/4] resume from newest valid checkpoint", flush=True)
    assert worker("--checkpoint-dir", ckpt,
                  "--out", res_npz).wait() == 0, "resume failed"

    print("[4/4] bit-identical frontier comparison", flush=True)
    a, b = np.load(ref_npz), np.load(res_npz)
    assert set(a.files) == set(b.files), (a.files, b.files)
    for k in sorted(a.files):
        if not np.array_equal(a[k], b[k]):
            print(f"MISMATCH in {k}:\nref={a[k]!r}\nres={b[k]!r}")
            return 1
    print(f"kill-and-resume OK: {len(a.files)} arrays bit-identical "
          f"(workdir {workdir})")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    run = sub.add_parser("run", help="one sweep invocation")
    run.add_argument("--checkpoint-dir", default=None)
    run.add_argument("--out", default=None)
    run.add_argument("--max-segments", type=int, default=0)
    run.add_argument("--sleep", type=float, default=0.0)
    chk = sub.add_parser("check", help="full kill-and-resume proof")
    chk.add_argument("--workdir", default=None)
    chk.add_argument("--timeout", type=float, default=900.0,
                     help="max seconds to wait for the first checkpoint")
    args = ap.parse_args()
    return cmd_run(args) if args.cmd == "run" else cmd_check(args)


if __name__ == "__main__":
    sys.exit(main())
