#!/usr/bin/env python
"""Serving smoke proof: kill-and-resume a whole pathfinding service.

Two entry points:

``run``
    Start a :class:`~repro.serving.PathfinderService` over a fixed
    2-workload catalog, submit six mixed jobs spanning two bucket
    shapes (swap cadences 5 and 3 at four chains each), drain inline,
    and optionally write every job's history/best/frontier to an
    ``.npz``. With ``--checkpoint-root`` each job snapshots at every
    segment boundary and a rerun resumes all of them from their newest
    snapshots. ``--solo`` runs ONE job in a fresh single-job service
    (the bit-identity reference); ``--mode solo`` does that for the
    whole job table sequentially. ``--max-segments N`` hard-exits the
    process (code 3) right after the N-th snapshot; ``--sleep S``
    sleeps after each snapshot to widen the window for a real SIGTERM.

``check``
    The full CI lane: solo uninterrupted references for all six jobs,
    a live multiplexed service SIGTERMed mid-flight, a restarted
    service that resumes every job, and a final assertion that each
    resumed job is **bit-identical** to its solo reference — packing,
    preemption and restart are all invisible to a job's trajectory.
    All subprocesses share a JAX persistent compilation cache so only
    the first pays the XLA compile.

Usage::

    PYTHONPATH=src python scripts/serve_pathfinder.py check
    PYTHONPATH=src python scripts/serve_pathfinder.py run --out ref.npz
"""
from __future__ import annotations

import argparse
import glob
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

# the fixed job table: big enough for contention (6 jobs, 4 slots) and
# several boundaries per job, small enough for CI
KEY = 5
SLOTS = 4
SEGMENT = 2
SWEEPS = 8
NORM_SAMPLES = 80
#          job id        workload  carbon    swap_every
JOBS = [("wl1-mid", 0, 0.475, 5),
        ("wl1-hydro", 0, 0.024, 5),
        ("wl6-coal", 1, 0.82, 5),
        ("wl6-mid", 1, 0.475, 3),
        ("wl1-coal", 0, 0.82, 3),
        ("wl6-hydro", 1, 0.024, 3)]


def _workloads():
    from repro.core import workload

    return [workload(1), workload(6)]


def _spec(job_id: str, widx: int, ci: float, swap: int):
    from repro.pathfinding import ScalarizationSweep
    from repro.serving import JobSpec

    return JobSpec(
        job_id=job_id, workload=_workloads()[widx].name,
        strategy=ScalarizationSweep(directions=2, n_chains=2,
                                    sweeps=SWEEPS, swap_every=swap),
        carbon_intensity=ci)


def _service(checkpoint_root=None):
    from repro.serving import PathfinderService

    return PathfinderService(
        _workloads(), slots=SLOTS, segment=SEGMENT,
        norm_samples=NORM_SAMPLES, key=KEY,
        checkpoint_root=checkpoint_root)


def _collect(svc, jobs, payload):
    for job_id, *_ in jobs:
        res = svc.result(job_id)
        payload[f"enc_{job_id}"] = res.frontier.encoded
        payload[f"vec_{job_id}"] = res.frontier.vectors
        payload[f"hist_{job_id}"] = np.asarray(res.history)
        payload[f"best_cost_{job_id}"] = np.float64(res.best_cost)
        payload[f"best_enc_{job_id}"] = res.best_enc
        payload[f"sweeps_{job_id}"] = np.int64(res.sweeps)


def cmd_run(args: argparse.Namespace) -> int:
    if args.max_segments or args.sleep:
        from repro.pathfinding.resume import SearchCheckpointer

        orig_save = SearchCheckpointer.save
        state = {"saves": 0}

        def save(self, *a, **kw):
            path = orig_save(self, *a, **kw)
            state["saves"] += 1
            if args.sleep:
                time.sleep(args.sleep)
            if args.max_segments and state["saves"] >= args.max_segments:
                # hard exit: no cleanup, exactly like a preemption
                os._exit(3)
            return path

        SearchCheckpointer.save = save

    jobs = JOBS
    if args.solo:
        jobs = [j for j in JOBS if j[0] == args.solo]
        assert jobs, f"unknown job {args.solo!r}"
    payload = {}
    if args.mode == "solo":
        # one fresh single-job service per job: the reference runs that
        # multiplexed/preempted/restarted jobs must match bit for bit
        for job in jobs:
            svc = _service()
            svc.submit(_spec(*job))
            svc.drain()
            _collect(svc, [job], payload)
    else:
        svc = _service(checkpoint_root=args.checkpoint_root)
        for job in jobs:
            svc.submit(_spec(*job))
        svc.drain()
        _collect(svc, jobs, payload)
    if args.out:
        np.savez(args.out, **payload)
    n_pts = sum(len(payload[f"enc_{j}"]) for j, *_ in jobs)
    print(f"service drained: {len(jobs)} jobs, "
          f"{n_pts} frontier points")
    return 0


def _finished_steps(root: str):
    """Completed snapshot dirs across all job subdirectories — torn
    ``step_N.tmp`` dirs from a save interrupted mid-write count for
    nothing (restore ignores them too)."""
    return [d for d in glob.glob(os.path.join(root, "*", "step_*"))
            if not d.endswith(".tmp")
            and os.path.exists(os.path.join(d, "checkpoint.json"))]


def _wait_for_checkpoint(root: str, proc: subprocess.Popen,
                         timeout: float) -> bool:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            return False  # finished (or died) before any snapshot
        if _finished_steps(root):
            return True
        time.sleep(0.05)
    return False


def cmd_check(args: argparse.Namespace) -> int:
    workdir = args.workdir or tempfile.mkdtemp(prefix="serve-smoke-")
    os.makedirs(workdir, exist_ok=True)
    env = dict(os.environ)
    # every subprocess shares one persistent XLA cache: only the first
    # pays the compile for the two bucket shapes
    env.setdefault("JAX_COMPILATION_CACHE_DIR",
                   os.path.join(workdir, "jax-cache"))
    env.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
    me = os.path.abspath(__file__)

    def worker(*extra: str) -> subprocess.Popen:
        return subprocess.Popen([sys.executable, me, "run", *extra],
                                env=env)

    ref_npz = os.path.join(workdir, "reference.npz")
    res_npz = os.path.join(workdir, "resumed.npz")
    ckpt = os.path.join(workdir, "ckpt")

    print("[1/4] solo uninterrupted reference runs", flush=True)
    assert worker("--mode", "solo",
                  "--out", ref_npz).wait() == 0, "reference runs failed"

    print("[2/4] multiplexed service + SIGTERM mid-flight", flush=True)
    killed = False
    for attempt, sleep_s in enumerate((1.0, 3.0), 1):
        # fresh checkpoint root per attempt: stale snapshots from an
        # attempt that drained before its SIGTERM must not satisfy the
        # wait (the lane would then "resume" finished jobs and prove
        # nothing)
        shutil.rmtree(ckpt, ignore_errors=True)
        proc = worker("--checkpoint-root", ckpt, "--sleep", str(sleep_s))
        if _wait_for_checkpoint(ckpt, proc, timeout=args.timeout):
            proc.send_signal(signal.SIGTERM)
            rc = proc.wait()
            print(f"    SIGTERM delivered (attempt {attempt}), "
                  f"service exit code {rc}", flush=True)
            assert rc != 0, "service survived SIGTERM?"
            killed = True
            break
        proc.wait()
        print(f"    attempt {attempt}: service drained before SIGTERM "
              "window; widening sleep", flush=True)
    assert killed, "could not interrupt the service mid-flight"
    steps = _finished_steps(ckpt)
    assert steps, "no checkpoint survived the kill"
    by_job = sorted({os.path.basename(os.path.dirname(s)) for s in steps})
    print(f"    jobs with snapshots on disk: {by_job}", flush=True)

    print("[3/4] restart service, resume all jobs", flush=True)
    assert worker("--checkpoint-root", ckpt,
                  "--out", res_npz).wait() == 0, "restarted service failed"

    print("[4/4] bit-identical comparison against solo references",
          flush=True)
    a, b = np.load(ref_npz), np.load(res_npz)
    assert set(a.files) == set(b.files), (a.files, b.files)
    for k in sorted(a.files):
        if not np.array_equal(a[k], b[k]):
            print(f"MISMATCH in {k}:\nref={a[k]!r}\nres={b[k]!r}")
            return 1
    print(f"serving kill-and-resume OK: {len(JOBS)} jobs, "
          f"{len(a.files)} arrays bit-identical (workdir {workdir})")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    run = sub.add_parser("run", help="one service process")
    run.add_argument("--mode", choices=("service", "solo"),
                     default="service")
    run.add_argument("--solo", default=None, metavar="JOB_ID",
                     help="restrict to one job from the table")
    run.add_argument("--checkpoint-root", default=None)
    run.add_argument("--out", default=None)
    run.add_argument("--max-segments", type=int, default=0)
    run.add_argument("--sleep", type=float, default=0.0)
    chk = sub.add_parser("check", help="full serving kill-and-resume proof")
    chk.add_argument("--workdir", default=None)
    chk.add_argument("--timeout", type=float, default=900.0,
                     help="max seconds to wait for the first checkpoint")
    args = ap.parse_args()
    return cmd_run(args) if args.cmd == "run" else cmd_check(args)


if __name__ == "__main__":
    sys.exit(main())
