"""Serving example: batched prefill + greedy decode on the sharded cache.

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b   # O(1) state
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "smollm-135m"]
    if "--reduced" not in argv:
        argv.append("--reduced")
    raise SystemExit(main(argv))
