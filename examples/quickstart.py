"""Quickstart: CarbonPATH's public API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Evaluate one HI system's PPAC + CFP on a paper workload.
2. Anneal a carbon-aware design for the same workload (fast schedule).
"""
from repro.core import (
    HISystem, Mapping, SAConfig, SimCache, TEMPLATES,
    anneal, evaluate, fit_normalizer, workload,
)
from repro.core.chiplet import different_chiplet_system

wl = workload(1)                       # GPT-2 MLP GEMM (512 x 768 x 3072)

# -- 1. evaluate a hand-picked system --------------------------------------
sys = HISystem(
    chiplets=different_chiplet_system(),          # 64/96/128/192 @ 7nm
    style="2.5D", memory="DDR5",
    mapping=Mapping.parse("1-OS-0"),              # order-dataflow-splitK
    pkg_25d="RDL", proto_25d="UCIe-S",
)
m = evaluate(sys, wl)
print(f"[evaluate] {sys.describe()}  mapping={sys.mapping.name}")
print(f"  latency {m.latency_s*1e6:8.2f} us   energy {m.energy_j*1e3:6.3f} mJ")
print(f"  area    {m.area_mm2:8.1f} mm2  cost   {m.dollar:6.2f} $")
print(f"  CFP     {m.emb_cfp_kg:.2f} kg embodied + {m.ope_cfp_kg:.2f} kg "
      f"operational   Perf-SI {m.perf_si:.3e}")

# -- 2. let the SA engine design one (carbon-aware template T1) ------------
cache = SimCache()
norm = fit_normalizer(wl, samples=1500, cache=cache)
cfg = SAConfig(t_initial=400, t_final=0.01, cooling=0.93, moves_per_temp=25)
res = anneal(wl, TEMPLATES["T1"], config=cfg, norm=norm, cache=cache)
b = res.best
print(f"\n[anneal T1] best system after {res.evaluations} evaluations:")
print(f"  {b.describe()}  chiplets={[c.name for c in b.chiplets]} "
      f"mapping={b.mapping.name}")
print(f"  latency {res.best_metrics.latency_s*1e6:.2f} us  "
      f"CFP {res.best_metrics.total_cfp:.2f} kg  "
      f"cost {res.best_metrics.dollar:.2f} $")
print(f"  sim-cache: {cache.hits} hits / {cache.misses} misses")
