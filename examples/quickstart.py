"""Quickstart: CarbonPATH's public API (Pathfinder v2) in ~50 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Evaluate one HI system's PPAC + CFP on a paper workload.
2. Evaluate a whole random population at once (encoded design space).
3. Let the SA engine design a carbon-aware system via the Pathfinder
   facade (fast schedule), then cross-check with parallel tempering.

Migration note: the seed entry points ``anneal(...)`` / ``fit_normalizer``
still work as deprecation shims; new code should use
``Pathfinder(wl, template).search(strategy=...)``.
"""
from repro.core import HISystem, Mapping, SAConfig, TEMPLATES, evaluate, workload
from repro.core.chiplet import different_chiplet_system
from repro.core.regions import Region, measured_profile
from repro.pathfinding import (
    DesignSpace,
    ParallelTempering,
    Pathfinder,
    ScalarizationSweep,
    ScenarioSpec,
    SimulatedAnnealing,
    evaluate_batch,
)

wl = workload(1)                       # GPT-2 MLP GEMM (512 x 768 x 3072)

# -- 1. evaluate a hand-picked system --------------------------------------
sys = HISystem(
    chiplets=different_chiplet_system(),          # 64/96/128/192 @ 7nm
    style="2.5D", memory="DDR5",
    mapping=Mapping.parse("1-OS-0"),              # order-dataflow-splitK
    pkg_25d="RDL", proto_25d="UCIe-S",
)
m = evaluate(sys, wl)
print(f"[evaluate] {sys.describe()}  mapping={sys.mapping.name}")
print(f"  latency {m.latency_s*1e6:8.2f} us   energy {m.energy_j*1e3:6.3f} mJ")
print(f"  area    {m.area_mm2:8.1f} mm2  cost   {m.dollar:6.2f} $")
print(f"  CFP     {m.emb_cfp_kg:.2f} kg embodied + {m.ope_cfp_kg:.2f} kg "
      f"operational   Perf-SI {m.perf_si:.3e}")

# -- 2. batched evaluation over the encoded design space -------------------
space = DesignSpace()
pop = space.sample(4096, key=0)                   # valid by construction
mb = evaluate_batch(pop, wl)
best = int(mb.total_cfp.argmin())
print(f"\n[evaluate_batch] {len(mb)} systems in one call; lowest-CFP draw: "
      f"{space.decode(pop[best]).describe()} "
      f"({mb.total_cfp[best]:.2f} kg, {mb.latency_s[best]*1e6:.1f} us)")

# -- 3. let the SA engine design one (carbon-aware template T1) ------------
pf = Pathfinder(wl, TEMPLATES["T1"])
pf.fit_normalizer(samples=2000, seed=1)           # batched min/median fit
cfg = SAConfig(t_initial=400, t_final=0.01, cooling=0.93, moves_per_temp=25)
res = pf.search(strategy=SimulatedAnnealing(cfg))
b = res.best
print(f"\n[anneal T1] best system after {res.evaluations} evaluations:")
print(f"  {b.describe()}  chiplets={[c.name for c in b.chiplets]} "
      f"mapping={b.mapping.name}")
print(f"  latency {res.best_metrics.latency_s*1e6:.2f} us  "
      f"CFP {res.best_metrics.total_cfp:.2f} kg  "
      f"cost {res.best_metrics.dollar:.2f} $")

# -- 4. same objective, batched parallel-tempering strategy ----------------
res_pt = pf.search(strategy=ParallelTempering(n_chains=8, sweeps=120), key=0)
print(f"\n[tempering] best of {res_pt.evaluations} batched evaluations: "
      f"{res_pt.best.describe()}  cost {res_pt.best_cost:.3f} "
      f"(SA found {res.best_cost:.3f})")

# -- 5. deployment scenarios as one value: ScenarioSpec --------------------
# Regions carry measured 24h grid traces (ElectricityMaps-style) and
# schedule="window" makes *when to run* a searched axis: every design
# also picks a start hour + duty-window shape against its region's
# trace, concentrating the same lifetime energy into low-carbon hours.
spec = ScenarioSpec(
    workloads=(wl,),
    regions={
        "hydro": Region(carbon_intensity=0.024,
                        grid_profile=measured_profile("hydro")),
        "solar-heavy": Region(carbon_intensity=0.31,
                              grid_profile=measured_profile("solar-heavy")),
    },
    schedule="window", budget=2000)
from repro.pathfinding import ScenarioSweep

sf = ScenarioSweep(strategy=ScalarizationSweep(
    directions=2, n_chains=2, sweeps=40)).run(spec, key=0)
print("\n[scenarios] operational CFP with the schedule axis searched:")
for s in sf.scenarios:
    best = sf.results[s.key].best
    mm = sf.results[s.key].best_metrics
    when = ("always-on" if not best.schedule or best.schedule[1] == 0
            else f"start {best.schedule[0]:2d}h shape {best.schedule[1]}")
    print(f"  {s.region:12s} {when}  ope {mm.ope_cfp_kg:.3f} kg  "
          f"total {mm.total_cfp:.2f} kg")
