"""Beyond-paper: CarbonPATH's methodology applied to TPU-pod planning.

    PYTHONPATH=src python examples/carbon_pathfinder.py

Anneals (chip count, TP width, microbatch, remat, int8 gradient
compression) for three assigned architectures under two objectives —
pure speed vs carbon-weighted — and prints how the chosen plan shifts,
mirroring the paper's T1-vs-T3 template analysis at pod scale.

For the paper's own chiplet design space, use the Pathfinder v2 API
instead (``repro.pathfinding.Pathfinder`` + a search strategy — see
examples/quickstart.py); this example keeps its bespoke pod-level
annealer because its design vector is not an HI system.
"""
from repro.analysis.tpu_pathfinder import pathfind
from repro.configs import get_config

for arch in ("smollm-135m", "qwen3-8b", "deepseek-v2-236b"):
    cfg = get_config(arch)
    fast, m_fast = pathfind(cfg, global_batch=256, seq=4096,
                            carbon_weight=0.0, seed=1)
    green, m_green = pathfind(cfg, global_batch=256, seq=4096,
                              carbon_weight=0.9, seed=1)
    print(f"\n{arch}:")
    print(f"  speed-first : {fast.describe()}")
    print(f"     step {m_fast.step_time_s*1e3:8.2f} ms   "
          f"CFP/step {m_fast.total_cfp*1e3:.3f} g")
    print(f"  carbon-aware: {green.describe()}")
    print(f"     step {m_green.step_time_s*1e3:8.2f} ms   "
          f"CFP/step {m_green.total_cfp*1e3:.3f} g")
    if m_green.total_cfp < m_fast.total_cfp:
        saved = (1 - m_green.total_cfp / m_fast.total_cfp) * 100
        slower = (m_green.step_time_s / m_fast.step_time_s - 1) * 100
        print(f"  -> {saved:.0f}% CFP saved for {slower:.0f}% slower steps")
