"""End-to-end training example: fault-tolerant sharded LM training.

    PYTHONPATH=src python examples/train_lm.py             # CPU-reduced
    PYTHONPATH=src python examples/train_lm.py --full      # real scale

Drives launch/train.py: deterministic pipeline, remat'd sharded
train_step, AdamW, checkpoints, failure injection (2% of steps fault and
restart from the last checkpoint — the loss curve is identical to a
fault-free run), straggler monitoring.
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    full = "--full" in sys.argv
    args = [
        "--arch", "smollm-135m",
        "--steps", "200" if full else "120",
        "--batch", "16" if full else "8",
        "--seq", "512" if full else "128",
        "--fail-rate", "0.02",
        "--ckpt-every", "20",
        "--ckpt-dir", "/tmp/repro_example_ckpt",
    ]
    if not full:
        args.append("--reduced")
    raise SystemExit(main(args))
