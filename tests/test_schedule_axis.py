"""Carbon-aware temporal-scheduling tests: the windowed effective
intensity vs a direct convolution reference (property-based), exact
neutrality of the (0, 0) schedule, scalar-vs-device parity of the
window model, bit-identity of legacy replay through the env-forced
window program, compile-count flatness across schedule mixes, and the
host-side schedule move/seeding satellites."""
import dataclasses
import random

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import schedule as sched_mod
from repro.core import workload
from repro.core.carbon import effective_intensity, effective_price
from repro.core.evaluate import evaluate
from repro.core.regions import measured_profile
from repro.core.sa import propose, random_system, seed_schedule
from repro.core.scalesim import SimCache
from repro.core.system import is_valid
from repro.core.techdb import DEFAULT_DB, HOURS_PER_DAY
from repro.core.templates import METRIC_FIELDS
from repro.pathfinding import DesignSpace, get_device_evaluator
from repro.pathfinding.device import get_scenario_engine, trace_count

WL = workload(1)
PARITY_FIELDS = METRIC_FIELDS + (
    "l_compute_rd_s", "l_d2d_s", "l_dram_wr_s", "e_compute_j", "e_d2d_j",
    "d2d_bits", "macs")

# a db whose grid *and* price curves are non-flat, so the schedule axis
# actually moves both operational metrics
PRICE_CURVE = tuple(0.05 + 0.03 * np.sin(2 * np.pi * h / HOURS_PER_DAY)
                    for h in range(HOURS_PER_DAY))
PROFILED_DB = dataclasses.replace(
    DEFAULT_DB, electricity_price=0.07,
    grid_profile=measured_profile("solar-heavy"),
    price_profile=PRICE_CURVE)


# ---------------------------------------------------------------------------
# Shape-table structure + the windowed-intensity convolution property
# ---------------------------------------------------------------------------


def test_schedule_tables_structure():
    """Row 0 *is* the per-db load profile (the neutral gather), every
    row sums to 1, window rows carry exactly their duty-hour count."""
    tab = sched_mod.schedule_tables(DEFAULT_DB)
    assert tab.shape == (sched_mod.n_schedule_shapes(), HOURS_PER_DAY)
    assert tuple(tab[0]) == tuple(
        float(x) for x in DEFAULT_DB.load_profile)
    for r, row_ in enumerate(tab):
        assert float(np.sum(row_)) == pytest.approx(1.0, abs=1e-12), r
    for hours, row_ in zip(sched_mod.SCHEDULE_WINDOW_HOURS, tab[1:]):
        assert np.count_nonzero(row_) == hours
        assert float(row_.max()) == pytest.approx(1.0 / hours)


def test_validate_schedule_errors():
    with pytest.raises(ValueError, match="start hour"):
        sched_mod.validate_schedule((HOURS_PER_DAY, 0))
    with pytest.raises(ValueError, match="shape index"):
        sched_mod.validate_schedule((0, sched_mod.n_schedule_shapes()))
    with pytest.raises(ValueError, match="entries"):
        sched_mod.validate_schedule((1, 2, 3))


@settings(max_examples=200, deadline=None)
@given(st.floats(min_value=0.01, max_value=2.0),
       st.lists(st.floats(min_value=0.0, max_value=2.0),
                min_size=HOURS_PER_DAY, max_size=HOURS_PER_DAY),
       st.integers(min_value=0, max_value=HOURS_PER_DAY - 1),
       st.integers(min_value=0,
                   max_value=sched_mod.n_schedule_shapes() - 1))
def test_windowed_intensity_matches_direct_convolution(
        ci, profile, start, shape):
    """Property: the windowed effective intensity equals the direct
    convolution reference — the shape row rolled to the start hour,
    dotted against the 24h profile (plus the base-intensity remainder
    of any load mass the roll can't move)."""
    load = sched_mod.schedule_load_row((start, shape), DEFAULT_DB)
    ref_load = np.roll(sched_mod.schedule_tables(DEFAULT_DB)[shape],
                       start)
    assert load == tuple(ref_load)        # the roll identity, exact
    got = effective_intensity(ci, tuple(profile), load)
    direct = float(np.dot(profile, ref_load)) \
        + ci * (1.0 - float(np.sum(ref_load)))
    assert got == pytest.approx(direct, rel=1e-9, abs=1e-9)
    # the price twin shares the formulation verbatim
    assert effective_price(ci, tuple(profile), load) == got


@settings(max_examples=100, deadline=None)
@given(st.floats(min_value=0.0, max_value=2.0),
       st.integers(min_value=0, max_value=HOURS_PER_DAY - 1),
       st.integers(min_value=0,
                   max_value=sched_mod.n_schedule_shapes() - 1))
def test_flat_profile_neutral_under_any_schedule(ci, start, shape):
    """A flat grid curve contributes exactly +0.0 no matter *when* the
    design runs: every (profile[h] - ci) term is exactly zero."""
    load = sched_mod.schedule_load_row((start, shape), DEFAULT_DB)
    assert effective_intensity(ci, (ci,) * HOURS_PER_DAY, load) == ci


# ---------------------------------------------------------------------------
# Exact neutrality of the (0, 0) schedule + scalar-vs-device parity
# ---------------------------------------------------------------------------


def test_neutral_schedule_is_bit_invisible():
    """A system pinned at the neutral (0, 0) schedule evaluates
    bit-identically to the same system with no schedule at all — under
    the default db *and* a db with non-flat grid/price curves. This is
    the invariant that lets the forced window program replay every
    legacy golden."""
    rng = random.Random(9)
    for db in (DEFAULT_DB, PROFILED_DB):
        cache = SimCache()
        for _ in range(12):
            sys = random_system(rng)
            neutral = dataclasses.replace(
                sys, schedule=sched_mod.SCHED_NEUTRAL)
            a = evaluate(sys, WL, db, cache=cache)
            b = evaluate(neutral, WL, db, cache=cache)
            for f in PARITY_FIELDS:
                assert getattr(a, f) == getattr(b, f), f
    assert sched_mod.schedule_load_row(sched_mod.SCHED_NEUTRAL) == tuple(
        float(x) for x in DEFAULT_DB.load_profile)


def _scheduled_systems(count: int, seed: int):
    rng = random.Random(seed)
    out = []
    for _ in range(count):
        sys = random_system(rng)
        out.append(dataclasses.replace(sys, schedule=(
            rng.randrange(HOURS_PER_DAY),
            rng.randrange(sched_mod.n_schedule_shapes()))))
    return out


def test_schedule_scalar_device_parity_240():
    """The fused device program under ``schedule="window"`` matches
    scalar ``evaluate`` within 1e-6 relative on every metric over >= 200
    random schedule-carrying systems (2.5D and 3D styles both present),
    with non-flat grid *and* price curves in play."""
    systems = _scheduled_systems(240, 20260808)
    styles = {s.style for s in systems}
    assert {"2.5D", "3D"} <= styles, f"population too narrow: {styles}"
    space = DesignSpace(PROFILED_DB, schedule="window")
    assert space.sched_live
    dev = get_device_evaluator(WL, PROFILED_DB, space=space)
    mb = dev.metrics(space.encode_many(systems))
    cache = SimCache()
    for i, sys in enumerate(systems):
        m = evaluate(sys, WL, PROFILED_DB, cache=cache)
        for f in PARITY_FIELDS:
            ref = getattr(m, f)
            got = float(getattr(mb, f)[i])
            assert got == pytest.approx(ref, rel=1e-6, abs=1e-300), (
                f"{sys.describe()} schedule={sys.schedule} field {f}: "
                f"scalar {ref} device {got}")


# ---------------------------------------------------------------------------
# Env-forced window program: legacy replay bit-identity + compile flatness
# ---------------------------------------------------------------------------


def _scenario_args(space, S, n):
    v0 = np.stack([space.sample(n, 10 + s) for s in range(S)])
    return v0, dict(
        temps=np.tile(np.geomspace(2.0, 0.01, n), (S, 1)),
        sweeps=16, swap_every=2, seed=3, mins=np.zeros((S, 6)),
        medians=np.ones((S, 6)),
        weights=np.tile(np.ones(6) / 6, (S, n, 1)),
        pair_mask=np.ones((S, n - 1), bool), ci=np.full(S, 0.475),
        widx=np.zeros(S, np.int32))


@pytest.mark.slow
def test_env_forced_window_replays_legacy_bits(monkeypatch):
    """``REPRO_SCHEDULE=window`` reroutes default DesignSpaces through
    the windowed program with the schedule axes frozen at the neutral
    (0, 0); the fused scenario trajectory must stay bit-identical to
    the fixed-schedule run."""
    S, n = 2, 6
    legacy = DesignSpace(DEFAULT_DB, schedule="fixed")
    v0, kw = _scenario_args(legacy, S, n)
    eng_l = get_scenario_engine((WL,), DEFAULT_DB, space=legacy)
    r_l = eng_l.parallel_tempering(v0, **kw)

    monkeypatch.setenv(sched_mod.SCHEDULE_ENV_VAR, "window")
    forced = DesignSpace(DEFAULT_DB)
    assert forced.schedule == "window" and not forced.sched_live
    v0_f, kw_f = _scenario_args(forced, S, n)
    # same systems, wider rows: the legacy columns must round-trip
    assert np.array_equal(v0_f[:, :, :legacy.width], v0)
    eng_f = get_scenario_engine((WL,), DEFAULT_DB, space=forced)
    r_f = eng_f.parallel_tempering(v0_f, **kw_f)

    assert np.array_equal(r_f.best_cost, r_l.best_cost)
    assert np.array_equal(r_f.history, r_l.history)
    assert np.array_equal(r_f.best_enc[:, :legacy.width], r_l.best_enc)


@pytest.mark.slow
def test_schedule_shapes_are_data_not_shape():
    """One fused compile serves every (start hour, duty shape) mix:
    re-running the scenario grid with different encoded schedule axes
    and a different per-cell ``sched_on`` mask must not retrace."""
    S, n = 2, 6
    space = DesignSpace(DEFAULT_DB, schedule="window")
    eng = get_scenario_engine((WL,), DEFAULT_DB, space=space)
    v0, kw = _scenario_args(space, S, n)
    eng.parallel_tempering(v0, **kw)
    c_pt, c_init = trace_count("scenario_pt"), trace_count("scenario_init")

    # move every design to a different start hour and duty shape and
    # flip one cell's move gate: runtime data only
    v1 = v0.copy()
    sc = space.sched_col
    v1[..., sc] = (v1[..., sc] + 5) % HOURS_PER_DAY
    v1[..., sc + 1] = (v1[..., sc + 1] + 1) % sched_mod.n_schedule_shapes()
    r1 = eng.parallel_tempering(v1, sched_on=np.array([1.0, 0.0]), **kw)
    assert trace_count("scenario_pt") == c_pt
    assert trace_count("scenario_init") == c_init
    assert np.isfinite(r1.best_cost).all()


# ---------------------------------------------------------------------------
# Host-side satellites: seeding, schedule moves, spec validation
# ---------------------------------------------------------------------------


def test_seed_schedule_and_schedule_moves():
    rng = random.Random(11)
    sys = seed_schedule(random_system(rng))
    assert sys.schedule == sched_mod.SCHED_NEUTRAL
    assert seed_schedule(sys) is sys     # idempotent
    moved = 0
    cur = sys
    for _ in range(200):
        cand = propose(cur, rng, DEFAULT_DB, schedule_moves=True)
        assert is_valid(cand, DEFAULT_DB)
        sched_mod.validate_schedule(cand.schedule)
        if cand.schedule != cur.schedule:
            moved += 1
        cur = cand
    assert moved > 0, "schedule move level never fired in 200 proposals"


def test_propose_without_schedule_moves_stays_fixed():
    rng = random.Random(12)
    cur = random_system(rng)
    for _ in range(50):
        cur = propose(cur, rng, DEFAULT_DB)
        assert cur.schedule is None


def test_jobspec_schedule_validation():
    from repro.serving.jobs import JobSpec

    spec = JobSpec(job_id="j", workload="w", schedule="window")
    assert spec.bucket_key()[-1] == "window"
    fixed = JobSpec(job_id="j", workload="w")
    # fixed-schedule jobs keep the exact legacy bucket key
    assert len(fixed.bucket_key()) == 3
    assert fixed.bucket_key()[-1] == "legacy"
    with pytest.raises(ValueError, match="unknown schedule model"):
        JobSpec(job_id="j", workload="w", schedule="nightly")
