"""Unit + property tests for the CarbonPATH analytical models."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import DEFAULT_DB, Chiplet, HISystem, Mapping, library
from repro.core import validate, InvalidSystem
from repro.core import workload, tile_and_assign, all_pkg_protocol_pairs
from repro.core import evaluate
from repro.core.chiplet import different_chiplet_system, identical_chiplet_system
from repro.core import scalesim
from repro.core.workload import Tile, destination_index, ALL_MAPPINGS
from repro.core import d2d as d2d_mod
from repro.core import floorplan as fp
from repro.core import cost as cost_mod
from repro.core import carbon as carbon_mod

DB = DEFAULT_DB


# ---------------------------------------------------------------------------
# techdb / design space
# ---------------------------------------------------------------------------

def test_43_pkg_protocol_pairs():
    assert all_pkg_protocol_pairs() == 43  # Sec V-A: 10 + 3 + 30


def test_12_mapping_strategies():
    assert len(ALL_MAPPINGS) == 12  # 2 orders x 3 dataflows x 2 split-K


def test_chiplet_library_size():
    # 4 array sizes x 5 nodes x 4 SRAM options = 80 chiplets
    assert len(library()) == 80


def test_yield_monotone_in_area():
    ys = [DB.die_yield(a, 7) for a in (1, 10, 50, 200, 600)]
    assert all(a > b for a, b in zip(ys, ys[1:]))
    assert all(0 < y <= 1 for y in ys)


def test_yield_better_at_older_nodes():
    assert DB.die_yield(100, 28) > DB.die_yield(100, 7)


def test_dies_per_wafer_decreasing():
    assert DB.dies_per_wafer(10) > DB.dies_per_wafer(100)


@given(st.floats(0.5, 800.0))
@settings(max_examples=50, deadline=None)
def test_yield_bounds_property(area):
    for node in DB.tech_nodes:
        y = DB.die_yield(area, node)
        assert 0.0 < y <= 1.0


# ---------------------------------------------------------------------------
# chiplet physical model
# ---------------------------------------------------------------------------

def test_area_power_scale_with_node():
    new = Chiplet(128, 7, 1024)
    old = Chiplet(128, 28, 1024)
    assert old.area_mm2() > new.area_mm2()
    assert old.freq_ghz() < new.freq_ghz()


def test_notation_roundtrip():
    c = Chiplet(96, 14, 1536)
    assert Chiplet.parse(c.name) == c


# ---------------------------------------------------------------------------
# Algorithm 1: tiler / assigner
# ---------------------------------------------------------------------------

@given(st.sampled_from([1, 2, 3, 4, 5, 6]),
       st.sampled_from(ALL_MAPPINGS))
@settings(max_examples=40, deadline=None)
def test_tiler_covers_workload(wl_idx, mapping):
    """Property: assigned tile MACs sum exactly to the workload MACs."""
    wl = workload(wl_idx)
    cores = different_chiplet_system()
    assignments = tile_and_assign(wl, cores, mapping)
    assert sum(a.macs for a in assignments) == wl.macs
    # every m/k/n within bounds
    for a in assignments:
        for t in a.tiles:
            assert 0 < t.m <= wl.M and 0 < t.k <= wl.K and 0 < t.n <= wl.N


def test_split_k_partitions_k():
    wl = workload(5)  # K = 4096
    cores = different_chiplet_system()
    on = tile_and_assign(wl, cores, Mapping(0, "OS", 1))
    off = tile_and_assign(wl, cores, Mapping(0, "OS", 0))
    assert any(t.partial for a in on for t in a.tiles)
    assert not any(t.partial for a in off for t in a.tiles)
    assert all(t.k == wl.K for a in off for t in a.tiles)


def test_assignment_proportional_to_power():
    wl = workload(2)  # big enough for many tiles
    cores = different_chiplet_system()
    assignments = tile_and_assign(wl, cores, Mapping(0, "OS", 0))
    powers = [c.compute_power_ratio() for c in cores]
    total_tiles = sum(len(a.tiles) for a in assignments)
    for a, p in zip(assignments, powers):
        ideal = p / sum(powers) * total_tiles
        assert abs(len(a.tiles) - ideal) <= 1.0, "within rounding of ideal"


def test_destination_is_largest():
    cores = different_chiplet_system()
    assert destination_index(cores) == 3  # 192-7-2048


# ---------------------------------------------------------------------------
# ScaleSim-equivalent timing model
# ---------------------------------------------------------------------------

def test_dataflow_shape_sensitivity():
    """OS passes scale with M*N, WS with K*N, IS with M*K — so the best
    dataflow depends on workload shape (the paper's Fig. 9 premise)."""
    core = Chiplet(128, 7, 1024)
    tall = Tile(4096, 128, 128, False)   # M >> K,N: IS/OS cheap on passes
    wide = Tile(128, 4096, 128, False)   # K >> M,N
    os_t = scalesim.simulate_tile(tall, core, "OS").cycles
    ws_t = scalesim.simulate_tile(tall, core, "WS").cycles
    assert ws_t != os_t
    os_w = scalesim.simulate_tile(wide, core, "OS").cycles
    is_w = scalesim.simulate_tile(wide, core, "IS").cycles
    assert os_w != is_w


def test_bigger_array_fewer_cycles():
    t = Tile(512, 512, 512, False)
    small = scalesim.simulate_tile(t, Chiplet(64, 7, 1024), "OS").cycles
    big = scalesim.simulate_tile(t, Chiplet(192, 7, 2048), "OS").cycles
    assert big < small


def test_bigger_buffer_less_dram_traffic():
    t = Tile(2048, 2048, 2048, False)
    small = scalesim.simulate_tile(t, Chiplet(64, 7, 256), "OS")
    big = scalesim.simulate_tile(t, Chiplet(64, 7, 1024), "OS")
    assert big.dram_rd_bits <= small.dram_rd_bits


def test_sim_cache_hits():
    cache = scalesim.SimCache()
    t = (Tile(128, 128, 128, False),)
    core = Chiplet(64, 7, 256)
    cache.simulate(t, core, "OS")
    cache.simulate(t, core, "OS")
    assert cache.hits == 1 and cache.misses == 1
    # node change does NOT invalidate (cycle count is node-independent):
    cache.simulate(t, Chiplet(64, 22, 256), "OS")
    assert cache.hits == 2


# ---------------------------------------------------------------------------
# D2D model (Eqs. 6-10)
# ---------------------------------------------------------------------------

def test_bump_count_3d_beats_25d():
    """Eq. 7: area-limited 3D bumps >> perimeter-limited 2.5D bumps."""
    c = Chiplet(128, 7, 1024)
    n3d = d2d_mod.bump_count(c, 25.0, True)
    n25 = d2d_mod.bump_count(c, 25.0, False)
    assert n3d > 10 * n25


def test_3d_bandwidth_exceeds_25d():
    c = Chiplet(128, 7, 1024)
    bw3 = d2d_mod.chiplet_d2d_bw_bits(c, DB.packages["HybBond"].bump_pitch_um,
                                      "UCIe-3D", True)
    bw25 = d2d_mod.chiplet_d2d_bw_bits(c, DB.packages["RDL"].bump_pitch_um,
                                       "UCIe-S", False)
    assert bw3 > bw25


def test_min_bw_path_semantics():
    sys = HISystem(chiplets=identical_chiplet_system(4), style="3D",
                   memory="DDR5", mapping=Mapping(0, "OS", 0),
                   pkg_3d="uBump", proto_3d="UCIe-3D")
    topo = d2d_mod.build_topology(sys)
    order = topo.stack_order
    top = order[-1]
    base = topo.base_die
    path_bw = topo.min_path_bw(top, base)
    link_bws = [l.bw_bits_s for l in topo.path_links(top, base)]
    assert path_bw == min(link_bws)


def test_3d_stacked_die_dram_bw_limited():
    """Eqs. 8-10: a stacked die's effective DRAM bw <= base die's."""
    sys = HISystem(chiplets=identical_chiplet_system(3), style="3D",
                   memory="HBM3", mapping=Mapping(0, "OS", 0),
                   pkg_3d="TSV", proto_3d="UCIe-3D")
    topo = d2d_mod.build_topology(sys)
    base = topo.base_die
    for i in range(3):
        assert topo.effective_dram_bw(i) <= topo.effective_dram_bw(base)


def test_shared_link_serialization():
    """Fig. 4: concurrent transfers on a shared link add (latency grows
    superlinearly vs a single source)."""
    sys = HISystem(chiplets=identical_chiplet_system(4), style="3D",
                   memory="DDR5", mapping=Mapping(0, "OS", 0),
                   pkg_3d="TSV", proto_3d="UCIe-3D")
    topo = d2d_mod.build_topology(sys)
    one = d2d_mod.route_reduction(topo, [0, 0, 10**9, 0]).latency_s
    # everyone sends through the same chain links
    many = d2d_mod.route_reduction(topo, [10**9, 10**9, 10**9, 0]).latency_s
    assert many > one


# ---------------------------------------------------------------------------
# floorplanner
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(1.0, 100.0), min_size=1, max_size=8))
@settings(max_examples=60, deadline=None)
def test_floorplan_properties(areas):
    plan = fp.floorplan(areas)
    # area conservation: die area == requested
    assert math.isclose(plan.die_area, sum(areas), rel_tol=1e-6)
    # slots fit in bbox and white space is non-negative
    assert plan.white_space >= -1e-6
    for r in plan.rects:
        assert r.x >= -1e-9 and r.y >= -1e-9
        assert r.x + r.w <= plan.width + 1e-6
        assert r.y + r.h <= plan.height + 1e-6
    # connectivity: BFS from node 0 reaches everyone
    adj = plan.adjacency()
    if len(areas) > 1:
        seen = {0}
        frontier = [0]
        while frontier:
            u = frontier.pop()
            for v in adj[u]:
                if v not in seen:
                    seen.add(v)
                    frontier.append(v)
        assert len(seen) == len(areas), "floorplan adjacency disconnected"


# ---------------------------------------------------------------------------
# cost + carbon
# ---------------------------------------------------------------------------

def test_chiplet_cost_increases_with_area_and_node():
    small_old = cost_mod.chiplet_cost(Chiplet(64, 28, 256))
    big_new = cost_mod.chiplet_cost(Chiplet(192, 7, 8192))
    assert big_new > small_old


def test_rdl_cheapest_hybbond_most_expensive():
    """Paper Sec VI-B2: RDL most mature/highest yield; HybBond lowest."""
    chips = identical_chiplet_system(4)
    mk = lambda style, **kw: HISystem(chiplets=chips, style=style,
                                      memory="DDR5",
                                      mapping=Mapping(0, "OS", 0), **kw)
    rdl = evaluate(mk("2.5D", pkg_25d="RDL", proto_25d="UCIe-S"),
                   workload(1)).dollar
    hb = evaluate(mk("3D", pkg_3d="HybBond", proto_3d="UCIe-3D"),
                  workload(1)).dollar
    tsv = evaluate(mk("3D", pkg_3d="TSV", proto_3d="UCIe-3D"),
                   workload(1)).dollar
    assert rdl < hb
    assert tsv < hb, "TSV is the cheapest 3D interconnect"


def test_bonding_yield_compounds():
    chips2 = identical_chiplet_system(2)
    chips6 = identical_chiplet_system(6)
    mk = lambda c: HISystem(chiplets=c, style="3D", memory="DDR5",
                            mapping=Mapping(0, "OS", 0),
                            pkg_3d="HybBond", proto_3d="UCIe-3D")
    assert cost_mod.bonding_yield(mk(chips6)) < cost_mod.bonding_yield(mk(chips2))


def test_embodied_cfp_scales_with_silicon():
    chips2 = identical_chiplet_system(2)
    chips6 = identical_chiplet_system(6)
    mk = lambda c: HISystem(chiplets=c, style="2.5D", memory="DDR5",
                            mapping=Mapping(0, "OS", 0),
                            pkg_25d="RDL", proto_25d="UCIe-S")
    e2 = evaluate(mk(chips2), workload(1)).emb_cfp_kg
    e6 = evaluate(mk(chips6), workload(1)).emb_cfp_kg
    assert e6 > e2


def test_perf_si_higher_is_better():
    assert carbon_mod.perf_si(1e-4, 10.0) > carbon_mod.perf_si(2e-4, 10.0)
    assert carbon_mod.perf_si(1e-4, 10.0) > carbon_mod.perf_si(1e-4, 20.0)


# ---------------------------------------------------------------------------
# validity rules (Sec V-A)
# ---------------------------------------------------------------------------

def test_invalid_configs_rejected():
    chips = identical_chiplet_system(2)
    with pytest.raises(InvalidSystem):   # UCIe-3D in a 2.5D system
        validate(HISystem(chiplets=chips, style="2.5D", memory="DDR5",
                          mapping=Mapping(0, "OS", 0),
                          pkg_25d="RDL", proto_25d="UCIe-3D"))
    with pytest.raises(InvalidSystem):   # 2.5D+3D with only two chiplets
        validate(HISystem(chiplets=chips, style="2.5D+3D", memory="DDR5",
                          mapping=Mapping(0, "OS", 0),
                          pkg_25d="RDL", proto_25d="UCIe-S",
                          pkg_3d="TSV", proto_3d="UCIe-3D", stack=(0, 1)))
    with pytest.raises(InvalidSystem):   # monolithic with 2 chiplets
        validate(HISystem(chiplets=chips, style="2D", memory="DDR5",
                          mapping=Mapping(0, "OS", 0)))
    with pytest.raises(InvalidSystem):   # RDL only pairs with UCIe-S
        validate(HISystem(chiplets=chips, style="2.5D", memory="DDR5",
                          mapping=Mapping(0, "OS", 0),
                          pkg_25d="RDL", proto_25d="AIB"))


def test_3d_stack_order_largest_at_base():
    chips = different_chiplet_system()
    sys = HISystem(chiplets=chips, style="3D", memory="DDR5",
                   mapping=Mapping(0, "OS", 0), pkg_3d="TSV",
                   proto_3d="UCIe-3D")
    order = sys.stack_order()
    areas = [chips[i].area_mm2() for i in order]
    assert areas == sorted(areas, reverse=True)
