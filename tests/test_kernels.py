"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles.

All kernels run in interpret mode on CPU; tolerances account for blocked
fp32 accumulation-order differences.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    gemm_ref,
    prefix_segment_gather,
    prefix_segment_ref,
    prefix_select_gather,
    prefix_select_ref,
    rglru,
    rglru_assoc_ref,
    rglru_ref,
    systolic_gemm,
    wkv6,
    wkv6_ref_vmapped,
)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# systolic_gemm
# ---------------------------------------------------------------------------

GEMM_SHAPES = [
    (128, 128, 128),   # exact blocks
    (200, 300, 450),   # ragged
    (64, 512, 64),     # deep K
    (1, 256, 257),     # degenerate M
]


@pytest.mark.parametrize("shape", GEMM_SHAPES)
@pytest.mark.parametrize("dataflow", ["OS", "WS", "IS"])
def test_gemm_dataflows(shape, dataflow):
    m, k, n = shape
    a = jax.random.normal(jax.random.fold_in(KEY, 1), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (k, n), jnp.float32)
    out = systolic_gemm(a, b, bm=64, bk=64, bn=64, dataflow=dataflow)
    np.testing.assert_allclose(out, gemm_ref(a, b), rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("split_k", [2, 4])
def test_gemm_split_k(split_k):
    a = jax.random.normal(jax.random.fold_in(KEY, 3), (96, 512), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 4), (512, 160), jnp.float32)
    out = systolic_gemm(a, b, bm=32, bk=64, bn=32, dataflow="OS",
                        split_k=split_k)
    np.testing.assert_allclose(out, gemm_ref(a, b), rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_dtypes(dtype):
    a = (jax.random.normal(jax.random.fold_in(KEY, 5), (128, 128))
         .astype(dtype))
    b = (jax.random.normal(jax.random.fold_in(KEY, 6), (128, 128))
         .astype(dtype))
    out = systolic_gemm(a, b, bm=64, bk=64, bn=64)
    assert out.dtype == dtype
    ref = gemm_ref(a, b)
    tol = 5e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)


def test_gemm_block_shape_sweep():
    a = jax.random.normal(jax.random.fold_in(KEY, 7), (160, 224), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 8), (224, 96), jnp.float32)
    ref = gemm_ref(a, b)
    for bm, bk, bn in [(32, 32, 32), (64, 128, 32), (128, 64, 96)]:
        out = systolic_gemm(a, b, bm=bm, bk=bk, bn=bn)
        np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4,
                                   err_msg=f"block {(bm, bk, bn)}")


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g,t,d,ct", [(2, 64, 32, 16), (4, 48, 16, 48),
                                      (1, 100, 64, 25)])
def test_wkv6_shapes(g, t, d, ct):
    ks = jax.random.split(jax.random.fold_in(KEY, 9), 5)
    r = jax.random.normal(ks[0], (g, t, d)) * 0.4
    k = jax.random.normal(ks[1], (g, t, d)) * 0.4
    v = jax.random.normal(ks[2], (g, t, d)) * 0.4
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (g, t, d))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (g, d)) * 0.1
    out = wkv6(r, k, v, w, u, ct=ct)
    ref = wkv6_ref_vmapped(r, k, v, w, u)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_wkv6_state_persistence_across_chunks():
    """Chunked execution must match unchunked (state carries in VMEM)."""
    ks = jax.random.split(jax.random.fold_in(KEY, 10), 5)
    g, t, d = 2, 64, 16
    r, k, v = (jax.random.normal(ks[i], (g, t, d)) * 0.3 for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (g, t, d))) * 0.4 + 0.5
    u = jax.random.normal(ks[4], (g, d)) * 0.1
    np.testing.assert_allclose(wkv6(r, k, v, w, u, ct=8),
                               wkv6(r, k, v, w, u, ct=64),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# rglru
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,c,bc,ct", [(2, 64, 128, 128, 16),
                                         (1, 80, 200, 128, 40),
                                         (3, 33, 64, 64, 33)])
def test_rglru_shapes(b, t, c, bc, ct):
    ks = jax.random.split(jax.random.fold_in(KEY, 11), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, t, c))) * 0.9
    x = jax.random.normal(ks[1], (b, t, c)) * 0.3
    out = rglru(a, x, bc=bc, ct=ct)
    np.testing.assert_allclose(out, rglru_ref(a, x), rtol=2e-4, atol=2e-4)


def test_rglru_assoc_matches_sequential():
    ks = jax.random.split(jax.random.fold_in(KEY, 12), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 50, 32))) * 0.95
    x = jax.random.normal(ks[1], (2, 50, 32))
    np.testing.assert_allclose(rglru_assoc_ref(a, x), rglru_ref(a, x),
                               rtol=1e-4, atol=1e-4)


def test_rglru_identity_decay():
    """a == 1 everywhere -> cumulative sum of inputs."""
    x = jnp.ones((1, 10, 8))
    out = rglru(jnp.ones_like(x), x, bc=8, ct=10)
    np.testing.assert_allclose(out[0, :, 0], jnp.arange(1, 11, dtype=jnp.float32),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# prefix_gather (device pathfinder stage-3 inner loop)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(48, 91, 64, 6), (5, 13, 17, 3)])
def test_prefix_gather_matches_ref(shape):
    """Interpreter-mode kernel vs the pure-jnp oracle: bit-exact, the
    values are prefix-sum differences of exact integers."""
    from jax.experimental import enable_x64

    R, T1, P, C = shape
    with enable_x64():
        rng = np.random.default_rng(1)
        pref = jnp.asarray(np.cumsum(
            rng.integers(0, 10**9, (R, T1)), axis=1).astype(np.float64))
        rows = jnp.asarray(rng.integers(0, R, (P, C)).astype(np.int32))
        start = rng.integers(0, T1, (P, C)).astype(np.int32)
        end = np.minimum(start + rng.integers(0, T1, (P, C)),
                         T1 - 1).astype(np.int32)
        diff, total = prefix_segment_gather(
            pref, rows, jnp.asarray(start), jnp.asarray(end))
        diff_r, total_r = prefix_segment_ref(
            pref, rows, jnp.asarray(start), jnp.asarray(end))
        assert (np.asarray(diff) == np.asarray(diff_r)).all()
        assert (np.asarray(total) == np.asarray(total_r)).all()


def test_prefix_gather_int32_path():
    """The kernel is dtype-generic: int32 tables round-trip exactly."""
    rng = np.random.default_rng(2)
    pref = jnp.asarray(np.cumsum(rng.integers(0, 100, (8, 20)),
                                 axis=1).astype(np.int32))
    rows = jnp.asarray(rng.integers(0, 8, (16, 4)).astype(np.int32))
    start = jnp.asarray(np.full((16, 4), 2, dtype=np.int32))
    end = jnp.asarray(np.full((16, 4), 10, dtype=np.int32))
    diff, total = prefix_segment_gather(pref, rows, start, end)
    diff_r, total_r = prefix_segment_ref(pref, rows, start, end)
    assert (np.asarray(diff) == np.asarray(diff_r)).all()
    assert (np.asarray(total) == np.asarray(total_r)).all()


# ---------------------------------------------------------------------------
# prefix_select (fused stacked gather -> split-select -> segment reduce)
# ---------------------------------------------------------------------------


def _select_tables(rng, F, R, t0, t1, tb0, tb1):
    """Integer prefix tables with true totals t0/t1, edge-padded to the
    tile buckets tb0/tb1 (exactly what the stacked engine builds)."""
    p0 = np.cumsum(rng.integers(0, 10**9, (F, R, t0 + 1)), axis=2)
    p1 = np.cumsum(rng.integers(0, 10**9, (F, R, t1 + 1)), axis=2)
    pad0 = np.pad(p0, [(0, 0), (0, 0), (0, tb0 - t0)], mode="edge")
    pad1 = np.pad(p1, [(0, 0), (0, 0), (0, tb1 - t1)], mode="edge")
    return jnp.asarray(pad0), jnp.asarray(pad1)


def test_prefix_select_matches_ref_t0_ne_t1():
    """Fused kernel vs the jnp oracle with T0 != T1 split tables and
    per-row clip bounds: bit-exact integer prefix differences."""
    from jax.experimental import enable_x64

    with enable_x64():
        rng = np.random.default_rng(7)
        F, R, P, C = 5, 36, 48, 6
        t0, t1, tb0, tb1 = 37, 81, 64, 128
        p0, p1 = _select_tables(rng, F, R, t0, t1, tb0, tb1)
        rows = jnp.asarray(rng.integers(0, R, (P, C)).astype(np.int32))
        # bounds deliberately overrun both true totals -> must clip
        start = jnp.asarray(rng.integers(0, tb1, (P, C)).astype(np.int32))
        end = start + jnp.asarray(
            rng.integers(0, tb1, (P, C)).astype(np.int32))
        split = jnp.asarray(rng.integers(0, 2, (P,)).astype(np.int32))
        t0v = jnp.full((P,), t0, jnp.int32)
        t1v = jnp.full((P,), t1, jnp.int32)
        sel, tot = prefix_select_gather(p0, p1, rows, start, end, split,
                                        t0v, t1v)
        sel_r, tot_r = prefix_select_ref(p0, p1, rows, start, end, split,
                                         t0v, t1v)
        assert (np.asarray(sel) == np.asarray(sel_r)).all()
        assert (np.asarray(tot) == np.asarray(tot_r)).all()
        # cross-check against the PR-2 single-table oracle: clip, gather
        # each split table, select per row
        for fi in range(F):
            d0, _ = prefix_segment_ref(p0[fi], rows,
                                       jnp.clip(start, 0, t0),
                                       jnp.clip(end, 0, t0))
            d1, _ = prefix_segment_ref(p1[fi], rows,
                                       jnp.clip(start, 0, t1),
                                       jnp.clip(end, 0, t1))
            want = np.where(np.asarray(split)[:, None] == 1,
                            np.asarray(d1), np.asarray(d0))
            assert (np.asarray(sel)[:, :, fi] == want).all()


def test_prefix_select_empty_segments_and_padded_rows():
    """Bucket-padding boundaries: start == end slots contribute exactly
    zero, and ranges clipped into the edge-replicated padding match the
    unpadded tables bit-for-bit."""
    from jax.experimental import enable_x64

    with enable_x64():
        rng = np.random.default_rng(8)
        F, R, P, C = 5, 12, 16, 4
        t0, t1, tb0, tb1 = 19, 23, 64, 64
        p0, p1 = _select_tables(rng, F, R, t0, t1, tb0, tb1)
        rows = jnp.asarray(rng.integers(0, R, (P, C)).astype(np.int32))
        base = rng.integers(0, tb0 + 1, (P, C)).astype(np.int32)
        start = jnp.asarray(base)
        end = jnp.asarray(base)  # every segment empty
        split = jnp.asarray(rng.integers(0, 2, (P,)).astype(np.int32))
        t0v = jnp.full((P,), t0, jnp.int32)
        t1v = jnp.full((P,), t1, jnp.int32)
        sel, tot = prefix_select_gather(p0, p1, rows, start, end, split,
                                        t0v, t1v)
        assert (np.asarray(sel) == 0).all()
        assert (np.asarray(tot) == 0).all()
        # whole-range gathers that overrun into the padded tail equal
        # the true totals of the unpadded tables
        start = jnp.zeros((P, C), jnp.int32)
        end = jnp.full((P, C), tb0, jnp.int32)  # beyond both true totals
        sel, _ = prefix_select_gather(p0, p1, rows, start, end, split,
                                      t0v, t1v)
        pick = np.where(np.asarray(split)[None, :, None] == 1,
                        np.asarray(p1)[:, np.asarray(rows), t1]
                        - np.asarray(p1)[:, np.asarray(rows), 0],
                        np.asarray(p0)[:, np.asarray(rows), t0]
                        - np.asarray(p0)[:, np.asarray(rows), 0]
                        ).transpose(1, 2, 0)
        assert (np.asarray(sel) == pick).all()


def test_prefix_select_two_workload_stack():
    """A 2-workload stack with different true tile counts: rows offset
    by wi*R reproduce each workload's solo gather bit-for-bit."""
    from jax.experimental import enable_x64

    with enable_x64():
        rng = np.random.default_rng(9)
        F, R, P, C = 5, 10, 24, 5
        # workload a: 11/17 tiles, workload b: 45/29 -> shared buckets
        ta0, ta1, tb_0, tb_1 = 11, 17, 45, 29
        bk0, bk1 = 64, 64
        a0, a1 = _select_tables(rng, F, R, ta0, ta1, bk0, bk1)
        b0, b1 = _select_tables(rng, F, R, tb_0, tb_1, bk0, bk1)
        s0 = jnp.concatenate([a0, b0], axis=1)  # [F, 2R, bk0+1]
        s1 = jnp.concatenate([a1, b1], axis=1)
        rows = jnp.asarray(rng.integers(0, R, (P, C)).astype(np.int32))
        start = jnp.asarray(rng.integers(0, 50, (P, C)).astype(np.int32))
        end = start + jnp.asarray(
            rng.integers(0, 30, (P, C)).astype(np.int32))
        split = jnp.asarray(rng.integers(0, 2, (P,)).astype(np.int32))
        for wi, (w0, w1, tt0, tt1) in enumerate(
                [(a0, a1, ta0, ta1), (b0, b1, tb_0, tb_1)]):
            t0v = jnp.full((P,), tt0, jnp.int32)
            t1v = jnp.full((P,), tt1, jnp.int32)
            solo, _ = prefix_select_gather(w0, w1, rows, start, end,
                                           split, t0v, t1v)
            stacked, _ = prefix_select_gather(
                s0, s1, rows + wi * R, start, end, split, t0v, t1v)
            assert (np.asarray(solo) == np.asarray(stacked)).all()


def test_prefix_select_vmap_flattens_cell_axis():
    """The custom_vmap rule (scenario cells -> kernel grid) matches a
    per-cell loop bit-for-bit, tables shared across the mapped axis."""
    from jax.experimental import enable_x64

    with enable_x64():
        rng = np.random.default_rng(10)
        F, R, P, C, B = 5, 8, 6, 4, 3
        t0, t1 = 21, 13
        p0, p1 = _select_tables(rng, F, R, t0, t1, 64, 64)
        rows = jnp.asarray(rng.integers(0, R, (B, P, C)).astype(np.int32))
        start = jnp.asarray(
            rng.integers(0, 30, (B, P, C)).astype(np.int32))
        end = start + jnp.asarray(
            rng.integers(0, 10, (B, P, C)).astype(np.int32))
        split = jnp.asarray(rng.integers(0, 2, (B, P)).astype(np.int32))
        t0v = jnp.asarray(rng.integers(1, t0 + 1, (B, P)).astype(np.int32))
        t1v = jnp.asarray(rng.integers(1, t1 + 1, (B, P)).astype(np.int32))
        sel_v, tot_v = jax.vmap(
            lambda r, s, e, sp, a, b: prefix_select_gather(
                p0, p1, r, s, e, sp, a, b))(
            rows, start, end, split, t0v, t1v)
        assert sel_v.shape == (B, P, C, F)
        for i in range(B):
            sel_i, tot_i = prefix_select_gather(
                p0, p1, rows[i], start[i], end[i], split[i], t0v[i],
                t1v[i])
            assert (np.asarray(sel_v[i]) == np.asarray(sel_i)).all()
            assert (np.asarray(tot_v[i]) == np.asarray(tot_i)).all()
