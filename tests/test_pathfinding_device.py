"""Device-resident pathfinding tests: jitted + Pallas evaluator parity vs
the scalar reference, vectorized move validity, the lax.scan tempering
engine's trajectory equivalence with a host replay, and the supporting
satellites (LRU topology cache, exact-integer MetricsBatch rows)."""
import math
import random

import numpy as np
import pytest

from repro.core import TEMPLATES, workload
from repro.core.evaluate import evaluate
from repro.core.sa import random_system
from repro.core.scalesim import SimCache
from repro.core.system import is_valid
from repro.core.templates import METRIC_FIELDS, sa_cost
from repro.pathfinding import (
    DesignSpace,
    DeviceEvaluator,
    Pathfinder,
    ParallelTempering,
    evaluate_batch,
    fit_normalizer_batched,
    get_device_evaluator,
)

SPACE = DesignSpace()
WL = workload(1)
PARITY_FIELDS = METRIC_FIELDS + (
    "l_compute_rd_s", "l_d2d_s", "l_dram_wr_s", "e_compute_j", "e_d2d_j",
    "d2d_bits", "macs")


@pytest.fixture(scope="module")
def dev():
    return get_device_evaluator(WL, space=SPACE)


@pytest.fixture(scope="module")
def norm():
    return fit_normalizer_batched(WL, samples=400, seed=7, space=SPACE)


# ---------------------------------------------------------------------------
# Fused jitted evaluator: parity vs the scalar reference
# ---------------------------------------------------------------------------


def test_device_scalar_parity_500(dev):
    """Property: the jitted fused path matches scalar ``evaluate`` within
    1e-6 relative on every metric field over a >= 500-system random
    population (in practice the match is ~1e-15)."""
    rng = random.Random(20260730)
    systems = [random_system(rng) for _ in range(500)]
    mb = dev.metrics(SPACE.encode_many(systems))
    cache = SimCache()
    for i, sys in enumerate(systems):
        m = evaluate(sys, WL, cache=cache)
        for f in PARITY_FIELDS:
            ref = getattr(m, f)
            got = float(getattr(mb, f)[i])
            assert got == pytest.approx(ref, rel=1e-6, abs=1e-300), (
                f"{sys.describe()} field {f}: scalar {ref} device {got}")


@pytest.mark.slow
def test_device_pallas_parity(dev):
    """The Pallas prefix-gather path (interpreter mode on CPU) produces
    the same metrics as the plain jitted gathers."""
    enc = SPACE.sample(256, key=31)
    dev_pl = DeviceEvaluator(WL, space=SPACE, use_pallas=True)
    a = dev.metrics(enc)
    b = dev_pl.metrics(enc)
    for f in PARITY_FIELDS:
        np.testing.assert_allclose(getattr(a, f), getattr(b, f), rtol=1e-12)


def test_device_matches_host_batch(dev):
    """Device vs host ``evaluate_batch`` across styles/workloads."""
    enc = SPACE.sample(490, key=5)  # shares the 512 bucket with the
    # scalar-parity population: no extra compile
    mb_h = evaluate_batch(enc, WL, space=SPACE)
    mb_d = dev.metrics(enc)
    for f in PARITY_FIELDS:
        a = np.asarray(getattr(mb_h, f), dtype=np.float64)
        b = np.asarray(getattr(mb_d, f), dtype=np.float64)
        rel = np.abs(a - b) / np.maximum(np.abs(a), 1e-300)
        assert rel.max() < 1e-9, f"{f}: {rel.max():.3e}"


def test_device_cost_fused(dev, norm):
    """evaluate_cost's fused Eq. 17 matches Objective.cost_batch."""
    from repro.pathfinding.strategies import Objective

    enc = SPACE.sample(128, key=9)
    tpl = TEMPLATES["T2"]
    mb, cost = dev.evaluate_cost(enc, norm, tpl)
    obj = Objective(WL, tpl, norm, device=False)
    np.testing.assert_allclose(cost, obj.cost_batch(mb), rtol=1e-12)
    # and against the scalar sa_cost for a few rows
    for i in (0, 17, 99):
        m = evaluate(SPACE.decode(enc[i]), WL)
        assert cost[i] == pytest.approx(sa_cost(m, tpl, norm), rel=1e-9)


def test_bucketing_consistency(dev):
    """Odd population sizes are padded to buckets; the padding must not
    leak into real rows."""
    enc = SPACE.sample(97, key=13)
    mb_all = dev.metrics(enc)
    mb_one = dev.metrics(enc[:1])
    assert len(mb_all) == 97 and len(mb_one) == 1
    assert float(mb_all.latency_s[0]) == float(mb_one.latency_s[0])


# ---------------------------------------------------------------------------
# Vectorized hierarchical moves
# ---------------------------------------------------------------------------


def test_propose_batch_valid_and_diverse(dev):
    enc = SPACE.sample(2048, key=3)
    out = dev.propose(enc, seed=5)
    assert out.dtype == np.int32 and out.shape == enc.shape
    assert SPACE.validity_mask(out).all()
    for sys in SPACE.decode_many(out[:128]):
        assert is_valid(sys)
    changed = (out != enc).any(axis=1)
    assert changed.mean() > 0.8  # only no-op moves (e.g. 2D package) skip
    # every move level occurs: mapping cols, memory, chiplet cols, count,
    # package cols
    diff_any = lambda cols: (out[:, cols] != enc[:, cols]).any()  # noqa: E731
    assert diff_any([3, 4, 5]) and diff_any([2]) and diff_any([0])
    assert diff_any([6, 7]) and diff_any(list(range(9, enc.shape[1])))


def test_propose_batch_deterministic(dev):
    enc = SPACE.sample(64, key=1)
    a = dev.propose(enc, seed=42)
    b = dev.propose(enc, seed=42)
    assert (a == b).all()
    c = dev.propose(enc, seed=43)
    assert (a != c).any()


# ---------------------------------------------------------------------------
# The lax.scan tempering engine
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_device_pt_trajectory_matches_host_replay(dev, norm):
    """Fixed-seed trajectory equivalence: replaying the device engine's
    recorded proposals and uniforms through a host loop built on scalar
    ``evaluate`` reproduces the accepted-cost history exactly (within
    float tolerance)."""
    tpl = TEMPLATES["T1"]
    n, sweeps, swap_every = 6, 25, 5
    rng = random.Random(3)
    v0 = SPACE.encode_many([random_system(rng) for _ in range(n)])
    ratio = (1.0 / 4000.0) ** (1.0 / (n - 1))
    temps = np.array([4000.0 * ratio ** i for i in range(n)])
    res = dev.parallel_tempering(v0, temps, sweeps, swap_every, seed=11,
                                 norm=norm, template=tpl, record_trace=True)
    tr = res.trace
    cache = SimCache()

    def scost(vec):
        return sa_cost(evaluate(SPACE.decode(vec), WL, cache=cache),
                       tpl, norm)

    costs = [scost(v0[i]) for i in range(n)]
    hist = [min(costs)]
    best_c = min(costs)
    inv_t = 1.0 / temps
    for s in range(sweeps):
        pcost = [scost(tr["proposals"][s][i]) for i in range(n)]
        u, us = tr["u_accept"][s], tr["u_swap"][s]
        for i in range(n):
            delta = pcost[i] - costs[i]
            if delta <= 0 or u[i] < math.exp(-delta / max(temps[i], 1e-12)):
                costs[i] = pcost[i]
                best_c = min(best_c, pcost[i])
        if s % swap_every == 0:
            for i in range(n - 1):
                d = (inv_t[i] - inv_t[i + 1]) * (costs[i] - costs[i + 1])
                if d >= 0 or us[i] < math.exp(min(d, 0.0)):
                    costs[i], costs[i + 1] = costs[i + 1], costs[i]
        hist.append(costs[-1])
        np.testing.assert_allclose(costs, tr["costs"][s], rtol=1e-9,
                                   err_msg=f"sweep {s}")
    np.testing.assert_allclose(hist, res.history, rtol=1e-9)
    assert res.best_cost == pytest.approx(best_c, rel=1e-9)


@pytest.mark.slow
def test_device_pt_deterministic_and_improves(dev, norm):
    tpl = TEMPLATES["T1"]
    v0 = SPACE.sample(4, key=2)
    temps = np.array([4000.0, 200.0, 10.0, 1.0])
    r1 = dev.parallel_tempering(v0, temps, 30, 5, seed=1, norm=norm,
                                template=tpl)
    r2 = dev.parallel_tempering(v0, temps, 30, 5, seed=1, norm=norm,
                                template=tpl)
    assert r1.history == r2.history and r1.best_cost == r2.best_cost
    assert (r1.best_enc == r2.best_enc).all()
    assert r1.evaluations == 4 + 4 * 30
    assert r1.best_cost <= r1.history[0] + 1e-12
    assert SPACE.validity_mask(r1.final_enc).all()
    assert is_valid(SPACE.decode(r1.best_enc))


@pytest.mark.slow
def test_pt_strategy_device_flag(norm):
    """ParallelTempering through the facade: the device engine honors
    budgets (whole sweeps only, evals <= budget) and the scalar fallback
    still engages when device=False."""
    pf = Pathfinder(WL, TEMPLATES["T1"], norm=norm, space=SPACE)
    assert pf.device
    res = pf.search(strategy=ParallelTempering(n_chains=4, sweeps=50),
                    budget=30, key=3)
    assert res.evaluations <= 30
    assert res.evaluations == 4 + 4 * ((30 - 4) // 4)
    assert is_valid(res.best)
    pf_host = Pathfinder(WL, TEMPLATES["T1"], norm=norm, space=SPACE,
                         device=False)
    assert not pf_host.device
    res_h = pf_host.search(
        strategy=ParallelTempering(n_chains=4, sweeps=5), key=3)
    assert is_valid(res_h.best)


def test_grid_sweep_device_matches_host(norm):
    """GridSweep through the fused evaluator finds the same optimum as
    the host path."""
    from repro.core.workload import ALL_MAPPINGS
    from repro.pathfinding import GridSweep

    g = GridSweep(memories=("DDR5",), mappings=ALL_MAPPINGS[:1])
    pf_d = Pathfinder(WL, TEMPLATES["T1"], norm=norm, space=SPACE)
    pf_h = Pathfinder(WL, TEMPLATES["T1"], norm=norm, space=SPACE,
                      device=False)
    rd = pf_d.search(strategy=g)
    rh = pf_h.search(strategy=g)
    assert rd.best == rh.best
    assert rd.best_cost == pytest.approx(rh.best_cost, rel=1e-9)


# ---------------------------------------------------------------------------
# Satellites: LRU topology cache
# ---------------------------------------------------------------------------


def test_topo_cache_lru_eviction(monkeypatch):
    from repro.pathfinding import batch as batch_mod
    from repro.pathfinding.batch import BatchEvaluator

    monkeypatch.setattr(batch_mod, "_TOPO_CACHE_MAX", 8)
    ev = BatchEvaluator(WL, space=SPACE)
    enc = SPACE.sample(64, key=21)
    # only 2.5D/hybrid rows hit the descriptor cache
    ev(enc)
    assert len(ev._topo_cache) <= 8
    keys_after_first = list(ev._topo_cache)
    # re-evaluating the same rows must refresh recency, not grow the dict
    ev(enc[-16:])
    assert len(ev._topo_cache) <= 8
    # and newly seen topologies keep being cached (no silent stop)
    ev(SPACE.sample(64, key=22))
    assert len(ev._topo_cache) == 8
    assert list(ev._topo_cache) != keys_after_first
