"""Deterministic fallback for ``hypothesis`` when it is not installed.

The tier-1 suite property-tests several models with hypothesis. On
machines without the package (it is listed in ``requirements-dev.txt``
but absent from minimal images) the suite must still collect and run, so
``conftest.py`` registers this module under ``sys.modules["hypothesis"]``
as a drop-in for the subset of the API the tests use: ``given``,
``settings``, ``assume`` and the ``integers`` / ``floats`` / ``lists`` /
``sampled_from`` strategies.

Instead of adaptive random search the fallback draws a fixed, seeded set
of examples per test — boundary combinations first (min/max and every
``sampled_from`` element, crossed over all strategies up to the example
cap) then pseudo-random draws. The example count is capped at
``MAX_FALLBACK_EXAMPLES`` regardless of ``settings(max_examples=...)``;
install hypothesis for the full adaptive search.
"""
from __future__ import annotations

import functools
import itertools
import random

MAX_FALLBACK_EXAMPLES = 12


class _Unsatisfied(Exception):
    """Raised by ``assume(False)`` to skip one drawn example."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Strategy:
    """Base strategy: subclasses yield deterministic then random draws."""

    def boundary(self):
        """Fixed boundary examples, tried before random draws."""
        return []

    def draw(self, rng: random.Random):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value: int, max_value: int):
        self.lo, self.hi = int(min_value), int(max_value)

    def boundary(self):
        return [self.lo, self.hi] if self.lo != self.hi else [self.lo]

    def draw(self, rng):
        return rng.randint(self.lo, self.hi)


class _Floats(_Strategy):
    def __init__(self, min_value: float, max_value: float):
        self.lo, self.hi = float(min_value), float(max_value)

    def boundary(self):
        return [self.lo, self.hi]

    def draw(self, rng):
        return rng.uniform(self.lo, self.hi)


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def boundary(self):
        return list(self.elements)

    def draw(self, rng):
        return rng.choice(self.elements)


class _Lists(_Strategy):
    def __init__(self, elements: _Strategy, min_size: int = 0,
                 max_size: int = 10):
        self.elements = elements
        self.min_size, self.max_size = min_size, max_size

    def boundary(self):
        out = []
        rng = random.Random(0)
        for size in {self.min_size, self.max_size}:
            out.append([self.elements.draw(rng) for _ in range(size)])
        return out

    def draw(self, rng):
        size = rng.randint(self.min_size, self.max_size)
        return [self.elements.draw(rng) for _ in range(size)]


class strategies:  # noqa: N801 - mirrors the hypothesis module name
    integers = _Integers
    floats = _Floats
    lists = _Lists
    sampled_from = _SampledFrom


def settings(**_kwargs):
    """Accepted for compatibility; the fallback caps its own example count."""

    def decorate(fn):
        return fn

    return decorate


def given(*strats: _Strategy):
    """Run the test over boundary examples plus seeded random draws."""

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper():
            rng = random.Random(fn.__name__)
            # boundary combinations across every strategy — sampled from
            # the cross product so no axis is pinned — then seeded random
            # draws fill the remainder
            pools = [s.boundary() or [s.draw(rng)] for s in strats]
            product = list(itertools.islice(itertools.product(*pools), 512))
            if len(product) > MAX_FALLBACK_EXAMPLES:
                examples = rng.sample(product, MAX_FALLBACK_EXAMPLES)
            else:
                examples = product
            while len(examples) < MAX_FALLBACK_EXAMPLES:
                examples.append(tuple(s.draw(rng) for s in strats))
            ran = 0
            for ex in examples[:MAX_FALLBACK_EXAMPLES]:
                try:
                    fn(*ex)
                    ran += 1
                except _Unsatisfied:
                    continue
            if not ran:
                # mirror hypothesis' excessive-rejection error: a property
                # test whose body never executed must not look green
                raise RuntimeError(
                    f"{fn.__name__}: assume() rejected every fallback "
                    "example; the property was never exercised")

        # pytest inspects ``__wrapped__`` to discover fixture parameters;
        # the strategy-drawn arguments must not look like fixtures.
        del wrapper.__wrapped__
        return wrapper

    return decorate
