"""Pluggable communication-model tests: closed-form mesh-NoC hop counts
vs a BFS reference, scalar-vs-device parity of the mesh_noc model,
bit-identity of legacy replay through the env-forced mesh program,
compile-count flatness across mesh-dim mixes, and the host-side NoC
move/seeding satellites."""
import dataclasses
import random
from collections import deque

import numpy as np
import pytest
from hypothesis import assume, given, settings
from hypothesis import strategies as st

from repro.core import comm as comm_mod
from repro.core import workload
from repro.core.evaluate import evaluate
from repro.core.sa import propose, random_system, seed_noc
from repro.core.scalesim import SimCache
from repro.core.system import is_valid
from repro.core.techdb import DEFAULT_DB
from repro.core.templates import METRIC_FIELDS
from repro.pathfinding import DesignSpace, get_device_evaluator
from repro.pathfinding.device import get_scenario_engine, trace_count

WL = workload(1)
PARITY_FIELDS = METRIC_FIELDS + (
    "l_compute_rd_s", "l_d2d_s", "l_dram_wr_s", "e_compute_j", "e_d2d_j",
    "d2d_bits", "macs")


# ---------------------------------------------------------------------------
# Closed-form Manhattan hop arithmetic vs an explicit BFS reference
# ---------------------------------------------------------------------------


def _bfs_mean_hops(mx: int, my: int, ex: int, ey: int) -> float:
    """Mean shortest-path distance from every tile of an ``mx x my``
    mesh to the entry router at ``(ex, ey)``, by breadth-first search
    over the grid graph — the model the closed form must reproduce."""
    dist = {(ex, ey): 0}
    q = deque([(ex, ey)])
    while q:
        x, y = q.popleft()
        for nx, ny in ((x - 1, y), (x + 1, y), (x, y - 1), (x, y + 1)):
            if 0 <= nx < mx and 0 <= ny < my and (nx, ny) not in dist:
                dist[(nx, ny)] = dist[(x, y)] + 1
                q.append((nx, ny))
    assert len(dist) == mx * my
    return sum(dist.values()) / (mx * my)


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=1, max_value=9),
       st.integers(min_value=1, max_value=9),
       st.integers(min_value=0, max_value=8),
       st.integers(min_value=0, max_value=8))
def test_closed_form_hops_match_bfs(mx, my, ex, ey):
    """Property: ``mesh_mean_hops`` equals the BFS mean over arbitrary
    mesh dims and any in-mesh entry coordinate (XY routing on a grid is
    Manhattan, and the per-axis sums telescope)."""
    assume(ex < mx and ey < my)
    closed = comm_mod.mesh_mean_hops(mx, my, ex, ey)
    assert closed == pytest.approx(_bfs_mean_hops(mx, my, ex, ey),
                                   rel=1e-12, abs=1e-12)


@settings(max_examples=60, deadline=None)
@given(st.sampled_from(range(len(comm_mod.MESH_DIMS))),
       st.sampled_from(range(len(comm_mod.ENTRY_PLACEMENTS))),
       st.sampled_from(range(len(comm_mod.MESH_DIMS))),
       st.sampled_from(range(len(comm_mod.ENTRY_PLACEMENTS))))
def test_src_dst_pair_hops_match_bfs(mi_s, ei_s, mi_d, ei_d):
    """A src->dst transfer pays src egress + dst ingress NoC hops; both
    legs must match the BFS reference for the encoded table entries."""
    legs = []
    for mi, ei in ((mi_s, ei_s), (mi_d, ei_d)):
        mx, my = comm_mod.MESH_DIMS[mi]
        ex, ey = comm_mod.entry_coords(mx, my, ei)
        assert 0 <= ex < mx and 0 <= ey < my
        legs.append(_bfs_mean_hops(mx, my, ex, ey))
    pair = comm_mod.noc_hop_count(mi_s, ei_s) + comm_mod.noc_hop_count(
        mi_d, ei_d)
    assert pair == pytest.approx(sum(legs), rel=1e-12, abs=1e-12)


def test_noc_tables_neutral_element():
    """``MESH_DIMS[0]`` is the exact legacy limit: zero hops from every
    entry placement, one physical router."""
    hops, routers = comm_mod.noc_tables()
    assert hops.shape == (len(comm_mod.MESH_DIMS),
                          len(comm_mod.ENTRY_PLACEMENTS))
    assert np.all(hops[0] == 0.0)
    assert routers[0] == 1.0
    # monotonicity: a bigger mesh never shrinks the router count
    assert np.all(np.diff(routers) > 0)


# ---------------------------------------------------------------------------
# mesh_noc scalar-vs-device parity over a style-diverse population
# ---------------------------------------------------------------------------


def _mesh_systems(count: int, seed: int):
    rng = random.Random(seed)
    out = []
    for _ in range(count):
        sys = random_system(rng)
        noc = tuple(
            (rng.randrange(len(comm_mod.MESH_DIMS)),
             rng.randrange(len(comm_mod.ENTRY_PLACEMENTS)))
            for _ in range(sys.n_chiplets))
        out.append(dataclasses.replace(sys, noc=noc))
    return out


def test_mesh_scalar_device_parity_240():
    """The fused device program under ``comm="mesh_noc"`` matches scalar
    ``evaluate`` within 1e-6 relative on every metric over >= 200 random
    NoC-carrying systems, spanning 2.5D and 3D integration styles."""
    systems = _mesh_systems(240, 20260808)
    styles = {s.style for s in systems}
    assert {"2.5D", "3D"} <= styles, f"population too narrow: {styles}"
    space = DesignSpace(DEFAULT_DB, comm="mesh_noc")
    assert space.noc_live
    dev = get_device_evaluator(WL, space=space)
    mb = dev.metrics(space.encode_many(systems))
    cache = SimCache()
    for i, sys in enumerate(systems):
        m = evaluate(sys, WL, cache=cache)
        for f in PARITY_FIELDS:
            ref = getattr(m, f)
            got = float(getattr(mb, f)[i])
            assert got == pytest.approx(ref, rel=1e-6, abs=1e-300), (
                f"{sys.describe()} noc={sys.noc} field {f}: "
                f"scalar {ref} device {got}")


def test_neutral_noc_is_bit_invisible():
    """A system pinned at the neutral mesh evaluates bit-identically to
    the same system without any NoC at all — the invariant that lets
    the forced mesh program replay every legacy golden."""
    rng = random.Random(7)
    cache = SimCache()
    for _ in range(25):
        sys = random_system(rng)
        neutral = dataclasses.replace(
            sys, noc=(comm_mod.NOC_NEUTRAL,) * sys.n_chiplets)
        a = evaluate(sys, WL, cache=cache)
        b = evaluate(neutral, WL, cache=cache)
        for f in PARITY_FIELDS:
            assert getattr(a, f) == getattr(b, f), f
        assert comm_mod.system_noc_hops(neutral) == (0.0,) * sys.n_chiplets
        assert comm_mod.system_n_routers(neutral) == (1,) * sys.n_chiplets


# ---------------------------------------------------------------------------
# Env-forced mesh program: legacy replay bit-identity + compile flatness
# ---------------------------------------------------------------------------


def _scenario_args(space, S, n):
    v0 = np.stack([space.sample(n, 10 + s) for s in range(S)])
    return v0, dict(
        temps=np.tile(np.geomspace(2.0, 0.01, n), (S, 1)),
        sweeps=16, swap_every=2, seed=3, mins=np.zeros((S, 6)),
        medians=np.ones((S, 6)),
        weights=np.tile(np.ones(6) / 6, (S, n, 1)),
        pair_mask=np.ones((S, n - 1), bool), ci=np.full(S, 0.475),
        widx=np.zeros(S, np.int32))


@pytest.mark.slow
def test_env_forced_mesh_replays_legacy_bits(monkeypatch):
    """``REPRO_COMM_MODEL=mesh_noc`` reroutes default DesignSpaces
    through the mesh program with the NoC axes frozen at neutral; the
    fused scenario trajectory must stay bit-identical to legacy."""
    S, n = 2, 6
    legacy = DesignSpace(DEFAULT_DB, comm="legacy")
    v0, kw = _scenario_args(legacy, S, n)
    eng_l = get_scenario_engine((WL,), DEFAULT_DB, space=legacy)
    r_l = eng_l.parallel_tempering(v0, **kw)

    monkeypatch.setenv(comm_mod.COMM_ENV_VAR, "mesh_noc")
    forced = DesignSpace(DEFAULT_DB)
    assert forced.comm == "mesh_noc" and not forced.noc_live
    v0_f, kw_f = _scenario_args(forced, S, n)
    # same systems, wider rows: the legacy columns must round-trip
    assert np.array_equal(v0_f[:, :, :legacy.width], v0)
    eng_f = get_scenario_engine((WL,), DEFAULT_DB, space=forced)
    r_f = eng_f.parallel_tempering(v0_f, **kw_f)

    assert np.array_equal(r_f.best_cost, r_l.best_cost)
    assert np.array_equal(r_f.history, r_l.history)
    assert np.array_equal(r_f.best_enc[:, :legacy.width], r_l.best_enc)


@pytest.mark.slow
def test_mesh_dims_are_data_not_shape():
    """One fused compile serves every mesh-dim / entry-placement mix:
    re-running the scenario grid with different encoded NoC axes and a
    different per-cell ``noc_on`` mask must not retrace."""
    S, n = 2, 6
    space = DesignSpace(DEFAULT_DB, comm="mesh_noc")
    eng = get_scenario_engine((WL,), DEFAULT_DB, space=space)
    v0, kw = _scenario_args(space, S, n)
    eng.parallel_tempering(v0, **kw)
    c_pt, c_init = trace_count("scenario_pt"), trace_count("scenario_init")

    # scramble the NoC columns to a different mesh per cell and flip one
    # cell's move gate: runtime data only
    v1 = v0.copy()
    nc_col = space.noc_col
    v1[..., nc_col::2] = np.where(v1[..., nc_col::2] >= 0,
                                  (v1[..., nc_col::2] + 1)
                                  % len(comm_mod.MESH_DIMS),
                                  v1[..., nc_col::2])
    r1 = eng.parallel_tempering(v1, noc_on=np.array([1.0, 0.0]), **kw)
    assert trace_count("scenario_pt") == c_pt
    assert trace_count("scenario_init") == c_init
    assert np.isfinite(r1.best_cost).all()


# ---------------------------------------------------------------------------
# Host-side satellites: seeding, NoC moves, spec validation
# ---------------------------------------------------------------------------


def test_seed_noc_and_noc_moves():
    rng = random.Random(11)
    sys = seed_noc(random_system(rng))
    assert sys.noc == (comm_mod.NOC_NEUTRAL,) * sys.n_chiplets
    assert seed_noc(sys) is sys          # idempotent
    moved = 0
    cur = sys
    for _ in range(200):
        cand = propose(cur, rng, DEFAULT_DB, noc_moves=True)
        assert is_valid(cand, DEFAULT_DB)
        assert len(cand.noc) == cand.n_chiplets
        comm_mod.validate_noc(cand.noc, cand.n_chiplets)
        if cand.n_chiplets == cur.n_chiplets and cand.noc != cur.noc:
            moved += 1
        cur = cand
    assert moved > 0, "NoC move level never fired in 200 proposals"


def test_propose_without_noc_moves_stays_legacy():
    rng = random.Random(12)
    cur = random_system(rng)
    for _ in range(50):
        cur = propose(cur, rng, DEFAULT_DB)
        assert cur.noc == ()


def test_jobspec_comm_validation():
    from repro.serving.jobs import JobSpec

    spec = JobSpec(job_id="j", workload="w", comm="mesh_noc")
    assert spec.bucket_key()[-1] == "mesh_noc"
    legacy = JobSpec(job_id="j", workload="w")
    assert legacy.bucket_key()[-1] == "legacy"
    with pytest.raises(ValueError, match="unknown comm model"):
        JobSpec(job_id="j", workload="w", comm="torus")
