"""End-to-end behaviour tests for the paper's system.

These assert the paper's *claims*, not implementation details:
  * the SA engine finds solutions better than random sampling;
  * carbon-aware optimization (T4) achieves lower embodied CFP than the
    same engine with zeta = eta = 0 (the paper's 1.9x-3.16x direction);
  * the full pipeline (tile -> simulate -> topology -> PPAC -> CFP) is
    deterministic and self-consistent.
"""
import random

import pytest

from repro.core import (
    SAConfig,
    SimCache,
    TEMPLATES,
    anneal,
    evaluate,
    fit_normalizer,
    random_system,
    sa_cost,
    workload,
)

FAST = SAConfig(t_initial=50.0, t_final=0.05, cooling=0.88,
                moves_per_temp=20, norm_samples=300, seed=7)


@pytest.fixture(scope="module")
def norm_and_cache():
    cache = SimCache()
    norm = fit_normalizer(workload(1), samples=300, cache=cache)
    return norm, cache


def test_sa_beats_random_sampling(norm_and_cache):
    norm, cache = norm_and_cache
    wl = workload(1)
    t = TEMPLATES["T1"]
    res = anneal(wl, t, config=FAST, norm=norm, cache=cache)
    rng = random.Random(123)
    random_costs = []
    for _ in range(200):
        m = evaluate(random_system(rng), wl, cache=cache)
        random_costs.append(sa_cost(m, t, norm))
    assert res.best_cost <= min(random_costs) * 1.05, (
        "SA should match or beat the best of 200 random samples")
    # and hugely beat the average
    assert res.best_cost < sum(random_costs) / len(random_costs)


def test_carbon_aware_lowers_embodied_cfp(norm_and_cache):
    """The paper's central claim: adding zeta/eta steers the same engine
    to lower-CFP systems (1.9x avg, up to 3.16x for T4)."""
    norm, cache = norm_and_cache
    wl = workload(1)
    best_c, best_noc = [], []
    for seed in (1, 2, 3):
        cfg = SAConfig(**{**FAST.__dict__, "seed": seed})
        res_c = anneal(wl, TEMPLATES["T4"], config=cfg, norm=norm,
                       cache=cache)
        res_n = anneal(wl, TEMPLATES["T4"].without_carbon(), config=cfg,
                       norm=norm, cache=cache)
        best_c.append(res_c.best_metrics.emb_cfp_kg
                      + res_c.best_metrics.ope_cfp_kg)
        best_noc.append(res_n.best_metrics.emb_cfp_kg
                        + res_n.best_metrics.ope_cfp_kg)
    # best-of-seeds comparison absorbs short-schedule SA noise; the full
    # paper-schedule comparison lives in benchmarks/table06_sa_flows.py
    assert min(best_c) <= min(best_noc) * 1.02, (
        f"carbon-aware {best_c} should not exceed carbon-blind {best_noc}")


def test_evaluation_deterministic():
    rng = random.Random(5)
    sys = random_system(rng)
    wl = workload(2)
    m1 = evaluate(sys, wl)
    m2 = evaluate(sys, wl)
    assert m1 == m2


def test_metrics_positive():
    rng = random.Random(11)
    for _ in range(50):
        m = evaluate(random_system(rng), workload(4))
        assert m.latency_s > 0 and m.energy_j > 0
        assert m.area_mm2 > 0 and m.dollar > 0
        assert m.emb_cfp_kg > 0 and m.ope_cfp_kg > 0
        assert m.macs == workload(4).macs, "tiler must cover the workload"
