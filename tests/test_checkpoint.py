"""repro.checkpoint round-trip coverage: mixed-dtype pytrees, 0-d
leaves, elastic restore (different n_shards / ELASTIC template leaves /
pytrees of ParetoArchives), and the corrupt-checkpoint prune-and-fall-
back behaviour of ``CheckpointManager.restore``."""
import json
import os
import tempfile

import numpy as np
import pytest

from repro.checkpoint import (
    ELASTIC,
    CheckpointManager,
    CorruptCheckpointError,
    load_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.checkpoint import MANIFEST
from repro.pathfinding import ParetoArchive


def _mixed_tree():
    return {
        "ints": np.arange(12, dtype=np.int32).reshape(3, 4),
        "floats": np.linspace(0.0, 1.0, 7),          # float64
        "scalar_f": np.float64(3.25),                # 0-d float64
        "scalar_i": np.int64(11),                    # 0-d int64
        "nested": {"u32": np.asarray([1, 2], dtype=np.uint32),
                   "bools": np.asarray([True, False, True])},
        "listy": [np.zeros(3, dtype=np.int32), np.ones((2, 2))],
    }


def _assert_tree_equal(a, b):
    import jax

    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        np.testing.assert_array_equal(x, y)


def test_roundtrip_mixed_dtypes_and_0d_leaves():
    with tempfile.TemporaryDirectory() as d:
        t = _mixed_tree()
        p = save_checkpoint(d, 3, t, n_shards=2)
        step, r = load_checkpoint(p, t)
        assert step == 3
        _assert_tree_equal(t, r)


@pytest.mark.parametrize("save_shards,load_mgr_shards", [(1, 8), (5, 2)])
def test_elastic_restore_across_n_shards(save_shards, load_mgr_shards):
    """n_shards only shapes the on-disk layout: restore reassembles the
    logical arrays regardless of the manager's own shard setting."""
    with tempfile.TemporaryDirectory() as d:
        t = _mixed_tree()
        save_checkpoint(d, 1, t, n_shards=save_shards)
        mgr = CheckpointManager(d, keep=3, n_shards=load_mgr_shards)
        step, r = mgr.restore(t)
        assert step == 1
        _assert_tree_equal(t, r)


def test_elastic_template_leaf_takes_manifest_shape():
    """An ELASTIC template leaf restores with the saved shape — the
    grow-only history vector of a resumed search."""
    with tempfile.TemporaryDirectory() as d:
        t = {"hist": np.arange(9.0), "step": np.int64(4)}
        p = save_checkpoint(d, 4, t)
        _, r = load_checkpoint(p, {"hist": ELASTIC,
                                   "step": np.zeros((), np.int64)})
        np.testing.assert_array_equal(np.asarray(r["hist"]), t["hist"])
        # a non-elastic mismatch still fails loudly
        with pytest.raises(ValueError, match="shape mismatch"):
            load_checkpoint(p, {"hist": np.zeros(2),
                                "step": np.zeros((), np.int64)})


def _archive(rows):
    a = ParetoArchive(max_size=64)
    enc = np.arange(rows * 5, dtype=np.int32).reshape(rows, 5)
    vec = np.stack([np.arange(rows, dtype=np.float64),
                    -np.arange(rows, dtype=np.float64),
                    np.ones(rows)], axis=1)
    a.insert(enc, vec)
    return a


def test_pytree_of_archives_roundtrip():
    """ParetoArchive objects ride inside checkpoint trees: expanded to
    array dicts on save, reconstituted (with elastic row counts) on
    load."""
    with tempfile.TemporaryDirectory() as d:
        archives = [_archive(3), _archive(7), ParetoArchive(max_size=8)]
        tree = {"archives": archives, "counter": np.int64(2)}
        p = save_checkpoint(d, 2, tree)
        # templates are EMPTY archives: row counts come from the manifest
        like = {"archives": [ParetoArchive(max_size=64) for _ in range(3)],
                "counter": np.zeros((), np.int64)}
        _, r = load_checkpoint(p, like)
        for orig, got in zip(archives, r["archives"]):
            assert isinstance(got, ParetoArchive)
            assert got.max_size == 64
            np.testing.assert_array_equal(got.encoded, orig.encoded)
            np.testing.assert_array_equal(got.vectors, orig.vectors)


def test_subset_template_restore_is_not_misread_as_corruption():
    """The checksum covers the whole payload; a template requesting a
    subset of the saved leaves must verify against it (a false
    corruption verdict would PRUNE valid snapshots) and restore the
    subset."""
    with tempfile.TemporaryDirectory() as d:
        full = {"a": np.arange(4.0), "b": np.arange(6, dtype=np.int32),
                "arch": _archive(3)}
        mgr = CheckpointManager(d, keep=3)
        mgr.save(7, full)
        step, r = mgr.restore({"a": np.zeros(4)})
        assert step == 7
        np.testing.assert_array_equal(np.asarray(r["a"]), full["a"])
        # nothing was pruned: the snapshot is intact and fully loadable
        assert mgr.all_steps() == [7]
        _, r2 = mgr.restore({"a": np.zeros(4),
                             "b": np.zeros(6, np.int32),
                             "arch": ParetoArchive(max_size=64)})
        np.testing.assert_array_equal(r2["arch"].encoded,
                                      full["arch"].encoded)


def test_restore_prunes_corrupt_and_falls_back():
    """A torn copy of the newest checkpoint must not poison restart:
    restore skips + prunes it and lands on the next-newest valid step."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=5)
        t5 = {"x": np.full(4, 5.0)}
        t9 = {"x": np.full(4, 9.0)}
        mgr.save(5, t5)
        p9 = mgr.save(9, t9)
        # corrupt step 9's payload (bit-flip a shard, keep the manifest)
        shard = [f for f in os.listdir(p9) if f.endswith(".npy")][0]
        arr = np.load(os.path.join(p9, shard))
        np.save(os.path.join(p9, shard), arr + 1.0)
        step, r = mgr.restore({"x": np.zeros(4)})
        assert step == 5
        np.testing.assert_array_equal(np.asarray(r["x"]), t5["x"])
        # the poisoned directory is gone, not retried forever
        assert mgr.all_steps() == [5]


def test_restore_prunes_truncated_shard_and_unreadable_manifest():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=5)
        t = {"x": np.arange(6.0)}
        mgr.save(1, t)
        p2 = mgr.save(2, t)
        p3 = mgr.save(3, t)
        # step 3: unreadable manifest; step 2: truncated shard file
        with open(os.path.join(p3, MANIFEST), "w") as f:
            f.write("{not json")
        shard = [f for f in os.listdir(p2) if f.endswith(".npy")][0]
        with open(os.path.join(p2, shard), "wb") as f:
            f.write(b"\x93NUMPY")  # magic only, no header/payload
        step, _ = mgr.restore({"x": np.zeros(6)})
        assert step == 1
        assert mgr.all_steps() == [1]


def test_restore_all_corrupt_raises_filenotfound():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        p = mgr.save(1, {"x": np.zeros(3)})
        with open(os.path.join(p, MANIFEST), "w") as f:
            f.write("")
        with pytest.raises(FileNotFoundError, match="no valid checkpoint"):
            mgr.restore({"x": np.zeros(3)})


def test_structural_mismatch_is_not_pruned():
    """A *valid* checkpoint that does not fit the template is a caller
    bug: restore raises and leaves the directory alone."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        mgr.save(1, {"x": np.zeros(3)})
        with pytest.raises(KeyError, match="missing leaf"):
            mgr.restore({"y": np.zeros(3)})
        assert mgr.all_steps() == [1]


def test_corrupt_error_is_a_value_error():
    """Back-compat: callers catching ValueError keep working."""
    assert issubclass(CorruptCheckpointError, ValueError)
    with tempfile.TemporaryDirectory() as d:
        p = save_checkpoint(d, 1, {"x": np.zeros(2)})
        shard = [f for f in os.listdir(p) if f.endswith(".npy")][0]
        arr = np.load(os.path.join(p, shard))
        np.save(os.path.join(p, shard), arr + 1.0)
        with pytest.raises(ValueError, match="checksum"):
            load_checkpoint(p, {"x": np.zeros(2)})


def test_manifest_records_trajectory_step_and_checksum():
    with tempfile.TemporaryDirectory() as d:
        p = save_checkpoint(d, 17, {"x": np.arange(3)})
        with open(os.path.join(p, MANIFEST)) as f:
            m = json.load(f)
        assert m["step"] == 17
        assert m["checksum"]
        assert set(m["leaves"]) == {"x"}
