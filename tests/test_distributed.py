"""Distribution tests: sharding-rule properties and a real multi-device
mini train/serve run in a subprocess (8 fake host devices — the main test
process must keep the default 1-device view)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import DATA, fit_spec, param_spec_for

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH2 = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


# ---------------------------------------------------------------------------
# fit_spec properties
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(1, 4096), min_size=1, max_size=4))
@settings(max_examples=80, deadline=None)
def test_fit_spec_always_legal(shape):
    """Property: every produced spec only shards dims it divides, and
    never reuses a mesh axis."""
    spec = fit_spec(shape, (DATA, "model", "model", None)[:len(shape)], MESH3)
    used = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= MESH3.shape[a]
            used.append(a)
        assert dim % size == 0, f"{dim} not divisible by {size}"
    assert len(used) == len(set(used)), "mesh axis reused"


def test_fit_spec_drops_nondividing():
    # vocab 92553 (internvl2) is odd -> no axis fits
    assert fit_spec((92553, 6144), ("model", None), MESH2) == P(None, None)
    # 152064 divides 16
    assert fit_spec((152064, 5120), ("model", None), MESH2)[0] == "model"


def test_fit_spec_data_tuple_on_multipod():
    spec = fit_spec((256, 4096), (DATA, None), MESH3)
    assert spec[0] == ("pod", "data")
    spec1 = fit_spec((1, 4096), (DATA, None), MESH3)   # batch 1: replicate
    assert spec1[0] is None


def test_param_rules():
    assert param_spec_for("layers/attn/wq", (30, 576, 576), MESH2) == \
        P(None, "data", "model")
    assert param_spec_for("layers/mlp/w_down", (30, 1536, 576), MESH2) == \
        P(None, "model", "data")
    # moe experts 4d: E over model
    assert param_spec_for("moe_layers/moe/w_gate", (59, 160, 5120, 1536),
                          MESH2)[1] == "model"
    # norms replicate
    assert param_spec_for("layers/ln1", (30, 576), MESH2) == P()
    # kv projection with tiny kv*dh still fits if divisible
    assert param_spec_for("layers/attn/wk", (30, 576, 192), MESH2) == \
        P(None, "data", "model")


# ---------------------------------------------------------------------------
# real multi-device execution (subprocess with 8 host devices)
# ---------------------------------------------------------------------------

SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.configs.shapes import ShapeCell
    from repro.data import DataConfig, SyntheticTokenPipeline
    from repro.launch.steps import build_train_step, build_serve_step
    from repro.launch.mesh import _mesh_kwargs
    from repro.models.common import DTypePolicy
    from repro.models.transformer import init_model, init_cache
    from repro.optim import adamw

    mesh = jax.make_mesh((2, 4), ("data", "model"), **_mesh_kwargs(2))
    cfg = get_config("%ARCH%").reduced()
    policy = DTypePolicy()  # fp32 for determinism
    shape = ShapeCell("tiny_train", "train", 64, 4)

    opt_cfg = adamw.AdamWConfig(lr_peak=1e-2, warmup_steps=2, total_steps=30)
    step_fn, ispec = build_train_step(cfg, mesh, opt_cfg, policy, remat=True)
    args_sds, in_sh, out_sh = ispec(shape)
    params = init_model(jax.random.PRNGKey(0), cfg, policy)
    opt_state = adamw.init(params, opt_cfg)
    pipe = SyntheticTokenPipeline(DataConfig(cfg.vocab, shape.seq_len,
                                             shape.global_batch, seed=0))
    with mesh:
        jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh)
        losses = []
        for step in range(12):
            batch = pipe.batch(step)
            params, opt_state, m = jitted(params, opt_state, batch)
            losses.append(float(m["loss"]))
        # serve one decode step too; serving uses its own weight layout
        # (expert FFN dim over dp) so reshard once, as a loader would
        serve_fn, sspec = build_serve_step(cfg, mesh, policy)
        dshape = ShapeCell("tiny_decode", "decode", 32, 4)
        sargs, sin, sout = sspec(dshape)
        cache = init_cache(cfg, 4, 32, policy)
        token = jnp.zeros((4,), jnp.int32)
        length = jnp.full((4,), 8, jnp.int32)
        serve_params = jax.device_put(params, sin[0])
        sjit = jax.jit(serve_fn, in_shardings=sin, out_shardings=sout)
        nxt, logits, cache, length = sjit(serve_params, cache, token,
                                          length)
        ok_decode = bool(np.isfinite(np.asarray(logits,
                                                np.float32)).all())
    print(json.dumps({"losses": losses, "ok_decode": ok_decode}))
""")


def _run_sub(arch: str):
    prog = SUBPROCESS_PROG.replace("%ARCH%", arch)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_multidevice_train_loss_decreases_dense():
    out = _run_sub("smollm-135m")
    losses = out["losses"]
    assert losses[-1] < losses[0], f"no learning: {losses}"
    assert out["ok_decode"]


@pytest.mark.slow
def test_multidevice_train_moe_ep():
    """MoE arch exercises the shard_map EP path on a real 2x4 mesh."""
    out = _run_sub("deepseek-v2-236b")
    losses = out["losses"]
    assert all(l == l for l in losses), f"NaN loss: {losses}"
    assert losses[-1] < losses[0] * 1.05
    assert out["ok_decode"]
