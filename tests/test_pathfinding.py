"""Pathfinder API v2 tests: encoding round-trip, batch-vs-scalar parity,
normalizer median fix, strategies and the deprecation shims."""
import random
import warnings

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    SAConfig,
    SimCache,
    TEMPLATES,
    anneal,
    evaluate,
    workload,
)
from repro.core.evaluate import Metrics
from repro.core.sa import random_system
from repro.core.system import is_valid
from repro.core.templates import METRIC_FIELDS, Normalizer
from repro.core.workload import ALL_MAPPINGS
from repro.pathfinding import (
    DesignSpace,
    GridSweep,
    ParallelTempering,
    Pathfinder,
    RandomSearch,
    SimulatedAnnealing,
    evaluate_batch,
    fit_normalizer_batched,
)

SPACE = DesignSpace()
PARITY_FIELDS = METRIC_FIELDS + (
    "l_compute_rd_s", "l_d2d_s", "l_dram_wr_s", "e_compute_j", "e_d2d_j",
    "d2d_bits", "macs")


# ---------------------------------------------------------------------------
# DesignSpace: encode/decode round-trip, validity, sampling
# ---------------------------------------------------------------------------


@given(st.integers(0, 100_000))
@settings(max_examples=60, deadline=None)
def test_encode_decode_roundtrip(seed):
    """Property: decode(encode(sys)) == sys over random valid systems."""
    rng = random.Random(seed)
    sys = random_system(rng)
    vec = SPACE.encode(sys)
    assert SPACE.decode(vec) == sys
    assert SPACE.validity_mask(vec[None, :])[0]


def test_sampled_batches_valid():
    batch = SPACE.sample(512, key=11)
    assert SPACE.validity_mask(batch).all()
    for sys in SPACE.decode_many(batch[:64]):
        assert is_valid(sys)


def test_validity_mask_rejects_corruption():
    batch = SPACE.sample(64, key=3)
    bad = batch.copy()
    bad[:, 1] = 3          # claim hybrid without stack/pair fields
    bad[:, 8] = 0
    assert not SPACE.validity_mask(bad).any()


def test_sampling_covers_all_styles():
    batch = SPACE.sample(1000, key=5)
    assert set(np.unique(batch[:, 1]).tolist()) == {0, 1, 2, 3}


# ---------------------------------------------------------------------------
# evaluate_batch parity (the v2 guarantee)
# ---------------------------------------------------------------------------


def test_batch_scalar_parity_100():
    """>= 100 random systems: every metric field within 1e-6 relative of
    the scalar evaluator (in practice the match is ~1e-16)."""
    wl = workload(1)
    rng = random.Random(42)
    systems = [random_system(rng) for _ in range(120)]
    mb = evaluate_batch(SPACE.encode_many(systems), wl, space=SPACE)
    for i, sys in enumerate(systems):
        m = evaluate(sys, wl)
        for f in PARITY_FIELDS:
            ref = getattr(m, f)
            got = float(getattr(mb, f)[i])
            assert got == pytest.approx(ref, rel=1e-6, abs=1e-300), (
                f"{sys.describe()} field {f}: scalar {ref} batch {got}")


def test_batch_parity_other_workloads():
    rng = random.Random(9)
    systems = [random_system(rng) for _ in range(40)]
    enc = SPACE.encode_many(systems)
    for w in (2, 6):
        wl = workload(w)
        mb = evaluate_batch(enc, wl, space=SPACE)
        for i, sys in enumerate(systems):
            m = evaluate(sys, wl)
            for f in METRIC_FIELDS:
                assert float(getattr(mb, f)[i]) == pytest.approx(
                    getattr(m, f), rel=1e-6)


def test_metrics_batch_row_matches_scalar_type():
    wl = workload(1)
    sys = random_system(random.Random(0))
    mb = evaluate_batch(SPACE.encode(sys)[None, :], wl, space=SPACE)
    row = mb.row(0)
    assert isinstance(row, Metrics)
    assert row.total_cfp == pytest.approx(
        evaluate(sys, wl).total_cfp, rel=1e-6)


def test_metrics_batch_row_integers_exact():
    """Regression: ``row()`` used ``int()`` on the float64 ``d2d_bits`` /
    ``macs`` arrays, which truncates an epsilon-below value to the wrong
    integer. The batched integers must equal the scalar ones exactly."""
    wl = workload(2)
    rng = random.Random(77)
    systems = [random_system(rng) for _ in range(60)]
    mb = evaluate_batch(SPACE.encode_many(systems), wl, space=SPACE)
    for i, sys in enumerate(systems):
        m = evaluate(sys, wl)
        r = mb.row(i)
        assert r.d2d_bits == m.d2d_bits
        assert r.macs == m.macs
    # synthetic epsilon-below float: round-trips to the true integer
    import dataclasses as _dc
    import numpy as np
    fields = {f.name: np.array([1.0]) for f in _dc.fields(mb)}
    fields["d2d_bits"] = np.array([41.999999999999996])
    fields["macs"] = np.array([7.000000000000001])
    from repro.pathfinding import MetricsBatch
    r = MetricsBatch(**fields).row(0)
    assert r.d2d_bits == 42 and r.macs == 7


# ---------------------------------------------------------------------------
# Normalizer: true median (regression for the len//2 bug) + batched fit
# ---------------------------------------------------------------------------


def _metrics_with(vals, field="latency_s"):
    base = dict(latency_s=1.0, energy_j=1.0, area_mm2=1.0, dollar=1.0,
                emb_cfp_kg=1.0, ope_cfp_kg=1.0, l_compute_rd_s=0.0,
                l_d2d_s=0.0, l_dram_wr_s=0.0, e_compute_j=0.0, e_d2d_j=0.0,
                d2d_bits=0, macs=0)
    out = []
    for v in vals:
        d = dict(base)
        d[field] = v
        out.append(Metrics(**d))
    return out


def test_normalizer_true_median_even_population():
    """Regression: vals[len//2] returned the upper-middle element; the
    median of an even-length population is the midpoint average."""
    pop = _metrics_with([1.0, 2.0, 10.0, 20.0])
    norm = Normalizer.fit(pop)
    assert norm.medians["latency_s"] == pytest.approx(6.0)   # (2 + 10) / 2
    assert norm.mins["latency_s"] == 1.0
    odd = Normalizer.fit(_metrics_with([1.0, 3.0, 100.0]))
    assert odd.medians["latency_s"] == 3.0


def test_normalizer_fit_arrays_matches_fit():
    wl = workload(6)
    rng = random.Random(1)
    pop = [evaluate(random_system(rng), wl) for _ in range(101)]
    a = Normalizer.fit(pop)
    b = Normalizer.fit_arrays(
        {f: np.array([getattr(m, f) for m in pop]) for f in METRIC_FIELDS})
    for f in METRIC_FIELDS:
        assert a.mins[f] == pytest.approx(b.mins[f])
        assert a.medians[f] == pytest.approx(b.medians[f])


def test_fit_normalizer_batched_reasonable():
    norm = fit_normalizer_batched(workload(1), samples=400, seed=7)
    for f in METRIC_FIELDS:
        assert norm.medians[f] > 0
        assert norm.mins[f] >= 0


# ---------------------------------------------------------------------------
# Strategies + facade + shims
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pathfinder():
    wl = workload(6)
    cache = SimCache()
    pf = Pathfinder(wl, TEMPLATES["T1"], cache=cache)
    pf.fit_normalizer(samples=300, seed=1, method="scalar")
    return pf


def test_anneal_shim_matches_v2(pathfinder):
    """The deprecated anneal() and the v2 facade produce bit-identical
    trajectories for equal seeds/config."""
    cfg = SAConfig(t_initial=50, t_final=0.05, cooling=0.85,
                   moves_per_temp=15, seed=2)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        res_old = anneal(pathfinder.wl, TEMPLATES["T1"], config=cfg,
                         norm=pathfinder.norm, cache=pathfinder.cache)
        assert any(issubclass(w.category, DeprecationWarning)
                   for w in caught)
    res_new = pathfinder.search(strategy=SimulatedAnnealing(cfg))
    assert res_old.best == res_new.best
    assert res_old.history == res_new.history
    assert res_old.evaluations == res_new.evaluations


def test_parallel_tempering_valid_and_improves(pathfinder):
    res = pathfinder.search(
        strategy=ParallelTempering(n_chains=4, sweeps=30), key=3)
    assert is_valid(res.best)
    assert res.evaluations >= 4 * 30
    assert res.best_cost <= res.history[0] + 1e-12


def test_replica_exchange_moves_better_solution_cold():
    """Detailed balance: when the hotter replica holds the lower cost the
    swap is certain, so the better solution always flows toward the cold
    end (regression for an inverted acceptance sign)."""
    from repro.pathfinding.strategies import _replica_exchange
    rng = random.Random(0)
    for _ in range(20):
        chains = ["hot-better", "cold-worse"]
        costs = [1.0, 5.0]
        _replica_exchange([100.0, 1.0], chains, costs, rng)
        assert chains == ["cold-worse", "hot-better"]
        assert costs == [5.0, 1.0]
    # the reverse swap (demoting a better cold solution) must not be
    # certain: at a large beta gap its probability is ~exp(-large) ~ 0
    chains = ["hot-worse", "cold-better"]
    costs = [5.0, 1.0]
    _replica_exchange([100.0, 0.001], chains, costs, rng)
    assert chains == ["hot-worse", "cold-better"]


def test_random_search_respects_budget(pathfinder):
    res = pathfinder.search(strategy=RandomSearch(batch_size=128),
                            budget=256, key=4)
    assert res.evaluations == 256
    assert is_valid(res.best)


def test_grid_sweep_beats_worst_and_is_deterministic(pathfinder):
    g = GridSweep(memories=("DDR5",), mappings=ALL_MAPPINGS[:2])
    r1 = pathfinder.search(strategy=g)
    r2 = pathfinder.search(strategy=g)
    assert r1.best == r2.best and r1.best_cost == r2.best_cost
    assert r1.evaluations == 2 * 43  # 43 package-protocol combos x 2 maps
    assert min(r1.history) == r1.best_cost


def test_chipletgym_backend(pathfinder):
    pf = Pathfinder(workload(6), TEMPLATES["T1"], objective="chipletgym",
                    cache=pathfinder.cache)
    pf.fit_normalizer(samples=150, seed=5)
    cfg = SAConfig(t_initial=20, t_final=0.1, cooling=0.8,
                   moves_per_temp=8, seed=6)
    res = pf.search(strategy=SimulatedAnnealing(cfg))
    assert res.best_metrics.emb_cfp_kg == 0.0   # gym models no CFP
    # batched interface works through the scalar fallback
    mb = pf.evaluate_batch(SPACE.sample(16, key=2))
    assert (mb.emb_cfp_kg == 0.0).all()


def test_budget_caps_sa(pathfinder):
    cfg = SAConfig(t_initial=100, t_final=0.01, cooling=0.9,
                   moves_per_temp=50, seed=1)
    res = pathfinder.search(strategy=SimulatedAnnealing(cfg), budget=40)
    assert res.evaluations <= 40


def test_budget_guard_rejects_zero_and_non_int(pathfinder):
    """Regression: a zero/negative budget must raise up front in every
    strategy (not silently run the default schedule), and non-integer
    budgets (which would truncate in slicing arithmetic) are a
    TypeError."""
    strategies = (SimulatedAnnealing(SAConfig(seed=1)),
                  ParallelTempering(n_chains=2, sweeps=2),
                  RandomSearch(batch_size=8),
                  GridSweep(memories=("DDR5",)))
    for strat in strategies:
        for bad in (0, -1, -100):
            with pytest.raises(ValueError, match="budget"):
                pathfinder.search(strategy=strat, budget=bad)
        for bad in (1.5, "16", True):
            with pytest.raises(TypeError, match="budget"):
                pathfinder.search(strategy=strat, budget=bad)


def test_search_result_repr_reports_evaluations(pathfinder):
    res = pathfinder.search(strategy=RandomSearch(batch_size=16),
                            budget=32, key=9)
    r = repr(res)
    assert "evaluations=32" in r
    assert "best_cost=" in r and "frontier=" in r
    res_nf = pathfinder.search(
        strategy=RandomSearch(batch_size=16, frontier_size=0),
        budget=16, key=9)
    assert res_nf.frontier is None
    assert "frontier=none" in repr(res_nf)
