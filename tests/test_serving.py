"""Serving-layer tests: job isolation (solo == packed), continuous
batching on the warm engine (zero recompiles after warmup), queue
mechanics (admission, FIFO fairness, cancellation, preemption), whole-
service kill-and-resume through per-job checkpoints, and adaptive
budget donation.

The determinism spine of every test: a job's RNG stream comes from
``fold_job_key(base, job_id)`` and its sweep counter rides per-slot
through the scan, so the same spec must produce bit-identical
history/best/frontier however it is scheduled.
"""
import numpy as np
import pytest

from repro.core import workload
from repro.pathfinding import ScalarizationSweep, fold_job_key
from repro.pathfinding.device import trace_count
from repro.pathfinding.strategies import DEFAULT_SEARCH_KEY
from repro.serving import JobSpec, JobState, PathfinderService

WLS = [workload(1), workload(6)]
STRAT = ScalarizationSweep(directions=2, n_chains=2, sweeps=4)


def make_service(**kw):
    kw.setdefault("slots", 4)
    kw.setdefault("segment", 2)
    kw.setdefault("norm_samples", 80)
    return PathfinderService(WLS, **kw)


def spec(job_id, wl=0, ci=0.475, strategy=STRAT, **kw):
    return JobSpec(job_id=job_id, workload=WLS[wl].name,
                   strategy=strategy, carbon_intensity=ci, **kw)


def run_solo(sp, **svc_kw):
    svc = make_service(**svc_kw)
    svc.submit(sp)
    svc.drain()
    return svc.result(sp.job_id)


def assert_bit_equal(a, b):
    assert a.history == b.history
    assert a.best_cost == b.best_cost
    assert np.array_equal(a.best_enc, b.best_enc)
    assert np.array_equal(a.frontier.vectors, b.frontier.vectors)
    assert np.array_equal(a.frontier.encoded, b.frontier.encoded)


# ---------------------------------------------------------------------------
# Per-job RNG isolation (the serving bugfix)
# ---------------------------------------------------------------------------


def test_fold_job_key_deterministic_and_distinct():
    assert fold_job_key(7, "job-a") == fold_job_key(7, "job-a")
    assert fold_job_key(7, "job-a") != fold_job_key(7, "job-b")
    assert fold_job_key(7, "job-a") != fold_job_key(8, "job-a")
    # job keys are valid PRNGKey seeds (63-bit, like fold_cell_key)
    assert 0 <= fold_job_key(DEFAULT_SEARCH_KEY, "x") < 2 ** 63


def test_job_bit_identical_with_0_1_3_cotenants():
    """The regression test of the RNG-isolation bugfix: pack the same
    seeded job next to 0, 1 and 3 arbitrary co-tenants and bit-compare
    history/best/frontier. Would fail if the stream depended on slot
    index (the engine's on-device per-slot fold_in) or on co-tenant
    contents (any cross-lane op in the scan)."""
    anchor = spec("anchor", wl=0, ci=0.276)
    results = []
    for n_cotenants in (0, 1, 3):
        svc = make_service()
        svc.submit(anchor)
        for i in range(n_cotenants):
            svc.submit(spec(f"noise-{i}", wl=i % 2,
                            ci=[0.024, 0.475, 0.82][i % 3]))
        svc.drain()
        results.append(svc.result("anchor"))
    assert_bit_equal(results[0], results[1])
    assert_bit_equal(results[0], results[2])
    # and the co-tenants are genuinely different searches
    noise = make_service()
    noise.submit(spec("noise-0", wl=1, ci=0.024))
    noise.drain()
    assert noise.result("noise-0").history != results[0].history


# ---------------------------------------------------------------------------
# Continuous batching on the warm engine
# ---------------------------------------------------------------------------


def test_admission_into_partially_full_batch_zero_recompiles():
    """Jobs join the live batch at segment boundaries: a job admitted
    while another is mid-flight still reproduces its solo run, and
    after the bucket warmup no program is ever retraced (the N>=4
    concurrent-jobs acceptance gate)."""
    svc = make_service()
    svc.submit(spec("early", wl=0, ci=0.475))
    assert svc.step()           # bucket warmup + admit + first segment
    before = {k: trace_count(k)
              for k in ("scenario_pt", "scenario_init", "pt", "eval_cost")}
    # join mid-flight, mixed workloads/regions, same bucket shape
    svc.submit(spec("late-0", wl=1, ci=0.024))
    svc.submit(spec("late-1", wl=0, ci=0.82))
    svc.submit(spec("late-2", wl=1, ci=0.475))
    svc.drain()
    after = {k: trace_count(k) for k in before}
    assert after == before, "admission/draining must replay cached programs"
    for jid in ("early", "late-0", "late-1", "late-2"):
        assert svc.status(jid) is JobState.DONE
    # every job matches its solo uninterrupted reference, bit for bit
    assert_bit_equal(svc.result("early"),
                     run_solo(spec("early", wl=0, ci=0.475)))
    assert_bit_equal(svc.result("late-0"),
                     run_solo(spec("late-0", wl=1, ci=0.024)))


@pytest.mark.slow
def test_mixed_shape_buckets_compile_once_each():
    fat = ScalarizationSweep(directions=2, n_chains=4, sweeps=4)
    svc = make_service(slots=2)
    svc.submit(spec("thin", strategy=STRAT))
    svc.submit(spec("wide", strategy=fat))
    svc.step()                  # both buckets warm up (2 programs each)
    before = {k: trace_count(k)
              for k in ("scenario_pt", "scenario_init")}
    svc.submit(spec("thin-2", strategy=STRAT, ci=0.82))
    svc.submit(spec("wide-2", strategy=fat, ci=0.82))
    svc.drain()
    assert {k: trace_count(k) for k in before} == before
    assert svc.result("wide").sweeps == 4
    assert_bit_equal(svc.result("thin-2"),
                     run_solo(spec("thin-2", strategy=STRAT, ci=0.82)))


# ---------------------------------------------------------------------------
# Queue mechanics
# ---------------------------------------------------------------------------


def test_fifo_fairness_under_contention():
    svc = make_service(slots=1)
    order = []
    for jid in ("a", "b", "c"):
        svc.submit(spec(jid, strategy=ScalarizationSweep(
            directions=2, n_chains=2, sweeps=4)))
    while svc._work_left():
        svc.step()
        for jid in ("a", "b", "c"):
            if svc.status(jid) is JobState.RUNNING and (
                    not order or order[-1] != jid):
                order.append(jid)
    assert order == ["a", "b", "c"], "single slot must serve FIFO"
    assert all(svc.status(j) is JobState.DONE for j in "abc")


def test_cancel_releases_slot_for_next_job():
    svc = make_service(slots=1)
    long = ScalarizationSweep(directions=2, n_chains=2, sweeps=8)
    svc.submit(spec("doomed", strategy=long))
    svc.submit(spec("next", strategy=STRAT))
    svc.step()
    assert svc.status("doomed") is JobState.RUNNING
    assert svc.status("next") is JobState.PENDING
    svc.cancel("doomed")
    svc.step()                  # boundary applies the cancel
    assert svc.status("doomed") is JobState.CANCELLED
    svc.drain()
    assert svc.status("next") is JobState.DONE
    with pytest.raises(RuntimeError, match="cancelled"):
        svc.result("doomed")
    # the freed slot served the successor bit-identically to solo
    assert_bit_equal(svc.result("next"), run_solo(spec("next")))
    # cancelling a PENDING job never occupies a slot
    svc.submit(spec("never-ran"))
    svc.cancel("never-ran")
    assert svc.status("never-ran") is JobState.CANCELLED


def test_pause_at_boundary_then_resume_bit_identical():
    sp = spec("pausee", strategy=ScalarizationSweep(
        directions=2, n_chains=2, sweeps=8))
    svc = make_service()
    svc.submit(sp)
    svc.step()
    svc.pause("pausee")
    svc.step()                  # one more segment, then parked
    assert svc.status("pausee") is JobState.PAUSED
    assert not svc._work_left()         # paused jobs don't block drain
    svc.resume_job("pausee")
    svc.drain()
    assert_bit_equal(svc.result("pausee"), run_solo(sp))


def test_submit_validation():
    svc = make_service()
    with pytest.raises(ValueError, match="unknown workload"):
        svc.submit(JobSpec(job_id="x", workload="nope"))
    with pytest.raises(ValueError, match="frontier_size"):
        svc.submit(spec("x", strategy=ScalarizationSweep(
            directions=2, n_chains=2, sweeps=2, frontier_size=0)))
    svc.submit(spec("dup"))
    with pytest.raises(ValueError, match="already"):
        svc.submit(spec("dup"))
    with pytest.raises(KeyError):
        svc.status("ghost")
    with pytest.raises(RuntimeError, match="no worker"):
        svc.result("dup")


def test_worker_thread_and_budget():
    """Background worker mode + the budget_sweeps total-split semantics
    (budget 12 at population 4 pays 2 whole sweeps -> rounded up to one
    2-sweep segment)."""
    with make_service().start() as svc:
        svc.submit(spec("bg", budget=12))
        res = svc.result("bg", timeout=300)
    assert res.sweeps == 2
    assert res.evaluations == 4 * (1 + 2)
    # budget validation happens lazily at admission and surfaces as a
    # FAILED job, not a submit-time exception
    svc2 = make_service()
    svc2.submit(spec("starved", budget=3))
    svc2.drain()
    assert svc2.status("starved") is JobState.FAILED
    with pytest.raises(RuntimeError, match="failed"):
        svc2.result("starved")


def test_terminal_job_gc_evicts_oldest_past_retention_cap():
    """Terminal-job GC: with ``retain_jobs=2``, finishing four jobs
    keeps only the two newest-finished records; evicted ids raise
    :class:`JobEvictedError` (a ``KeyError`` that says *why* the id is
    gone) instead of a bare unknown-job KeyError, and resubmitting an
    evicted id starts a fresh job."""
    from repro.serving import JobEvictedError

    svc = make_service(retain_jobs=2)
    ids = [f"gc-{i}" for i in range(4)]
    for jid in ids:
        svc.submit(spec(jid, wl=0))
    svc.drain()
    evicted = [jid for jid in ids if jid not in svc._jobs]
    kept = [jid for jid in ids if jid in svc._jobs]
    assert len(evicted) == 2 and len(kept) == 2
    # kept jobs stay fully readable
    for jid in kept:
        assert svc.status(jid) is JobState.DONE
        assert svc.result(jid).job_id == jid
    # evicted ids: status AND result raise the self-explaining subclass
    for jid in evicted:
        for access in (svc.status, svc.result):
            with pytest.raises(JobEvictedError) as ei:
                access(jid)
            assert isinstance(ei.value, KeyError)
            msg = str(ei.value)
            assert "retain_jobs=2" in msg and jid in msg
            assert "resubmit" in msg
    # a never-seen id is still a plain unknown-job KeyError
    with pytest.raises(KeyError) as ei:
        svc.status("never-submitted")
    assert not isinstance(ei.value, JobEvictedError)
    # resubmitting an evicted id clears the tombstone and runs again
    svc.submit(spec(evicted[0], wl=0))
    svc.drain()
    assert svc.status(evicted[0]) is JobState.DONE
    assert svc.result(evicted[0]).history == run_solo(
        spec(evicted[0], wl=0)).history
    # the cap is validated up front
    with pytest.raises(ValueError, match="retain_jobs"):
        make_service(retain_jobs=0)


# ---------------------------------------------------------------------------
# Kill-and-resume of the whole service
# ---------------------------------------------------------------------------


def test_service_restart_resumes_jobs_bit_identical(tmp_path):
    specs = [spec("r0", wl=0, ci=0.475,
                  strategy=ScalarizationSweep(directions=2, n_chains=2,
                                              sweeps=8)),
             spec("r1", wl=1, ci=0.024,
                  strategy=ScalarizationSweep(directions=2, n_chains=2,
                                              sweeps=8))]
    refs = [run_solo(sp) for sp in specs]

    svc = make_service(checkpoint_root=str(tmp_path))
    for sp in specs:
        svc.submit(sp)
    svc.step()
    svc.step()                  # two boundaries snapshotted, then "die"
    del svc

    before = {k: trace_count(k)
              for k in ("scenario_pt", "scenario_init")}
    svc2 = make_service(checkpoint_root=str(tmp_path))
    for sp in specs:
        svc2.submit(sp)         # same job ids -> restore from snapshots
    svc2.drain()
    # the restarted service replays the warm engine's cached programs
    assert {k: trace_count(k) for k in before} == before
    for sp, ref in zip(specs, refs):
        assert_bit_equal(svc2.result(sp.job_id), ref)
        assert svc2.result(sp.job_id).sweeps == ref.sweeps


def test_restored_complete_job_finalizes_without_rerun(tmp_path):
    sp = spec("done-before", strategy=STRAT)
    svc = make_service(checkpoint_root=str(tmp_path))
    svc.submit(sp)
    svc.drain()
    ref = svc.result("done-before")
    svc2 = make_service(checkpoint_root=str(tmp_path))
    svc2.submit(sp)
    svc2.drain()
    res = svc2.result("done-before")
    assert res.sweeps == ref.sweeps
    assert_bit_equal(res, ref)


# ---------------------------------------------------------------------------
# Adaptive per-cell budgets
# ---------------------------------------------------------------------------


def test_hypervolume_stall_donates_sweeps_to_hard_jobs():
    """A converged job's remaining sweeps move to a still-improving one:
    the donor stops early, the drawer overshoots its nominal budget by
    exactly the donation, total consumption never exceeds the total
    nominal budget, and the drawer's trajectory is a bit-identical
    *extension* of its fixed-budget run (donation changes when a job
    stops, never the stream it consumes)."""
    eight = ScalarizationSweep(directions=2, n_chains=2, sweeps=8)
    donor = spec("donor", wl=0, strategy=eight, stall_tol=1e9,
                 stall_segments=1)
    drawer = spec("drawer", wl=1, ci=0.82, strategy=eight,
                  stall_tol=-1.0)
    svc = make_service(adaptive=True)
    svc.submit(donor)
    svc.submit(drawer)
    svc.step()
    before = {k: trace_count(k)
              for k in ("scenario_pt", "scenario_init")}
    svc.drain()
    d, w = svc.result("donor"), svc.result("drawer")
    # donor converged at its 2nd boundary (ref at 1st, stalled at 2nd)
    assert d.converged_early and d.sweeps == 4
    # drawer drew the donated 4 sweeps beyond its nominal 8
    assert not w.converged_early and w.sweeps == 12
    assert d.sweeps + w.sweeps == 16        # conservation at equal total
    assert svc.donated_pool(donor.bucket_key()) == 0
    # extension property: fixed-budget run is a strict prefix
    fixed = run_solo(spec("drawer", wl=1, ci=0.82, strategy=eight))
    assert w.history[:len(fixed.history)] == fixed.history
    assert len(w.history) == len(fixed.history) + 4
    # donated segments replay the same compiled program
    assert {k: trace_count(k) for k in before} == before


def test_adaptive_mean_hypervolume_not_worse_than_fixed():
    """The acceptance gate, in miniature: at equal total sweep budget,
    adaptive mode's mean per-cell hypervolume >= fixed mode's (donated
    sweeps only ever extend still-improving frontiers; archives are
    unpruned at these sizes, so extra sweeps cannot lose points)."""
    eight = ScalarizationSweep(directions=2, n_chains=2, sweeps=8)
    cells = [("c0", 0, 0.024), ("c1", 1, 0.475), ("c2", 0, 0.82)]

    def run(adaptive):
        svc = make_service(adaptive=adaptive, stall_segments=1,
                           stall_tol=0.0)
        for jid, wl, ci in cells:
            svc.submit(spec(jid, wl=wl, ci=ci, strategy=eight))
        svc.drain()
        return [svc.result(jid) for jid, *_ in cells]

    fixed, adapt = run(False), run(True)
    assert sum(r.sweeps for r in adapt) <= sum(r.sweeps for r in fixed)
    # compare on common per-cell reference points (fixed's nadir+margin)
    from repro.pathfinding.pareto import hypervolume

    hv_f, hv_a = [], []
    for rf, ra in zip(fixed, adapt):
        ref = np.maximum(rf.frontier.reference_point(),
                         ra.frontier.reference_point())
        hv_f.append(hypervolume(rf.frontier.vectors, ref))
        hv_a.append(hypervolume(ra.frontier.vectors, ref))
    assert np.mean(hv_a) >= np.mean(hv_f) - 1e-12
