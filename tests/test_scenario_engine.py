"""Scenario-engine tests: the stacked one-compile grid sweep, per-cell
RNG key folding, total-budget accounting, batched region-normalizer
fits, and the scenario-axis sharding path.

The compile-count regressions read :func:`repro.pathfinding.device
.trace_count`: a jit-wrapped Python body runs once per fresh XLA compile
and never on cache hits, so before/after deltas count compiles exactly.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import TEMPLATES, workload
from repro.core.techdb import DEFAULT_DB
from repro.pathfinding import (
    DesignSpace,
    ParallelTempering,
    Pathfinder,
    ScalarizationSweep,
    ScenarioSweep,
    fit_normalizer_batched,
    fit_region_normalizers,
    fold_cell_key,
    non_dominated_mask,
)
from repro.pathfinding.device import trace_count
from repro.pathfinding.strategies import DEFAULT_SEARCH_KEY

SPACE = DesignSpace()
WL = workload(1)


# ---------------------------------------------------------------------------
# Per-cell key folding (the shared-RNG bugfix)
# ---------------------------------------------------------------------------


def test_fold_cell_key_distinct_and_deterministic():
    keys = [fold_cell_key(7, i) for i in range(64)]
    assert len(set(keys)) == 64, "cells must get distinct streams"
    assert keys == [fold_cell_key(7, i) for i in range(64)]
    # distinct bases give distinct folds
    assert fold_cell_key(0, 3) != fold_cell_key(1, 3)
    # key=0 is a valid base, distinct from the key=None default
    assert fold_cell_key(0, 0) != fold_cell_key(DEFAULT_SEARCH_KEY, 0)


@pytest.mark.slow
def test_key_zero_distinct_from_default_key(norm_wl1):
    """key=None resolves to DEFAULT_SEARCH_KEY, so key=0 is its own
    stream (previously both collapsed onto seed 0 in _search_device)."""
    pf = Pathfinder(WL, TEMPLATES["T1"], norm=norm_wl1, space=SPACE)
    strat = ParallelTempering(n_chains=4, sweeps=10)
    r_none = pf.search(strategy=strat)
    r_zero = pf.search(strategy=strat, key=0)
    r_default = pf.search(strategy=strat, key=DEFAULT_SEARCH_KEY)
    assert r_none.history == r_default.history
    assert r_none.history != r_zero.history


@pytest.fixture(scope="module")
def norm_wl1():
    return fit_normalizer_batched(WL, samples=400, seed=7, space=SPACE)


# ---------------------------------------------------------------------------
# Batched region-normalizer fits
# ---------------------------------------------------------------------------


def test_region_normalizers_bit_identical_to_per_region_fits():
    """One evaluate_batch + per-region ope rescale must equal a full
    per-region fit exactly — only operational CFP depends on the grid
    intensity, and it is a pure scalar multiple of energy."""
    cis = [0.024, 0.475, 0.82]
    fitted = fit_region_normalizers(WL, cis, samples=120, seed=9,
                                    space=SPACE)
    for ci, nz in zip(cis, fitted):
        db_s = dataclasses.replace(DEFAULT_DB, carbon_intensity=ci)
        ref = fit_normalizer_batched(WL, db_s, samples=120, seed=9,
                                     space=DesignSpace(db_s))
        assert nz.mins == ref.mins
        assert nz.medians == ref.medians


# ---------------------------------------------------------------------------
# Total-budget accounting (the silent budget-multiplication bugfix)
# ---------------------------------------------------------------------------


def test_budget_below_one_eval_per_cell_rejected():
    sweep = ScenarioSweep(
        strategy=ScalarizationSweep(directions=2, n_chains=2, sweeps=3),
        regions={"a": 0.1, "b": 0.5}, norm_samples=80)
    with pytest.raises(ValueError, match="one evaluation per cell"):
        sweep.run(WL, budget=1, key=1)


@pytest.mark.slow
def test_budget_is_total_across_cells():
    """budget= is the sweep total, split evenly — not per cell."""
    sweep = ScenarioSweep(
        strategy=ScalarizationSweep(directions=2, n_chains=2, sweeps=10),
        regions={"clean": 0.024, "dirty": 0.82}, norm_samples=80)
    sf = sweep.run(WL, budget=40, key=2)
    evals = [sf.results[s.key].evaluations for s in sf.scenarios]
    assert sum(evals) <= 40
    # 40 // 2 cells = 20 each; population 4 -> 4 whole sweeps -> 20 evals
    assert evals == [20, 20]
    # a budget below one chain population per cell is rejected loudly
    with pytest.raises(ValueError, match="chain population"):
        sweep.run(WL, budget=7, key=2)


# ---------------------------------------------------------------------------
# The one-compile stacked grid (ISSUE acceptance grid: 5 regions x 2 wl)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_scenario_sweep_5x2_compiles_once_cells_differ_reproducible():
    wls = [workload(1), workload(6)]
    sweep = ScenarioSweep(
        strategy=ScalarizationSweep(directions=2, n_chains=2, sweeps=3),
        norm_samples=100)  # default REGION_INTENSITIES: 5 regions
    before = {k: trace_count(k) for k in ("scenario_pt", "pt", "eval_cost")}
    sf = sweep.run(wls, key=11)
    assert len(sf.scenarios) == 10
    # exactly ONE fused scenario-scan compile, ZERO per-cell programs
    assert trace_count("scenario_pt") == before["scenario_pt"] + 1
    assert trace_count("pt") == before["pt"]
    assert trace_count("eval_cost") == before["eval_cost"]
    fronts = [sf.results[s.key].frontier.vectors for s in sf.scenarios]
    for f in fronts:
        assert len(f) >= 1 and non_dominated_mask(f).all()
    # distinct cells explore with distinct streams: no two identical
    for i in range(len(fronts)):
        for j in range(i + 1, len(fronts)):
            assert not np.array_equal(fronts[i], fronts[j]), (i, j)
    # reproducible per key, and the rerun hits the jit cache
    sf2 = sweep.run(wls, key=11)
    assert trace_count("scenario_pt") == before["scenario_pt"] + 1
    for s in sf.scenarios:
        assert np.array_equal(sf.results[s.key].frontier.vectors,
                              sf2.results[s.key].frontier.vectors)
        assert (sf.results[s.key].best_cost
                == sf2.results[s.key].best_cost)
    # a different key moves the frontiers (same shapes: still no compile)
    sf3 = sweep.run(wls, key=12)
    assert trace_count("scenario_pt") == before["scenario_pt"] + 1
    assert any(
        not np.array_equal(sf.results[s.key].frontier.vectors,
                           sf3.results[s.key].frontier.vectors)
        for s in sf.scenarios)
    # (the region -> operational-CFP shift itself is asserted at a
    # meaningful budget by test_pareto.test_scenario_sweep_regions_shift_cfp)


@pytest.mark.slow
def test_scenario_sweep_pallas_parity_5x2(monkeypatch):
    """Kernel fast path vs jnp reference on the acceptance grid (5
    regions x 2 workloads): same designs, metrics within the XLA
    fusion-noise band.

    The two runs share everything but ``REPRO_PATHFINDER_PALLAS`` (the
    engine cache keys on the resolved setting, so each run builds its
    own engine). The stacked kernel gathers from int64 prefix tables and
    interpret mode subtracts them exactly, so the only divergence is
    1-2 ulp of downstream float fusion across the pallas custom-call
    boundary — orders of magnitude inside the 1e-6 acceptance bound."""
    wls = [workload(1), workload(6)]
    sweep = ScenarioSweep(
        strategy=ScalarizationSweep(directions=2, n_chains=2, sweeps=3),
        norm_samples=100)  # default REGION_INTENSITIES: 5 regions

    def run(env):
        monkeypatch.setenv("REPRO_PATHFINDER_PALLAS", env)
        return sweep.run(wls, key=11)

    ref, fast = run("0"), run("1")
    assert len(ref.scenarios) == 10
    for s in ref.scenarios:
        a, b = ref.results[s.key], fast.results[s.key]
        assert np.allclose(a.best_cost, b.best_cost,
                           rtol=1e-9, atol=1e-12), s.key
        assert np.allclose(a.history, b.history,
                           rtol=1e-9, atol=1e-12), s.key
        # the search visits the same designs: proposal/accept streams
        # did not diverge anywhere on the grid
        assert a.best == b.best, s.key
        assert a.frontier.vectors.shape == b.frontier.vectors.shape
        assert np.allclose(a.frontier.vectors, b.frontier.vectors,
                           rtol=1e-9, atol=1e-12), s.key


@pytest.mark.slow
def test_run_scenarios_facade(norm_wl1):
    pf = Pathfinder(WL, TEMPLATES["T1"], norm=norm_wl1, space=SPACE)
    sweep = ScenarioSweep(
        strategy=ScalarizationSweep(directions=2, n_chains=2, sweeps=2),
        norm_samples=80)
    sf = pf.run_scenarios(sweep=sweep,
                          regions={"clean": 0.024, "dirty": 0.82}, key=4)
    assert len(sf.scenarios) == 2
    assert {s.region for s in sf.scenarios} == {"clean", "dirty"}
    merged = sf.merged(WL.name)
    assert len(merged) >= 1 and non_dominated_mask(merged.vectors).all()


# ---------------------------------------------------------------------------
# Scenario-axis sharding (run under
# XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise)
# ---------------------------------------------------------------------------


def test_scenario_sweep_sharded_matches_unsharded():
    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs >1 local devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    from repro.distributed.sharding import scenario_mesh

    assert scenario_mesh() is not None
    wls = [workload(1), workload(6)]
    regions = {"hydro": 0.024, "eu-avg": 0.276,
               "world-avg": 0.475, "coal-heavy": 0.82}
    strat = ScalarizationSweep(directions=2, n_chains=2, sweeps=2)
    run = lambda shard: ScenarioSweep(   # noqa: E731
        strategy=strat, regions=regions, norm_samples=80,
        shard=shard).run(wls, key=5)
    sharded = run("auto")      # 8 cells over the virtual devices
    unsharded = run(False)
    assert len(sharded.scenarios) == 8
    for s in sharded.scenarios:
        a = sharded.results[s.key]
        b = unsharded.results[s.key]
        assert np.isfinite(a.best_cost)
        assert np.array_equal(a.frontier.vectors, b.frontier.vectors)
        assert a.best_cost == b.best_cost


def test_scenario_sweep_sharded_interrupt_resume_matches():
    """Checkpoint/resume under scenario-axis sharding: interrupt the
    sharded grid at a segment boundary, resume, and match the
    uninterrupted sharded run bit-for-bit without a second scan compile
    (the restored carry is re-placed onto the mesh)."""
    import tempfile

    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs >1 local devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    from repro.pathfinding.resume import SearchCheckpointer
    import repro.pathfinding.strategies as strategies_mod

    wls = [workload(1), workload(6)]
    regions = {"hydro": 0.024, "coal-heavy": 0.82}
    sweep = ScenarioSweep(
        strategy=ScalarizationSweep(directions=2, n_chains=2, sweeps=4),
        regions=regions, norm_samples=80, shard="auto")
    run = lambda **kw: sweep.run(wls, key=6, segment=2, **kw)  # noqa: E731
    ref = run()

    class Dying(SearchCheckpointer):
        saves = 0

        def save(self, *a, **kw):
            path = super().save(*a, **kw)
            Dying.saves += 1
            if Dying.saves == 1:
                raise KeyboardInterrupt("simulated preemption")
            return path

    with tempfile.TemporaryDirectory() as d:
        orig = strategies_mod._checkpointer
        strategies_mod._checkpointer = (
            lambda cd: Dying(cd) if cd is not None else None)
        try:
            with pytest.raises(KeyboardInterrupt):
                run(checkpoint_dir=d)
        finally:
            strategies_mod._checkpointer = orig
        before = trace_count("scenario_pt")
        res = run(checkpoint_dir=d)
        # the resumed segment reuses the sharded program signature
        assert trace_count("scenario_pt") == before
    for s in ref.scenarios:
        a, b = res.results[s.key], ref.results[s.key]
        assert np.array_equal(a.frontier.vectors, b.frontier.vectors)
        assert a.best_cost == b.best_cost
        assert a.history == b.history
