"""Property-based tests over the encode/evaluate/search contracts.

Runs under real ``hypothesis`` when installed (the CI path — see
requirements-dev.txt) and under the deterministic fixed-example fallback
otherwise (tests/_hypothesis_fallback.py), so the properties are always
exercised. The invariants locked down here are the ones the next
refactor is most likely to break:

* ``DesignSpace`` encode -> decode -> encode is the identity on encoded
  rows (both directions of the round-trip);
* every ``sample`` batch passes ``validity_mask`` *and* the scalar
  ``is_valid`` reference, for arbitrary seeds;
* ``propose_batch`` outputs stay inside the per-column encoding bounds
  and valid, for arbitrary seeds — the device move generator can never
  step outside the design space.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import workload
from repro.core.system import is_valid
from repro.pathfinding import DesignSpace, propose_batch
from repro.pathfinding.pareto import non_dominated_mask, \
    non_dominated_mask_jnp

SPACE = DesignSpace()
WL = workload(1)


@given(st.integers(0, 2**31 - 1), st.integers(1, 96))
@settings(max_examples=25, deadline=None)
def test_encode_decode_encode_roundtrip(seed, count):
    """decode is a right-inverse of encode on sampled rows: the encoded
    population survives a decode -> encode round-trip bit-for-bit."""
    batch = SPACE.sample(count, key=seed)
    again = SPACE.encode_many(SPACE.decode_many(batch))
    assert np.array_equal(batch, again)


@given(st.integers(0, 2**31 - 1), st.integers(1, 256))
@settings(max_examples=25, deadline=None)
def test_sample_batches_always_valid(seed, count):
    """Every sampled row is valid by construction: the vectorized mask
    accepts it and (spot-checked) so does the scalar reference."""
    batch = SPACE.sample(count, key=seed)
    assert SPACE.validity_mask(batch).all()
    lo, hi = SPACE.bounds()
    active = batch >= 0          # -1 is padding everywhere it appears
    assert (batch[active] <= np.broadcast_to(hi, batch.shape)[active]).all()
    for sys in SPACE.decode_many(batch[:8]):
        assert is_valid(sys)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_propose_batch_stays_in_bounds(seed):
    """Device moves never leave the encoding: outputs are valid rows
    whose every column sits inside DesignSpace.bounds()."""
    enc = SPACE.sample(64, key=seed % 7)   # few pops: shared jit buckets
    out = propose_batch(enc, WL, space=SPACE, seed=seed)
    assert out.shape == enc.shape and out.dtype == np.int32
    assert SPACE.validity_mask(out).all()
    lo, hi = SPACE.bounds()
    assert (out >= np.broadcast_to(lo, out.shape)).all()
    assert (out <= np.broadcast_to(hi, out.shape)).all()
    for sys in SPACE.decode_many(out[:4]):
        assert is_valid(sys)


@given(st.integers(0, 2**31 - 1), st.integers(2, 40))
@settings(max_examples=20, deadline=None)
def test_non_dominated_filter_equivalence_property(seed, size):
    """Host reference and jnp filter agree exactly on arbitrary fronts,
    including injected duplicates."""
    rng = np.random.default_rng(seed)
    pts = rng.random((size, 3))
    pts[size // 2] = pts[0]      # force one exact duplicate
    host = non_dominated_mask(pts)
    assert (host == non_dominated_mask_jnp(pts)).all()
    assert host.any()            # a finite front always has a survivor


def test_bounds_cover_encoding_columns():
    lo, hi = SPACE.bounds()
    assert lo.shape == hi.shape == (SPACE.width,)
    assert (hi >= lo).all()
    # spot values: chiplet count and style ranges
    assert lo[0] == 1 and hi[0] == SPACE.max_chiplets
    assert hi[1] == 3
    # sampled batches sit inside the bounds (loose-bound contract)
    batch = SPACE.sample(128, key=0)
    assert (batch >= np.broadcast_to(lo, batch.shape)).all()
    assert (batch <= np.broadcast_to(hi, batch.shape)).all()
