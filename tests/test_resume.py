"""Interruptible pathfinding: the segmented scan engine and its
checkpoint/resume invariants.

The contract under test: segmentation is *invisible* — a run advanced in
fixed-size segments consumes the identical key stream and sweep indices
as the monolithic scan, so (a) segmented == monolithic bit-for-bit, and
(b) a run interrupted at any segment boundary then resumed from its
checkpoint reproduces the uninterrupted run bit-for-bit (history, best,
frontier archive contents). A subprocess variant exercises a real
process death at a boundary; the CI kill-and-resume lane SIGTERMs a live
sweep mid-run (scripts/resume_worker.py).
"""
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from repro.core import TEMPLATES, workload
from repro.pathfinding import (
    DesignSpace,
    ParallelTempering,
    ParetoArchive,
    Pathfinder,
    SearchCheckpointer,
    fit_normalizer_batched,
)
from repro.pathfinding.device import get_device_evaluator, trace_count

SPACE = DesignSpace()
WL = workload(1)
TPL = TEMPLATES["T1"]


@pytest.fixture(scope="module")
def norm():
    return fit_normalizer_batched(WL, samples=400, seed=7, space=SPACE)


@pytest.fixture(scope="module")
def dev():
    return get_device_evaluator(WL, space=SPACE)


def _pt_args(n=4, seed=11):
    rng = np.random.default_rng(0)
    v0 = SPACE.sample(n, key=rng)
    ratio = (1.0 / 4000.0) ** (1.0 / (n - 1))
    temps = np.array([4000.0 * ratio ** i for i in range(n)])
    return v0, temps, seed


def _run(dev, norm, sweeps=12, frontier=4096, **kw):
    """Engine run with an external archive; frontier large enough that
    crowding pruning never engages (archive contents are then chunking-
    independent, so equality checks are exact by construction)."""
    v0, temps, seed = _pt_args()
    archive = ParetoArchive(max_size=frontier)
    res = dev.parallel_tempering(v0, temps, sweeps, 5, seed=seed,
                                 norm=norm, template=TPL,
                                 archive=archive, **kw)
    return res, archive


class _DyingCheckpointer(SearchCheckpointer):
    """Raises (simulating preemption) after N segment-boundary saves —
    the save itself completes first, exactly like SIGTERM landing
    between a finished snapshot and the next segment."""

    def __init__(self, directory, die_after):
        super().__init__(directory)
        self.die_after = die_after
        self._saves = 0

    def save(self, *a, **kw):
        path = super().save(*a, **kw)
        self._saves += 1
        if self._saves >= self.die_after:
            raise KeyboardInterrupt("simulated preemption")
        return path


@pytest.mark.slow
def test_segmented_matches_monolithic_bit_for_bit(dev, norm):
    ref, ref_arch = _run(dev, norm, sweeps=12, segment=None)
    for segment in (5, 1, 12, 30):
        got, got_arch = _run(dev, norm, sweeps=12, segment=segment)
        assert got.history == ref.history, f"segment={segment}"
        assert got.best_cost == ref.best_cost
        assert np.array_equal(got.best_enc, ref.best_enc)
        assert np.array_equal(got.final_enc, ref.final_enc)
        assert np.array_equal(got.final_costs, ref.final_costs)
        assert np.array_equal(got_arch.vectors, ref_arch.vectors)
        assert np.array_equal(got_arch.encoded, ref_arch.encoded)


@pytest.mark.slow
def test_interrupt_any_boundary_resume_bit_identical(dev, norm):
    """Kill after each possible boundary in turn; every resumed run must
    reproduce the uninterrupted segmented reference exactly."""
    ref, ref_arch = _run(dev, norm, sweeps=12, segment=5)  # segs 5,5,2
    for die_after in (1, 2, 3):
        with tempfile.TemporaryDirectory() as d:
            ck = _DyingCheckpointer(d, die_after=die_after)
            # a snapshot follows every segment (incl. the last), so the
            # dying checkpointer fires at every boundary choice
            with pytest.raises(KeyboardInterrupt):
                _run(dev, norm, sweeps=12, segment=5, checkpoint=ck)
            res, arch = _run(dev, norm, sweeps=12, segment=5,
                             checkpoint=SearchCheckpointer(d))
            assert res.history == ref.history, f"die_after={die_after}"
            assert res.best_cost == ref.best_cost
            assert np.array_equal(res.best_enc, ref.best_enc)
            assert np.array_equal(res.final_enc, ref.final_enc)
            assert np.array_equal(arch.vectors, ref_arch.vectors)
            assert np.array_equal(arch.encoded, ref_arch.encoded)


@pytest.mark.slow
def test_resume_after_completion_is_a_noop(dev, norm):
    with tempfile.TemporaryDirectory() as d:
        a, arch_a = _run(dev, norm, sweeps=10, segment=5,
                         checkpoint=SearchCheckpointer(d))
        before = trace_count("pt")
        b, arch_b = _run(dev, norm, sweeps=10, segment=5,
                         checkpoint=SearchCheckpointer(d))
        # restored at sweep 10: no segment runs, no compile, same result
        assert trace_count("pt") == before
        assert b.history == a.history and b.best_cost == a.best_cost
        assert np.array_equal(arch_b.vectors, arch_a.vectors)


@pytest.mark.slow
def test_fingerprint_mismatch_rejected(dev, norm):
    with tempfile.TemporaryDirectory() as d:
        ck = SearchCheckpointer(d)
        _run(dev, norm, sweeps=10, segment=5, checkpoint=ck)
        v0, temps, _ = _pt_args()
        with pytest.raises(ValueError, match="different search"):
            dev.parallel_tempering(
                v0, temps, 10, 5, seed=999, norm=norm, template=TPL,
                archive=ParetoArchive(max_size=64), segment=5,
                checkpoint=SearchCheckpointer(d))
        # a config mismatch must never be misread as corruption: the
        # rejected snapshots stay on disk for the original config
        assert SearchCheckpointer(d).manager.all_steps(), \
            "fingerprint rejection pruned valid snapshots"
        # same protection when the template drops the archive entirely
        # (frontier collection off => different fingerprint, not a
        # checksum-subset false corruption)
        with pytest.raises(ValueError, match="different search"):
            dev.parallel_tempering(
                v0, temps, 10, 5, seed=_pt_args()[2], norm=norm,
                template=TPL, collect_samples=False, segment=5,
                checkpoint=SearchCheckpointer(d))
        assert SearchCheckpointer(d).manager.all_steps()
        # resume=False ignores the stale state and starts fresh
        res = dev.parallel_tempering(
            v0, temps, 10, 5, seed=999, norm=norm, template=TPL,
            archive=ParetoArchive(max_size=64), segment=5,
            checkpoint=SearchCheckpointer(d), resume=False)
        assert len(res.history) == 11


@pytest.mark.slow
def test_zero_sweep_run_returns_seed_only(dev, norm):
    """budget == population clamps sweeps to 0; the segmented loop must
    degrade to the seed evaluation like the monolithic scan did."""
    pf = Pathfinder(WL, TPL, norm=norm, space=SPACE)
    res = pf.search(strategy=ParallelTempering(n_chains=4, sweeps=50),
                    budget=4, key=3)
    assert res.evaluations == 4
    assert len(res.history) == 1
    assert len(res.frontier) >= 1


@pytest.mark.slow
def test_resume_shrunken_budget_rejected(dev, norm):
    """A checkpoint further along than the requested sweep count must
    raise, not silently return the over-run state."""
    with tempfile.TemporaryDirectory() as d:
        _run(dev, norm, sweeps=10, segment=5,
             checkpoint=SearchCheckpointer(d))
        with pytest.raises(ValueError, match="shrinking a resumed"):
            _run(dev, norm, sweeps=5, segment=5,
                 checkpoint=SearchCheckpointer(d))


@pytest.mark.slow
def test_resume_extends_finished_run(dev, norm):
    """The documented extension use case: a finished segment=None run
    resumes under a larger sweep budget and continues its stream (the
    fingerprint hashes the segment knob, not the derived chunk size)."""
    with tempfile.TemporaryDirectory() as d:
        a, _ = _run(dev, norm, sweeps=6, segment=None,
                    checkpoint=SearchCheckpointer(d))
        b, _ = _run(dev, norm, sweeps=10, segment=None,
                    checkpoint=SearchCheckpointer(d))
        assert len(a.history) == 7 and len(b.history) == 11
        assert b.history[:7] == a.history


def test_checkpoint_with_samples_needs_archive(dev, norm):
    v0, temps, seed = _pt_args()
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="requires an archive"):
            dev.parallel_tempering(v0, temps, 4, 5, seed=seed, norm=norm,
                                   template=TPL,
                                   checkpoint=SearchCheckpointer(d))


def test_restore_skips_foreign_fingerprint_steps():
    """A stale snapshot from another configuration (e.g. a survivor of
    a resume=False restart sharing the directory) must not block
    resume: restore falls back to the newest snapshot of *this* search
    and leaves the foreign one on disk."""
    from repro.pathfinding.resume import search_fingerprint

    carry = {"x": np.arange(4.0)}
    with tempfile.TemporaryDirectory() as d:
        ck = SearchCheckpointer(d)
        fp_a = search_fingerprint("t", seed=np.int64(1))
        fp_b = search_fingerprint("t", seed=np.int64(2))
        ck.save(4, {"x": np.full(4, 2.0)}, None, np.arange(5.0), fp_b)
        ck.save(10, {"x": np.full(4, 1.0)}, None, np.arange(11.0), fp_a)
        got = SearchCheckpointer(d).restore(carry, None, fp_b)
        assert got is not None and got.sweep_done == 4
        np.testing.assert_array_equal(got.carry["x"], np.full(4, 2.0))
        # the foreign newest step is untouched and still restorable
        assert SearchCheckpointer(d).manager.all_steps() == [4, 10]
        assert SearchCheckpointer(d).restore(carry, None,
                                             fp_a).sweep_done == 10
        # a third config finds snapshots but none of its own: raises
        with pytest.raises(ValueError, match="different search"):
            SearchCheckpointer(d).restore(
                carry, None, search_fingerprint("t", seed=np.int64(3)))
        # a foreign snapshot with a different carry SHAPE (e.g. another
        # chain count) is skipped the same way, not crashed on
        ck.save(20, {"x": np.zeros(9)}, None, np.arange(3.0),
                search_fingerprint("t", seed=np.int64(4)))
        got = SearchCheckpointer(d).restore(carry, None, fp_b)
        assert got is not None and got.sweep_done == 4
        assert SearchCheckpointer(d).manager.all_steps() == [4, 10, 20]


def test_checkpoint_dir_requires_device_engine(norm):
    pf = Pathfinder(WL, TPL, norm=norm, space=SPACE, device=False)
    strat = ParallelTempering(n_chains=4, sweeps=4,
                              checkpoint_dir="/tmp/nonexistent-ok")
    with pytest.raises(ValueError, match="device engine"):
        pf.search(strategy=strat, key=1)


def test_scenario_checkpoint_dir_requires_device_path():
    from repro.pathfinding import ScenarioSweep

    with pytest.raises(ValueError, match="device path"):
        ScenarioSweep().run(WL, device=False,
                            checkpoint_dir="/tmp/nonexistent-ok")


def test_record_trace_cannot_checkpoint(dev, norm):
    v0, temps, seed = _pt_args()
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="record_trace"):
            dev.parallel_tempering(v0, temps, 4, 5, seed=seed, norm=norm,
                                   template=TPL, record_trace=True,
                                   checkpoint=SearchCheckpointer(d))


@pytest.mark.slow
def test_pt_strategy_checkpoint_surface(norm):
    """The ParallelTempering facade surface: interrupted strategy run +
    resumed strategy run == uninterrupted run (frontier bit-identical)."""
    import repro.pathfinding.strategies as strategies_mod

    pf = Pathfinder(WL, TPL, norm=norm, space=SPACE)
    mk = lambda d=None: ParallelTempering(   # noqa: E731
        n_chains=4, sweeps=12, segment=4, frontier_size=4096,
        checkpoint_dir=d)
    ref = pf.search(strategy=mk(), key=3)
    with tempfile.TemporaryDirectory() as d:
        orig = strategies_mod._checkpointer
        strategies_mod._checkpointer = (
            lambda cd: _DyingCheckpointer(cd, die_after=2)
            if cd is not None else None)
        try:
            with pytest.raises(KeyboardInterrupt):
                pf.search(strategy=mk(d), key=3)
        finally:
            strategies_mod._checkpointer = orig
        assert SearchCheckpointer(d).manager.all_steps(), "no snapshot"
        res = pf.search(strategy=mk(d), key=3)
    assert res.history == ref.history
    assert res.best_cost == ref.best_cost
    assert np.array_equal(res.frontier.vectors, ref.frontier.vectors)
    assert np.array_equal(res.frontier.encoded, ref.frontier.encoded)
    assert res.best == ref.best


@pytest.mark.slow
def test_scenario_sweep_resume_subprocess_boundary_exit():
    """Real process death: a ScenarioSweep subprocess exits hard (the
    worker's --max-segments preemption) after its first boundary, a
    second invocation resumes, and the final frontiers match an
    uninterrupted reference bit-for-bit."""
    script = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "scripts", "resume_worker.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(script), "..", "src")]
        + ([env["PYTHONPATH"]] if "PYTHONPATH" in env else []))
    with tempfile.TemporaryDirectory() as d:
        ckpt, out_ref, out_res = (os.path.join(d, x)
                                  for x in ("ckpt", "ref.npz", "res.npz"))
        run = lambda *a: subprocess.run(       # noqa: E731
            [sys.executable, script, *a], env=env, timeout=1200,
            capture_output=True, text=True)
        ref = run("run", "--out", out_ref)
        assert ref.returncode == 0, ref.stderr[-2000:]
        first = run("run", "--checkpoint-dir", ckpt, "--max-segments", "1")
        assert first.returncode == 3, (first.returncode, first.stderr[-2000:])
        resumed = run("run", "--checkpoint-dir", ckpt, "--out", out_res)
        assert resumed.returncode == 0, resumed.stderr[-2000:]
        a, b = np.load(out_ref), np.load(out_res)
        assert set(a.files) == set(b.files)
        for k in a.files:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
