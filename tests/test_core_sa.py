"""SA engine tests: move validity (property-based), convergence, cache."""
import random

from hypothesis import given, settings, strategies as st

from repro.core import (
    SAConfig,
    SimCache,
    TEMPLATES,
    anneal,
    evaluate,
    evaluate_chipletgym,
    fit_normalizer,
    is_valid,
    random_system,
    workload,
)
from repro.core.sa import propose


@given(st.integers(0, 10_000))
@settings(max_examples=80, deadline=None)
def test_random_system_always_valid(seed):
    rng = random.Random(seed)
    assert is_valid(random_system(rng))


@given(st.integers(0, 10_000), st.integers(1, 40))
@settings(max_examples=40, deadline=None)
def test_moves_preserve_validity(seed, n_moves):
    """Property: any chain of hierarchical moves stays in the feasible
    space (the paper's validation-after-every-transformation invariant)."""
    rng = random.Random(seed)
    sys = random_system(rng)
    for _ in range(n_moves):
        sys = propose(sys, rng)
        assert is_valid(sys)


def test_moves_reach_all_levels():
    """The move set must perturb application, chip-arch, chiplet and
    package levels (Sec V-B) — all four kinds observed in a short chain."""
    rng = random.Random(3)
    sys = random_system(rng)
    seen = set()
    for _ in range(400):
        new = propose(sys, rng)
        if new.mapping != sys.mapping:
            seen.add("application")
        if new.n_chiplets != sys.n_chiplets or new.memory != sys.memory:
            seen.add("chip-arch")
        if (new.n_chiplets == sys.n_chiplets
                and new.chiplets != sys.chiplets):
            seen.add("chiplet")
        if (new.pkg_25d, new.proto_25d, new.pkg_3d) != (
                sys.pkg_25d, sys.proto_25d, sys.pkg_3d):
            seen.add("package")
        sys = new
    assert seen == {"application", "chip-arch", "chiplet", "package"}


def test_anneal_history_converges():
    cache = SimCache()
    wl = workload(6)
    norm = fit_normalizer(wl, samples=200, cache=cache)
    cfg = SAConfig(t_initial=50, t_final=0.05, cooling=0.85,
                   moves_per_temp=15, seed=2)
    res = anneal(wl, TEMPLATES["T1"], config=cfg, norm=norm, cache=cache)
    # late-phase average cost below early-phase average
    h = res.history
    assert sum(h[-5:]) / 5 <= sum(h[:5]) / 5
    assert res.best_cost <= min(h) + 1e-9


def test_simulation_cache_speedup():
    """Sec V-D: the cache eliminates most re-simulations."""
    cache = SimCache()
    wl = workload(1)
    norm = fit_normalizer(wl, samples=300, cache=cache)
    cfg = SAConfig(t_initial=20, t_final=0.1, cooling=0.85,
                   moves_per_temp=10, seed=4)
    anneal(wl, TEMPLATES["T1"], config=cfg, norm=norm, cache=cache)
    assert cache.hits > cache.misses, (
        f"cache ineffective: {cache.hits} hits vs {cache.misses} misses")


def test_chipletgym_flow_runs():
    """The baseline flow plugs into the same engine (evaluate_fn swap)."""
    cache = SimCache()
    wl = workload(1)
    norm = fit_normalizer(wl, samples=200, cache=cache,
                          evaluate_fn=evaluate_chipletgym)
    cfg = SAConfig(t_initial=20, t_final=0.1, cooling=0.85,
                   moves_per_temp=10, seed=5)
    res = anneal(wl, TEMPLATES["T1"], config=cfg, norm=norm, cache=cache,
                 evaluate_fn=evaluate_chipletgym)
    assert res.best_metrics.emb_cfp_kg == 0.0  # ChipletGym models no CFP
    assert res.best_metrics.latency_s > 0


def test_chipletgym_underestimates_energy():
    """Sec VI-B2: ChipletGym's MAC-only energy model reports lower energy
    than CarbonPATH's DRAM+SRAM+compute+D2D model."""
    rng = random.Random(9)
    for _ in range(20):
        sys = random_system(rng)
        full = evaluate(sys, workload(1)).energy_j
        gym = evaluate_chipletgym(sys, workload(1)).energy_j
        assert gym < full
