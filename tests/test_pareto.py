"""Pareto-frontier machinery tests: host-vs-jnp non-dominated filter
equivalence, archive idempotence/determinism/crowding, hypervolume,
cost-vector parity across the scalar/batched/device paths, and the
ScalarizationSweep / ScenarioSweep strategies."""
import dataclasses
import random

import numpy as np
import pytest

from repro.core import TEMPLATES, workload
from repro.core.evaluate import evaluate
from repro.core.sa import OBJECTIVE_AXES, cost_vector, random_system
from repro.core.system import is_valid
from repro.pathfinding import (
    DesignSpace,
    ParetoArchive,
    Pathfinder,
    ScalarizationSweep,
    ScenarioSweep,
    crowding_distance,
    fit_normalizer_batched,
    get_device_evaluator,
    hypervolume,
    non_dominated_mask,
    non_dominated_mask_jnp,
    simplex_directions,
    workloads_from_configs,
)
from repro.pathfinding.pareto import (
    FrontierFeed,
    directions_to_weights,
)

SPACE = DesignSpace()
WL = workload(1)


@pytest.fixture(scope="module")
def norm():
    return fit_normalizer_batched(WL, samples=400, seed=7, space=SPACE)


def _fronts(n_fronts=200, size=24, seed=3):
    rng = np.random.default_rng(seed)
    pts = rng.random((n_fronts, size, 3))
    pts[:, ::5] = pts[:, 1::5]          # exact duplicate rows
    pts[:, 2::4, 1] = pts[:, 3::4, 1]   # single-axis ties
    return pts


# ---------------------------------------------------------------------------
# Non-dominated filtering: host reference vs jnp
# ---------------------------------------------------------------------------


def test_filter_host_jnp_equivalence_random_fronts():
    """The vectorized jnp filter matches the host reference *exactly*
    on random fronts with duplicates and per-axis ties."""
    fronts = _fronts()
    host = np.stack([non_dominated_mask(f) for f in fronts])
    dev = non_dominated_mask_jnp(fronts)   # batched leading dim
    assert host.shape == dev.shape
    assert (host == dev).all()
    # and per-front calls agree with the batched call
    for f in fronts[:10]:
        assert (non_dominated_mask_jnp(f) == non_dominated_mask(f)).all()


def test_filter_known_cases():
    pts = np.array([[1.0, 1.0, 1.0],
                    [2.0, 2.0, 2.0],    # dominated
                    [0.5, 3.0, 1.0],    # trade-off: survives
                    [1.0, 1.0, 1.0]])   # duplicate: survives (dedup later)
    m = non_dominated_mask(pts)
    assert m.tolist() == [True, False, True, True]
    assert (non_dominated_mask_jnp(pts) == m).all()
    assert non_dominated_mask(np.zeros((0, 3))).shape == (0,)


def test_hypervolume_exact_values():
    # one point: a single box
    assert hypervolume([[0.0, 0.0]], [1.0, 1.0]) == pytest.approx(1.0)
    # two staircase points with overlap
    assert hypervolume([[0.0, 0.5], [0.5, 0.0]],
                       [1.0, 1.0]) == pytest.approx(0.75)
    # 3D: unit box minus nothing
    assert hypervolume([[0.0, 0.0, 0.0]], [1, 1, 1]) == pytest.approx(1.0)
    # 3D staircase: two boxes of 0.5 volume overlapping in 0.25
    assert hypervolume([[0.5, 0.0, 0.0], [0.0, 0.5, 0.0]],
                       [1, 1, 1]) == pytest.approx(0.75)
    # points at/behind the reference contribute nothing
    assert hypervolume([[1.0, 1.0, 1.0], [2, 2, 2]], [1, 1, 1]) == 0.0
    # dominated points do not change the volume
    a = hypervolume([[0.2, 0.2, 0.2]], [1, 1, 1])
    b = hypervolume([[0.2, 0.2, 0.2], [0.6, 0.6, 0.6]], [1, 1, 1])
    assert a == pytest.approx(b)


def test_crowding_distance_boundaries_inf():
    pts = np.array([[0.0, 1.0], [0.25, 0.75], [0.5, 0.5], [1.0, 0.0]])
    cd = crowding_distance(pts)
    assert np.isinf(cd[0]) and np.isinf(cd[-1])
    assert np.isfinite(cd[1]) and np.isfinite(cd[2])
    assert crowding_distance(pts[:2]).tolist() == [np.inf, np.inf]


# ---------------------------------------------------------------------------
# The archive
# ---------------------------------------------------------------------------


def _random_batch(n, seed=0, width=12):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 9, (n, width)).astype(np.int32),
            rng.random((n, 3)))


def test_archive_insert_idempotent():
    enc, vec = _random_batch(500, seed=1)
    a = ParetoArchive(max_size=64)
    a.insert(enc, vec)
    before = (a.vectors, a.encoded)
    a.insert(a.encoded, a.vectors)   # self-insert: must be a no-op
    assert np.array_equal(a.vectors, before[0])
    assert np.array_equal(a.encoded, before[1])
    assert non_dominated_mask(a.vectors).all()


def test_archive_crowding_prune_deterministic():
    """Crowding-prune determinism: the same insert sequence always yields
    the identical archive (single-shot and repeated)."""
    enc, vec = _random_batch(2000, seed=2)
    a = ParetoArchive(max_size=32)
    a.insert(enc, vec)
    b = ParetoArchive(max_size=32)
    b.insert(enc, vec)
    assert np.array_equal(a.vectors, b.vectors)
    assert np.array_equal(a.encoded, b.encoded)
    assert len(a) <= 32
    # chunked feeds in the same sequence are deterministic too
    c = ParetoArchive(max_size=32)
    d = ParetoArchive(max_size=32)
    for lo in range(0, len(vec), 173):
        c.insert(enc[lo:lo + 173], vec[lo:lo + 173])
        d.insert(enc[lo:lo + 173], vec[lo:lo + 173])
    assert np.array_equal(c.vectors, d.vectors)
    assert np.array_equal(c.encoded, d.encoded)


def test_archive_order_invariant_under_bound():
    """While the bound is not hit, insertion order never matters: dedup +
    canonical storage make any order and chunking converge."""
    enc, vec = _random_batch(2000, seed=2)
    a = ParetoArchive(max_size=512)   # front is far smaller than this
    a.insert(enc, vec)
    assert len(a) < 512
    b = ParetoArchive(max_size=512)
    perm = np.random.default_rng(3).permutation(len(vec))
    for lo in range(0, len(vec), 173):   # ragged chunks, shuffled order
        b.insert(enc[perm][lo:lo + 173], vec[perm][lo:lo + 173])
    assert np.array_equal(a.vectors, b.vectors)
    assert np.array_equal(a.encoded, b.encoded)


def test_archive_dedup_and_bound():
    enc, vec = _random_batch(100, seed=4)
    # all-identical vectors: dedup keeps distinct encodings only
    same = np.tile(vec[:1], (100, 1))
    a = ParetoArchive(max_size=256)
    a.insert(np.vstack([enc, enc]), np.vstack([same, same]))
    assert len(a) == len(np.unique(enc, axis=0))
    # bound is enforced
    b = ParetoArchive(max_size=5)
    enc2, _ = _random_batch(400, seed=5)
    theta = np.linspace(0, np.pi / 2, 400)
    front = np.stack([np.cos(theta), np.sin(theta),
                      np.zeros_like(theta)], axis=1)
    b.insert(enc2, front)          # 400 mutually non-dominated points
    assert len(b) == 5
    # crowding keeps the extremes
    assert front[:, 0].min() in b.vectors[:, 0]
    assert front[:, 0].max() in b.vectors[:, 0]


def test_archive_backends_agree():
    enc, vec = _random_batch(600, seed=6)
    a = ParetoArchive(max_size=48, backend="numpy")
    b = ParetoArchive(max_size=48, backend="jnp")
    a.insert(enc, vec)
    b.insert(enc, vec)
    assert np.array_equal(a.vectors, b.vectors)
    assert np.array_equal(a.encoded, b.encoded)


def test_archive_project_2d_front():
    enc, vec = _random_batch(300, seed=7)
    a = ParetoArchive(max_size=128)
    a.insert(enc, vec)
    front2d = a.project((1, 2))
    assert non_dominated_mask(front2d).all()
    # the projected front dominates every archived point on those axes
    for c, f in a.vectors[:, 1:3]:
        assert any(fc <= c + 1e-12 and ff <= f + 1e-12
                   for fc, ff in front2d)


def test_archive_input_validation():
    a = ParetoArchive(max_size=8)
    enc, vec = _random_batch(4, seed=8)
    with pytest.raises(ValueError):
        a.insert(enc[:2], vec)
    with pytest.raises(ValueError):
        a.insert(enc, vec[:, :2])
    with pytest.raises(ValueError):
        ParetoArchive(max_size=0)
    with pytest.raises(ValueError):
        ParetoArchive(backend="cuda")
    a.insert(enc, vec)
    with pytest.raises(ValueError):
        a.insert(enc[:, :5], vec)   # width mismatch after first insert


def test_frontier_feed_disabled_and_buffering():
    feed = FrontierFeed(0)
    feed.add(*_random_batch(10))
    assert feed.done() is None
    feed = FrontierFeed(16, chunk=8)
    enc, vec = _random_batch(20, seed=9)
    for i in range(20):
        feed.add(enc[i], vec[i])
    arch = feed.done()
    ref = ParetoArchive(max_size=16)
    ref.insert(enc, vec)
    assert np.array_equal(arch.vectors, ref.vectors)


# ---------------------------------------------------------------------------
# Directions
# ---------------------------------------------------------------------------


def test_simplex_directions_deterministic_and_cover_corners():
    for k in (1, 3, 7, 16, 64):
        w = simplex_directions(k)
        assert w.shape == (k, 3)
        np.testing.assert_allclose(w.sum(axis=1), 1.0)
        assert np.array_equal(w, simplex_directions(k))
    w = simplex_directions(64)
    for corner in np.eye(3):
        assert (w == corner).all(axis=1).any()


def test_directions_to_weights_axes():
    w6 = directions_to_weights([[0.5, 0.3, 0.2]])
    # energy/area zero; latency->gamma, dollar->theta, cfp->zeta+eta
    np.testing.assert_allclose(w6[0], [0, 0, 0.5, 0.3, 0.2, 0.2])


# ---------------------------------------------------------------------------
# Cost-vector parity: scalar vs batched vs fused device program
# ---------------------------------------------------------------------------


def test_cost_vector_parity_scalar_batch_device(norm):
    rng = random.Random(11)
    systems = [random_system(rng) for _ in range(64)]
    enc = SPACE.encode_many(systems)
    pf = Pathfinder(WL, TEMPLATES["T1"], norm=norm, space=SPACE)
    mb, cost, vec = pf.evaluate_cost_vector(enc)
    assert vec.shape == (64, len(OBJECTIVE_AXES))
    # batched host rendering
    np.testing.assert_allclose(vec, mb.objective_vectors(), rtol=1e-9)
    # scalar reference (the <= 1e-6 device-parity contract)
    for i in (0, 13, 37, 63):
        ref = np.asarray(cost_vector(evaluate(systems[i], WL)))
        np.testing.assert_allclose(vec[i], ref, rtol=1e-6)
    # host (device=False) objective produces the same vectors
    pf_h = Pathfinder(WL, TEMPLATES["T1"], norm=norm, space=SPACE,
                      device=False)
    _, cost_h, vec_h = pf_h.evaluate_cost_vector(enc)
    np.testing.assert_allclose(vec, vec_h, rtol=1e-9)
    np.testing.assert_allclose(cost, cost_h, rtol=1e-9)


def test_device_evaluate_cost_vector_consistent(norm):
    dev = get_device_evaluator(WL, space=SPACE)
    enc = SPACE.sample(96, key=21)
    mb, cost, vec = dev.evaluate_cost_vector(enc, norm, TEMPLATES["T2"])
    mb2, cost2 = dev.evaluate_cost(enc, norm, TEMPLATES["T2"])
    np.testing.assert_allclose(cost, cost2, rtol=0)
    np.testing.assert_allclose(
        vec[:, 2], mb.emb_cfp_kg + mb.ope_cfp_kg, rtol=1e-12)


# ---------------------------------------------------------------------------
# Strategies: frontier field + ScalarizationSweep + ScenarioSweep
# ---------------------------------------------------------------------------


def test_every_strategy_returns_frontier(norm):
    from repro.pathfinding import GridSweep, RandomSearch

    pf = Pathfinder(WL, TEMPLATES["T1"], norm=norm, space=SPACE,
                    device=False)
    for strat in (RandomSearch(batch_size=32),
                  GridSweep(memories=("DDR5",))):
        res = pf.search(strategy=strat, budget=64, key=1)
        assert res.frontier is not None and len(res.frontier) >= 1
        assert non_dominated_mask(res.frontier.vectors).all()
        assert f"frontier={len(res.frontier)}" in repr(res)


@pytest.mark.slow
def test_scalarization_sweep_device(norm):
    pf = Pathfinder(WL, TEMPLATES["T1"], norm=norm, space=SPACE)
    strat = ScalarizationSweep(directions=6, n_chains=3, sweeps=10)
    res = pf.search(strategy=strat, key=5)
    assert res.evaluations == 18 + 18 * 10
    assert len(res.frontier) >= 3
    assert non_dominated_mask(res.frontier.vectors).all()
    assert is_valid(res.best)
    # the best row is drawn from the frontier archive
    assert any(np.array_equal(SPACE.encode(res.best), e)
               for e in res.frontier.encoded)
    # deterministic per key
    res2 = pf.search(strategy=strat, key=5)
    assert np.array_equal(res.frontier.vectors, res2.frontier.vectors)
    assert res.best_cost == res2.best_cost
    # budget truncates to whole sweeps
    res3 = pf.search(strategy=strat, budget=100, key=5)
    assert res3.evaluations <= 100
    with pytest.raises(ValueError):
        pf.search(strategy=strat, budget=10, key=5)   # < one population
    # the frontier IS the sweep's output: disabling it is rejected
    with pytest.raises(ValueError, match="frontier_size"):
        pf.search(strategy=ScalarizationSweep(directions=2, n_chains=2,
                                              frontier_size=0), key=5)


def test_scalarization_sweep_host_fallback(norm):
    pf = Pathfinder(WL, TEMPLATES["T1"], norm=norm, space=SPACE,
                    device=False)
    strat = ScalarizationSweep(directions=3, n_chains=2, sweeps=4)
    res = pf.search(strategy=strat, key=2)
    assert res.frontier is not None and len(res.frontier) >= 2
    assert non_dominated_mask(res.frontier.vectors).all()
    assert res.evaluations == 3 * (2 + 2 * 4)
    assert is_valid(res.best)


@pytest.mark.slow
def test_scenario_sweep_regions_shift_cfp():
    """Operational CFP scales with the region's grid intensity, so the
    clean-grid frontier's best total CFP must beat the dirty grid's."""
    wls = workloads_from_configs(["smollm-135m"], tokens=256)
    sweep = ScenarioSweep(
        strategy=ScalarizationSweep(directions=3, n_chains=2, sweeps=5),
        regions={"clean": 0.024, "dirty": 0.82}, norm_samples=150)
    sf = sweep.run(wls, template="T1", device=False, key=1)
    assert len(sf.scenarios) == 2
    clean = sf.frontier(wls[0].name, "clean")
    dirty = sf.frontier(wls[0].name, "dirty")
    assert len(clean) and len(dirty)
    assert clean.vectors[:, 2].min() < dirty.vectors[:, 2].min()
    merged = sf.merged(wls[0].name)
    assert non_dominated_mask(merged.vectors).all()
    rows = list(sf.rows())
    assert len(rows) == len(clean) + len(dirty)
    assert {r[1] for r in rows} == {"clean", "dirty"}


def test_workloads_from_configs_shapes():
    (wl,) = workloads_from_configs(["smollm-135m"], tokens=128)
    assert wl.M == 128 and wl.K == 576 and wl.N == 1536
    assert "smollm" in wl.name


def test_objective_replace_keeps_vector_axes(norm):
    """Scalarization directions change the template, never the vector:
    frontiers merge across directions because the axes are raw units."""
    pf = Pathfinder(WL, TEMPLATES["T1"], norm=norm, space=SPACE,
                    device=False)
    obj = pf.objective()
    obj2 = dataclasses.replace(
        obj, template=dataclasses.replace(TEMPLATES["T3"], name="dir"))
    enc = SPACE.sample(16, key=1)
    _, c1, v1 = obj.eval_cost_vector_encoded(enc, SPACE)
    _, c2, v2 = obj2.eval_cost_vector_encoded(enc, SPACE)
    np.testing.assert_allclose(v1, v2, rtol=0)
    assert not np.allclose(c1, c2)
