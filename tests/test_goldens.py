"""Golden-trajectory regression tests.

Seeded searches are pinned to checked-in accepted-cost histories so any
silent change to the RNG streams, move distribution, evaluator numerics
or accept/exchange logic fails loudly. To regenerate after an
*intentional* behaviour change::

    REPRO_UPDATE_GOLDENS=1 PYTHONPATH=src python -m pytest tests/test_goldens.py

(the run rewrites ``tests/goldens/*.json`` and reports the tests as
skipped; commit the refreshed files alongside the change).

Tolerances: the scalar SA path is plain float64 host math (1e-9); the
device path crosses XLA codegen, which may fuse differently across CPU
generations (1e-6 — still far below any behavioural change).
"""
import json
import os

import numpy as np
import pytest

from repro.core import SAConfig, TEMPLATES, workload
from repro.pathfinding import (
    DesignSpace,
    ParallelTempering,
    Pathfinder,
    SimulatedAnnealing,
    fit_normalizer_batched,
)

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "goldens")


def _check_golden(name: str, data: dict, rtol: float) -> None:
    path = os.path.join(GOLDEN_DIR, f"{name}.json")
    if os.environ.get("REPRO_UPDATE_GOLDENS") == "1":
        with open(path, "w") as f:
            json.dump(data, f, indent=2)
        pytest.skip(f"regenerated golden {name}")
    if not os.path.exists(path):
        pytest.fail(f"golden {path} missing — run with "
                    "REPRO_UPDATE_GOLDENS=1 to create it")
    with open(path) as f:
        golden = json.load(f)
    assert set(golden) == set(data), (
        f"golden {name} fields changed: {sorted(golden)} vs {sorted(data)}")
    for field, ref in golden.items():
        got = data[field]
        if isinstance(ref, (int, str)):
            assert got == ref, f"{name}.{field}: {got!r} != golden {ref!r}"
        else:
            np.testing.assert_allclose(
                got, ref, rtol=rtol,
                err_msg=f"{name}.{field} deviates from golden")


def test_golden_simulated_annealing_trajectory():
    """Seeded scalar SA: the full accepted-cost history is pinned."""
    pf = Pathfinder(workload(6), TEMPLATES["T1"])
    pf.fit_normalizer(samples=200, seed=1, method="scalar")
    cfg = SAConfig(t_initial=50.0, t_final=0.05, cooling=0.85,
                   moves_per_temp=15, seed=2)
    res = pf.search(strategy=SimulatedAnnealing(cfg))
    _check_golden("sa_wl6_t1", {
        "history": res.history,
        "best_cost": res.best_cost,
        "evaluations": res.evaluations,
        "best": res.best.describe(),
    }, rtol=1e-9)


@pytest.mark.slow
def test_golden_device_parallel_tempering_trajectory():
    """Seeded device PT (the fused lax.scan engine): coldest-chain
    history, best cost and frontier size are pinned."""
    space = DesignSpace()
    wl = workload(1)
    norm = fit_normalizer_batched(wl, samples=400, seed=7, space=space)
    pf = Pathfinder(wl, TEMPLATES["T1"], norm=norm, space=space)
    assert pf.device, "device engine unavailable — golden requires it"
    res = pf.search(strategy=ParallelTempering(n_chains=4, sweeps=20),
                    key=3)
    # the archive size itself is NOT pinned: membership rides on exact
    # float dominance ties, so an ulp of cross-platform drift could
    # legitimately shift it by one — only bound it, pin the extremes
    assert len(res.frontier) >= 3
    _check_golden("device_pt_wl1_t1", {
        "history": res.history,
        "best_cost": res.best_cost,
        "evaluations": res.evaluations,
        "frontier_latency_min": float(res.frontier.vectors[:, 0].min()),
        "frontier_cfp_min": float(res.frontier.vectors[:, 2].min()),
    }, rtol=1e-6)
