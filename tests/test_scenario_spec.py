"""Unified ScenarioSpec API tests: normalization + hashability of the
frozen spec, loose-kwarg conflict detection, and the deprecation shims
(which must warn exactly once per call site and replay the loose
spellings bit-identically)."""
import warnings

import numpy as np
import pytest

from repro.core import TEMPLATES, workload
from repro.core.regions import Region, measured_profile
from repro.pathfinding import (
    Pathfinder,
    ScalarizationSweep,
    ScenarioSpec,
    ScenarioSweep,
)
from repro.serving.jobs import JobSpec

WL = workload(1)


def test_spec_normalizes_and_hashes():
    """Floats coerce to scalar-CI Regions, a single workload wraps to a
    tuple, and two equal-content specs hash equal — the spec is usable
    as a cache key directly."""
    spec = ScenarioSpec(workloads=WL, regions={"a": 0.1, "b": Region(0.5)})
    assert spec.workloads == (WL,)
    assert all(isinstance(r, Region) for _, r in spec.regions)
    again = ScenarioSpec(workloads=(WL,),
                         regions=(("a", Region(0.1)), ("b", Region(0.5))))
    assert spec == again and hash(spec) == hash(again)
    assert list(spec.region_map()) == ["a", "b"]
    assert spec.region_map()["b"].carbon_intensity == 0.5


def test_spec_validation():
    with pytest.raises(ValueError, match="unknown comm model"):
        ScenarioSpec(workloads=WL, regions={"a": 0.1}, comm="torus")
    with pytest.raises(ValueError, match="unknown schedule model"):
        ScenarioSpec(workloads=WL, regions={"a": 0.1}, schedule="nightly")
    with pytest.raises(ValueError, match="1 region"):
        ScenarioSpec(workloads=WL, regions={})
    with pytest.raises(ValueError, match="GEMMWorkload"):
        ScenarioSpec(workloads=(), regions={"a": 0.1})


def test_spec_rejects_loose_kwargs_alongside():
    spec = ScenarioSpec(workloads=WL, regions={"a": 0.1}, budget=100)
    with pytest.raises(ValueError, match="ride inside"):
        ScenarioSweep().run(spec, budget=50)
    pf = Pathfinder(WL, TEMPLATES["T1"])
    with pytest.raises(ValueError, match="already carries"):
        pf.run_scenarios(spec, budget=50)
    with pytest.raises(ValueError, match="already carries"):
        pf.run_scenarios(spec, regions={"a": 0.1})


@pytest.mark.slow
def test_spec_replays_loose_regions_bits():
    """The deprecated ``run_scenarios(regions=...)`` spelling warns and
    produces the bit-exact trajectory of the equivalent ScenarioSpec."""
    strat = ScalarizationSweep(directions=2, n_chains=2, sweeps=10)
    pf = Pathfinder(WL, TEMPLATES["T1"])
    with pytest.warns(DeprecationWarning, match="run_scenarios"):
        sf_loose = pf.run_scenarios(
            ScenarioSweep(strategy=strat),
            regions={"a": 0.1, "b": 0.7}, budget=200, key=5)
    spec = ScenarioSpec(workloads=(WL,), regions={"a": 0.1, "b": 0.7},
                        budget=200)
    sf_spec = pf.run_scenarios(spec, key=5)
    # the spec path defaults the sweep's strategy; rebuild it to match
    sf_spec2 = ScenarioSweep(strategy=strat).run(spec, key=5)
    del sf_spec
    for s in sf_loose.scenarios:
        rl = sf_loose.results[s.key]
        rs = sf_spec2.results[s.key]
        assert rl.best_cost == rs.best_cost
        assert np.array_equal(np.asarray(rl.history),
                              np.asarray(rs.history))
        assert rl.best == rs.best


def test_jobspec_region_unifies_loose_fields():
    """The loose regional JobSpec fields warn once and collapse into a
    Region whose slot rows are bit-identical to the unified spelling."""
    with pytest.warns(DeprecationWarning,
                      match="loose JobSpec regional fields"):
        loose = JobSpec(job_id="j", workload="w", carbon_intensity=0.1,
                        electricity_price=0.05,
                        grid_profile=measured_profile("hydro"))
    unified = JobSpec(
        job_id="j", workload="w",
        region=Region(carbon_intensity=0.1, electricity_price=0.05,
                      grid_profile=measured_profile("hydro")))
    assert loose.resolved_region() == unified.resolved_region()
    assert np.array_equal(loose.profile_row(), unified.profile_row())
    assert np.array_equal(loose.pprofile_row(), unified.pprofile_row())
    # identical search knobs -> identical bucket
    assert loose.bucket_key() == unified.bucket_key()


def test_jobspec_region_conflict_and_clean_path():
    # the unified spelling raises no deprecation noise
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        spec = JobSpec(job_id="j", workload="w", region=Region(0.2))
    assert spec.resolved_region().carbon_intensity == 0.2
    # neutral loose defaults are silent too (nothing to migrate)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        JobSpec(job_id="j", workload="w")
    with pytest.raises(ValueError, match="not both"):
        JobSpec(job_id="j", workload="w", region=Region(0.2),
                carbon_intensity=0.1)
