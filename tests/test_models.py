"""Per-architecture smoke tests (reduced configs) + decode consistency +
flash-attention oracle checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models.attention import chunked_attention
from repro.models.transformer import (
    decode_step,
    forward,
    init_model,
    loss_fn,
    prefill,
)

KEY = jax.random.PRNGKey(0)
B, S = 2, 48


def _reduced(name):
    cfg = get_config(name).reduced()
    if cfg.moe:  # deterministic smoke: no capacity drops
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


def _batch(cfg):
    if cfg.family == "audio":
        return {
            "embeds": jax.random.normal(KEY, (B, S, cfg.d_model)),
            "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        }
    if cfg.family == "vlm":
        p = cfg.frontend_prefix
        return {
            "tokens": jax.random.randint(KEY, (B, S - p), 0, cfg.vocab),
            "embeds": jax.random.normal(KEY, (B, p, cfg.d_model)),
            "labels": jax.random.randint(KEY, (B, S - p), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
    }


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_forward_and_loss(name):
    """Instantiate the reduced config, one forward + loss: output shapes
    correct, no NaNs (per-arch smoke test requirement)."""
    cfg = _reduced(name)
    params = init_model(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = forward(params, cfg, batch.get("tokens"),
                          batch.get("embeds"))
    assert logits.shape == (B, S, cfg.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    loss = loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_train_step(name):
    """One gradient step on CPU: grads finite, params change."""
    from repro.optim import AdamWConfig, apply_updates, init as opt_init
    cfg = _reduced(name)
    params = init_model(KEY, cfg)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    ocfg = AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
    state = opt_init(params, ocfg)
    new_params, state, metrics = apply_updates(params, grads, state, ocfg)
    changed = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params)
    assert max(jax.tree_util.tree_leaves(changed)) > 0.0


@pytest.mark.parametrize(
    "name", [n for n in ARCH_NAMES
             if not get_config(n).encoder_only and
             get_config(n).family != "vlm"])
def test_decode_matches_forward(name):
    """prefill(S-1) + decode(1 token) == forward(S) at the last position —
    validates every cache structure (KV, latent, ring-buffer, recurrent)."""
    cfg = _reduced(name)
    params = init_model(jax.random.PRNGKey(42), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, tokens)
    pre_logits, cache, length = prefill(params, cfg, tokens[:, :S - 1],
                                        cache_len=S + 4)
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(full_logits[:, S - 2]),
                               rtol=3e-4, atol=3e-4)
    dec_logits, cache = decode_step(params, cfg, tokens[:, S - 1], cache,
                                    length)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=6e-4, atol=6e-4)


def test_multi_token_decode_chain():
    """Greedy decode 4 tokens sequentially — cache stays consistent."""
    cfg = _reduced("smollm-135m")
    params = init_model(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, 16), 0, cfg.vocab)
    logits, cache, length = prefill(params, cfg, tokens, cache_len=24)
    toks = []
    for _ in range(4):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(nxt)
        logits, cache = decode_step(params, cfg, nxt, cache, length)
        length = length + 1
    # reference: forward over the full greedy sequence
    seq = jnp.concatenate([tokens] + [t[:, None] for t in toks], axis=1)
    ref, _ = forward(params, cfg, seq)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(ref[:, -1]), rtol=1e-3, atol=1e-3)


def test_encoder_bidirectional():
    """hubert: flipping a late frame changes logits at earlier positions
    (bidirectional); a causal model would be invariant."""
    cfg = _reduced("hubert-xlarge")
    params = init_model(KEY, cfg)
    e1 = jax.random.normal(KEY, (1, 16, cfg.d_model))
    e2 = e1.at[:, -1].add(1.0)
    l1, _ = forward(params, cfg, embeds=e1)
    l2, _ = forward(params, cfg, embeds=e2)
    assert np.abs(np.asarray(l1[:, 0]) - np.asarray(l2[:, 0])).max() > 1e-6


def test_param_count_analytical_vs_actual():
    """ModelConfig.param_count within 2% of actual initialized params."""
    for name in ("smollm-135m", "qwen3-8b", "rwkv6-3b"):
        cfg = get_config(name).reduced()
        params = init_model(KEY, cfg)
        actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.15, (
            f"{name}: predicted {predicted} vs actual {actual}")


# ---------------------------------------------------------------------------
# flash attention oracle
# ---------------------------------------------------------------------------

def _naive(q, k, v, causal=True, window=None):
    b, s, kv, g, dh = q.shape
    t = k.shape[1]
    s_ = jnp.einsum("bqkgd,btkd->bkgqt", q, k) * dh ** -0.5
    qpos, kpos = jnp.arange(s), jnp.arange(t)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s_ = jnp.where(mask[None, None, None], s_, -1e30)
    return jnp.einsum("bkgqt,btkd->bqkgd", jax.nn.softmax(s_, -1), v)


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 24)])
@pytest.mark.parametrize("chunks", [(32, 16), (96, 96), (25, 40)])
def test_flash_matches_naive(causal, window, chunks):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 96, 3, 2, 32))
    k = jax.random.normal(ks[1], (2, 96, 3, 32))
    v = jax.random.normal(ks[2], (2, 96, 3, 32))
    qc, kc = chunks
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(out, _naive(q, k, v, causal, window),
                               rtol=3e-5, atol=3e-5)


def test_flash_gradients_match_naive():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    f = lambda *a: (chunked_attention(*a, causal=True, q_chunk=16,
                                      kv_chunk=32) ** 2).sum()
    g = lambda *a: (_naive(*a) ** 2).sum()
    for a, b in zip(jax.grad(f, (0, 1, 2))(q, k, v),
                    jax.grad(g, (0, 1, 2))(q, k, v)):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)
