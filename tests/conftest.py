"""Pytest config. NOTE: never set --xla_force_host_platform_device_count
here — smoke tests and benches must see 1 device; only launch/dryrun.py
(as an entry point) and explicit subprocess tests use fake device counts.

When ``hypothesis`` is not installed (it is a dev dependency, see
requirements-dev.txt), a deterministic fixed-example fallback is
registered under the same module name so the property tests still
collect and run.
"""
import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-device subprocess tests and jit-compile-heavy device "
        "searches (deselect with -m 'not slow')")
