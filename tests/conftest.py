"""Pytest config. NOTE: never set --xla_force_host_platform_device_count
here — smoke tests and benches must see 1 device; only launch/dryrun.py
(as an entry point) and explicit subprocess tests use fake device counts.
"""
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess tests (deselect with -m 'not slow')")
