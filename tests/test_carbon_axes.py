"""Lifecycle carbon-axis tests: the full embodied model (wasted-die,
recycling, router split), the 24h grid-intensity profile as a runtime
column, the ``dies_per_wafer`` edge-loss raise, and the engine-cache
aliasing guard.

The bit-exactness contract under test: every lifecycle knob defaults to
a *neutral* value (0.0 addend, 1.0 multiplier, flat profile), so the
scalar and device paths with defaults reproduce the pre-lifecycle
numbers bit-for-bit — the pinned goldens never move. Non-neutral knobs
are then pinned scalar-vs-device at <= 1e-9 relative.
"""
import dataclasses
import random

import numpy as np
import pytest

from repro.core import workload
from repro.core import carbon
from repro.core.evaluate import evaluate
from repro.core.regions import Region, as_region, diurnal_profile
from repro.core.sa import random_system
from repro.core.scalesim import SimCache
from repro.core.techdb import DEFAULT_DB, HOURS_PER_DAY, TechDB
from repro.pathfinding import DesignSpace, DeviceEvaluator
from repro.pathfinding.device import get_scenario_engine, trace_count

WL = workload(1)
SPACE = DesignSpace()

#: every lifecycle knob set non-neutral at once — the parity tests must
#: hold on the *full* model, not just one axis at a time
LIFECYCLE_OVERRIDES = {
    "carbon_intensity": 0.31,
    "electricity_price": 0.12,
    "emb_factor": 1.25,
    "grid_profile": tuple(diurnal_profile(0.31, swing=0.4, peak_hour=19)),
    "load_profile": tuple(
        w / sum(1.0 + 0.5 * ((h % 12) / 11.0) for h in range(24))
        for w in (1.0 + 0.5 * ((h % 12) / 11.0) for h in range(24))),
    "rcy_mat_frac": 0.15,
    "rcy_cpa_frac": 0.10,
    "wasted_die_scale": 1.0,
    "router_area_frac": 0.08,
}


# ---------------------------------------------------------------------------
# dies_per_wafer: raise past the edge-loss boundary (satellite 1)
# ---------------------------------------------------------------------------


def test_dies_per_wafer_raises_past_edge_loss_boundary():
    """The edge-corrected DPW formula crosses zero at A = r^2/2 =
    11250 mm^2 on a 300 mm wafer; beyond it the estimate is negative
    garbage and must raise, not clamp to 1."""
    with pytest.raises(ValueError, match="does not fit"):
        DEFAULT_DB.dies_per_wafer(11250.5)
    with pytest.raises(ValueError, match="does not fit"):
        DEFAULT_DB.dies_per_wafer(20000.0)


def test_dies_per_wafer_rejects_nonpositive_area():
    for area in (0.0, -5.0):
        with pytest.raises(ValueError, match="positive"):
            DEFAULT_DB.dies_per_wafer(area)


def test_dies_per_wafer_positive_fraction_clamps_to_one():
    """Just inside the boundary the formula yields 0 < DPW < 1: the die
    does fit, so a wafer yields at least one (clamp, not raise)."""
    assert DEFAULT_DB.dies_per_wafer(11000.0) == 1
    assert DEFAULT_DB.dies_per_wafer(11249.0) == 1


def test_dies_per_wafer_sane_for_real_die():
    dpw = DEFAULT_DB.dies_per_wafer(20.0)
    assert 3000 < dpw < 3600  # ~70685/20 minus edge loss


# ---------------------------------------------------------------------------
# TechDB knob hygiene: clamps, override resolution, profile validation
# ---------------------------------------------------------------------------


def test_recycling_fractions_clamped_to_unit_interval():
    db = TechDB(rcy_mat_frac=1.5, rcy_cpa_frac=-0.2)
    assert db.rcy_mat_frac == 1.0 and db.rcy_cpa_frac == 0.0
    # fully recycled material -> zero manufacturing credit factor
    assert carbon.recycling_credit(db) == 0.0
    # the clamp also runs over the overrides path
    db2 = TechDB(overrides={"rcy_mat_frac": 2.0, "rcy_cpa_frac": 0.25})
    assert db2.rcy_mat_frac == 1.0 and db2.rcy_cpa_frac == 0.25
    assert carbon.recycling_credit(DEFAULT_DB) == 1.0  # neutral default


def test_overrides_unknown_name_raises():
    with pytest.raises(ValueError, match="no knob named"):
        TechDB(overrides={"grid_profle": (0.5,) * HOURS_PER_DAY})


def test_overrides_resolve_new_columns_and_are_consumed():
    """The new lifecycle columns patch via ``overrides`` like any other
    knob, and the dict is consumed at construction — a later
    ``dataclasses.replace`` must not have a stale overrides dict undo
    the change (the satellite-3 default-resolution bug)."""
    prof = tuple(diurnal_profile(0.5))
    db = TechDB(overrides={"grid_profile": prof, "electricity_price": 0.2,
                           "router_area_frac": 0.05})
    assert db.grid_profile == prof
    assert db.electricity_price == 0.2 and db.router_area_frac == 0.05
    assert db.overrides is None
    db2 = dataclasses.replace(db, electricity_price=0.3)
    assert db2.electricity_price == 0.3       # not reverted to 0.2
    assert db2.grid_profile == prof           # inherited, not dropped


def test_profile_length_validation():
    with pytest.raises(ValueError, match="hourly entries"):
        TechDB(grid_profile=(0.5,) * 23)
    with pytest.raises(ValueError, match="hourly entries"):
        TechDB(load_profile=(1.0 / 12,) * 12)
    with pytest.raises(ValueError, match="hourly entries"):
        Region(carbon_intensity=0.5, grid_profile=(0.5,) * 25)


def test_region_spec_roundtrip():
    """``as_region`` lifts bare floats (the legacy regions dict value)
    and passes Region specs through; ``db_overrides`` feeds TechDB."""
    r = as_region(0.475)
    assert r == Region(carbon_intensity=0.475)
    spec = Region(carbon_intensity=0.3, electricity_price=0.1,
                  emb_factor=1.1, grid_profile=tuple(diurnal_profile(0.3)))
    assert as_region(spec) is spec
    db = TechDB(overrides=spec.db_overrides())
    assert db.carbon_intensity == 0.3 and db.emb_factor == 1.1
    np.testing.assert_array_equal(spec.profile_array(),
                                  np.asarray(spec.grid_profile))


def test_diurnal_profile_preserves_daily_mean():
    prof = diurnal_profile(0.42, swing=0.35, peak_hour=18)
    assert len(prof) == HOURS_PER_DAY
    assert float(np.mean(prof)) == pytest.approx(0.42, rel=1e-12)
    assert max(prof) > 0.42 > min(prof)


# ---------------------------------------------------------------------------
# Flat profile == scalar model, bit-for-bit (satellite 4)
# ---------------------------------------------------------------------------


def test_effective_intensity_flat_profile_is_exact_identity():
    """The device formulation ci + sum((p - ci) * load) makes a flat
    profile a chain of exact +0.0 terms — bitwise, not approximately."""
    ci = 0.475
    flat = (ci,) * HOURS_PER_DAY
    assert carbon.effective_intensity(ci, flat) == ci
    assert carbon.effective_intensity(ci, None) == ci
    skewed_load = tuple(
        (1.0 if h < 12 else 3.0) / (12 * 4.0) for h in range(24))
    assert carbon.effective_intensity(ci, flat, skewed_load) == ci
    # a non-flat profile under flat load recovers its arithmetic mean
    prof = diurnal_profile(ci, swing=0.5)
    assert carbon.effective_intensity(ci, prof) == pytest.approx(
        float(np.mean(prof)), rel=1e-12)


def test_flat_profile_scalar_evaluate_bitwise():
    """``evaluate`` under an explicit flat grid profile is bit-identical
    to the scalar-CI model on every metric field."""
    db_flat = dataclasses.replace(
        DEFAULT_DB,
        grid_profile=(DEFAULT_DB.carbon_intensity,) * HOURS_PER_DAY)
    rng = random.Random(20260808)
    cache = SimCache()
    for _ in range(20):
        sys = random_system(rng)
        a = evaluate(sys, WL, cache=cache)
        b = evaluate(sys, WL, db_flat, cache=cache)
        for f in ("energy_j", "area_mm2", "latency_s", "dollar",
                  "emb_cfp_kg", "ope_cfp_kg"):
            assert getattr(a, f) == getattr(b, f), (sys.describe(), f)


def test_neutral_knobs_leave_carbon_models_bitwise():
    """Explicitly-neutral lifecycle knobs (0 addends, 1 multipliers)
    reproduce the default model bit-for-bit through the carbon layer."""
    neutral = TechDB(overrides={
        "electricity_price": 0.0, "emb_factor": 1.0,
        "rcy_mat_frac": 0.0, "rcy_cpa_frac": 0.0,
        "wasted_die_scale": 0.0, "router_area_frac": 0.0})
    rng = random.Random(7)
    for _ in range(10):
        sys = random_system(rng)
        area = sum(c.area_mm2(DEFAULT_DB) for c in sys.chiplets) * 1.1
        a = carbon.embodied_cfp(sys, area, DEFAULT_DB)
        b = carbon.embodied_cfp(sys, area, neutral)
        assert a == b
        e = 1.7e-3
        assert (carbon.operational_cfp(e, 1e-3, DEFAULT_DB)
                == carbon.operational_cfp(e, 1e-3, neutral))
        assert carbon.operational_cost_usd(e, neutral) == 0.0


# ---------------------------------------------------------------------------
# Scalar evaluate vs device _metrics_jax parity on the full lifecycle
# model (satellites 2 + 4: packaging/router/recycling/wasted-die and
# the price/embodied/profile columns)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def lifecycle_db():
    return TechDB(overrides=dict(LIFECYCLE_OVERRIDES))


@pytest.fixture(scope="module")
def lifecycle_dev(lifecycle_db):
    return DeviceEvaluator(WL, db=lifecycle_db)


def test_scalar_vs_device_parity_full_lifecycle(lifecycle_db,
                                                lifecycle_dev):
    """Every lifecycle knob non-neutral at once: the fused device
    program (price/embf/profile as runtime columns, router split and
    recycling baked into its tile tables) matches scalar ``evaluate``
    within 1e-9 relative on dollar, embodied and operational CFP.

    This is the ``packaging_cfp`` parity pin of satellite 2: embodied
    carbon includes C_HI with the substrate term *inside* the
    bonding-yield division on both paths (ECO-CHIP scraps the whole
    assembly, substrate included, when a bond fails)."""
    space = lifecycle_dev.space
    rng = random.Random(20260801)
    systems = [random_system(rng) for _ in range(200)]
    mb = lifecycle_dev.metrics(space.encode_many(systems))
    cache = SimCache()
    styles = set()
    for i, sys in enumerate(systems):
        styles.add(sys.style)
        m = evaluate(sys, WL, lifecycle_db, cache=cache)
        for f in ("dollar", "emb_cfp_kg", "ope_cfp_kg", "energy_j",
                  "latency_s", "area_mm2"):
            ref = getattr(m, f)
            got = float(getattr(mb, f)[i])
            assert got == pytest.approx(ref, rel=1e-9, abs=1e-300), (
                f"{sys.describe()} field {f}: scalar {ref} device {got}")
    # the parity population must actually exercise bonded styles, or
    # the packaging-yield pin proves nothing
    assert {"2.5D", "3D"} <= styles


def test_lifecycle_moves_every_metric_direction(lifecycle_db):
    """Sanity on the model's signs: a dirty-peak profile with a peaky
    load raises operational CFP, a nonzero price raises dollars, and
    emb_factor > 1 with router/wasted-die terms raises embodied CFP."""
    rng = random.Random(3)
    sys = random_system(rng)
    base = evaluate(sys, WL)
    life = evaluate(sys, WL, lifecycle_db)
    db_iso = dataclasses.replace(
        DEFAULT_DB, electricity_price=0.12, emb_factor=1.25,
        router_area_frac=0.08)
    iso = evaluate(sys, WL, db_iso)
    assert iso.dollar > base.dollar
    assert iso.emb_cfp_kg > base.emb_cfp_kg
    assert life.energy_j == base.energy_j  # lifecycle never touches perf
    assert life.latency_s == base.latency_s


# ---------------------------------------------------------------------------
# Engine cache + compile-count regressions (satellite 3 / tentpole b)
# ---------------------------------------------------------------------------


def test_scenario_engine_cache_keys_cfg_static_knobs():
    """``load_profile`` and ``router_area_frac`` are trace-time
    constants of the fused program, so they are default-resolved into
    the ``get_scenario_engine`` cache key: two dbs differing only there
    can never alias onto one engine. The runtime axes (price, embf,
    grid profile) deliberately do NOT fork the engine."""
    db_a = TechDB()
    db_b = dataclasses.replace(db_a, load_profile=tuple(
        w / 300.0 for w in range(1, 25)))
    db_c = dataclasses.replace(db_a, router_area_frac=0.1)
    e_a = get_scenario_engine((WL,), db_a)
    assert get_scenario_engine((WL,), db_a) is e_a  # stable hit
    assert get_scenario_engine((WL,), db_b) is not e_a
    assert get_scenario_engine((WL,), db_c) is not e_a


def test_profile_axis_is_data_not_a_recompile():
    """The richer grid — per-cell price/embf/24h-profile columns — runs
    on the same compiled program as the scalar-CI grid: neutral columns
    are always materialized, so both calls share one signature and the
    scenario trace count stays flat."""
    engine = get_scenario_engine((WL,), DEFAULT_DB)
    from repro.pathfinding import fit_normalizer_batched

    nz = fit_normalizer_batched(WL, samples=80, seed=3, space=SPACE)
    mins_v, medians_v = nz.weights_arrays()
    S, m = 3, 4
    enc = SPACE.sample(S * m, key=17).reshape(S, m, -1)
    mins = np.tile(mins_v, (S, 1))
    medians = np.tile(medians_v, (S, 1))
    w = np.tile(np.full(6, 1.0 / 6.0), (S, 1))
    ci = np.array([0.024, 0.475, 0.82])
    widx = np.zeros(S, dtype=np.int64)

    cost_scalar, _ = engine.evaluate_cost(enc, mins, medians, w, ci, widx)
    after_first = trace_count("scenario_eval")

    price = np.array([0.05, 0.12, 0.20])
    embf = np.array([0.9, 1.0, 1.3])
    profile = np.stack([diurnal_profile(c, swing=0.3) for c in ci])
    cost_rich, _ = engine.evaluate_cost(enc, mins, medians, w, ci, widx,
                                        price=price, embf=embf,
                                        profile=profile)
    assert trace_count("scenario_eval") == after_first, (
        "profile/price/embf columns forced a retrace — they must be "
        "runtime data of the one fused program")

    # flat columns reproduce the scalar grid bitwise on-device too
    flat_prof = np.repeat(ci[:, None], HOURS_PER_DAY, axis=1)
    cost_flat, _ = engine.evaluate_cost(
        enc, mins, medians, w, ci, widx,
        price=np.zeros(S), embf=np.ones(S), profile=flat_prof)
    np.testing.assert_array_equal(cost_flat, cost_scalar)
    # and the rich columns actually change the answer somewhere
    assert not np.array_equal(cost_rich, cost_scalar)


def test_before_first_trace_counts_exist():
    """trace_count names used above are registered families."""
    assert trace_count("scenario_eval") >= 0
    assert trace_count("scenario_pt") >= 0
