"""Substrate tests: data determinism, optimizer, compression, checkpoint,
fault-tolerant restart, stragglers."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.optim import (
    AdamWConfig,
    apply_updates,
    compress_with_feedback,
    decompress,
    init as opt_init,
    init_error,
    schedule,
)
from repro.runtime import (
    FailureInjector,
    RestartSupervisor,
    SimulatedFailure,
    StragglerMonitor,
)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_sharded():
    pipe = SyntheticTokenPipeline(DataConfig(vocab=256, seq_len=32,
                                             global_batch=16, seed=3))
    b1, b2 = pipe.batch(7), pipe.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not (pipe.batch(8)["tokens"] == b1["tokens"]).all()
    # host shards tile the global batch exactly
    parts = [pipe.shard(7, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])


def test_pipeline_has_learnable_structure():
    """The bigram sieve must make odd-position tokens predictable from
    their predecessor."""
    pipe = SyntheticTokenPipeline(DataConfig(vocab=512, seq_len=256,
                                             global_batch=8, seed=0))
    b = pipe.batch(0)
    toks, labels = np.asarray(b["tokens"]), np.asarray(b["labels"])
    # labels[i] sits at sequence position i+1; odd positions follow the rule
    odd = (np.arange(labels.shape[1]) + 1) % 2 == 1
    pred = (toks * 31 + 7) % 97
    hits = (pred == labels)[:, odd].mean()
    assert hits > 0.99, f"sieve rule not learnable: {hits}"


@given(st.integers(0, 1000), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_pipeline_shard_property(step, n_hosts_pow):
    n_hosts = 2 ** (n_hosts_pow % 4)
    pipe = SyntheticTokenPipeline(DataConfig(vocab=64, seq_len=8,
                                             global_batch=8, seed=1))
    full = pipe.batch(step)["tokens"]
    parts = [pipe.shard(step, h, n_hosts)["tokens"] for h in range(n_hosts)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = {"w": jnp.full((8,), 5.0)}
    cfg = AdamWConfig(lr_peak=0.3, warmup_steps=5, total_steps=200,
                      weight_decay=0.0)
    state = opt_init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr_peak=1.0, lr_min=0.1, warmup_steps=10,
                      total_steps=100)
    assert float(schedule(cfg, jnp.asarray(0))) < 0.2
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1.0) < 0.12
    assert float(schedule(cfg, jnp.asarray(100))) <= 0.11


def test_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    cfg = AdamWConfig(clip_norm=1.0)
    state = opt_init(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = apply_updates(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip norm


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_roundtrip_accuracy():
    g = {"a": jax.random.normal(jax.random.PRNGKey(0), (1000,)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (37, 13))}
    c, err = compress_with_feedback(g, init_error(g))
    r = decompress(c, g)
    for k in g:
        rel = float(jnp.linalg.norm(r[k] - g[k]) / jnp.linalg.norm(g[k]))
        assert rel < 0.02


def test_error_feedback_unbiased_over_time():
    """Sum of dequantized grads converges to sum of true grads."""
    key = jax.random.PRNGKey(2)
    g_true = jax.random.normal(key, (256,)) * 0.01
    err = init_error({"g": g_true})
    acc = jnp.zeros_like(g_true)
    for i in range(50):
        c, err = compress_with_feedback({"g": g_true}, err)
        acc = acc + decompress(c, {"g": g_true})["g"]
    rel = float(jnp.linalg.norm(acc - 50 * g_true)
                / jnp.linalg.norm(50 * g_true))
    assert rel < 0.01, f"error feedback biased: {rel}"


def test_compression_ratio():
    """int8 payload is 4x smaller than fp32."""
    g = {"w": jnp.zeros((4096,), jnp.float32)}
    c, _ = compress_with_feedback(g, init_error(g))
    payload = c.q["w"].size  # int8 bytes
    assert payload * 4 <= g["w"].size * 4  # 4x reduction on the mantissa


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree():
    return {"layer": {"w": jnp.arange(24.0).reshape(4, 6),
                      "b": jnp.ones((7,))},
            "step_scalar": jnp.asarray(3.0),
            "int_leaf": jnp.arange(5, dtype=jnp.int32)}


def test_checkpoint_roundtrip_exact():
    with tempfile.TemporaryDirectory() as d:
        t = _tree()
        p = save_checkpoint(d, 12, t, n_shards=3)
        step, r = load_checkpoint(p, t)
        assert step == 12
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(r)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rotation_and_latest():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 5, 9):
            mgr.save(s, _tree())
        assert mgr.all_steps() == [5, 9]
        assert mgr.latest().endswith("step_00000009")


def test_checkpoint_detects_corruption():
    with tempfile.TemporaryDirectory() as d:
        t = _tree()
        p = save_checkpoint(d, 1, t, n_shards=2)
        # corrupt one shard
        for f in os.listdir(p):
            if f.endswith(".npy") and "layer.w" in f:
                arr = np.load(os.path.join(p, f))
                np.save(os.path.join(p, f), arr + 1.0)
                break
        with pytest.raises(ValueError, match="checksum"):
            load_checkpoint(p, t)


def test_checkpoint_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        p = save_checkpoint(d, 1, {"w": jnp.ones((4, 4))})
        with pytest.raises(ValueError, match="shape"):
            load_checkpoint(p, {"w": jnp.ones((5, 4))})


def test_checkpoint_elastic_resharding():
    """Restore places leaves onto a different device layout (1-dev CPU
    mesh here; the API contract is sharding_fn controls placement)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import _mesh_kwargs
    mesh = jax.make_mesh((1,), ("data",), **_mesh_kwargs(1))
    with tempfile.TemporaryDirectory() as d:
        t = {"w": jnp.arange(16.0).reshape(4, 4)}
        p = save_checkpoint(d, 1, t)
        _, r = load_checkpoint(
            p, t, sharding_fn=lambda name, arr: NamedSharding(mesh, P("data")))
        np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
        assert r["w"].sharding.spec == P("data")


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_restart_replay_exact():
    pipe = SyntheticTokenPipeline(DataConfig(vocab=64, seq_len=8,
                                             global_batch=4, seed=2))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        step_fn = lambda s, x: x + float(pipe.batch(s)["tokens"].sum())
        save_fn = lambda s, x: mgr.save(s, {"x": jnp.asarray(x)})
        def restore_fn():
            if mgr.latest() is None:
                return 0, 0.0
            s, t = mgr.restore({"x": jnp.zeros(())})
            return s, float(t["x"])
        sup = RestartSupervisor(step_fn, save_fn, restore_fn, save_every=3,
                                injector=FailureInjector(rate=0.2, seed=1))
        out = sup.run(15, 0.0)
        ref = 0.0
        for s in range(15):
            ref = step_fn(s, ref)
        assert out == ref
        assert sup.stats.restarts > 0, "injector never fired (tune rate)"


def test_straggler_detection():
    mon = StragglerMonitor(window=16, threshold=2.0)
    for s in range(12):
        mon.observe(s, 0.1)
    assert mon.observe(12, 0.5) is True
    assert mon.observe(13, 0.11) is False
    assert 12 in mon.flagged_steps


def test_injector_transient():
    inj = FailureInjector(rate=1.0, seed=0)
    with pytest.raises(SimulatedFailure):
        inj.check(5)
    inj.check(5)  # replay of the same step succeeds
