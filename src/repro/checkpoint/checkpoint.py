"""Sharded checkpointing: per-leaf ``.npy`` shards + a JSON manifest.

Design (offline container — no orbax/tensorstore):

  * Every pytree leaf is saved as one or more ``.npy`` shard files, split
    along its largest axis into ``n_shards`` pieces so that (a) hosts write
    in parallel on a real cluster, and (b) restore can re-assemble onto a
    DIFFERENT mesh — the manifest stores only the logical array, not the
    device layout, which is what makes restarts elastic (restore onto
    more or fewer devices than saved from).
  * The manifest (checkpoint.json) records the tree structure, per-leaf
    dtype/shape/shard files, the step, and a payload checksum; writes are
    atomic (tmp dir + rename) so a failure mid-save never corrupts the
    latest valid checkpoint.
  * ``CheckpointManager`` keeps the last ``keep`` checkpoints and finds
    the newest valid one on restart.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "checkpoint.json"


def _leaf_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def _shard_slices(shape: Tuple[int, ...], n_shards: int):
    """Split along the largest axis into up to n_shards contiguous slices."""
    if not shape or n_shards <= 1:
        return [tuple(slice(None) for _ in shape)]
    axis = int(np.argmax(shape))
    n = min(n_shards, shape[axis])
    edges = np.linspace(0, shape[axis], n + 1, dtype=int)
    slices = []
    for lo, hi in zip(edges[:-1], edges[1:]):
        if hi > lo:
            s = [slice(None)] * len(shape)
            s[axis] = slice(int(lo), int(hi))
            slices.append(tuple(s))
    return slices


def save_checkpoint(directory: str, step: int, tree: Any,
                    n_shards: int = 8) -> str:
    """Atomic save of a pytree. Returns the checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest: Dict[str, Any] = {"step": step, "leaves": {}, "time": 0.0}
    manifest["time"] = time.time()
    digest = hashlib.sha256()
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        entry = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                 "shards": []}
        for i, sl in enumerate(_shard_slices(arr.shape, n_shards)):
            fname = f"{name.replace('/', '.')}.{i}.npy"
            piece = np.ascontiguousarray(arr[sl])
            np.save(os.path.join(tmp, fname), piece)
            digest.update(piece.tobytes()[:4096])
            entry["shards"].append({
                "file": fname,
                "slices": [[s.start, s.stop] if s.start is not None
                           or s.stop is not None else None
                           for s in sl],
            })
        manifest["leaves"][name] = entry
    manifest["checksum"] = digest.hexdigest()
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(path: str, like: Any,
                    sharding_fn=None) -> Tuple[int, Any]:
    """Restore into the structure of ``like``. ``sharding_fn(name, arr)``
    may return a jax.sharding.Sharding to place each leaf directly onto
    the *current* mesh (which may differ from the save-time mesh)."""
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    leaves = manifest["leaves"]

    names = [n for n, _ in _leaf_paths(like)]
    flat_like, tdef = jax.tree_util.tree_flatten(like)
    out = []
    digest = hashlib.sha256()
    for name, leaf in zip(names, flat_like):
        entry = leaves.get(name)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = np.empty(entry["shape"], dtype=np.dtype(entry["dtype"]))
        for sh in entry["shards"]:
            piece = np.load(os.path.join(path, sh["file"]))
            sl = tuple(slice(None) if s is None else slice(s[0], s[1])
                       for s in sh["slices"])
            arr[sl if sl else ...] = piece
            digest.update(piece.tobytes()[:4096])
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs "
                f"model {np.shape(leaf)}")
        if sharding_fn is not None:
            sharding = sharding_fn(name, arr)
            out.append(jax.device_put(arr, sharding) if sharding is not None
                       else jnp.asarray(arr))
        else:
            out.append(jnp.asarray(arr))
    if manifest.get("checksum") and manifest["checksum"] != digest.hexdigest():
        raise ValueError(f"checkpoint {path} checksum mismatch (corrupt?)")
    return manifest["step"], jax.tree_util.tree_unflatten(tdef, out)


class CheckpointManager:
    """Rotating checkpoint directory with newest-valid discovery."""

    def __init__(self, directory: str, keep: int = 3, n_shards: int = 8):
        self.directory = directory
        self.keep = keep
        self.n_shards = n_shards
        os.makedirs(directory, exist_ok=True)

    def all_steps(self) -> List[int]:
        steps = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, d, MANIFEST)):
                    steps.append(int(d.split("_")[1]))
        return sorted(steps)

    def latest(self) -> Optional[str]:
        steps = self.all_steps()
        if not steps:
            return None
        return os.path.join(self.directory, f"step_{steps[-1]:08d}")

    def save(self, step: int, tree: Any) -> str:
        path = save_checkpoint(self.directory, step, tree, self.n_shards)
        for s in self.all_steps()[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
        return path

    def restore(self, like: Any, sharding_fn=None) -> Tuple[int, Any]:
        path = self.latest()
        if path is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        return load_checkpoint(path, like, sharding_fn)
