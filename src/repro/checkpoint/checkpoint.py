"""Sharded checkpointing: per-leaf ``.npy`` shards + a JSON manifest.

Design (offline container — no orbax/tensorstore):

  * Every pytree leaf is saved as one or more ``.npy`` shard files, split
    along its largest axis into ``n_shards`` pieces so that (a) hosts write
    in parallel on a real cluster, and (b) restore can re-assemble onto a
    DIFFERENT mesh — the manifest stores only the logical array, not the
    device layout, which is what makes restarts elastic (restore onto
    more or fewer devices than saved from).
  * The manifest (checkpoint.json) records the tree structure, per-leaf
    dtype/shape/shard files, the step, and a payload checksum; writes are
    atomic (tmp dir + rename) so a failure mid-save never corrupts the
    latest valid checkpoint.
  * ``CheckpointManager`` keeps the last ``keep`` checkpoints and finds
    the newest valid one on restart; ``restore`` prunes directories whose
    payload fails verification (a torn non-atomic copy must not poison
    restart) and falls back to the next-newest valid step.
  * Pytrees may contain *checkpointable objects* — anything exposing
    ``checkpoint_arrays() -> dict[str, ndarray]`` and
    ``from_checkpoint_arrays(dict) -> object`` (e.g.
    :class:`repro.pathfinding.pareto.ParetoArchive`). They are expanded
    to their array dict on save and reconstituted on load; their array
    shapes are *elastic* (a restored archive may hold a different number
    of rows than the template). The :data:`ELASTIC` sentinel marks any
    other template leaf whose shape should be taken from the manifest
    instead of the template (e.g. a grow-only history vector).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "checkpoint.json"


class CorruptCheckpointError(ValueError):
    """The checkpoint payload is unreadable or fails verification
    (missing/truncated shard, unreadable manifest, checksum mismatch) —
    as opposed to a *valid* checkpoint that is structurally incompatible
    with the template (missing leaf / shape mismatch), which raises
    ``KeyError``/``ValueError`` and is never silently pruned."""


class _Elastic:
    """Template sentinel: restore this leaf with the manifest's shape and
    dtype instead of requiring the template's."""

    def __repr__(self) -> str:  # pragma: no cover - repr only
        return "ELASTIC"


ELASTIC = _Elastic()


def _is_checkpointable(x: Any) -> bool:
    return (hasattr(x, "checkpoint_arrays")
            and hasattr(x, "from_checkpoint_arrays"))


def _expand_for_save(tree: Any) -> Any:
    """Replace checkpointable objects with their array dicts (the dict
    becomes a subtree, so each array gets its own manifest leaf)."""
    return jax.tree_util.tree_map(
        lambda leaf: (dict(leaf.checkpoint_arrays())
                      if _is_checkpointable(leaf) else leaf),
        tree, is_leaf=_is_checkpointable)


def _expand_for_load(tree: Any) -> Any:
    """Template twin of :func:`_expand_for_save`: every object array is
    marked :data:`ELASTIC` (its saved shape wins over the template's)."""
    return jax.tree_util.tree_map(
        lambda leaf: ({k: ELASTIC for k in leaf.checkpoint_arrays()}
                      if _is_checkpointable(leaf) else leaf),
        tree, is_leaf=_is_checkpointable)


def _collapse(like: Any, restored: Any) -> Any:
    """Reconstitute objects: where ``like`` holds a checkpointable leaf,
    ``restored`` holds its array-dict subtree."""
    return jax.tree_util.tree_map(
        lambda leaf, sub: (leaf.from_checkpoint_arrays(sub)
                           if _is_checkpointable(leaf) else sub),
        like, restored, is_leaf=_is_checkpointable)


def _leaf_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def _shard_slices(shape: Tuple[int, ...], n_shards: int):
    """Split along the largest axis into up to n_shards contiguous slices."""
    if not shape or n_shards <= 1:
        return [tuple(slice(None) for _ in shape)]
    axis = int(np.argmax(shape))
    n = min(n_shards, shape[axis])
    edges = np.linspace(0, shape[axis], n + 1, dtype=int)
    slices = []
    for lo, hi in zip(edges[:-1], edges[1:]):
        if hi > lo:
            s = [slice(None)] * len(shape)
            s[axis] = slice(int(lo), int(hi))
            slices.append(tuple(s))
    return slices


def _as_jnp(arr: np.ndarray):
    """Device conversion that preserves the manifest dtype exactly: a
    float64/int64 leaf must not silently demote to 32-bit when the
    process runs without global x64 (the search-state checkpoints are
    float64 end to end)."""
    from jax.experimental import enable_x64

    if arr.dtype in (np.float64, np.int64, np.uint64, np.complex128):
        with enable_x64():
            return jnp.asarray(arr)
    return jnp.asarray(arr)


def save_checkpoint(directory: str, step: int, tree: Any,
                    n_shards: int = 8) -> str:
    """Atomic save of a pytree. Returns the checkpoint path.

    The tree may contain checkpointable objects (see module docstring);
    they are expanded to their array dicts before writing."""
    tree = _expand_for_save(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest: Dict[str, Any] = {"step": step, "leaves": {}, "time": 0.0}
    manifest["time"] = time.time()
    digest = hashlib.sha256()
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        entry = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                 "shards": []}
        for i, sl in enumerate(_shard_slices(arr.shape, n_shards)):
            fname = f"{name.replace('/', '.')}.{i}.npy"
            piece = np.ascontiguousarray(arr[sl])
            np.save(os.path.join(tmp, fname), piece)
            digest.update(piece.tobytes()[:4096])
            entry["shards"].append({
                "file": fname,
                "slices": [[s.start, s.stop] if s.start is not None
                           or s.stop is not None else None
                           for s in sl],
            })
        manifest["leaves"][name] = entry
    manifest["checksum"] = digest.hexdigest()
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(path: str, like: Any,
                    sharding_fn=None) -> Tuple[int, Any]:
    """Restore into the structure of ``like``. ``sharding_fn(name, arr)``
    may return a jax.sharding.Sharding to place each leaf directly onto
    the *current* mesh (which may differ from the save-time mesh).

    Template leaves that are :data:`ELASTIC` (or arrays belonging to a
    checkpointable object) take their shape/dtype from the manifest.
    Unreadable payloads raise :class:`CorruptCheckpointError`; a valid
    checkpoint that does not fit the template raises ``KeyError`` /
    ``ValueError`` as before."""
    try:
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
        leaves = manifest["leaves"]
    except (OSError, ValueError, KeyError) as e:
        raise CorruptCheckpointError(
            f"checkpoint {path}: unreadable manifest ({e})") from e

    # read + digest EVERY manifest leaf in manifest (= save) order
    # before any template matching: the checksum covers the whole
    # payload, so verification must too — a template requesting a subset
    # of the saved leaves must not skew the digest into a false
    # corruption verdict (CheckpointManager.restore *prunes* on
    # corruption, so a false positive would destroy valid snapshots)
    digest = hashlib.sha256()
    arrays: Dict[str, np.ndarray] = {}
    for name, entry in leaves.items():
        arr = np.empty(entry["shape"], dtype=np.dtype(entry["dtype"]))
        for sh in entry["shards"]:
            try:
                piece = np.load(os.path.join(path, sh["file"]))
            except (OSError, ValueError) as e:
                raise CorruptCheckpointError(
                    f"checkpoint {path}: bad shard {sh['file']} ({e})"
                ) from e
            sl = tuple(slice(None) if s is None else slice(s[0], s[1])
                       for s in sh["slices"])
            try:
                arr[sl if sl else ...] = piece
            except ValueError as e:
                raise CorruptCheckpointError(
                    f"checkpoint {path}: shard {sh['file']} does not fit "
                    f"its manifest slice ({e})") from e
            digest.update(piece.tobytes()[:4096])
        arrays[name] = arr
    if manifest.get("checksum") and manifest["checksum"] != digest.hexdigest():
        raise CorruptCheckpointError(
            f"checkpoint {path} checksum mismatch (corrupt?)")

    like_x = _expand_for_load(like)
    names = [n for n, _ in _leaf_paths(like_x)]
    flat_like, tdef = jax.tree_util.tree_flatten(like_x)
    out = []
    for name, leaf in zip(names, flat_like):
        arr = arrays.get(name)
        if arr is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        if (leaf is not ELASTIC
                and list(arr.shape) != list(np.shape(leaf))):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs "
                f"model {np.shape(leaf)}")
        if sharding_fn is not None:
            sharding = sharding_fn(name, arr)
            out.append(jax.device_put(arr, sharding) if sharding is not None
                       else _as_jnp(arr))
        else:
            out.append(_as_jnp(arr))
    restored = jax.tree_util.tree_unflatten(tdef, out)
    return manifest["step"], _collapse(like, restored)


class CheckpointManager:
    """Rotating checkpoint directory with newest-valid discovery."""

    def __init__(self, directory: str, keep: int = 3, n_shards: int = 8):
        self.directory = directory
        self.keep = keep
        self.n_shards = n_shards
        os.makedirs(directory, exist_ok=True)

    def all_steps(self) -> List[int]:
        steps = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, d, MANIFEST)):
                    steps.append(int(d.split("_")[1]))
        return sorted(steps)

    def step_path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def latest(self) -> Optional[str]:
        steps = self.all_steps()
        if not steps:
            return None
        return self.step_path(steps[-1])

    def save(self, step: int, tree: Any) -> str:
        path = save_checkpoint(self.directory, step, tree, self.n_shards)
        for s in self.all_steps()[:-self.keep]:
            shutil.rmtree(self.step_path(s), ignore_errors=True)
        return path

    def restore(self, like: Any, sharding_fn=None) -> Tuple[int, Any]:
        """Restore the newest *valid* checkpoint.

        A directory whose payload fails verification (torn non-atomic
        copy, truncated shard, checksum mismatch) is pruned and the
        next-newest step is tried — previously a single corrupt copy
        poisoned every restart. Structural incompatibility with ``like``
        (missing leaf / shape mismatch) still raises immediately: that
        is a caller bug, not corruption."""
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        last_err: Optional[Exception] = None
        for s in reversed(steps):
            path = self.step_path(s)
            try:
                return load_checkpoint(path, like, sharding_fn)
            except CorruptCheckpointError as e:
                last_err = e
                shutil.rmtree(path, ignore_errors=True)
        raise FileNotFoundError(
            f"no valid checkpoint in {self.directory} "
            f"(every step failed verification; last: {last_err})")
