from repro.checkpoint.checkpoint import (
    ELASTIC,
    CheckpointManager,
    CorruptCheckpointError,
    load_checkpoint,
    save_checkpoint,
)

__all__ = ["CheckpointManager", "CorruptCheckpointError", "ELASTIC",
           "save_checkpoint", "load_checkpoint"]
