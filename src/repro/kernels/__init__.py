"""Pallas TPU kernels for the compute hot-spots (validated via
``interpret=True`` on CPU; compiled path on TPU backends).

  systolic_gemm — BlockSpec-tiled GEMM carrying the paper's mapping knobs
                  (dataflow OS/WS/IS, split-K, tile shape).
  wkv6          — RWKV-6 data-dependent-decay recurrence.
  rglru         — RecurrentGemma gated linear recurrence.
  prefix_gather — prefix-table gather + per-chiplet-slot segment reduction
                  (the device pathfinder's stage-3 inner loop).
"""
from repro.kernels.prefix_gather import (
    prefix_segment_gather,
    prefix_segment_ref,
    prefix_select_gather,
    prefix_select_ref,
)
from repro.kernels.rglru import rglru, rglru_assoc_ref, rglru_ref
from repro.kernels.systolic_gemm import gemm_ref, systolic_gemm
from repro.kernels.wkv6 import wkv6, wkv6_ref, wkv6_ref_vmapped

__all__ = [
    "systolic_gemm", "gemm_ref",
    "wkv6", "wkv6_ref", "wkv6_ref_vmapped",
    "rglru", "rglru_ref", "rglru_assoc_ref",
    "prefix_segment_gather", "prefix_segment_ref",
    "prefix_select_gather", "prefix_select_ref",
]
