"""Pallas kernel: prefix-table gather + per-chiplet-slot segment reduction.

The hottest inner loop of the device evaluator's stage 3
(:mod:`repro.pathfinding.device`): every system gathers, per chiplet
slot, the difference of two entries of a per-(array, sram, dataflow)
prefix-sum table — Algorithm 1 assigns contiguous tile ranges, so a
core's ScaleSim aggregate is ``pref[row, end] - pref[row, start]`` — and
reduces the slot values to a per-system total.

Layout: one grid step per system. The three index arrays ride in scalar
prefetch (SMEM) — the canonical Pallas embedding-gather idiom — while the
prefix table lives in (V)MEM as a single resident block; the slot loop is
unrolled (``C`` = max chiplets, 6 by default), each iteration issuing two
dynamically indexed scalar loads.

Two kernels share the idiom:

  ``_gather_kernel``  — one table, raw [start, end] differences (PR 2's
                        original single-metric entry point).
  ``_select_kernel``  — the fused tempering gather stage: both split-K
                        table stacks resident at once, per-row clip
                        bounds applied on the SMEM scalars, per-slot
                        split select and per-metric segment reduction
                        emitted in the same grid step. This is the one
                        the device evaluator and the workload-stacked
                        ScenarioEngine route through.

CPU containers run this in interpreter mode, which is exact for the
float64 tables the device evaluator feeds it (prefix magnitudes < 2^53).
On TPU the same kernel compiles for float32/int32 tables; the f64 parity
contract then requires rebased (per-range) tables, which is why the
device evaluator only enables the kernel path explicitly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(rows_ref, start_ref, end_ref, pref_ref, diff_ref,
                   total_ref, *, nc: int):
    i = pl.program_id(0)
    tot = None
    for c in range(nc):  # static unroll over chiplet slots
        r = rows_ref[i, c]
        s = start_ref[i, c]
        e = end_ref[i, c]
        d = pref_ref[r, e] - pref_ref[r, s]
        diff_ref[0, c] = d
        tot = d if tot is None else tot + d
    total_ref[0, 0] = tot


def prefix_segment(pref, rows, start, end, *, interpret: bool):
    """(diff [P, C], total [P, 1]) via one grid step per system."""
    P, C = rows.shape
    R, T1 = pref.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(P,),
        in_specs=[pl.BlockSpec((R, T1), lambda i, *_: (0, 0))],
        out_specs=[pl.BlockSpec((1, C), lambda i, *_: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i, *_: (i, 0))],
    )
    return pl.pallas_call(
        functools.partial(_gather_kernel, nc=C),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((P, C), pref.dtype),
                   jax.ShapeDtypeStruct((P, 1), pref.dtype)],
        interpret=interpret,
    )(rows.astype(jnp.int32), start.astype(jnp.int32),
      end.astype(jnp.int32), pref)


def _select_kernel(rows_ref, start_ref, end_ref, split_ref, t0_ref, t1_ref,
                   p0_ref, p1_ref, sel_ref, total_ref, *, nc: int, nf: int):
    """Fused gather → per-slot split-K select → segment reduce.

    One grid step per system: the six index/bound vectors ride in scalar
    prefetch (SMEM); BOTH split-K table stacks (``[F, R, T+1]``, one
    plane per sim metric) are resident (V)MEM blocks with a constant
    index map, so Pallas's double-buffered block pipeline copies them in
    once and every grid step reuses the same buffers. Clipping to the
    per-row tile totals happens on the SMEM scalars, so bucket-padded
    rows and ``T0 != T1`` split tables never leak padding into a gather.
    """
    i = pl.program_id(0)
    sp = split_ref[i] == 1
    t0 = t0_ref[i]
    t1 = t1_ref[i]
    tot = [None] * nf
    for c in range(nc):  # static unroll over chiplet slots
        r = rows_ref[i, c]
        s = start_ref[i, c]
        e = end_ref[i, c]
        # clip against the true (unpadded) per-row tile totals
        s0 = jnp.minimum(jnp.maximum(s, 0), t0)
        e0 = jnp.minimum(jnp.maximum(e, 0), t0)
        s1 = jnp.minimum(jnp.maximum(s, 0), t1)
        e1 = jnp.minimum(jnp.maximum(e, 0), t1)
        for f in range(nf):  # static unroll over sim metrics
            d = jnp.where(sp, p1_ref[f, r, e1] - p1_ref[f, r, s1],
                          p0_ref[f, r, e0] - p0_ref[f, r, s0])
            sel_ref[0, c, f] = d
            tot[f] = d if tot[f] is None else tot[f] + d
    for f in range(nf):
        total_ref[0, f] = tot[f]


def prefix_select(pref0, pref1, rows, start, end, split, t0, t1, *,
                  interpret: bool):
    """(sel [P, C, F], total [P, F]) — the fused tempering gather stage.

    ``pref0``/``pref1`` are the two split-K table stacks ``[F, R, T+1]``
    (row counts match, tile axes may differ); ``rows``/``start``/``end``
    are ``[P, C]``; ``split``/``t0``/``t1`` are per-system ``[P]`` split
    selectors and clip bounds. Rows already carry any workload-stack
    offset, so the same kernel serves the single-workload flat layout
    and the scenario engine's ``[(Wk*A*S*3), T_bucket+1]`` layout.
    """
    P, C = rows.shape
    F, R0, T0b = pref0.shape
    F1, R1, T1b = pref1.shape
    assert F == F1 and R0 == R1, (pref0.shape, pref1.shape)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(P,),
        in_specs=[pl.BlockSpec((F, R0, T0b), lambda i, *_: (0, 0, 0)),
                  pl.BlockSpec((F, R1, T1b), lambda i, *_: (0, 0, 0))],
        out_specs=[pl.BlockSpec((1, C, F), lambda i, *_: (i, 0, 0)),
                   pl.BlockSpec((1, F), lambda i, *_: (i, 0))],
    )
    return pl.pallas_call(
        functools.partial(_select_kernel, nc=C, nf=F),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((P, C, F), pref0.dtype),
                   jax.ShapeDtypeStruct((P, F), pref0.dtype)],
        interpret=interpret,
    )(rows.astype(jnp.int32), start.astype(jnp.int32),
      end.astype(jnp.int32), split.astype(jnp.int32),
      t0.astype(jnp.int32), t1.astype(jnp.int32), pref0, pref1)
