"""Pallas kernel: prefix-table gather + per-chiplet-slot segment reduction.

The hottest inner loop of the device evaluator's stage 3
(:mod:`repro.pathfinding.device`): every system gathers, per chiplet
slot, the difference of two entries of a per-(array, sram, dataflow)
prefix-sum table — Algorithm 1 assigns contiguous tile ranges, so a
core's ScaleSim aggregate is ``pref[row, end] - pref[row, start]`` — and
reduces the slot values to a per-system total.

Layout: one grid step per system. The three index arrays ride in scalar
prefetch (SMEM) — the canonical Pallas embedding-gather idiom — while the
prefix table lives in (V)MEM as a single resident block; the slot loop is
unrolled (``C`` = max chiplets, 6 by default), each iteration issuing two
dynamically indexed scalar loads.

CPU containers run this in interpreter mode, which is exact for the
float64 tables the device evaluator feeds it (prefix magnitudes < 2^53).
On TPU the same kernel compiles for float32/int32 tables; the f64 parity
contract then requires rebased (per-range) tables, which is why the
device evaluator only enables the kernel path explicitly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(rows_ref, start_ref, end_ref, pref_ref, diff_ref,
                   total_ref, *, nc: int):
    i = pl.program_id(0)
    tot = None
    for c in range(nc):  # static unroll over chiplet slots
        r = rows_ref[i, c]
        s = start_ref[i, c]
        e = end_ref[i, c]
        d = pref_ref[r, e] - pref_ref[r, s]
        diff_ref[0, c] = d
        tot = d if tot is None else tot + d
    total_ref[0, 0] = tot


def prefix_segment(pref, rows, start, end, *, interpret: bool):
    """(diff [P, C], total [P, 1]) via one grid step per system."""
    P, C = rows.shape
    R, T1 = pref.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(P,),
        in_specs=[pl.BlockSpec((R, T1), lambda i, *_: (0, 0))],
        out_specs=[pl.BlockSpec((1, C), lambda i, *_: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i, *_: (i, 0))],
    )
    return pl.pallas_call(
        functools.partial(_gather_kernel, nc=C),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((P, C), pref.dtype),
                   jax.ShapeDtypeStruct((P, 1), pref.dtype)],
        interpret=interpret,
    )(rows.astype(jnp.int32), start.astype(jnp.int32),
      end.astype(jnp.int32), pref)
