"""Jitted public wrappers for the prefix-gather kernels.

Dispatches to interpreter mode on non-TPU backends (the kernel body runs
in Python but stays bit-exact, including for float64 tables) and to the
compiled path on TPU.

``prefix_select_gather`` — the fused tempering gather stage — carries a
``jax.custom_batching.custom_vmap`` rule: the stacked ScenarioEngine
calls it from inside a ``vmap`` over scenario cells, and the rule
flattens the mapped cell axis into the kernel grid (``[B, P, C] ->
[B*P, C]``) instead of relying on ``pallas_call``'s own batching. The
prefix tables stay unbatched operands (cells share one workload-stacked
table), so one kernel launch covers the whole grid.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import custom_batching

from repro.kernels.prefix_gather import kernel as K


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def prefix_segment_gather(pref, rows, start, end,
                          interpret: Optional[bool] = None):
    """Per-slot prefix differences + per-row segment totals.

    Args:
      pref: ``[R, T+1]`` prefix-sum table (one row per (array, sram,
        dataflow) combination).
      rows/start/end: ``[P, C]`` int index arrays — table row and the
        [start, end] tile range per chiplet slot.
      interpret: force Pallas interpret mode; default on non-TPU backends.

    Returns:
      ``(diff [P, C], total [P])``.
    """
    interp = _default_interpret() if interpret is None else interpret
    diff, total = K.prefix_segment(pref, rows, start, end, interpret=interp)
    return diff, total[:, 0]


@functools.lru_cache(maxsize=None)
def _select_fn(interpret: bool):
    """The custom_vmap-wrapped fused kernel for one interpret setting."""

    def call(pref0, pref1, rows, start, end, split, t0, t1):
        return K.prefix_select(pref0, pref1, rows, start, end, split,
                               t0, t1, interpret=interpret)

    fn = custom_batching.custom_vmap(call)

    @fn.def_vmap
    def _rule(axis_size, in_batched, pref0, pref1, rows, start, end,
              split, t0, t1):
        (b_p0, b_p1, b_rows, b_start, b_end, b_split, b_t0,
         b_t1) = in_batched
        if b_p0 or b_p1:
            raise NotImplementedError(
                "prefix_select_gather: batched prefix tables are not "
                "supported — the vmapped axis must share one "
                "(workload-stacked) table pair")
        B = axis_size

        def bat(x, batched):
            return x if batched else jnp.broadcast_to(x, (B,) + x.shape)

        rows_b = bat(rows, b_rows)
        P = rows_b.shape[1]

        def flat(x):
            return x.reshape((B * P,) + x.shape[2:])

        sel, tot = call(pref0, pref1, flat(rows_b),
                        flat(bat(start, b_start)), flat(bat(end, b_end)),
                        flat(bat(split, b_split)), flat(bat(t0, b_t0)),
                        flat(bat(t1, b_t1)))
        return (sel.reshape((B, P) + sel.shape[1:]),
                tot.reshape((B, P) + tot.shape[1:])), (True, True)

    return fn


@functools.partial(jax.jit, static_argnames=("interpret",))
def prefix_select_gather(pref0, pref1, rows, start, end, split, t0, t1,
                         interpret: Optional[bool] = None):
    """Fused gather → split-K select → per-metric segment reduce.

    The tempering inner step's whole table stage in one kernel launch
    (where the PR-2 entry point needed ``F metrics x 2 splits`` calls).

    Args:
      pref0/pref1: ``[F, R, T0+1]`` / ``[F, R, T1+1]`` split-K prefix
        table stacks — one plane per sim metric; the tile axes may
        differ (``T0 != T1``) and may be bucket-padded past the true
        totals (edge padding).
      rows: ``[P, C]`` table row per chiplet slot. Rows carry any
        workload-stack offset (``((wi*A + a)*S + s)*3 + d``) already.
      start/end: ``[P, C]`` unclipped tile ranges.
      split: ``[P]`` per-system split-K selector (1 selects ``pref1``).
      t0/t1: ``[P]`` per-row true tile totals — gathers clip here, so
        padded tail slots are never read.
      interpret: force Pallas interpret mode; default on non-TPU
        backends.

    Returns:
      ``(sel [P, C, F], total [P, F])`` — split-selected per-slot
      differences and their per-system segment reduction.

    Under ``vmap`` the mapped axis is flattened into the kernel grid
    (tables must be unbatched); see the module docstring.
    """
    interp = _default_interpret() if interpret is None else interpret
    fn = _select_fn(bool(interp))
    return fn(pref0, pref1, rows.astype(jnp.int32),
              start.astype(jnp.int32), end.astype(jnp.int32),
              split.astype(jnp.int32), t0.astype(jnp.int32),
              t1.astype(jnp.int32))
