"""Jitted public wrapper for the prefix-gather kernel.

Dispatches to interpreter mode on non-TPU backends (the kernel body runs
in Python but stays bit-exact, including for float64 tables) and to the
compiled path on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.prefix_gather import kernel as K


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def prefix_segment_gather(pref, rows, start, end,
                          interpret: Optional[bool] = None):
    """Per-slot prefix differences + per-row segment totals.

    Args:
      pref: ``[R, T+1]`` prefix-sum table (one row per (array, sram,
        dataflow) combination).
      rows/start/end: ``[P, C]`` int index arrays — table row and the
        [start, end] tile range per chiplet slot.
      interpret: force Pallas interpret mode; default on non-TPU backends.

    Returns:
      ``(diff [P, C], total [P])``.
    """
    interp = _default_interpret() if interpret is None else interpret
    diff, total = K.prefix_segment(pref, rows, start, end, interpret=interp)
    return diff, total[:, 0]
