"""Pure-jnp oracle for the prefix-gather + segment-reduction kernel."""
from __future__ import annotations

import jax.numpy as jnp


def prefix_segment_ref(pref: jnp.ndarray, rows: jnp.ndarray,
                       start: jnp.ndarray, end: jnp.ndarray):
    """Per-slot prefix-sum differences and their per-row totals.

    ``pref`` is a ``[R, T+1]`` prefix-sum table; ``rows``/``start``/``end``
    are ``[P, C]`` index arrays. Returns ``(diff [P, C], total [P])`` with
    ``diff[p, c] = pref[rows[p, c], end[p, c]] - pref[rows[p, c],
    start[p, c]]`` — Algorithm 1 assigns each core a contiguous tile
    range, so a core's simulation aggregate is exactly this difference —
    and ``total`` the per-system (all-slot) segment reduction.
    """
    diff = (jnp.take_along_axis(pref[rows], end[..., None], axis=2)
            - jnp.take_along_axis(pref[rows], start[..., None], axis=2)
            )[..., 0]
    return diff, diff.sum(axis=1)


def prefix_select_ref(pref0: jnp.ndarray, pref1: jnp.ndarray,
                      rows: jnp.ndarray, start: jnp.ndarray,
                      end: jnp.ndarray, split: jnp.ndarray,
                      t0: jnp.ndarray, t1: jnp.ndarray):
    """Oracle for the fused gather → split-select → segment-reduce kernel.

    ``pref0``/``pref1`` are ``[F, R, T+1]`` split-K table stacks (tile
    axes may differ and may be padded past the true totals);
    ``rows``/``start``/``end`` are ``[P, C]``; ``split``/``t0``/``t1``
    per-system ``[P]``. Gathers clip to the per-row true tile totals,
    then the split selector picks per system which table's difference
    survives. Returns ``(sel [P, C, F], total [P, F])``.
    """
    def gather(pref, s, e):
        tab = pref[:, rows]  # [F, P, C, T+1]
        d = (jnp.take_along_axis(tab, e[None, ..., None], axis=3)
             - jnp.take_along_axis(tab, s[None, ..., None], axis=3)
             )[..., 0]
        return jnp.moveaxis(d, 0, -1)  # [P, C, F]

    s0 = jnp.clip(start, 0, t0[:, None])
    e0 = jnp.clip(end, 0, t0[:, None])
    s1 = jnp.clip(start, 0, t1[:, None])
    e1 = jnp.clip(end, 0, t1[:, None])
    sel = jnp.where((split == 1)[:, None, None],
                    gather(pref1, s1, e1), gather(pref0, s0, e0))
    return sel, sel.sum(axis=1)
