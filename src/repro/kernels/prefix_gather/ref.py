"""Pure-jnp oracle for the prefix-gather + segment-reduction kernel."""
from __future__ import annotations

import jax.numpy as jnp


def prefix_segment_ref(pref: jnp.ndarray, rows: jnp.ndarray,
                       start: jnp.ndarray, end: jnp.ndarray):
    """Per-slot prefix-sum differences and their per-row totals.

    ``pref`` is a ``[R, T+1]`` prefix-sum table; ``rows``/``start``/``end``
    are ``[P, C]`` index arrays. Returns ``(diff [P, C], total [P])`` with
    ``diff[p, c] = pref[rows[p, c], end[p, c]] - pref[rows[p, c],
    start[p, c]]`` — Algorithm 1 assigns each core a contiguous tile
    range, so a core's simulation aggregate is exactly this difference —
    and ``total`` the per-system (all-slot) segment reduction.
    """
    diff = (jnp.take_along_axis(pref[rows], end[..., None], axis=2)
            - jnp.take_along_axis(pref[rows], start[..., None], axis=2)
            )[..., 0]
    return diff, diff.sum(axis=1)
