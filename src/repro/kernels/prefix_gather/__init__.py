from repro.kernels.prefix_gather.ops import (
    prefix_segment_gather,
    prefix_select_gather,
)
from repro.kernels.prefix_gather.ref import (
    prefix_segment_ref,
    prefix_select_ref,
)

__all__ = ["prefix_segment_gather", "prefix_segment_ref",
           "prefix_select_gather", "prefix_select_ref"]
