"""Cross-version Pallas TPU compatibility helpers."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def compiler_params(**kwargs):
    """``CompilerParams`` was renamed from ``TPUCompilerParams``; build
    whichever this jax provides."""
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
