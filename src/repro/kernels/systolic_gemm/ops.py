"""Jitted public wrapper for the systolic GEMM kernel.

Handles padding to block multiples, dataflow dispatch, the split-K
destination reduction, and interpret-mode selection (CPU containers run
the kernel body in Python via ``interpret=True``; on TPU backends the
compiled path is used).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.systolic_gemm import kernel as K

DATAFLOWS = ("OS", "WS", "IS")


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "bn", "dataflow", "split_k", "out_dtype",
                     "interpret"))
def systolic_gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    dataflow: str = "OS",
    split_k: int = 1,
    out_dtype=None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """``a @ b`` through the paper's (dataflow, split-K, tile) mapping.

    Args:
      a: (M, K) left operand.
      b: (K, N) right operand.
      bm/bk/bn: BlockSpec tile shape — the paper's (t_M, t_K, t_N).
      dataflow: OS | WS | IS (Sec IV-A).
      split_k: number of K shards for OS; each produces a partial slab
        reduced here (the destination-chiplet reduction). WS/IS spill one
        slab per K block inherently.
      out_dtype: output dtype (defaults to ``a.dtype``).
      interpret: force Pallas interpret mode; default on non-TPU backends.
    """
    if dataflow not in DATAFLOWS:
        raise ValueError(f"dataflow must be one of {DATAFLOWS}")
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} x {b.shape}")
    out_dtype = out_dtype or a.dtype
    interp = _default_interpret() if interpret is None else interpret
    m, n = a.shape[0], b.shape[1]

    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    if dataflow == "OS" and split_k > 1:
        # pad K so it also divides split_k * bk
        kq = split_k * bk
        pk = (-ap.shape[1]) % kq
        if pk:
            ap = jnp.pad(ap, ((0, 0), (0, pk)))
            bp = jnp.pad(bp, ((0, pk), (0, 0)))

    if dataflow == "OS":
        if split_k > 1:
            slabs = K.os_gemm_splitk(
                ap, bp, splits=split_k, bm=bm, bk=bk, bn=bn,
                out_dtype=jnp.float32, interpret=interp)
            out = jnp.sum(slabs, axis=0).astype(out_dtype)
        else:
            out = K.os_gemm(ap, bp, bm=bm, bk=bk, bn=bn,
                            out_dtype=out_dtype, interpret=interp)
    elif dataflow == "WS":
        slabs = K.ws_gemm_partials(ap, bp, bm=bm, bk=bk, bn=bn,
                                   interpret=interp)
        out = jnp.sum(slabs, axis=0).astype(out_dtype)
    else:  # IS
        slabs = K.is_gemm_partials(ap, bp, bm=bm, bk=bk, bn=bn,
                                   interpret=interp)
        out = jnp.sum(slabs, axis=0).astype(out_dtype)
    return out[:m, :n]
