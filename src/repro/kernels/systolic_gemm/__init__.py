from repro.kernels.systolic_gemm.ops import systolic_gemm
from repro.kernels.systolic_gemm.ref import gemm_ref

__all__ = ["systolic_gemm", "gemm_ref"]
