"""Pallas TPU kernel: BlockSpec-tiled GEMM with the paper's mapping knobs.

This is the MXU rendering of CarbonPATH's workload-mapping vocabulary
(Sec IV-A, Algorithm 1). The systolic array of the paper is the TPU MXU;
the (t_M, t_K, t_N) tile sizes are the BlockSpec block shapes; and the
three dataflows map to grid iteration orders:

  OS  (output stationary) — grid (m, n, k), k innermost. Partial sums stay
      in a VMEM scratch accumulator and each output block is written once:
      the paper's reason OS minimizes data movement, rendered literally.
  WS  (weight stationary)  — grid (n, k, m), m innermost. The weight block
      is resident across the m sweep; output partial sums spill to a
      per-k-slab HBM buffer and are reduced by the wrapper — the psum
      write-back traffic the paper charges WS for.
  IS  (input stationary)   — grid (m, k, n), n innermost. Symmetric to WS
      with the input block resident.

split-K adds a leading slab axis for OS: each K-shard accumulates into its
own output slab, and the wrapper performs the destination reduction
(paper: partial sums shipped over D2D to the destination chiplet; here:
the slab-sum the distributed layer lowers to a reduce-scatter).

Block shapes should be multiples of 128 in the lane dimension and of 8
(fp32) / 16 (bf16) in the sublane dimension so the MXU tiles align.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import compiler_params as _compiler_params


def _os_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """Output-stationary: accumulate over the innermost k axis in VMEM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _os_splitk_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """Output-stationary with a leading split-K slab axis: grid
    (s, m, n, k); each slab holds the partial sum of its K shard."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[0] += jnp.dot(a_ref[0], b_ref[0],
                          preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _spill_kernel(a_ref, b_ref, o_ref):
    """WS/IS: one partial product per (k-slab, m, n) block; the stationary
    operand is pinned by its index_map across the innermost sweep."""
    o_ref[0] = jnp.dot(a_ref[0], b_ref[0],
                       preferred_element_type=jnp.float32)


def os_gemm(a, b, *, bm, bk, bn, out_dtype, interpret):
    m, k = a.shape
    _, n = b.shape
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_os_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


def os_gemm_splitk(a, b, *, splits, bm, bk, bn, out_dtype, interpret):
    """Returns (splits, m, n) partial slabs; caller reduces over axis 0."""
    m, k = a.shape
    _, n = b.shape
    k_shard = k // splits
    grid = (splits, m // bm, n // bn, k_shard // bk)
    nk = grid[3]
    return pl.pallas_call(
        functools.partial(_os_splitk_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk),
                         lambda s, i, j, kk, nk=nk: (0, i, s * nk + kk)),
            pl.BlockSpec((1, bk, bn),
                         lambda s, i, j, kk, nk=nk: (0, s * nk + kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda s, i, j, kk: (s, i, j)),
        out_shape=jax.ShapeDtypeStruct((splits, m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((1, bm, bn), jnp.float32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(a[None], b[None])


def ws_gemm_partials(a, b, *, bm, bk, bn, interpret):
    """Weight-stationary: grid (n, k, m), m innermost; psum slabs out."""
    m, k = a.shape
    _, n = b.shape
    grid = (n // bn, k // bk, m // bm)
    return pl.pallas_call(
        _spill_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda j, kk, i: (0, i, kk)),
            # weight block: index ignores the innermost m axis -> resident
            pl.BlockSpec((1, bk, bn), lambda j, kk, i: (0, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda j, kk, i: (kk, i, j)),
        out_shape=jax.ShapeDtypeStruct((k // bk, m, n), jnp.float32),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(a[None], b[None])


def is_gemm_partials(a, b, *, bm, bk, bn, interpret):
    """Input-stationary: grid (m, k, n), n innermost; psum slabs out."""
    m, k = a.shape
    _, n = b.shape
    grid = (m // bm, k // bk, n // bn)
    return pl.pallas_call(
        _spill_kernel,
        grid=grid,
        in_specs=[
            # input block: index ignores the innermost n axis -> resident
            pl.BlockSpec((1, bm, bk), lambda i, kk, j: (0, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda i, kk, j: (0, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda i, kk, j: (kk, i, j)),
        out_shape=jax.ShapeDtypeStruct((k // bk, m, n), jnp.float32),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(a[None], b[None])
