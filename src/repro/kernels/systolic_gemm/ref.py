"""Pure-jnp oracle for the systolic GEMM kernel."""
from __future__ import annotations

import jax.numpy as jnp


def gemm_ref(a: jnp.ndarray, b: jnp.ndarray,
             out_dtype=None) -> jnp.ndarray:
    """Plain matmul with fp32 accumulation — the correctness oracle for
    every (dataflow, split-K, block-shape) variant of the kernel."""
    out = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or a.dtype)
