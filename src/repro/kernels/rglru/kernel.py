"""Pallas TPU kernel for the RG-LRU gated linear recurrence.

Grid: (B, C // bc, T // ct) with time innermost; the (1, bc) hidden-state
carry lives in VMEM scratch, persisting across time chunks and re-zeroed
whenever a new (batch, channel-block) row starts. Channels are the lane
dimension (bc a multiple of 128); the fori_loop body is a pure VPU
elementwise multiply-add, so the kernel is memory-bound by design — its
purpose is fusing the scan so HBM sees each element exactly once instead
of the O(T) small-kernel launches an unfused scan lowers to.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import compiler_params as _compiler_params


def _rglru_kernel(a_ref, b_ref, h_ref, carry_ref, *, ct: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    def step(i, h):
        h = a_ref[0, i] * h + b_ref[0, i]
        h_ref[0, i] = h.astype(h_ref.dtype)
        return h

    carry_ref[0] = jax.lax.fori_loop(0, ct, step, carry_ref[0])


def rglru_pallas(a, b, *, bc: int = 128, ct: int = 128,
                 interpret: bool = True):
    """a, b: (B, T, C) -> h: (B, T, C) fp32."""
    bsz, t, ch = a.shape
    assert t % ct == 0 and ch % bc == 0
    grid = (bsz, ch // bc, t // ct)
    blk = pl.BlockSpec((1, ct, bc), lambda bi, ci, ti: (bi, ti, ci))
    return pl.pallas_call(
        functools.partial(_rglru_kernel, ct=ct),
        grid=grid,
        in_specs=[blk, blk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((bsz, t, ch), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bc), jnp.float32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
