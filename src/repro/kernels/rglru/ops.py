"""Jitted public wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.rglru import kernel as K


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bc", "ct", "interpret"))
def rglru(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bc: int = 128,
    ct: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Gated linear recurrence h_t = a_t * h_{t-1} + b_t over (B, T, C).

    Pads T with a_t = 1, b_t = 0 (identity elements) and C with zeros;
    slices the result back to the input shape.
    """
    bsz, t, ch = a.shape
    interp = _default_interpret() if interpret is None else interpret
    ct_eff = min(ct, t) if t % min(ct, t) == 0 else t
    bc_eff = min(bc, ch) if ch % min(bc, ch) == 0 else ch
    pt = (-t) % ct_eff
    pc = (-ch) % bc_eff
    if pt or pc:
        a = jnp.pad(a, ((0, 0), (0, pt), (0, pc)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pt), (0, pc)))
    out = K.rglru_pallas(a, b, bc=bc_eff, ct=ct_eff, interpret=interp)
    return out[:, :t, :ch].astype(a.dtype)
