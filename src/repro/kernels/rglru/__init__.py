from repro.kernels.rglru.ops import rglru
from repro.kernels.rglru.ref import rglru_assoc_ref, rglru_ref

__all__ = ["rglru", "rglru_ref", "rglru_assoc_ref"]
