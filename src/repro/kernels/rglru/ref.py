"""Pure-jnp oracles for the RG-LRU gated linear recurrence.

    h_t = a_t * h_{t-1} + b_t

with elementwise decay a_t in (0, 1) and pre-gated input b_t (the
RecurrentGemma layer computes a_t = exp(-c * softplus(lambda) * sigmoid(r_t))
and b_t = sqrt(1 - a_t^2) * (i_t * x_t) before calling this primitive).

Two formulations: a sequential lax.scan (the bitwise oracle) and an
associative_scan (log-depth; what the long-context serving path uses).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a, b: (B, T, C) -> h: (B, T, C), via sequential scan over T."""
    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    def one(a1, b1):
        h0 = jnp.zeros(a1.shape[-1], jnp.float32)
        _, hs = jax.lax.scan(step, h0, (a1.astype(jnp.float32),
                                        b1.astype(jnp.float32)))
        return hs

    return jax.vmap(one)(a, b).astype(a.dtype)


def rglru_assoc_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Same recurrence via associative scan: elements (a, b) compose as
    (a2*a1, a2*b1 + b2) — log-depth on parallel hardware."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    av, bv = jax.lax.associative_scan(
        combine, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=1)
    del av
    return bv.astype(a.dtype)
