"""Pure-jnp oracle for the RWKV-6 (Finch) WKV recurrence.

Per head with key/value dims D:
    S_0 = 0                                  (D_k x D_v state)
    y_t = r_t . (S_t + diag(u) k_t v_t^T)    (readout, current-token bonus u)
    S_{t+1} = diag(w_t) S_t + k_t v_t^T      (data-dependent decay w_t)

Shapes: r, k, v, w are (G, T, D) with G = batch x heads flattened and
w in (0, 1); u is (G, D). Returns y of shape (G, T, D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u):
    g, t, d = r.shape

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[:, None] * v_t[None, :]                    # (Dk, Dv)
        y = jnp.einsum("k,kv->v", r_t, s + u_g[:, None] * kv)
        s = w_t[:, None] * s + kv
        return s, y

    ys = []
    for gi in range(g):
        u_g = u[gi]
        s0 = jnp.zeros((d, d), jnp.float32)
        _, y = jax.lax.scan(
            step, s0,
            (r[gi].astype(jnp.float32), k[gi].astype(jnp.float32),
             v[gi].astype(jnp.float32), w[gi].astype(jnp.float32)))
        ys.append(y)
    return jnp.stack(ys).astype(r.dtype)


def wkv6_ref_vmapped(r, k, v, w, u):
    """vmap formulation — used by the models (no Python loop over G)."""
    def one(r1, k1, v1, w1, u1):
        def step(s, inp):
            r_t, k_t, v_t, w_t = inp
            kv = k_t[:, None] * v_t[None, :]
            y = jnp.einsum("k,kv->v", r_t, s + u1[:, None] * kv)
            return w_t[:, None] * s + kv, y
        d = r1.shape[-1]
        _, y = jax.lax.scan(step, jnp.zeros((d, d), jnp.float32),
                            (r1.astype(jnp.float32), k1.astype(jnp.float32),
                             v1.astype(jnp.float32), w1.astype(jnp.float32)))
        return y
    return jax.vmap(one)(r, k, v, w, u).astype(r.dtype)
