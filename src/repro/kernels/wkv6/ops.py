"""Jitted public wrapper for the WKV-6 kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.wkv6 import kernel as K


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("ct", "interpret"))
def wkv6(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    *,
    ct: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """RWKV-6 WKV recurrence over flattened (batch x heads, T, D) inputs.

    ``w`` is the per-step decay already mapped into (0, 1); ``u`` the
    current-token bonus. Pads T up to a chunk multiple (decay of the pad
    region is irrelevant — outputs are sliced back).
    """
    g, t, d = r.shape
    interp = _default_interpret() if interpret is None else interpret
    ct = min(ct, t) if t % min(ct, t) == 0 else t
    pad = (-t) % ct
    if pad:
        def padt(x):
            return jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        r, k, v, w = padt(r), padt(k), padt(v), padt(w)
    out = K.wkv6_pallas(r, k, v, w, u, ct=ct, interpret=interp)
    return out[:, :t].astype(r.dtype)
