"""Pallas TPU kernel for the RWKV-6 WKV recurrence.

TPU adaptation: the recurrence is sequential in T but embarrassingly
parallel over G = batch x heads, so the grid is (G, T // ct) with the time
axis innermost ("arbitrary" semantics). The (D, D) state matrix lives in a
VMEM scratch that persists across time chunks and is re-initialized when a
new G row begins. Inside a chunk, a fori_loop performs ct rank-1 updates;
all operands for the chunk are VMEM-resident blocks of (1, ct, D).

VMEM budget per program: 4 x (ct x D) operand blocks + (D, D) state +
(ct, D) output, fp32. For D = 64, ct = 256 that's ~0.4 MB — comfortably
under the ~16 MB/core VMEM of current TPUs; BlockSpecs keep every matmul
dimension a multiple of the 8x128 register tile when D >= 128 (smaller D
still works; Pallas pads lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import compiler_params as _compiler_params


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref, *, ct: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0]                                  # (D,)

    def step(i, s):
        r_t = r_ref[0, i]                         # (D,)
        k_t = k_ref[0, i]
        v_t = v_ref[0, i]
        w_t = w_ref[0, i]
        kv = k_t[:, None] * v_t[None, :]          # (Dk, Dv)
        y = jnp.sum(r_t[:, None] * (s + u[:, None] * kv), axis=0)
        y_ref[0, i] = y.astype(y_ref.dtype)
        return w_t[:, None] * s + kv

    s_ref[...] = jax.lax.fori_loop(0, ct, step, s_ref[...])


def wkv6_pallas(r, k, v, w, u, *, ct: int = 128, interpret: bool = True):
    """r/k/v/w: (G, T, D); u: (G, D). Returns y: (G, T, D) in fp32."""
    g, t, d = r.shape
    assert t % ct == 0, f"T={t} not divisible by chunk {ct}"
    grid = (g, t // ct)
    blk = pl.BlockSpec((1, ct, d), lambda gi, c: (gi, c, 0))
    return pl.pallas_call(
        functools.partial(_wkv6_kernel, ct=ct),
        grid=grid,
        in_specs=[blk, blk, blk, blk,
                  pl.BlockSpec((1, d), lambda gi, c: (gi, 0))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((g, t, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
