"""Job model of the pathfinding service.

A *job* is one multi-objective search — a
:class:`~repro.pathfinding.pareto.ScalarizationSweep` over one
(workload, deployment region) cell — submitted to the shared warm
engine instead of run as a blocking call. The service packs jobs into
slots of a batched scenario axis and advances everybody one *segment*
(a fixed number of sweeps) at a time, so a job's lifecycle is quantized
at segment boundaries:

    PENDING -> RUNNING -> DONE
                  |  ^
                  v  |  (pause/resume_job, preemption)
               PAUSED -> PENDING
    PENDING/RUNNING -> CANCELLED      (cancel; slot freed at boundary)
    RUNNING -> FAILED                 (admission/engine error)

Determinism contract: a job's RNG stream is derived from
:func:`repro.pathfinding.pareto.fold_job_key` over its *job id* — never
from the slot it lands in — and its sweep counter rides per-slot
through the engine scan, so history/best/frontier are bit-identical
whether the job runs solo, packed next to arbitrary co-tenants, or is
preempted and resumed (including across a restart of the whole
service, via per-job :class:`~repro.pathfinding.resume
.SearchCheckpointer` snapshots at every boundary).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.regions import Region, as_region
from repro.core.techdb import HOURS_PER_DAY
from repro.pathfinding.pareto import ParetoArchive, ScalarizationSweep


class JobState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    PAUSED = "paused"
    DONE = "done"
    CANCELLED = "cancelled"
    FAILED = "failed"


#: states a job never leaves
TERMINAL = (JobState.DONE, JobState.CANCELLED, JobState.FAILED)


class JobEvictedError(KeyError):
    """A job finished and its record was garbage-collected past the
    service's ``retain_jobs`` retention cap.

    Subclasses :class:`KeyError` (lookups by id still behave like a
    missing key for callers that catch broadly) but renders its message
    verbatim instead of KeyError's quoted-args repr, so clients see why
    the id is gone and what to do about it."""

    def __str__(self) -> str:  # KeyError would repr() the message
        return self.args[0] if self.args else ""


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """What a client submits.

    ``job_id`` is the identity: it names the RNG stream (via
    :func:`~repro.pathfinding.pareto.fold_job_key`), the checkpoint
    subdirectory, and the handle for ``status``/``result``/``cancel``.
    Resubmitting the same spec to a service with a checkpoint root
    resumes the job bit-identically from its newest snapshot.

    ``workload`` must name one of the workloads the service was built
    over (the stacked engine bakes its tile tables per workload set).
    ``strategy`` carries the search knobs; its ``sweeps`` are rounded
    *up* to whole service segments (jobs join and leave the batch only
    at segment boundaries). ``budget`` caps total evaluations with the
    :func:`~repro.pathfinding.strategies.budget_sweeps` total-split
    semantics, applied *before* the round-up."""

    job_id: str
    workload: str
    strategy: ScalarizationSweep = dataclasses.field(
        default_factory=lambda: ScalarizationSweep(
            directions=2, n_chains=2, sweeps=8))
    carbon_intensity: float = 0.475
    # regional lifecycle axes (neutral defaults reproduce the
    # scalar-CI job bit-for-bit): $/kWh electricity price, embodied
    # multiplier, optional 24h grid-intensity profile (None = flat at
    # carbon_intensity). These loose fields are the historical API;
    # ``region`` is the unified one — a single
    # :class:`~repro.core.regions.Region` value carrying all the axes
    # (including the 24h price curve the loose fields never exposed).
    # Setting both at once is an error.
    electricity_price: float = 0.0
    emb_factor: float = 1.0
    grid_profile: Optional[Tuple[float, ...]] = None
    region: Optional[Region] = None
    budget: Optional[int] = None
    key: Optional[int] = None
    # communication model of the searched design space: "legacy" (the
    # bit-pinned default) or "mesh_noc" (adds per-chiplet mesh-dims /
    # NoI-entry axes). Jobs with different comm models never share a
    # bucket — the encoded row width and the fused program differ.
    comm: str = "legacy"
    # schedule model (repro.core.schedule): "fixed" (the bit-pinned
    # default) or "window" (adds the per-design start-hour/duty-shape
    # axes so the search co-optimizes *when* the design runs). Like
    # ``comm`` it is part of the bucket shape — and it enters the
    # checkpoint fingerprint only when non-neutral, so pre-scheduling
    # checkpoints stay byte-identical.
    schedule: str = "fixed"
    # per-job overrides of the service's adaptive-budget knobs (None =
    # service default); only read when the service runs adaptive=True
    stall_segments: Optional[int] = None
    stall_tol: Optional[float] = None

    def __post_init__(self) -> None:
        if self.grid_profile is not None:
            prof = tuple(float(x) for x in self.grid_profile)
            if len(prof) != HOURS_PER_DAY:
                raise ValueError(
                    f"grid_profile needs {HOURS_PER_DAY} hourly entries, "
                    f"got {len(prof)}")
            object.__setattr__(self, "grid_profile", prof)
        if self.region is not None:
            if (self.carbon_intensity != 0.475
                    or self.electricity_price != 0.0
                    or self.emb_factor != 1.0
                    or self.grid_profile is not None):
                raise ValueError(
                    "pass the deployment region either as the unified "
                    "region= value or as the loose carbon_intensity/"
                    "electricity_price/emb_factor/grid_profile fields, "
                    "not both")
            object.__setattr__(self, "region", as_region(self.region))
        elif (self.carbon_intensity != 0.475
                or self.electricity_price != 0.0
                or self.emb_factor != 1.0
                or self.grid_profile is not None):
            import warnings

            warnings.warn(
                "loose JobSpec regional fields (carbon_intensity/"
                "electricity_price/emb_factor/grid_profile) are "
                "deprecated: pass the unified region="
                "repro.core.regions.Region(...) instead (bit-identical, "
                "and it carries the 24h price curve too)",
                DeprecationWarning, stacklevel=3)
        from repro.core.comm import COMM_MODELS

        if self.comm not in COMM_MODELS:
            raise ValueError(
                f"unknown comm model {self.comm!r}; "
                f"options: {sorted(COMM_MODELS)}")
        from repro.core.schedule import SCHEDULE_MODELS

        if self.schedule not in SCHEDULE_MODELS:
            raise ValueError(
                f"unknown schedule model {self.schedule!r}; "
                f"options: {sorted(SCHEDULE_MODELS)}")

    def bucket_key(self) -> tuple:
        """(total chains, swap cadence, comm model[, schedule]): the
        static shape of the batched program this job can share. The
        schedule model joins the tuple only when non-fixed, so legacy
        bucket keys are unchanged."""
        k = self.strategy.weight_rows().shape[0]
        key = (k * self.strategy.n_chains, self.strategy.swap_every,
               self.comm)
        if self.schedule != "fixed":
            key = key + (self.schedule,)
        return key

    def resolved_region(self) -> Region:
        """The job's deployment region: the unified ``region`` value
        when given, else the loose legacy fields assembled into an
        equivalent (bit-identical) :class:`Region`."""
        if self.region is not None:
            return self.region
        return Region(carbon_intensity=float(self.carbon_intensity),
                      electricity_price=float(self.electricity_price),
                      emb_factor=float(self.emb_factor),
                      grid_profile=self.grid_profile)

    def profile_row(self) -> np.ndarray:
        """float64[24] grid-intensity row for this job's slot; a region
        without a profile synthesizes the flat row at its carbon
        intensity (in-program correction exactly +0.0, i.e. the scalar
        model)."""
        return self.resolved_region().profile_array()

    def pprofile_row(self) -> np.ndarray:
        """float64[24] electricity-price row for this job's slot (flat
        at the region's scalar price when it carries no curve)."""
        return self.resolved_region().price_array()


@dataclasses.dataclass(frozen=True)
class JobResult:
    """Terminal output of a DONE job.

    ``history`` is the per-sweep coldest-chain accepted cost (seed
    population first) — the bit-compared trajectory. ``best_cost`` /
    ``best_enc`` are the scalarized incumbent across the job's chains;
    ``frontier`` the job's own :class:`ParetoArchive`. ``sweeps`` is
    what actually ran (>= the nominal request only via adaptive-budget
    donations, < it only via early convergence)."""

    job_id: str
    history: List[float]
    best_cost: float
    best_enc: np.ndarray
    frontier: ParetoArchive
    evaluations: int
    sweeps: int
    converged_early: bool = False


@dataclasses.dataclass
class SearchJob:
    """Internal mutable per-job record (service-lock protected).

    The numpy ``carry`` mirrors one slot of the batched scan carry —
    chain populations/costs, incumbent, raw RNG key words — and is the
    unit that moves between the live batch, PAUSED parking, and
    checkpoint snapshots."""

    spec: JobSpec
    state: JobState = JobState.PENDING
    widx: int = 0
    seed: int = 0                      # fold_job_key(base, job_id)
    # static per-slot rows (built once at first admission)
    temps: Optional[np.ndarray] = None        # [nc]
    weights: Optional[np.ndarray] = None      # [nc, 6]
    pair_mask: Optional[np.ndarray] = None    # [max(nc-1, 1)]
    mins: Optional[np.ndarray] = None         # [6]
    medians: Optional[np.ndarray] = None      # [6]
    # live search state
    carry: Optional[Dict[str, np.ndarray]] = None
    sweep_done: int = 0
    target_sweeps: int = 0             # nominal, rounded up to segments
    extra_sweeps: int = 0              # adaptive-budget extensions
    history: Optional[List[float]] = None
    archive: Optional[ParetoArchive] = None
    # adaptive-budget convergence tracking (host-side, not checkpointed)
    hv_ref: Optional[np.ndarray] = None
    hv_last: float = 0.0
    stall: int = 0
    converged_early: bool = False
    # control flags, applied at the next segment boundary
    want_pause: bool = False
    want_cancel: bool = False
    # terminal-transition order stamp (drives retention-cap GC)
    finished_seq: int = -1
    slot: Optional[int] = None
    fingerprint: Optional[np.ndarray] = None
    checkpointer: Optional[object] = None
    result: Optional[JobResult] = None
    error: Optional[BaseException] = None

    @property
    def job_id(self) -> str:
        return self.spec.job_id

    @property
    def remaining(self) -> int:
        return max(0, self.target_sweeps + self.extra_sweeps
                   - self.sweep_done)
