"""Pathfinding-as-a-service: continuous batching on a warm engine.

Every search in this repo used to be a blocking call that owned the
device and recompiled per caller. :class:`PathfinderService` instead
keeps ONE warm :class:`~repro.pathfinding.device.ScenarioEngine` and
multiplexes many concurrent jobs onto it:

* **Shape-bucketed programs.** Jobs whose strategies share a
  ``(total chains, swap_every, comm)`` shape share a *bucket*: a fixed
  ``slots``-wide batched scenario axis with exactly two compiled
  programs — the seed-population eval (``"scenario_init"``) and the
  ``segment``-sweep scan (``"scenario_pt"``) — both traced once by a
  warmup pass at bucket creation (the maxtext ``offline_inference``
  idiom: pre-compile per shape bucket, then only ever replay). After
  warmup, admissions, departures and whole-service restarts replay the
  cached programs; ``device.trace_count`` stays flat.

* **Segment-quantum scheduling.** The worker advances each bucket one
  *segment* (``segment`` sweeps) per tick. Jobs join and leave the
  batch only at segment boundaries: admission writes a slot's carry
  rows in place, departure frees them, and nothing else in the batch
  notices — the scan is a pure per-slot ``vmap`` with no cross-lane
  ops, each slot carries its own RNG key words and its own sweep
  counter (the per-cell ``sweep0`` vector), so a job's trajectory is
  bit-identical solo vs packed, whatever the co-tenants do.

* **Preempt + bit-identical resume.** Each job's slot carry (chain
  populations, costs, incumbent, raw RNG key words), frontier archive
  and history snapshot through a per-job
  :class:`~repro.pathfinding.resume.SearchCheckpointer` at every
  boundary. ``pause``/``resume_job`` park and re-admit a live job;
  killing the whole service and resubmitting the same specs restores
  every job from its newest snapshot and continues the exact sweep
  stream.

* **Adaptive per-cell budgets.** With ``adaptive=True`` a job whose
  frontier hypervolume stalls for ``stall_segments`` consecutive
  boundaries is declared converged: it finishes early and donates its
  remaining sweeps to the bucket pool, from which jobs that hit their
  nominal budget still improving draw one segment at a time. Total
  sweeps consumed never exceed the total nominal budget, and because
  donation only ever changes *when a job stops* (never the stream it
  consumes), the sweeps a job does run remain bit-identical to its
  fixed-budget prefix. Convergence bookkeeping is host-side state and
  is deliberately not checkpointed: a restarted service re-measures
  stall from fresh boundaries.
"""
from __future__ import annotations

import os
import random
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.regions import Region
from repro.core.sa import random_system
from repro.core.techdb import DEFAULT_DB, HOURS_PER_DAY, TechDB
from repro.core.workload import GEMMWorkload
from repro.pathfinding.pareto import ParetoArchive, fold_job_key
from repro.serving.jobs import (
    TERMINAL,
    JobEvictedError,
    JobResult,
    JobSpec,
    JobState,
    SearchJob,
)


class _Bucket:
    """One warm shape bucket: ``slots`` lanes of an ``nc``-chain batched
    tempering scan, plus the numpy slot state the host owns between
    segments."""

    def __init__(self, service: "PathfinderService", nc: int,
                 swap_every: int, comm: str = "legacy",
                 schedule: str = "fixed"):
        self.nc, self.swap_every, self.comm = nc, swap_every, comm
        self.schedule = schedule
        self.engine = service._engine_for(comm, schedule)
        self.space = self.engine.space
        S = service.slots
        key_np = service._key_np(0)
        # deterministic filler rows: empty slots hold a valid population
        # so the fused program never sees degenerate inputs
        fv = self.space.encode_many(
            [random_system(random.Random(0), service.db,
                           self.space.max_chiplets)
             for _ in range(nc)])
        self.filler_v = fv
        self.v = np.repeat(fv[None], S, axis=0).astype(np.int32)
        self.costs = np.zeros((S, nc), np.float64)
        self.best_v = self.v[:, 0].copy()
        self.best_c = np.zeros(S, np.float64)
        self.keys = np.repeat(key_np[None], S, axis=0)
        self.sweep0 = np.zeros(S, np.int64)
        self.temps = np.ones((S, nc), np.float64)
        self.mins = np.ones((S, 6), np.float64)
        self.med = np.ones((S, 6), np.float64)
        self.w = np.full((S, nc, 6), 1.0 / 6.0, np.float64)
        self.pair = np.zeros((S, max(nc - 1, 1)), bool)
        self.ci = np.full(S, 0.475, np.float64)
        # regional axes of each lane: neutral columns (0.0 price, 1.0
        # embodied factor, flat-at-ci profile) reproduce the scalar-CI
        # program bit-for-bit; always present so the bucket programs
        # keep ONE signature regardless of which jobs use the axes
        self.price = np.zeros(S, np.float64)
        self.embf = np.ones(S, np.float64)
        self.profile = np.repeat(self.ci[:, None], HOURS_PER_DAY, axis=1)
        # electricity-price curve of each lane: flat at the lane's
        # scalar price (zeros here) is the exact neutral element — the
        # in-program price correction is +0.0
        self.pprofile = np.repeat(self.price[:, None], HOURS_PER_DAY,
                                  axis=1)
        self.widx = np.zeros(S, np.int32)
        # per-lane NoC-move gate of mesh_noc buckets: constant 1.0 (every
        # job here asked for the mesh model), so lanes stay independent
        # of co-tenants; legacy buckets never pass the column at all
        self.noc_on = np.full(S, 1.0 if comm == "mesh_noc" else 0.0,
                              np.float64)
        # same story for the schedule-move gate of window buckets
        self.sched_on = np.full(S, 1.0 if schedule == "window" else 0.0,
                                np.float64)
        self.slot_jobs: List[Optional[SearchJob]] = [None] * S

    def free_slot(self) -> Optional[int]:
        for s, j in enumerate(self.slot_jobs):
            if j is None:
                return s
        return None

    def active_slots(self) -> List[int]:
        return [s for s, j in enumerate(self.slot_jobs) if j is not None]

    def clear_slot(self, s: int) -> None:
        """Back to inert filler (lanes are independent either way; this
        just keeps dormant state deterministic)."""
        self.slot_jobs[s] = None
        self.v[s] = self.filler_v
        self.costs[s] = 0.0
        self.best_v[s] = self.filler_v[0]
        self.best_c[s] = 0.0
        self.keys[s] = self.keys[s] * 0
        self.sweep0[s] = 0
        self.temps[s] = 1.0
        self.mins[s] = 1.0
        self.med[s] = 1.0
        self.w[s] = 1.0 / 6.0
        self.pair[s] = False
        self.ci[s] = 0.475
        self.price[s] = 0.0
        self.embf[s] = 1.0
        self.profile[s] = 0.475
        self.pprofile[s] = 0.0
        self.widx[s] = 0


class PathfinderService:
    """Async facade over the warm engine: ``submit`` / ``status`` /
    ``result`` / ``cancel`` / ``pause`` / ``resume_job`` / ``drain``.

    The service is built over a fixed workload catalog (the stacked
    engine bakes its tile tables per workload set); jobs reference a
    catalog entry by name. ``slots`` lanes per bucket and ``segment``
    sweeps per scheduling quantum are service-wide constants — part of
    every job's determinism envelope, so keep them stable across
    restarts of a checkpointed service.

    ``start()`` spawns the background worker thread; without it the
    service runs inline inside :meth:`drain` (deterministic
    single-thread mode, what the tests use). With ``checkpoint_root``
    every job snapshots at each boundary under
    ``<checkpoint_root>/<job_id>``.

    Terminal-job GC: a long-lived service would otherwise accumulate
    every finished job's record (history, frontier archive, parked
    carry) forever. The newest ``retain_jobs`` terminal jobs are kept
    for result pickup; older ones are evicted in the order they
    finished, and any later access to an evicted id raises
    :class:`~repro.serving.jobs.JobEvictedError` (still a ``KeyError``)
    naming the cap. Resubmitting an evicted id starts a fresh job —
    with a checkpoint root, bit-identically resuming from its newest
    snapshot (checkpoints live on disk and are not GC'd)."""

    def __init__(self, workloads: Sequence[GEMMWorkload],
                 db: TechDB = DEFAULT_DB, slots: int = 4,
                 segment: int = 2, norm_samples: int = 120,
                 norm_seed: int = 1234, adaptive: bool = False,
                 stall_segments: int = 2, stall_tol: float = 0.0,
                 checkpoint_root: Optional[str] = None,
                 key: Optional[int] = None, space=None,
                 retain_jobs: int = 256):
        from repro.pathfinding.device import get_scenario_engine
        from repro.pathfinding.strategies import _resolve_key

        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if segment < 1:
            raise ValueError(f"segment must be >= 1, got {segment}")
        if retain_jobs < 1:
            raise ValueError(
                f"retain_jobs must be >= 1, got {retain_jobs}")
        self.workloads = tuple(workloads)
        if not self.workloads:
            raise ValueError("PathfinderService needs >= 1 workload")
        self.db = db
        self.slots, self.segment = int(slots), int(segment)
        self.norm_samples, self.norm_seed = norm_samples, norm_seed
        self.adaptive = bool(adaptive)
        self.stall_segments = int(stall_segments)
        self.stall_tol = float(stall_tol)
        self.checkpoint_root = checkpoint_root
        self.base_key = _resolve_key(key)
        self.engine = get_scenario_engine(self.workloads, db, space=space)
        self.space = self.engine.space
        #: per-(comm, schedule) warm engines; buckets resolve theirs
        #: lazily so a service only pays for the models its jobs use
        self._engines = {(self.space.comm, self.space.schedule):
                         self.engine}
        self._widx = {wl.name: i for i, wl in enumerate(self.workloads)}
        self._norms: Dict[Tuple[int, float], object] = {}
        self._buckets: Dict[tuple, _Bucket] = {}
        self._pool: Dict[tuple, int] = {}      # donated sweeps per bucket
        self.retain_jobs = int(retain_jobs)
        self._jobs: Dict[str, SearchJob] = {}
        self._evicted: set = set()             # ids GC'd past the cap
        self._finished_seq = 0                 # terminal-order stamp
        self._queue: List[str] = []            # FIFO admission order
        self._cond = threading.Condition()
        self._thread: Optional[threading.Thread] = None
        self._running = False

    # -- context manager ----------------------------------------------------

    def __enter__(self) -> "PathfinderService":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client API ---------------------------------------------------------

    def submit(self, spec: JobSpec) -> str:
        """Queue a job; returns its ``job_id``. FIFO per bucket: jobs
        contending for the same shape are admitted in submission
        order."""
        if spec.workload not in self._widx:
            raise ValueError(
                f"unknown workload {spec.workload!r}: this service was "
                f"built over {sorted(self._widx)}")
        if spec.strategy.frontier_size < 1:
            raise ValueError("serving requires frontier_size >= 1 (the "
                             "frontier archive is the job's output)")
        with self._cond:
            old = self._jobs.get(spec.job_id)
            if old is not None and old.state not in TERMINAL:
                raise ValueError(f"job {spec.job_id!r} is already "
                                 f"{old.state.value}")
            job = SearchJob(spec=spec, widx=self._widx[spec.workload])
            self._evicted.discard(spec.job_id)
            self._jobs[spec.job_id] = job
            self._queue.append(spec.job_id)
            self._cond.notify_all()
        return spec.job_id

    def status(self, job_id: str) -> JobState:
        with self._cond:
            return self._job(job_id).state

    def result(self, job_id: str,
               timeout: Optional[float] = None) -> JobResult:
        """Block until the job is terminal; DONE returns its
        :class:`JobResult`, CANCELLED/FAILED raise."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            job = self._job(job_id)
            while job.state not in TERMINAL:
                if self._thread is None:
                    raise RuntimeError(
                        f"job {job_id!r} is {job.state.value} and no "
                        "worker is running — call start() or drain()")
                wait = None if deadline is None \
                    else deadline - time.monotonic()
                if wait is not None and wait <= 0:
                    raise TimeoutError(f"job {job_id!r} still "
                                       f"{job.state.value}")
                self._cond.wait(timeout=wait)
            return self._terminal_result(job)

    def cancel(self, job_id: str) -> None:
        """PENDING jobs leave the queue immediately; RUNNING jobs free
        their slot at the next segment boundary."""
        with self._cond:
            job = self._job(job_id)
            if job.state in TERMINAL:
                return
            if job.state in (JobState.PENDING, JobState.PAUSED):
                if job.job_id in self._queue:
                    self._queue.remove(job.job_id)
                job.state = JobState.CANCELLED
                self._note_terminal(job)
            else:
                job.want_cancel = True
            self._cond.notify_all()

    def pause(self, job_id: str) -> None:
        """Preempt at the next boundary: the slot is freed, the carry
        parked (and checkpointed when enabled) for a bit-identical
        continuation via :meth:`resume_job`."""
        with self._cond:
            job = self._job(job_id)
            if job.state == JobState.RUNNING:
                job.want_pause = True
            elif job.state == JobState.PENDING:
                self._queue.remove(job.job_id)
                job.state = JobState.PAUSED
            self._cond.notify_all()

    def resume_job(self, job_id: str) -> None:
        with self._cond:
            job = self._job(job_id)
            if job.state != JobState.PAUSED:
                raise ValueError(f"job {job_id!r} is {job.state.value}, "
                                 "not paused")
            job.state = JobState.PENDING
            job.want_pause = False
            self._queue.append(job.job_id)
            self._cond.notify_all()

    def step(self) -> bool:
        """One inline scheduling quantum: admit whatever fits, then
        advance every bucket with live jobs by one segment. Returns
        whether anything happened — the deterministic single-step
        surface (tests, CLI hooks); :meth:`drain` is a step loop."""
        with self._cond:
            return self._tick()

    def drain(self, timeout: Optional[float] = None) -> None:
        """Run until no job is PENDING or RUNNING (PAUSED jobs are
        parked by user intent and don't block a drain). Inline when no
        worker thread is running."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._cond:
                if not self._work_left():
                    return
                if self._thread is not None:
                    wait = None if deadline is None \
                        else deadline - time.monotonic()
                    if wait is not None and wait <= 0:
                        raise TimeoutError("drain timed out")
                    self._cond.wait(timeout=wait)
                    continue
                progressed = self._tick()
                if not progressed and self._work_left():
                    raise RuntimeError(
                        "service is stuck: jobs pending but no slot "
                        "frees (all lanes held by non-terminal jobs?)")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("drain timed out")

    # -- worker thread ------------------------------------------------------

    def start(self) -> "PathfinderService":
        with self._cond:
            if self._thread is not None:
                return self
            self._running = True
            self._thread = threading.Thread(
                target=self._worker, name="pathfinder-service",
                daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        with self._cond:
            self._running = False
            thread, self._thread = self._thread, None
            self._cond.notify_all()
        if thread is not None:
            thread.join(timeout=60)

    def _worker(self) -> None:
        while True:
            with self._cond:
                if not self._running:
                    return
                try:
                    progressed = self._tick()
                except BaseException:
                    # a failed tick must not silently wedge clients
                    for job in list(self._jobs.values()):
                        if job.state in (JobState.RUNNING,
                                         JobState.PENDING):
                            job.state = JobState.FAILED
                            self._note_terminal(job)
                    self._cond.notify_all()
                    raise
                if not progressed:
                    self._cond.wait(timeout=0.05)

    # -- scheduling core (caller holds self._cond) --------------------------

    def _work_left(self) -> bool:
        return any(j.state in (JobState.PENDING, JobState.RUNNING)
                   for j in self._jobs.values())

    def _tick(self) -> bool:
        """One scheduling quantum: admit what fits, then advance every
        bucket with live jobs by one segment. Returns whether anything
        happened."""
        progressed = self._admit_pending()
        for bkey in list(self._buckets):
            if self._buckets[bkey].active_slots():
                self._run_bucket_segment(bkey)
                progressed = True
        return progressed

    def _admit_pending(self) -> bool:
        admitted = False
        blocked: set = set()
        for job_id in list(self._queue):
            job = self._jobs[job_id]
            bkey = job.spec.bucket_key()
            if bkey in blocked:
                continue              # FIFO within a bucket shape
            bucket = self._bucket(bkey)
            slot = bucket.free_slot()
            if slot is None:
                blocked.add(bkey)
                continue
            self._queue.remove(job_id)
            try:
                self._admit(job, bucket, slot)
            except BaseException as e:  # noqa: BLE001 - surfaced via job
                job.state = JobState.FAILED
                job.error = e
                bucket.clear_slot(slot)
                self._note_terminal(job)
            admitted = True
            self._cond.notify_all()
        return admitted

    def _run_bucket_segment(self, bkey: tuple) -> None:
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.pathfinding.device import _key_from_np, _key_to_np

        b = self._buckets[bkey]
        seg = self.segment
        with enable_x64():
            fn = b.engine.segment_runner(
                self.slots, b.nc, seg, b.swap_every, collect_samples=True)
            args = (
                jnp.asarray(b.v), jnp.asarray(b.costs),
                jnp.asarray(b.best_v), jnp.asarray(b.best_c),
                _key_from_np(b.keys, jax.random.PRNGKey(0)),
                jnp.asarray(b.sweep0), jnp.asarray(b.temps),
                jnp.asarray(b.mins), jnp.asarray(b.med),
                jnp.asarray(b.w), jnp.asarray(b.pair),
                jnp.asarray(b.ci), jnp.asarray(b.price),
                jnp.asarray(b.embf), jnp.asarray(b.profile),
                jnp.asarray(b.pprofile), jnp.asarray(b.widx))
            if b.comm == "mesh_noc":
                args = args + (jnp.asarray(b.noc_on),)
            if b.schedule == "window":
                args = args + (jnp.asarray(b.sched_on),)
            carry, ys = fn(*args)
            # np.array (not asarray): device outputs view as read-only
            # numpy and the slot state is written in place at boundaries
            b.v = np.array(carry[0])
            b.costs = np.array(carry[1])
            b.best_v = np.array(carry[2])
            b.best_c = np.array(carry[3])
            b.keys = np.array(_key_to_np(carry[4]))
        hist = np.asarray(ys[0])          # [seg, S]
        enc = np.asarray(ys[2])           # [seg, S, nc, width]
        vec = np.asarray(ys[3])           # [seg, S, nc, 3]
        b.sweep0 = b.sweep0 + seg
        for s in b.active_slots():
            job = b.slot_jobs[s]
            job.sweep_done += seg
            job.history.extend(hist[:, s].tolist())
            job.archive.insert(enc[:, s].reshape(-1, enc.shape[-1]),
                               vec[:, s].reshape(-1, vec.shape[-1]))
            self._boundary(job, b, s)
        self._cond.notify_all()

    def _boundary(self, job: SearchJob, b: _Bucket, s: int) -> None:
        """Everything that may only happen between segments: snapshot,
        cancellation/preemption, convergence + donation, completion."""
        self._park_carry(job, b, s)
        if job.checkpointer is not None:
            job.checkpointer.save(
                job.sweep_done, job.carry, job.archive,
                np.asarray(job.history, np.float64), job.fingerprint)
        if job.want_cancel:
            job.state = JobState.CANCELLED
            b.clear_slot(s)
            self._note_terminal(job)
            return
        if job.want_pause:
            job.want_pause = False
            job.state = JobState.PAUSED
            b.clear_slot(s)
            return
        bkey = job.spec.bucket_key()
        if self.adaptive:
            self._update_convergence(job)
            if job.converged_early and job.remaining > 0:
                self._pool[bkey] = (self._pool.get(bkey, 0)
                                    + job.remaining)
                self._finalize(job, b, s)
                return
        if job.remaining <= 0:
            if (self.adaptive and not job.converged_early
                    and self._pool.get(bkey, 0) >= self.segment):
                # still improving at its nominal budget: draw a donated
                # segment and keep going
                self._pool[bkey] -= self.segment
                job.extra_sweeps += self.segment
                return
            self._finalize(job, b, s)

    def _update_convergence(self, job: SearchJob) -> None:
        """Frontier-hypervolume stall detector. The reference point is
        frozen at the job's first boundary so successive hypervolumes
        are comparable; ``stall_tol`` is the relative improvement below
        which a boundary counts as stalled."""
        from repro.pathfinding.pareto import hypervolume

        spec = job.spec
        tol = self.stall_tol if spec.stall_tol is None else spec.stall_tol
        k = self.stall_segments if spec.stall_segments is None \
            else spec.stall_segments
        if job.hv_ref is None:
            job.hv_ref = job.archive.reference_point(margin=0.1)
            job.hv_last = hypervolume(job.archive.vectors, job.hv_ref)
            return
        hv = hypervolume(job.archive.vectors, job.hv_ref)
        gain = hv - job.hv_last
        if gain <= tol * max(abs(job.hv_last), 1e-12):
            job.stall += 1
        else:
            job.stall = 0
        job.hv_last = hv
        if job.stall >= k:
            job.converged_early = True

    def _finalize(self, job: SearchJob, b: _Bucket, s: int) -> None:
        nc = b.nc
        job.result = JobResult(
            job_id=job.job_id,
            history=list(job.history),
            best_cost=float(job.carry["best_c"]),
            best_enc=np.asarray(job.carry["best_v"]).copy(),
            frontier=job.archive,
            evaluations=nc * (1 + job.sweep_done),
            sweeps=job.sweep_done,
            converged_early=job.converged_early)
        job.state = JobState.DONE
        b.clear_slot(s)
        self._note_terminal(job)

    # -- admission ----------------------------------------------------------

    def _admit(self, job: SearchJob, b: _Bucket, slot: int) -> None:
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.pathfinding.device import _key_to_np
        from repro.pathfinding.resume import (
            check_not_shrunk,
            segment_fingerprint,
        )
        from repro.pathfinding.strategies import budget_sweeps

        spec, strat = job.spec, job.spec.strategy
        nc, seg = b.nc, self.segment
        if job.temps is None:
            from repro.pathfinding.strategies import _resolve_key

            w6 = strat.weight_rows()
            k = w6.shape[0]
            job.seed = fold_job_key(
                _resolve_key(spec.key) if spec.key is not None
                else self.base_key, spec.job_id)
            job.temps = strat.chain_temps(k)
            job.weights = strat.chain_weights(w6)
            job.pair_mask = strat.chain_pair_mask(nc)
            job.mins, job.medians = self._norm_rows(
                job.widx, self._region_of(spec), b.space)
            sweeps = budget_sweeps(
                strat.sweeps, nc, spec.budget,
                detail=f" for job {spec.job_id!r}")
            # jobs advance in whole segment quanta: round UP so the
            # nominal budget is never silently under-run
            job.target_sweeps = -(-sweeps // seg) * seg if sweeps else 0
        v0 = b.space.encode_many(
            [random_system(random.Random(job.seed), self.db,
                           b.space.max_chiplets)
             for _ in range(nc)]).astype(np.int32)
        if self.checkpoint_root is not None and job.fingerprint is None:
            from repro.pathfinding.strategies import _checkpointer

            region = self._region_of(spec)
            fp_extra = {}
            if b.comm != "legacy":
                # comm model enters the envelope (legacy fingerprints
                # stay byte-identical to pre-NoC checkpoints)
                fp_extra["comm"] = np.frombuffer(
                    b.comm.encode(), np.uint8)
            if b.schedule != "fixed":
                # same convention for the schedule model: only a
                # non-neutral schedule enters the envelope, so every
                # pre-scheduling checkpoint stays byte-identical
                fp_extra["schedule"] = np.frombuffer(
                    b.schedule.encode(), np.uint8)
            if region.price_profile is not None:
                fp_extra["pprofile"] = spec.pprofile_row()
            job.fingerprint = segment_fingerprint(
                "serve_job", v0=v0, temps=job.temps,
                swap_every=b.swap_every, seed=job.seed, mins=job.mins,
                medians=job.medians, weights=job.weights,
                pair_mask=job.pair_mask, ci=np.float64(
                    region.carbon_intensity),
                segment=seg, collect=True,
                workload=np.frombuffer(spec.workload.encode(), np.uint8),
                job=np.frombuffer(spec.job_id.encode(), np.uint8),
                price=np.float64(region.electricity_price),
                embf=np.float64(region.emb_factor),
                profile=spec.profile_row(), **fp_extra)
            job.checkpointer = _checkpointer(
                os.path.join(self.checkpoint_root, spec.job_id))
        # slot statics (identical for fresh admission and re-admission)
        b.temps[slot] = job.temps
        b.mins[slot] = job.mins
        b.med[slot] = job.medians
        b.w[slot] = job.weights
        b.pair[slot] = job.pair_mask
        slot_region = self._region_of(spec)
        b.ci[slot] = float(slot_region.carbon_intensity)
        b.price[slot] = float(slot_region.electricity_price)
        b.embf[slot] = float(slot_region.emb_factor)
        b.profile[slot] = spec.profile_row()
        b.pprofile[slot] = spec.pprofile_row()
        b.widx[slot] = job.widx

        if job.carry is None and job.checkpointer is not None:
            key_like = self._key_np(0)
            restored = job.checkpointer.restore(
                dict(v=np.zeros((nc, b.space.width), np.int32),
                     costs=np.zeros(nc, np.float64),
                     best_v=np.zeros(b.space.width, np.int32),
                     best_c=np.zeros((), np.float64),
                     key=np.zeros_like(key_like)),
                job.archive or self._fresh_archive(job), job.fingerprint)
            if restored is not None:
                job.carry = dict(restored.carry)
                job.sweep_done = int(restored.sweep_done)
                job.history = restored.history.tolist()
                # adaptive extensions aren't re-donated across restarts
                job.target_sweeps = max(job.target_sweeps,
                                        job.sweep_done)
                check_not_shrunk(job.sweep_done,
                                 job.target_sweeps + job.extra_sweeps)
        if job.carry is None:
            # fresh job: seed-evaluate its slot through the warmed
            # stacked init program (keys0 is slot-position-dependent and
            # deliberately discarded — the job's stream comes from its
            # job id, so packing cannot change it)
            job.archive = job.archive or self._fresh_archive(job)
            b.v[slot] = v0
            with enable_x64():
                _, cost0, vec0 = b.engine._init_fn(self.slots, nc)(
                    jnp.asarray(b.v), jnp.asarray(b.mins),
                    jnp.asarray(b.med), jnp.asarray(b.w),
                    jnp.asarray(b.ci), jnp.asarray(b.price),
                    jnp.asarray(b.embf), jnp.asarray(b.profile),
                    jnp.asarray(b.pprofile), jnp.asarray(b.widx),
                    jax.random.PRNGKey(0))
                cost_row = np.asarray(cost0)[slot]
                vec_row = np.asarray(vec0)[slot]
                key_row = np.asarray(
                    _key_to_np(jax.random.PRNGKey(job.seed)))
            bi = int(np.argmin(cost_row))
            job.carry = dict(v=v0, costs=cost_row,
                             best_v=v0[bi].copy(),
                             best_c=np.float64(cost_row[bi]),
                             key=key_row)
            job.history = [float(cost_row.min())]
            job.archive.insert(v0, vec_row)
            if job.checkpointer is not None:
                job.checkpointer.save(
                    0, job.carry, job.archive,
                    np.asarray(job.history, np.float64), job.fingerprint)
        if job.archive is None:
            job.archive = self._fresh_archive(job)
        if job.remaining <= 0:
            # zero-sweep budget or restored-already-complete
            job.slot = None
            self._finalize(job, b, slot)
            return
        job.slot = slot
        b.slot_jobs[slot] = job
        b.v[slot] = job.carry["v"]
        b.costs[slot] = job.carry["costs"]
        b.best_v[slot] = job.carry["best_v"]
        b.best_c[slot] = job.carry["best_c"]
        b.keys[slot] = job.carry["key"]
        b.sweep0[slot] = job.sweep_done
        job.state = JobState.RUNNING

    def _park_carry(self, job: SearchJob, b: _Bucket, s: int) -> None:
        job.carry = dict(v=b.v[s].copy(), costs=b.costs[s].copy(),
                         best_v=b.best_v[s].copy(),
                         best_c=np.float64(b.best_c[s]),
                         key=b.keys[s].copy())

    def _fresh_archive(self, job: SearchJob) -> ParetoArchive:
        job.archive = ParetoArchive(
            max_size=job.spec.strategy.frontier_size)
        return job.archive

    # -- shared warm resources ----------------------------------------------

    def _engine_for(self, comm: str, schedule: str = "fixed"):
        """Warm :class:`ScenarioEngine` for a bucket's (comm, schedule)
        models. The default-space engine built in ``__init__`` serves
        its own pair; any other combination gets a lazily-built engine
        over a same-shape :class:`DesignSpace` (shared process-wide by
        :func:`get_scenario_engine`'s cache)."""
        eng = self._engines.get((comm, schedule))
        if eng is None:
            from repro.pathfinding.device import get_scenario_engine
            from repro.pathfinding.space import DesignSpace

            sp = DesignSpace(self.db,
                             max_chiplets=self.space.max_chiplets,
                             comm=comm, schedule=schedule)
            eng = get_scenario_engine(self.workloads, self.db, space=sp)
            self._engines[(comm, schedule)] = eng
        return eng

    def _bucket(self, bkey: tuple) -> _Bucket:
        b = self._buckets.get(bkey)
        if b is None:
            b = _Bucket(self, *bkey)
            self._warmup(b)
            self._buckets[bkey] = b
        return b

    def _warmup(self, b: _Bucket) -> None:
        """Trace both programs of the bucket shape once, on filler data
        (outputs discarded, slot state untouched). Everything after
        this replays from the jit cache."""
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        with enable_x64():
            keys0, cost0, _ = b.engine._init_fn(self.slots, b.nc)(
                jnp.asarray(b.v), jnp.asarray(b.mins),
                jnp.asarray(b.med), jnp.asarray(b.w), jnp.asarray(b.ci),
                jnp.asarray(b.price), jnp.asarray(b.embf),
                jnp.asarray(b.profile), jnp.asarray(b.pprofile),
                jnp.asarray(b.widx), jax.random.PRNGKey(0))
            fn = b.engine.segment_runner(
                self.slots, b.nc, self.segment, b.swap_every,
                collect_samples=True)
            args = (
                jnp.asarray(b.v), cost0, jnp.asarray(b.best_v),
                jnp.asarray(cost0[:, 0]), keys0,
                jnp.asarray(b.sweep0), jnp.asarray(b.temps),
                jnp.asarray(b.mins), jnp.asarray(b.med),
                jnp.asarray(b.w), jnp.asarray(b.pair),
                jnp.asarray(b.ci), jnp.asarray(b.price),
                jnp.asarray(b.embf), jnp.asarray(b.profile),
                jnp.asarray(b.pprofile), jnp.asarray(b.widx))
            if b.comm == "mesh_noc":
                args = args + (jnp.asarray(b.noc_on),)
            if b.schedule == "window":
                args = args + (jnp.asarray(b.sched_on),)
            carry, _ = fn(*args)
            np.asarray(carry[0])      # block until compiled + run

    @staticmethod
    def _region_of(spec: JobSpec) -> Region:
        """The job's full deployment region (all axes): the unified
        ``region`` value when given, else the loose legacy fields."""
        return spec.resolved_region()

    def _norm_rows(self, widx: int, region: Region,
                   space=None) -> Tuple[np.ndarray, np.ndarray]:
        # Region is frozen/hashable, so the cache key distinguishes jobs
        # that share a scalar CI but differ in price/embodied/profile —
        # a profile axis can never alias another job's normalizer rows.
        # The comm and schedule models join the key: mesh-space
        # normalizers see the NoC cost terms, window-space ones the
        # duty-cycled operational terms; neither may alias legacy rows.
        space = self.space if space is None else space
        nz = self._norms.get((widx, region, space.comm, space.schedule))
        if nz is None:
            from repro.pathfinding.batch import fit_region_normalizers

            nz = fit_region_normalizers(
                self.workloads[widx], [region], self.db,
                samples=self.norm_samples, seed=self.norm_seed,
                space=space)[0]
            self._norms[(widx, region, space.comm, space.schedule)] = nz
        mins, medians = nz.weights_arrays()
        return (np.asarray(mins, np.float64),
                np.asarray(medians, np.float64))

    def _key_np(self, seed: int) -> np.ndarray:
        import jax
        from jax.experimental import enable_x64

        from repro.pathfinding.device import _key_to_np

        with enable_x64():
            return np.asarray(_key_to_np(jax.random.PRNGKey(seed)))

    # -- internals ----------------------------------------------------------

    def _job(self, job_id: str) -> SearchJob:
        job = self._jobs.get(job_id)
        if job is None:
            if job_id in self._evicted:
                raise JobEvictedError(
                    f"job {job_id!r} finished and was evicted by "
                    f"terminal-job GC (retain_jobs="
                    f"{self.retain_jobs}); fetch results before more "
                    "than retain_jobs jobs finish, raise the cap, or "
                    "resubmit (a checkpoint root resumes it from its "
                    "newest on-disk snapshot)")
            raise KeyError(f"unknown job {job_id!r}")
        return job

    def _note_terminal(self, job: SearchJob) -> None:
        """Stamp the terminal transition order and evict the oldest
        terminal records past ``retain_jobs`` (caller holds
        ``self._cond``). Only terminal jobs are ever evicted; live ones
        are untouched no matter how many finish around them."""
        job.finished_seq = self._finished_seq
        self._finished_seq += 1
        term = [j for j in self._jobs.values() if j.state in TERMINAL]
        excess = len(term) - self.retain_jobs
        if excess <= 0:
            return
        term.sort(key=lambda j: j.finished_seq)
        for j in term[:excess]:
            del self._jobs[j.job_id]
            self._evicted.add(j.job_id)

    @staticmethod
    def _terminal_result(job: SearchJob) -> JobResult:
        if job.state == JobState.DONE:
            return job.result
        if job.state == JobState.FAILED and job.error is not None:
            raise RuntimeError(
                f"job {job.job_id!r} failed") from job.error
        raise RuntimeError(f"job {job.job_id!r} is {job.state.value}")

    def donated_pool(self, bucket_key: tuple) -> int:
        """Donated-but-undrawn sweeps for a bucket (observability)."""
        with self._cond:
            return self._pool.get(bucket_key, 0)
