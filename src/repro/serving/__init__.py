"""Pathfinding as a service: a multi-tenant runtime over the warm
device engines.

:class:`PathfinderService` keeps one warm
:class:`~repro.pathfinding.device.ScenarioEngine` and multiplexes many
concurrent :class:`JobSpec` searches onto shape-bucketed pre-compiled
programs, advancing everybody one segment at a time — see
:mod:`repro.serving.service` for the scheduling/determinism contract
and the README's "Pathfinding as a service" section for the tour.
"""
from repro.serving.jobs import (
    JobEvictedError,
    JobResult,
    JobSpec,
    JobState,
    SearchJob,
)
from repro.serving.service import PathfinderService

__all__ = [
    "JobEvictedError",
    "JobResult",
    "JobSpec",
    "JobState",
    "PathfinderService",
    "SearchJob",
]
