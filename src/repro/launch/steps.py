"""Step builders: train_step / prefill_step / serve_step with shardings.

These are the functions the dry-run lowers and the drivers execute. All
of them are pure jit-able functions of explicitly sharded pytrees; the
builders return (fn, in_shardings, out_shardings, input_specs) so the
launcher and the dry-run share one source of truth.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeCell
from repro.data.pipeline import make_batch_specs
from repro.distributed import sharding as shd
from repro.models.common import DTypePolicy
from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_model,
    loss_fn,
    prefill,
)
from repro.optim import adamw

BF16 = DTypePolicy(jnp.bfloat16, jnp.bfloat16)


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def model_shape_specs(cfg: ModelConfig, policy: DTypePolicy = BF16):
    """ShapeDtypeStruct tree of the params (no allocation)."""
    return jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg, policy))


def opt_shape_specs(params_sds, opt_cfg: adamw.AdamWConfig):
    return jax.eval_shape(lambda: adamw.init(params_sds, opt_cfg))


# ---------------------------------------------------------------------------
# train_step
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, mesh: Mesh,
                     opt_cfg: Optional[adamw.AdamWConfig] = None,
                     policy: DTypePolicy = BF16,
                     remat: bool = True):
    """Returns (train_step, (in_shardings, out_shardings), input_specs_fn).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics).
    """
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    params_sds = model_shape_specs(cfg, policy)
    opt_sds = opt_shape_specs(params_sds, opt_cfg)
    pspecs = shd.param_specs(params_sds, mesh)
    ospecs = shd.opt_state_specs(opt_sds, pspecs)
    pshardings = _named(mesh, pspecs)

    def train_step(params, opt_state, batch):
        with shd.activation_policy(mesh):
            loss, grads = jax.value_and_grad(loss_fn)(
                params, cfg, batch, remat=remat)
        # pin gradients to the param layout before optimizer math — the
        # embed/lm_head scatter grads otherwise reach AdamW replicated
        grads = jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, grads, pshardings)
        params, opt_state, metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    def input_specs(shape: ShapeCell):
        batch_sds = make_batch_specs(cfg, shape)
        bspecs = shd.batch_specs(batch_sds, mesh)
        in_sh = (_named(mesh, pspecs), _named(mesh, ospecs),
                 _named(mesh, bspecs))
        out_sh = (_named(mesh, pspecs), _named(mesh, ospecs), None)
        return (params_sds, opt_sds, batch_sds), in_sh, out_sh

    return train_step, input_specs


# ---------------------------------------------------------------------------
# eval / encoder forward step (audio prefill cells)
# ---------------------------------------------------------------------------


def build_eval_step(cfg: ModelConfig, mesh: Mesh,
                    policy: DTypePolicy = BF16):
    def eval_step(params, batch):
        with shd.activation_policy(mesh):
            logits, _ = forward(params, cfg, batch.get("tokens"),
                                batch.get("embeds"))
        return logits

    params_sds = model_shape_specs(cfg, policy)
    pspecs = shd.param_specs(params_sds, mesh)

    def input_specs(shape: ShapeCell):
        batch_sds = make_batch_specs(cfg, shape)
        if "labels" in batch_sds:
            batch_sds = {k: v for k, v in batch_sds.items() if k != "labels"}
        bspecs = shd.batch_specs(batch_sds, mesh)
        in_sh = (_named(mesh, pspecs), _named(mesh, bspecs))
        # logits (B, S, V): batch over DP, vocab over model
        out_sh = None
        return (params_sds, batch_sds), in_sh, out_sh

    return eval_step, input_specs


# ---------------------------------------------------------------------------
# prefill_step
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, mesh: Mesh,
                       policy: DTypePolicy = BF16):
    """prefill_step(params, batch) -> (last logits, cache, lengths)."""

    def prefill_step(params, batch, cache_len: int):
        with shd.activation_policy(mesh):
            if cfg.family == "vlm":
                # stub frontend embeds are prepended inside forward; for the
                # cache we prefill on the token stream only (backbone cells)
                tokens = batch["tokens"]
            else:
                tokens = batch["tokens"]
            return prefill(params, cfg, tokens, cache_len, policy)

    params_sds = model_shape_specs(cfg, policy)
    pspecs = shd.param_specs(params_sds, mesh)

    def input_specs(shape: ShapeCell):
        batch_sds = make_batch_specs(cfg, shape)
        batch_sds = {k: v for k, v in batch_sds.items() if k != "labels"}
        bspecs = shd.batch_specs(batch_sds, mesh)
        cache_sds = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                               policy))
        cspecs = shd.cache_specs(cache_sds, mesh)
        in_sh = (_named(mesh, pspecs), _named(mesh, bspecs))
        out_sh = (None, _named(mesh, cspecs), None)
        return (params_sds, batch_sds), in_sh, out_sh

    return prefill_step, input_specs


# ---------------------------------------------------------------------------
# serve_step (decode: one new token against the cache)
# ---------------------------------------------------------------------------


def build_serve_step(cfg: ModelConfig, mesh: Mesh,
                     policy: DTypePolicy = BF16):
    """serve_step(params, cache, token, length) ->
    (next_token, logits, cache, length+1)."""

    def serve_step(params, cache, token, length):
        with shd.activation_policy(mesh, shard_residual_seq=False):
            logits, cache = decode_step(params, cfg, token, cache, length)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, cache, length + 1

    params_sds = model_shape_specs(cfg, policy)
    pspecs = shd.param_specs_serving(params_sds, mesh)

    def input_specs(shape: ShapeCell):
        b = shape.global_batch
        cache_sds = jax.eval_shape(
            lambda: init_cache(cfg, b, shape.seq_len, policy))
        cspecs = shd.cache_specs(cache_sds, mesh)
        token_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
        len_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
        # serving layout: tokens/activations REPLICATED over the dp axes.
        # Sharding the (tiny) decode batch over 'data' conflicts with the
        # weights' FSDP dim and makes XLA all-gather every weight per
        # step; replicated activations turn those into small activation
        # all-reduces instead (weights stay put). Caches stay sharded.
        tspec = P()
        in_sh = (_named(mesh, pspecs), _named(mesh, cspecs),
                 NamedSharding(mesh, tspec), NamedSharding(mesh, tspec))
        out_sh = (NamedSharding(mesh, tspec), None,
                  _named(mesh, cspecs), NamedSharding(mesh, tspec))
        return (params_sds, cache_sds, token_sds, len_sds), in_sh, out_sh

    return serve_step, input_specs


# ---------------------------------------------------------------------------
# Cell dispatch: which step does a (cfg, shape) cell lower?
# ---------------------------------------------------------------------------


def build_cell(cfg: ModelConfig, shape: ShapeCell, mesh: Mesh,
               policy: DTypePolicy = BF16):
    """Returns (fn, args_sds, in_shardings, out_shardings, static_kwargs)."""
    if shape.kind == "train":
        fn, ispec = build_train_step(cfg, mesh, policy=policy)
        args, in_sh, out_sh = ispec(shape)
        return fn, args, in_sh, out_sh, {}
    if shape.kind == "prefill":
        if cfg.encoder_only:
            fn, ispec = build_eval_step(cfg, mesh, policy=policy)
            args, in_sh, out_sh = ispec(shape)
            return fn, args, in_sh, out_sh, {}
        fn, ispec = build_prefill_step(cfg, mesh, policy=policy)
        args, in_sh, out_sh = ispec(shape)
        return fn, args, in_sh, out_sh, {"cache_len": shape.seq_len}
    if shape.kind == "decode":
        fn, ispec = build_serve_step(cfg, mesh, policy=policy)
        args, in_sh, out_sh = ispec(shape)
        return fn, args, in_sh, out_sh, {}
    raise ValueError(shape.kind)
