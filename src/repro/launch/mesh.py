"""Mesh factories for the production topology.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is the DCN dimension; batch (pure DP) shards over it so the
only cross-pod collective in steady state is the gradient all-reduce.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Tuple

import jax


def _mesh_kwargs(n: int) -> dict:
    """``axis_types`` only exists on newer jax; omit it where unavailable
    (older versions treat every axis as Auto anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many real devices exist (tests/examples)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    return jax.make_mesh((n // model, model), ("data", "model"),
                         **_mesh_kwargs(2))


def data_axes(mesh) -> Tuple[str, ...]:
    """The batch (pure-DP) axes: ('pod', 'data') on multi-pod meshes."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, *names: str) -> int:
    total = 1
    for n in names:
        if n in mesh.axis_names:
            total *= mesh.shape[n]
    return total
