"""Serving driver: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b \
        --reduced --batch 4 --prompt-len 64 --gen 32

Builds the mesh, prefills a batch of prompts, then runs the decode loop
through ``serve_step`` (one new token per sequence per step against the
sharded cache), reporting per-step latency.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.shapes import ShapeCell
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_serve_step
from repro.models.common import DTypePolicy
from repro.models.transformer import init_model, prefill
from repro.distributed import sharding as shd


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--model-par", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = cfg.reduced()
    if cfg.encoder_only:
        raise SystemExit("encoder-only architectures have no decode step")
    if cfg.family == "vlm":
        raise SystemExit("vlm serving runs via the dry-run decode cells")
    mesh = make_host_mesh(model=args.model_par)
    policy = DTypePolicy()

    cache_len = args.prompt_len + args.gen
    params = init_model(jax.random.PRNGKey(0), cfg, policy)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)

    serve_fn, ispec = build_serve_step(cfg, mesh, policy)
    shape = ShapeCell("cli", "decode", cache_len, args.batch)
    _, in_sh, out_sh = ispec(shape)

    with mesh:
        t0 = time.time()
        with shd.activation_policy(mesh):
            logits, cache, length = prefill(params, cfg, prompts, cache_len,
                                            policy)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0
        print(f"[serve] prefill {args.batch}x{args.prompt_len} "
              f"in {t_prefill*1e3:.1f}ms")

        jitted = jax.jit(serve_fn, in_shardings=in_sh, out_shardings=out_sh)
        token = jnp.argmax(logits, -1).astype(jnp.int32)
        generated = [token]
        times = []
        for i in range(args.gen - 1):
            t0 = time.time()
            token, logits, cache, length = jitted(params, cache, token,
                                                  length)
            jax.block_until_ready(token)
            times.append(time.time() - t0)
            generated.append(token)
        gen = jnp.stack(generated, axis=1)
        # skip the first (compile) step in the latency stats
        steady = times[1:] or times
        print(f"[serve] generated {gen.shape} tokens; "
              f"decode latency p50 {sorted(steady)[len(steady)//2]*1e3:.2f}ms"
              f" (first step incl. compile {times[0]*1e3:.0f}ms)")
        print(f"[serve] sample row 0: {gen[0][:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
