"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt \
        --fail-rate 0.02

Runs the full production stack on whatever devices exist: sharded params
(DP x TP via the host mesh), remat'd train step, deterministic pipeline,
AdamW, periodic checkpointing, failure injection + restart supervision,
straggler monitoring, and optional carbon accounting of the run.

``--pathfind`` first runs the TPU carbon pathfinder (the paper's SA
machinery over mesh/microbatch plans) and applies its chosen plan.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.shapes import ShapeCell
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_train_step
from repro.models.common import DTypePolicy
from repro.models.transformer import init_model
from repro.optim import adamw
from repro.runtime import FailureInjector, RestartSupervisor, StragglerMonitor


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-rate", type=float, default=0.0)
    ap.add_argument("--pathfind", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = cfg.reduced()
    if cfg.family in ("audio", "vlm"):
        raise SystemExit("train driver supports token-LM archs; "
                         "audio/vlm run via the dry-run cells")
    mesh = make_host_mesh(model=args.model_par)
    policy = DTypePolicy()  # fp32 on CPU hosts

    if args.pathfind:
        from repro.analysis.tpu_pathfinder import pathfind
        plan = pathfind(cfg, args.batch, args.seq, verbose=True)
        print(f"[pathfind] chosen plan: {plan}")

    shape = ShapeCell("cli", "train", args.seq, args.batch)
    opt_cfg = adamw.AdamWConfig(lr_peak=args.lr, warmup_steps=10,
                                total_steps=args.steps)
    step_fn, ispec = build_train_step(cfg, mesh, opt_cfg, policy)
    _, in_sh, out_sh = ispec(shape)

    params = init_model(jax.random.PRNGKey(0), cfg, policy)
    opt_state = adamw.init(params, opt_cfg)
    pipe = SyntheticTokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    mgr = CheckpointManager(args.ckpt_dir, keep=3)

    with mesh:
        jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh)

        state = {"params": params, "opt": opt_state}
        losses = []

        def one_step(step, state):
            batch = pipe.batch(step)
            p, o, metrics = jitted(state["params"], state["opt"], batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}", flush=True)
            return {"params": p, "opt": o}

        def save(step, state):
            mgr.save(step, {"params": state["params"],
                            "opt_mu": state["opt"].mu,
                            "opt_nu": state["opt"].nu,
                            "opt_step": state["opt"].step})

        def restore():
            if mgr.latest() is None:
                return 0, {"params": params, "opt": opt_state}
            like = {"params": params, "opt_mu": opt_state.mu,
                    "opt_nu": opt_state.nu, "opt_step": opt_state.step}
            step, tree = mgr.restore(like)
            return step, {"params": tree["params"],
                          "opt": adamw.AdamWState(tree["opt_step"],
                                                  tree["opt_mu"],
                                                  tree["opt_nu"])}

        sup = RestartSupervisor(
            one_step, save, restore, save_every=args.ckpt_every,
            injector=FailureInjector(rate=args.fail_rate, seed=11),
            monitor=StragglerMonitor())
        t0 = time.time()
        state = sup.run(args.steps, state)
        wall = time.time() - t0

    print(f"[train] {args.steps} steps in {wall:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"restarts={sup.stats.restarts} "
          f"replayed={sup.stats.replayed_steps} "
          f"stragglers={sup.stats.straggler_steps}")
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    raise SystemExit(main())
