# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and
# must only ever be imported as the program entry point.
from repro.launch.mesh import (
    axis_size,
    data_axes,
    make_host_mesh,
    make_production_mesh,
)
