import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init); 512 host devices back both the 16x16 single-pod and
the 2x16x16 multi-pod production meshes.

For every applicable cell this driver:
    with mesh:
        lowered = jax.jit(step, in_shardings=..., out_shardings=...) \
            .lower(*input_specs(arch))
        compiled = lowered.compile()
        print(compiled.memory_analysis())    # proves it fits
        print(compiled.cost_analysis())      # FLOPs/bytes for the roofline

and records per-cell: FLOPs, bytes, per-device memory, and the collective
schedule (bytes per collective op parsed from the compiled HLO) into a
JSON report consumed by EXPERIMENTS.md and benchmarks/roofline.py.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k --mesh multi                           # one cell
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.analysis.depth import depth_variants, extrapolate
from repro.analysis.hlo import collective_bytes
from repro.configs import ARCH_NAMES, SHAPES, applicable, get_config, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell


def _compile_cell(cfg, shape, mesh):
    import functools
    fn, args, in_sh, out_sh, static = build_cell(cfg, shape, mesh)
    if static:
        fn = functools.partial(fn, **static)
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    lowered = jitted.lower(*args)
    return lowered.compile()


def _cost_terms(compiled):
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return (cost.get("flops", 0.0), cost.get("bytes accessed", 0.0), coll)


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    t0 = time.time()
    try:
        with mesh:
            # full-depth compile: memory fit + the real collective schedule
            compiled = _compile_cell(cfg, shape, mesh)
            t_compile = time.time() - t0
            mem = compiled.memory_analysis()
            flops_raw, bytes_raw, coll_raw = _cost_terms(compiled)
            # XLA costs while-loop bodies once -> compile two reduced
            # depths and extrapolate linearly to the full layer count
            c1, d1, c2, d2, full = depth_variants(cfg)
            f1, b1, coll1 = _cost_terms(_compile_cell(c1, shape, mesh))
            f2, b2, coll2 = _cost_terms(_compile_cell(c2, shape, mesh))
            flops = extrapolate(f1, f2, d1, d2, full)
            nbytes = extrapolate(b1, b2, d1, d2, full)
            coll = {
                k: extrapolate(coll1.get(k, 0.0), coll2.get(k, 0.0),
                               d1, d2, full)
                for k in set(coll1) | set(coll2)
            }
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "ok",
            "flops": flops,
            "bytes_accessed": nbytes,
            "collectives": coll,
            "flops_raw": flops_raw,
            "bytes_raw": bytes_raw,
            "collectives_raw": coll_raw,
            "depth_extrapolation": [d1, d2, full],
            "lower_s": 0.0,
            "compile_s": round(t_compile, 1),
        }
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            rec[attr] = getattr(mem, attr, None)
        if verbose:
            print(f"  memory_analysis: args="
                  f"{(rec['argument_size_in_bytes'] or 0)/2**30:.2f}GiB "
                  f"temp={(rec['temp_size_in_bytes'] or 0)/2**30:.2f}GiB "
                  f"out={(rec['output_size_in_bytes'] or 0)/2**30:.2f}GiB "
                  f"(per device)")
            print(f"  cost_analysis: flops={rec['flops']:.3e} "
                  f"bytes={rec['bytes_accessed']:.3e}")
            print(f"  collectives: " + (", ".join(
                f"{k}={v/2**30:.2f}GiB" for k, v in coll.items()
                if k != 'total' and not k.endswith('_count')) or "none"))
        return rec
    except Exception as e:  # noqa: BLE001 — report and continue
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, help="one shape (default all)")
    ap.add_argument("--mesh", default="both",
                    choices=("single", "multi", "both"))
    ap.add_argument("--out", default="dryrun_report.json")
    ap.add_argument("--append", action="store_true",
                    help="merge into an existing report")
    args = ap.parse_args(argv)

    arches = [args.arch] if args.arch else list(ARCH_NAMES)
    shapes = [args.shape] if args.shape else [s.name for s in SHAPES]
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    records = []
    if args.append and args.out:
        try:
            with open(args.out) as f:
                records = json.load(f)
        except FileNotFoundError:
            pass
    done = {(r["arch"], r["shape"], r["mesh"]) for r in records
            if r.get("status") == "ok"}

    failures = 0
    for mesh_name, mesh in meshes:
        for arch in arches:
            for shape in shapes:
                key = (arch, shape, mesh_name)
                if key in done:
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_name}")
                rec = run_cell(arch, shape, mesh, mesh_name)
                records = [r for r in records
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                records.append(rec)
                if rec["status"] == "error":
                    failures += 1
                    print(f"  ERROR: {rec['error']}")
                elif rec["status"] == "skipped":
                    print(f"  skipped: {rec['reason']}")
                else:
                    print(f"  ok in {rec['lower_s']}+{rec['compile_s']}s")
                with open(args.out, "w") as f:
                    json.dump(records, f, indent=1)
    print(f"[dryrun] wrote {args.out}: "
          f"{sum(r['status'] == 'ok' for r in records)} ok, "
          f"{sum(r['status'] == 'skipped' for r in records)} skipped, "
          f"{failures} errors")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
