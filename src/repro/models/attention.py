"""Attention variants: GQA (qk-norm / QKV-bias / sliding-window), MLA.

All full-sequence paths use a chunked flash-style attention (online
softmax over KV chunks inside a scan over Q chunks) so no (S, S) score
matrix is ever materialized — mandatory for the 32k prefill cells. Decode
paths attend a single query against the cache.

Shapes: x (B, S, D); q (B, S, KV, G, Dh) grouped so KV heads are never
`repeat`ed; caches (B, T, KV, Dh).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (
    DTypePolicy,
    apply_rope,
    init_rms_norm,
    normal_init,
    rms_norm,
)

Params = Dict[str, jnp.ndarray]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention core
# ---------------------------------------------------------------------------


def _attend_chunk(q, k, v, bias, scale):
    """q: (B, qc, KV, G, Dh); k/v: (B, kc, KV, Dh); bias: f32 (qc, kc)
    additive mask (0 / -inf) — kept 2-D so XLA's loop hoisting stores a
    (qc, kc) constant per chunk pair instead of a full-rank bool tensor.
    Returns (scores_max, exp_scores@v, exp_sums) for online softmax."""
    s = jnp.einsum("bqkgd,btkd->bkgqt", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = s + bias[None, None, None]
    m = jnp.max(s, axis=-1)                                   # (B,KV,G,qc)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                                   # (B,KV,G,qc)
    o = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, o, l


def _chunk_mask(q_pos, k_pos, causal, window, t):
    """f32 additive bias (qc, kc): 0 where attended, NEG_INF where masked."""
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    mask &= k_pos[None, :] < t                     # kv padding
    return jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, q_offset, q_chunk, kv_chunk, t_true):
    """Flash attention on chunk-padded operands.

    q: (B, NQ*qc, KV, G, Dh); k/v: (B, NK*kc, KV, Dh). Returns fp32 out of
    q's shape. The custom VJP recomputes chunk probabilities in the
    backward pass, so neither direction ever materializes an (S, T) score
    matrix — this is the memory property the 32k cells depend on.
    """
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset,
                             q_chunk, kv_chunk, t_true)
    return out


def _flash_fwd_impl(q, k, v, causal, window, q_offset, q_chunk, kv_chunk,
                    t_true):
    b, sp, kv_heads, g, dh = q.shape
    t = t_true                     # unpadded kv length (masks the pad tail)
    scale = 1.0 / (dh ** 0.5)
    nq = sp // q_chunk
    nkv = k.shape[1] // kv_chunk
    from repro.distributed import sharding as shd
    # pin the chunk-stacked scan inputs: chunk axes must stay UNsharded or
    # every dynamic_slice in the scan forces an SPMD rematerialization
    qs = shd.constrain(q.reshape(b, nq, q_chunk, kv_heads, g, dh),
                       (shd.DATA, None, None, "model", None, None))
    kc = shd.constrain(k.reshape(b, nkv, kv_chunk, kv_heads, dh),
                       (shd.DATA, None, None, "model", None))
    vc = shd.constrain(v.reshape(b, nkv, kv_chunk, kv_heads, dh),
                       (shd.DATA, None, None, "model", None))
    q_pos_base = jnp.arange(q_chunk)
    k_pos_base = jnp.arange(kv_chunk)

    def q_block(args):
        qi, q_blk = args
        m0 = shd.constrain(
            jnp.full((b, kv_heads, g, q_chunk), NEG_INF, jnp.float32),
            (shd.DATA, "model", None, None))
        l0 = jnp.zeros_like(m0)
        o0 = shd.constrain(
            jnp.zeros((b, kv_heads, g, q_chunk, dh), jnp.float32),
            (shd.DATA, "model", None, None, None))

        def step(ki, carry):
            m, l, o = carry
            k_blk = jax.lax.dynamic_index_in_dim(kc, ki, 1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vc, ki, 1, keepdims=False)
            q_pos = q_offset + qi * q_chunk + q_pos_base
            k_pos = ki * kv_chunk + k_pos_base
            mask = _chunk_mask(q_pos, k_pos, causal, window, t)
            mc, oc, lc = _attend_chunk(q_blk, k_blk, v_blk, mask, scale)
            m_new = jnp.maximum(m, mc)
            a_old = jnp.exp(m - m_new)
            a_new = jnp.exp(mc - m_new)
            l = l * a_old + lc * a_new
            o = o * a_old[..., None] + oc * a_new[..., None]
            return m_new, l, o

        # block-triangular schedule: the forward is never differentiated
        # through (custom VJP), so dynamic fori bounds are legal. Causal
        # masking skips kv chunks beyond the q chunk's last row; windows
        # skip chunks before the window start — ~2x fewer chunk einsums
        # for causal prefill, O(S*W) instead of O(S^2) for local attention.
        lo = jnp.asarray(0, jnp.int32)
        hi = jnp.asarray(nkv, jnp.int32)
        if causal:
            q_end = q_offset + qi * q_chunk + q_chunk - 1
            hi = jnp.minimum(hi, (q_end // kv_chunk + 1).astype(jnp.int32))
        if window is not None:
            q_start = q_offset + qi * q_chunk
            lo = jnp.maximum(lo, ((q_start - window + 1) // kv_chunk)
                             .astype(jnp.int32))
        m, l, o = jax.lax.fori_loop(lo, hi, step, (m0, l0, o0))
        out = o / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))   # (b, kv, g, qc)
        return jnp.transpose(out, (0, 3, 1, 2, 4)), lse

    outs, lses = jax.lax.map(q_block, (jnp.arange(nq), jnp.moveaxis(qs, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sp, kv_heads, g, dh)
    lse = jnp.moveaxis(lses, 0, 1)                 # (b, nq, kv, g, qc)
    return out, lse


def _flash_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk,
               t_true):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset,
                               q_chunk, kv_chunk, t_true)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, q_chunk, kv_chunk, t_true, res,
               dout):
    from repro.distributed import sharding as shd
    q, k, v, out, lse = res
    b, sp, kv_heads, g, dh = q.shape
    t = t_true
    scale = 1.0 / (dh ** 0.5)
    nq = sp // q_chunk
    nkv = k.shape[1] // kv_chunk
    qspec = (shd.DATA, None, None, "model", None, None)
    qs = jnp.moveaxis(shd.constrain(
        q.reshape(b, nq, q_chunk, kv_heads, g, dh), qspec), 1, 0)
    dos = jnp.moveaxis(shd.constrain(
        dout.reshape(b, nq, q_chunk, kv_heads, g, dh), qspec), 1, 0)
    kc = shd.constrain(k.reshape(b, nkv, kv_chunk, kv_heads, dh),
                       (shd.DATA, None, None, "model", None))
    vc = shd.constrain(v.reshape(b, nkv, kv_chunk, kv_heads, dh),
                       (shd.DATA, None, None, "model", None))
    # delta = rowsum(dout * out): (b, nq, kv, g, qc)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)
    delta = jnp.moveaxis(shd.constrain(
        delta.reshape(b, nq, q_chunk, kv_heads, g),
        (shd.DATA, None, None, "model", None)), 1, 0)
    lses = jnp.moveaxis(shd.constrain(
        lse, (shd.DATA, None, "model", None, None)), 1, 0)
    q_pos_base = jnp.arange(q_chunk)
    k_pos_base = jnp.arange(kv_chunk)

    def outer(carry, inp):
        dk_t, dv_t = carry                          # (b, nkv, kc, kv, dh)
        qi, q_blk, do_blk, dl_blk, lse_blk = inp
        do_t = jnp.transpose(do_blk, (0, 2, 3, 1, 4))   # b,kv,g,qc,dh
        dl_t = jnp.transpose(dl_blk, (0, 2, 3, 1))      # b,kv,g,qc

        def inner(icarry, jnp_in):
            # operands stay bf16 (f32 casts here would be loop-hoisted by
            # XLA into full-tensor f32 copies); accumulation is f32 via
            # preferred_element_type, p/ds cast down for their matmuls.
            dq_c, dk_t, dv_t = icarry
            ki, k_blk, v_blk = jnp_in
            q_pos = q_offset + qi * q_chunk + q_pos_base
            k_pos = ki * kv_chunk + k_pos_base
            bias = _chunk_mask(q_pos, k_pos, causal, window, t)
            s = jnp.einsum("bqkgd,btkd->bkgqt", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            s = s + bias[None, None, None]
            p = jnp.exp(s - lse_blk[..., None])         # b,kv,g,qc,kc f32
            p_lo = p.astype(v.dtype)
            dv_blk = jnp.einsum("bkgqt,bkgqd->btkd", p_lo, do_t,
                                preferred_element_type=jnp.float32)
            dp = jnp.einsum("bkgqd,btkd->bkgqt", do_t, v_blk,
                            preferred_element_type=jnp.float32)
            ds = (p * (dp - dl_t[..., None]) * scale).astype(v.dtype)
            dq_c += jnp.einsum("bkgqt,btkd->bqkgd", ds, k_blk,
                               preferred_element_type=jnp.float32)
            dk_blk = jnp.einsum("bkgqt,bqkgd->btkd", ds, q_blk,
                                preferred_element_type=jnp.float32)
            dk_t = dk_t.at[:, ki].add(dk_blk)
            dv_t = dv_t.at[:, ki].add(dv_blk)
            return (dq_c, dk_t, dv_t), None

        dq0 = shd.constrain(
            jnp.zeros((b, q_chunk, kv_heads, g, dh), jnp.float32),
            (shd.DATA, None, "model", None, None))
        (dq_c, dk_t, dv_t), _ = jax.lax.scan(
            inner, (dq0, dk_t, dv_t),
            (jnp.arange(nkv), jnp.moveaxis(kc, 1, 0),
             jnp.moveaxis(vc, 1, 0)))
        return (dk_t, dv_t), dq_c.astype(q.dtype)

    from repro.distributed import sharding as shd
    dk0 = shd.constrain(
        jnp.zeros((b, nkv, kv_chunk, kv_heads, dh), jnp.float32),
        (shd.DATA, None, None, "model", None))
    dv0 = jnp.zeros_like(dk0)
    (dk_t, dv_t), dqs = jax.lax.scan(
        outer, (dk0, dv0), (jnp.arange(nq), qs, dos, delta, lses))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(q.shape).astype(q.dtype)
    dk = dk_t.reshape(k.shape).astype(k.dtype)
    dv = dv_t.reshape(v.shape).astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def chunked_attention(
    q: jnp.ndarray,        # (B, S, KV, G, Dh)
    k: jnp.ndarray,        # (B, T, KV, Dh)
    v: jnp.ndarray,        # (B, T, KV, Dh)
    *,
    causal: bool,
    q_offset: int = 0,     # absolute position of q[0] within the kv axis
    window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Flash attention (online softmax fwd, recompute bwd);
    returns (B, S, KV, G, Dh) in v.dtype."""
    b, s, kv_heads, g, dh = q.shape
    t = k.shape[1]
    from repro.models.common import probe_mode
    if probe_mode():          # monolithic: exact FLOP counting, no loops
        q_chunk, kv_chunk = s, t
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    nq = -(-s // q_chunk)
    nkv = -(-t // kv_chunk)
    qp = nq * q_chunk - s
    kp = nkv * kv_chunk - t
    if qp:
        q = jnp.pad(q, ((0, 0), (0, qp), (0, 0), (0, 0), (0, 0)))
    if kp:
        k = jnp.pad(k, ((0, 0), (0, kp), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kp), (0, 0), (0, 0)))
    out = _flash(q, k, v, causal, window, q_offset, q_chunk, kv_chunk, t)
    return out[:, :s].astype(v.dtype)


def decode_attention(q1, k, v, *, length, window: Optional[int] = None):
    """Single-token attention: q1 (B, KV, G, Dh) vs cache k/v (B, T, KV, Dh);
    positions >= ``length`` (and outside the window) are masked."""
    b, kv_heads, g, dh = q1.shape
    t = k.shape[1]
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("bkgd,btkd->bkgt", q1, k,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(t)
    mask = pos[None] < length[:, None] if length.ndim else pos < length
    if window is not None:
        lo = (length if length.ndim else length[None]) - window
        mask &= pos[None] >= lo[:, None]
    s = jnp.where(mask[:, None, None] if mask.ndim == 2 else mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


# ---------------------------------------------------------------------------
# GQA layer (covers MHA/GQA/MQA, qk-norm, qkv-bias, sliding window)
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig, policy: DTypePolicy) -> Params:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    keys = jax.random.split(key, 4)
    p: Params = {
        "wq": normal_init(keys[0], (d, h * dh), 1.0, policy.param_dtype),
        "wk": normal_init(keys[1], (d, kv * dh), 1.0, policy.param_dtype),
        "wv": normal_init(keys[2], (d, kv * dh), 1.0, policy.param_dtype),
        "wo": normal_init(keys[3], (h * dh, d), 1.0, policy.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), policy.param_dtype)
        p["bk"] = jnp.zeros((kv * dh,), policy.param_dtype)
        p["bv"] = jnp.zeros((kv * dh,), policy.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(dh, policy.param_dtype)
        p["k_norm"] = init_rms_norm(dh, policy.param_dtype)
    return p


def _project_qkv(p: Params, x, cfg: ModelConfig):
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = h // kv
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, kv, g, dh)
    k = k.reshape(b, s, kv, dh)
    v = v.reshape(b, s, kv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def gqa_forward(
    p: Params, x, positions, cfg: ModelConfig, *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 512, kv_chunk: int = 1024,
) -> jnp.ndarray:
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    q = apply_rope(q.reshape(b, s, -1, cfg.d_head), positions,
                   cfg.rope_theta).reshape(q.shape)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = out.reshape(b, s, cfg.n_heads * cfg.d_head)
    return jnp.einsum("bse,ed->bsd", out, p["wo"])


def gqa_prefill(p, x, positions, cfg: ModelConfig, cache_len: int, *,
                window: Optional[int] = None, q_chunk=512, kv_chunk=1024):
    """Forward + returns the (right-padded) KV cache of length cache_len."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    q = apply_rope(q.reshape(b, s, -1, cfg.d_head), positions,
                   cfg.rope_theta).reshape(q.shape)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=True, window=window,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = out.reshape(b, s, cfg.n_heads * cfg.d_head)
    y = jnp.einsum("bse,ed->bsd", out, p["wo"])
    pad = cache_len - s
    cache_k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cache_v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return y, (cache_k, cache_v)


def gqa_decode(p, x1, cache: Tuple[jnp.ndarray, jnp.ndarray], length,
               cfg: ModelConfig, *, window: Optional[int] = None):
    """x1: (B, 1, D); cache k/v (B, T, KV, Dh); length (B,) current lengths.
    Returns (y (B, 1, D), new cache)."""
    b = x1.shape[0]
    kv, dh = cfg.n_kv_heads, cfg.d_head
    g = cfg.n_heads // kv
    q, k, v = _project_qkv(p, x1, cfg)
    pos = length.astype(jnp.int32)
    q = apply_rope(q.reshape(b, 1, -1, dh), pos[:, None],
                   cfg.rope_theta).reshape(b, 1, kv, g, dh)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    ck, cv = cache
    # write the new kv at position `length` (same position for all rows
    # requires per-row dynamic update; use one-hot scatter)
    t = ck.shape[1]
    onehot = jax.nn.one_hot(pos, t, dtype=ck.dtype)             # (B, T)
    ck = ck * (1 - onehot[..., None, None]) + onehot[..., None, None] * k
    cv = cv * (1 - onehot[..., None, None]) + onehot[..., None, None] * v[:, :1]
    out = decode_attention(q[:, 0], ck, cv, length=pos + 1, window=window)
    out = out.reshape(b, 1, cfg.n_heads * dh)
    return jnp.einsum("bse,ed->bsd", out, p["wo"]), (ck, cv)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig, policy: DTypePolicy) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 7)
    return {
        "w_dq": normal_init(ks[0], (d, r_q), 1.0, policy.param_dtype),
        "w_uq": normal_init(ks[1], (r_q, h * (dn + dr)), 1.0,
                            policy.param_dtype),
        "w_dkv": normal_init(ks[2], (d, r_kv + dr), 1.0, policy.param_dtype),
        "w_uk": normal_init(ks[3], (r_kv, h * dn), 1.0, policy.param_dtype),
        "w_uv": normal_init(ks[4], (r_kv, h * dv), 1.0, policy.param_dtype),
        "wo": normal_init(ks[5], (h * dv, d), 1.0, policy.param_dtype),
        "kv_norm": init_rms_norm(r_kv, policy.param_dtype),
        "q_norm": init_rms_norm(r_q, policy.param_dtype),
    }


def _mla_qkv(p: Params, x, positions, cfg: ModelConfig):
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r_kv = cfg.kv_lora_rank
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("bsr,re->bse", cq, p["w_uq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    ckv, k_rope = ckv_full[..., :r_kv], ckv_full[..., r_kv:]
    ckv = rms_norm(ckv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return q_nope, q_rope, ckv, k_rope[:, :, 0]


def mla_forward(p: Params, x, positions, cfg: ModelConfig, *,
                q_chunk=256, kv_chunk=512) -> jnp.ndarray:
    """Training/prefill path: materialize per-head K/V from the latent and
    run chunked attention with the concatenated [nope | rope] key."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, x, positions, cfg)
    k_nope = jnp.einsum("bsr,re->bse", ckv, p["w_uk"]).reshape(b, s, h, dn)
    v = jnp.einsum("bsr,re->bse", ckv, p["w_uv"]).reshape(b, s, h, dv)
    # pad v up to key width so one attention call serves both (sliced after)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)              # (b,s,h,dn+dr)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          (b, s, h, dr))], axis=-1)
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
    out = chunked_attention(q[:, :, :, None, :].reshape(b, s, h, 1, dn + dr),
                            k, vp, causal=True,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = out.reshape(b, s, h, dn + dr)[..., :dv]
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, h * dv), p["wo"])


def mla_prefill(p, x, positions, cfg: ModelConfig, cache_len: int, **kw):
    """Returns forward output + the *latent* cache (c_kv, k_rope) — the
    MLA compression that makes 32k-decode caches rank-512 instead of
    per-head: (B, T, r_kv) + (B, T, dr)."""
    b, s, _ = x.shape
    y = mla_forward(p, x, positions, cfg, **kw)
    _, _, ckv, k_rope = _mla_qkv(p, x, positions, cfg)
    pad = cache_len - s
    c1 = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
    c2 = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    return y, (c1, c2)


def mla_decode(p, x1, cache, length, cfg: ModelConfig):
    """Absorbed decode: queries are mapped into the latent space
    (q_nope @ W_uk) so attention runs directly against the latent cache."""
    b = x1.shape[0]
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r_kv = cfg.kv_lora_rank
    pos = length.astype(jnp.int32)
    q_nope, q_rope, ckv_new, k_rope_new = _mla_qkv(
        p, x1, pos[:, None], cfg)
    c_cache, r_cache = cache
    t = c_cache.shape[1]
    onehot = jax.nn.one_hot(pos, t, dtype=c_cache.dtype)
    c_cache = c_cache * (1 - onehot[..., None]) + onehot[..., None] * ckv_new
    r_cache = r_cache * (1 - onehot[..., None]) + onehot[..., None] * k_rope_new
    # absorb: q_lat (b,h,r_kv) = q_nope @ W_uk per head
    w_uk = p["w_uk"].reshape(r_kv, h, dn)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    scale = 1.0 / ((dn + dr) ** 0.5)
    s_lat = jnp.einsum("bhr,btr->bht", q_lat, c_cache)
    s_rope = jnp.einsum("bhd,btd->bht", q_rope[:, 0], r_cache)
    scores = (s_lat + s_rope).astype(jnp.float32) * scale
    mask = jnp.arange(t)[None] <= pos[:, None]
    scores = jnp.where(mask[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(c_cache.dtype)
    ctx = jnp.einsum("bht,btr->bhr", probs, c_cache)            # latent ctx
    w_uv = p["w_uv"].reshape(r_kv, h, dv)
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv).reshape(b, 1, h * dv)
    return jnp.einsum("bse,ed->bsd", out, p["wo"]), (c_cache, r_cache)
