"""RecurrentGemma / Griffin recurrent block: temporal conv + RG-LRU.

Block structure per [arXiv:2402.19427]:
    gate branch : x -> linear(d -> w) -> GeLU
    input branch: x -> linear(d -> w) -> causal depthwise conv1d(width 4)
                    -> RG-LRU
    merge       : gate * lru_out -> linear(w -> d)

RG-LRU (block-diagonal gates over H heads, as in the released model):
    r_t = sigmoid(Wa xi_t);  i_t = sigmoid(Wx xi_t)
    a_t = exp(-c * softplus(Lambda) * r_t)              (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * xi_t)

The scan itself is the Pallas kernel (:mod:`repro.kernels.rglru`) on TPU;
here the associative-scan oracle is the default lowering.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import DTypePolicy, normal_init

Params = Dict[str, jnp.ndarray]

RG_C = 8.0
N_GATE_BLOCKS = 16


def init_rg_block(key, cfg: ModelConfig, policy: DTypePolicy) -> Params:
    d = cfg.d_model
    w = cfg.rg_lru_width or d
    bw = w // N_GATE_BLOCKS
    ks = jax.random.split(key, 7)
    dt = policy.param_dtype
    return {
        "w_in": normal_init(ks[0], (d, w), 1.0, dt),
        "w_gate": normal_init(ks[1], (d, w), 1.0, dt),
        "conv_w": normal_init(ks[2], (cfg.rg_conv_width, w), 1.0, dt),
        "conv_b": jnp.zeros((w,), dt),
        "gate_a": normal_init(ks[3], (N_GATE_BLOCKS, bw, bw), 1.0, dt),
        "gate_a_b": jnp.zeros((w,), dt),
        "gate_x": normal_init(ks[4], (N_GATE_BLOCKS, bw, bw), 1.0, dt),
        "gate_x_b": jnp.zeros((w,), dt),
        # Lambda parameterized so a is stable in (0.9, 0.999) at init
        "lam": jnp.asarray(
            jax.random.uniform(ks[5], (w,), jnp.float32, 0.2, 0.9)),
        "w_out": normal_init(ks[6], (w, d), 1.0, dt),
    }


def _block_diag(x: jnp.ndarray, wts: jnp.ndarray, bias) -> jnp.ndarray:
    """x: (..., W) with W = H*bw; wts: (H, bw, bw)."""
    h, bw, _ = wts.shape
    xb = x.reshape(*x.shape[:-1], h, bw)
    out = jnp.einsum("...hb,hbc->...hc", xb, wts)
    return out.reshape(*x.shape) + bias


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over (B, S, W); kernel (K, W). ``state`` is
    the trailing K-1 inputs from the previous segment (decode carry).
    Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    return y, xp[:, -(k - 1):]


def _rg_lru_coeffs(p: Params, xi: jnp.ndarray):
    r = jax.nn.sigmoid(_block_diag(xi, p["gate_a"], p["gate_a_b"]))
    i = jax.nn.sigmoid(_block_diag(xi, p["gate_x"], p["gate_x_b"]))
    log_a = (-RG_C * jax.nn.softplus(p["lam"])
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via log: 0.5*log1p(-exp(2 log_a))
    mult = jnp.exp(0.5 * jnp.log1p(-jnp.exp(2 * log_a)
                                   + 1e-12))
    b = mult * (i.astype(jnp.float32) * xi.astype(jnp.float32))
    return a, b


def _assoc_scan(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t over axis 1 via associative scan."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rg_block_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                     state: Optional[Tuple] = None):
    """x: (B, S, D). state = (conv_state (B, K-1, W), h (B, W)) or None.
    Returns (y, new_state)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"]))
    xi = jnp.einsum("bsd,dw->bsw", x, p["w_in"])
    conv_state = None if state is None else state[0]
    xi, new_conv = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    a, b = _rg_lru_coeffs(p, xi)
    h0 = None if state is None else state[1]
    h = _assoc_scan(a, b, h0)
    y = (h.astype(x.dtype) * gate)
    y = jnp.einsum("bsw,wd->bsd", y, p["w_out"])
    return y, (new_conv, h[:, -1])
