"""RWKV-6 (Finch) blocks: data-dependent token-shift time mix + channel mix.

Faithful structure per [arXiv:2404.05892]: the time-mix block derives its
five projections (r, k, v, w-decay, gate) from data-dependent lerps between
the token and its predecessor (the low-rank "ddlerp"), runs the WKV
recurrence with per-channel data-dependent decay, applies a per-head group
norm, and gates the output. The channel-mix block is a squared-ReLU MLP
with receptance gating.

The WKV recurrence itself is the Pallas kernel
(:mod:`repro.kernels.wkv6`) on TPU; the pure-jnp scan here is the oracle
and the default on CPU substrates (and is what the dry-run lowers).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import DTypePolicy, init_rms_norm, normal_init, rms_norm

Params = Dict[str, jnp.ndarray]

LORA_RANK = 32
HEAD_DIM = 64


def n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // HEAD_DIM


def init_time_mix(key, cfg: ModelConfig, policy: DTypePolicy) -> Params:
    d = cfg.d_model
    h = n_heads(cfg)
    ks = jax.random.split(key, 12)
    dt = policy.param_dtype
    return {
        # ddlerp: base mixes + shared lora (d -> 5*rank -> d per target)
        "mu_x": jnp.full((d,), 0.5, dt),
        "mu": jnp.full((5, d), 0.5, dt),
        "lora_a": normal_init(ks[0], (d, 5 * LORA_RANK), 0.1, dt),
        "lora_b": normal_init(ks[1], (5, LORA_RANK, d), 0.1, dt),
        # projections
        "w_r": normal_init(ks[2], (d, d), 1.0, dt),
        "w_k": normal_init(ks[3], (d, d), 1.0, dt),
        "w_v": normal_init(ks[4], (d, d), 1.0, dt),
        "w_g": normal_init(ks[5], (d, d), 1.0, dt),
        "w_o": normal_init(ks[6], (d, d), 1.0, dt),
        # decay: w0 + lora_w(x)
        "w0": jnp.full((d,), -6.0, dt),
        "decay_a": normal_init(ks[7], (d, LORA_RANK * 2), 0.1, dt),
        "decay_b": normal_init(ks[8], (LORA_RANK * 2, d), 0.1, dt),
        # current-token bonus
        "u": normal_init(ks[9], (h, HEAD_DIM), 0.5, jnp.float32),
        # per-head group norm
        "gn": init_rms_norm(d, dt),
    }


def init_channel_mix(key, cfg: ModelConfig, policy: DTypePolicy) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = policy.param_dtype
    return {
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_r": jnp.full((d,), 0.5, dt),
        "w_k": normal_init(ks[0], (d, f), 1.0, dt),
        "w_v": normal_init(ks[1], (f, d), 1.0, dt),
        "w_r": normal_init(ks[2], (d, d), 1.0, dt),
    }


def _shift(x: jnp.ndarray, last: jnp.ndarray = None) -> jnp.ndarray:
    """Previous-token sequence; position 0 sees ``last`` (decode carry) or
    zeros."""
    prev = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, 0]) if last is None else last
    return prev.at[:, 0].set(first)


def _ddlerp(p: Params, x: jnp.ndarray, x_prev: jnp.ndarray):
    """Data-dependent lerp producing the five mixed inputs (r,k,v,w,g)."""
    sx = x_prev - x                                            # (B,S,D)
    base = x + sx * p["mu_x"]
    lo = jnp.tanh(jnp.einsum("bsd,dr->bsr", base, p["lora_a"]))
    lo = lo.reshape(*lo.shape[:-1], 5, LORA_RANK)
    adj = jnp.einsum("bsir,ird->bsid", lo, p["lora_b"])        # (B,S,5,D)
    mixed = x[:, :, None] + sx[:, :, None] * (p["mu"] + adj)
    return tuple(mixed[:, :, i] for i in range(5))             # r,k,v,w,g


def _decay(p: Params, xw: jnp.ndarray) -> jnp.ndarray:
    """Per-channel data-dependent decay in (0, 1)."""
    lo = jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["decay_a"]))
    dw = jnp.einsum("bsr,rd->bsd", lo, p["decay_b"])
    return jnp.exp(-jnp.exp((p["w0"] + dw).astype(jnp.float32)))


def wkv_scan(r, k, v, w, u, s0=None):
    """Oracle recurrence over (B, S, H, Dh) tensors; returns (y, final S).

    S has shape (B, H, Dh_k, Dh_v); the u-bonus adds u[k]*k_t[k]*v_t[v]
    for the current token only."""
    b, s, h, dh = r.shape

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp                               # (B,H,Dh)
        kv = k_t[..., :, None] * v_t[..., None, :]             # (B,H,Dk,Dv)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[None, :, :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, y

    if s0 is None:
        s0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), final


def time_mix_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                     state: Tuple = None):
    """x: (B, S, D). state = (last_x (B,D), wkv_state (B,H,Dh,Dh)) for
    decode continuation; returns (y, new_state)."""
    b, s, d = x.shape
    h = n_heads(cfg)
    last_x = None if state is None else state[0]
    x_prev = _shift(x, last_x)
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)
    r = jnp.einsum("bsd,de->bse", xr, p["w_r"]).reshape(b, s, h, HEAD_DIM)
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"]).reshape(b, s, h, HEAD_DIM)
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"]).reshape(b, s, h, HEAD_DIM)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"]))
    w = _decay(p, xw).reshape(b, s, h, HEAD_DIM)

    s0 = None if state is None else state[1]
    y, wkv_state = wkv_scan(r, k, v, w, p["u"], s0)
    y = y.reshape(b, s, d).astype(x.dtype)
    y = rms_norm(y, p["gn"])                                   # head norm
    y = jnp.einsum("bsd,de->bse", y * g, p["w_o"])
    return y, (x[:, -1], wkv_state)


def channel_mix_forward(p: Params, x: jnp.ndarray,
                        state: jnp.ndarray = None):
    """state = last_x (B, D); returns (y, new_state)."""
    x_prev = _shift(x, state)
    xk = x + (x_prev - x) * p["mu_k"]
    xr = x + (x_prev - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["w_k"])))
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"]))
    return r * kv, x[:, -1]
