"""Feed-forward layers: SwiGLU MLP and capacity-based top-k MoE.

The MoE dispatch is sort-based gather/scatter: tokens are routed to
(expert, slot) coordinates via an argsort over expert assignments, expert
FFNs run as one batched einsum over the (E, C, D) gathered block, and
results scatter-add back weighted by router gates. Compiled matmul FLOPs
therefore track 6*N_active*D — no one-hot einsum over all experts.

Under pjit the expert axis E shards over the 'model' mesh axis (EP); the
gather/scatter lower to all-to-alls across it.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import DTypePolicy, normal_init

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Dense SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, policy: DTypePolicy) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": normal_init(ks[0], (d_model, d_ff), 1.0, policy.param_dtype),
        "w_up": normal_init(ks[1], (d_model, d_ff), 1.0, policy.param_dtype),
        "w_down": normal_init(ks[2], (d_ff, d_model), 1.0, policy.param_dtype),
    }


def mlp_forward(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["w_gate"]))
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    return jnp.einsum("...f,fd->...d", gate * up, p["w_down"])


# ---------------------------------------------------------------------------
# Mixture of experts
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig, policy: DTypePolicy) -> Params:
    d, f = cfg.d_model, cfg.moe_d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": normal_init(ks[0], (d, e), 1.0, jnp.float32),
        "w_gate": normal_init(ks[1], (e, d, f), 1.0, policy.param_dtype),
        "w_up": normal_init(ks[2], (e, d, f), 1.0, policy.param_dtype),
        "w_down": normal_init(ks[3], (e, f, d), 1.0, policy.param_dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d,
                               cfg.moe_d_ff * cfg.n_shared_experts, policy)
    return p


def _route(router_logits: jnp.ndarray, top_k: int):
    """Top-k routing with softmax over the selected experts' logits."""
    gates, idx = jax.lax.top_k(router_logits, top_k)       # (T, k)
    gates = jax.nn.softmax(gates, axis=-1)
    return gates, idx


def moe_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                capacity: Optional[int] = None,
                exact: bool = False,
                serving: bool = False) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D). Tokens over capacity are dropped (their
    contribution falls back to the shared experts / residual path).
    ``exact=True`` (decode/prefill paths) sizes capacity so nothing drops.

    When a mesh activation policy is live (pjit steps) the expert-parallel
    shard_map path runs for the big token counts of train/prefill; decode
    (``serving=True``, a handful of tokens) stays on the local dispatch —
    its tensors are tiny and SPMD turns the E-sharded expert matmuls into
    small activation all-reduces with the weights stationary.
    """
    from repro.distributed import sharding as shd
    mesh = shd.active_mesh()
    if (not serving and mesh is not None and "model" in mesh.axis_names
            and mesh.shape["model"] > 1
            and cfg.n_experts % mesh.shape["model"] == 0):
        return moe_forward_ep(p, x, cfg, mesh, exact=exact)
    return _moe_forward_local(p, x, cfg, capacity, exact)


def _moe_forward_local(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                       capacity: Optional[int] = None,
                       exact: bool = False) -> jnp.ndarray:
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    gates, expert_idx = _route(logits, k)                   # (T,k), (T,k)

    if capacity is None:
        if exact:
            capacity = t * k       # worst case: every token on one expert
        else:
            capacity = int(t * k / e * cfg.capacity_factor) + 1

    # flatten (token, k) pairs and sort by expert id
    flat_expert = expert_idx.reshape(-1)                    # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), k)               # (T*k,)
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    # position within each expert's contiguous run -> capacity slot
    ones = jnp.ones_like(sorted_expert)
    run_pos = jnp.cumsum(ones) - 1
    seg_start = jnp.searchsorted(sorted_expert, jnp.arange(e), side="left")
    slot = run_pos - seg_start[sorted_expert]               # (T*k,)
    keep = slot < capacity

    # gather tokens into (E, C, D); E shards over 'model' (EP), the slot
    # axis over the DP axes so the expert batch never lives replicated
    from repro.distributed import sharding as shd
    slot_c = jnp.where(keep, slot, capacity - 1)
    buf = jnp.zeros((e, capacity, d), x.dtype)
    buf = buf.at[sorted_expert, slot_c].add(
        jnp.where(keep[:, None], xf[sorted_token], 0).astype(x.dtype))
    buf = shd.constrain(buf, ("model", None, None))

    # batched expert FFN: (E, C, D) x (E, D, F). The E axis stays sharded
    # over 'model' (weights stationary); under the serving layout the FFN
    # dim is dp-sharded so gate/up are comm-free and only w_down's output
    # all-reduces.
    gate_act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", gate_act * up, p["w_down"])

    # scatter back, weighted by gates
    gathered = out_buf[sorted_expert, slot_c]               # (T*k, D)
    contrib = jnp.where(keep[:, None], gathered
                        * sorted_gate[:, None].astype(x.dtype), 0)
    y = jnp.zeros((t, d), x.dtype).at[sorted_token].add(contrib)

    if "shared" in p:
        y = y + mlp_forward(p["shared"], xf)
    return y.reshape(b, s, d)


def moe_forward_ep(p: Params, x: jnp.ndarray, cfg: ModelConfig, mesh,
                   exact: bool = False) -> jnp.ndarray:
    """Expert-parallel MoE via shard_map (the production path).

    Layout: tokens replicated across the 'model' axis (batch stays on the
    DP axes); experts sharded over 'model' (E_loc per shard). Each shard
    routes its local tokens, runs ONLY its own experts on a local
    capacity buffer (zero-communication dispatch), and the partial outputs
    psum over 'model' — one activation all-reduce per MoE layer instead of
    the scatter/gather storm SPMD infers for a global dispatch. This is
    the paper's split-K story at the package level: partial results
    produced where the weights live, reduced at the destination.
    """
    try:
        from jax import shard_map  # newer jax re-exports it at top level
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import data_axes

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    ep = mesh.shape["model"]
    e_loc = e // ep
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    b_spec = dp if (dp and b % dp_size == 0) else None
    t_loc = (b // dp_size if b_spec else b) * s
    if exact:
        cap = t_loc * k
    else:
        cap = int(t_loc * k / e * cfg.capacity_factor) + 1

    scatter_combine = (x.shape[1] % ep == 0)

    def body(xb, router, w_gate, w_up, w_down):
        # xb: (B_loc, S, D) replicated over 'model'; experts local slices.
        my = jax.lax.axis_index("model")
        tl = xb.shape[0] * xb.shape[1]
        xf = xb.reshape(tl, d)
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
        gates, expert_idx = _route(logits, k)               # (T_loc, k)
        flat_e = expert_idx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(tl), k)
        flat_g = gates.reshape(-1)
        # keep only assignments owned by this shard's experts
        local = (flat_e // e_loc) == my
        le = jnp.where(local, flat_e % e_loc, e_loc)        # e_loc = trash
        order = jnp.argsort(le, stable=True)
        se, st, sg = le[order], flat_t[order], flat_g[order]
        ones = jnp.ones_like(se)
        run = jnp.cumsum(ones) - 1
        seg = jnp.searchsorted(se, jnp.arange(e_loc + 1), side="left")
        slot = run - seg[jnp.minimum(se, e_loc)]
        keep = (slot < cap) & (se < e_loc)
        slot_c = jnp.where(keep, slot, cap - 1)
        se_c = jnp.where(keep, se, 0)
        # build the small (e_loc, cap) slot->token map + per-slot gates so
        # the only D-wide tensors are the (e_loc, cap, D) expert buffers —
        # never a (T_loc*k, D) flat intermediate
        slot_token = jnp.zeros((e_loc, cap), jnp.int32).at[se_c, slot_c].max(
            jnp.where(keep, st, 0))
        slot_gate = jnp.zeros((e_loc, cap), jnp.float32).at[se_c, slot_c].max(
            jnp.where(keep, sg, 0.0))
        slot_valid = jnp.zeros((e_loc, cap), bool).at[se_c, slot_c].max(keep)
        buf = jnp.where(slot_valid[..., None], xf[slot_token], 0)
        gate_act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
        up = jnp.einsum("ecd,edf->ecf", buf, w_up)
        out_buf = jnp.einsum("ecf,efd->ecd", gate_act * up, w_down)
        weighted = out_buf * (slot_gate * slot_valid)[..., None].astype(
            out_buf.dtype)
        y = jnp.zeros((tl, d), xb.dtype).at[
            slot_token.reshape(-1)].add(weighted.reshape(-1, d))
        if scatter_combine:
            # reduce-scatter the combine onto the seq-sharded residual
            # layout: moves half the bytes of a full all-reduce and saves
            # the re-shard the next layer boundary would insert anyway
            y = y.reshape(xb.shape[0], xb.shape[1], d)
            return jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                        tiled=True)   # (b, s/ep, d)
        y = jax.lax.psum(y, "model")                        # combine
        return y.reshape(xb.shape)

    in_specs = (P(b_spec, None, None), P(), P("model"), P("model"),
                P("model"))
    out_specs = (P(b_spec, "model", None) if scatter_combine
                 else P(b_spec, None, None))
    import inspect
    no_check = ("check_vma" if "check_vma" in
                inspect.signature(shard_map).parameters else "check_rep")
    y = shard_map(body, mesh=mesh, in_specs=in_specs,
                  out_specs=out_specs, **{no_check: False})(
        x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    if "shared" in p:
        y = y + mlp_forward(p["shared"], x)
    return y


def moe_aux_loss(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Load-balancing auxiliary loss (Switch-style: E * sum(f_e * p_e))."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(logits, cfg.top_k)
    counts = jnp.zeros((cfg.n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac_tokens = counts / counts.sum()
    frac_probs = probs.mean(axis=0)
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
