"""Shared model building blocks: norms, RoPE, initializers, dtype policy.

All models are pure-functional: params are pytrees of jnp arrays created by
``init_*`` functions and consumed by ``apply``-style functions. Layers are
stacked along a leading axis and iterated with ``lax.scan`` so HLO size and
compile time are O(1) in depth.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

# Cost-probe mode (set from cfg.unroll_layers by the model entry points):
# layer stacks run as Python loops, flash attention goes monolithic
# (nq = nkv = 1) and the loss uses a single chunk, so XLA's cost analysis
# (which visits while-loop bodies once) sees every FLOP. Probe modules are
# compiled for analysis only — never executed.
import threading as _threading

_PROBE = _threading.local()


def set_probe_mode(on: bool) -> None:
    _PROBE.value = bool(on)


def probe_mode() -> bool:
    return bool(getattr(_PROBE, "value", False))


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32

    @classmethod
    def bf16(cls) -> "DTypePolicy":
        return cls(jnp.bfloat16, jnp.bfloat16)


def normal_init(key, shape, scale: float, dtype) -> jnp.ndarray:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def init_rms_norm(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: (..., S). Rotates pairs (2i, 2i+1)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (Dh/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Scan-over-layers helper
# ---------------------------------------------------------------------------


def stack_layer_params(per_layer: Callable[[jax.Array], Params],
                       key: jax.Array, n_layers: int) -> Params:
    """Initialize n_layers copies of a layer and stack each leaf along a
    leading axis, producing the pytree ``lax.scan`` consumes."""
    keys = jax.random.split(key, n_layers)
    trees = [per_layer(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def scan_layers(block: Callable, stacked: Params, x: jnp.ndarray,
                *broadcast) -> jnp.ndarray:
    """Run ``block(layer_params, x, *broadcast) -> x`` over stacked layers."""
    def body(carry, layer_params):
        return block(layer_params, carry, *broadcast), None

    out, _ = jax.lax.scan(body, x, stacked)
    return out


def scan_layers_with_state(block: Callable, stacked: Params,
                           x: jnp.ndarray, states: Any,
                           *broadcast) -> Tuple[jnp.ndarray, Any]:
    """Like :func:`scan_layers` but each layer also consumes and produces a
    per-layer state (KV cache slab, recurrent state), stacked likewise."""
    def body(carry, inp):
        layer_params, state = inp
        new_carry, new_state = block(layer_params, carry, state, *broadcast)
        return new_carry, new_state

    out, new_states = jax.lax.scan(body, x, (stacked, states))
    return out, new_states
