"""Model assembly for all architecture families.

Families and their layer structure:
  dense  — uniform decoder layers (GQA + SwiGLU), lax.scan over the stack
  vlm    — dense backbone consuming stub patch embeddings as a prefix
  audio  — encoder-only (bidirectional) layers over stub frame embeddings
  moe    — deepseek-style (MLA attention + shared/routed MoE, leading dense
           layers) or llama4-style (GQA + MoE interleaved every ``moe_every``)
  hybrid — recurrentgemma groups (rglru, rglru, local-attention) + tail
  ssm    — rwkv6 (time-mix + channel-mix), attention-free

Every family exposes the same functional API:
  init_model(key, cfg, policy)                   -> params
  forward(params, cfg, tokens | embeds, ...)     -> logits (B, S, V)
  loss_fn(params, cfg, batch)                    -> scalar (chunked CE)
  init_cache(cfg, batch_size, cache_len, policy) -> decode state pytree
  prefill(params, cfg, tokens, cache_len)        -> (last_logits, cache, len)
  decode_step(params, cfg, token, cache, length) -> (logits, cache)

Layers are stacked and scanned: HLO size is O(1) in depth, which keeps the
62-cell dry-run compilable on one CPU core.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rg_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import (
    DTypePolicy,
    init_rms_norm,
    normal_init,
    rms_norm,
    stack_layer_params,
)

Params = Dict[str, Any]

MOE_AUX_WEIGHT = 0.01
LOSS_CHUNK = 1024

from repro.models.common import probe_mode, set_probe_mode


def _set_unroll(cfg):
    set_probe_mode(getattr(cfg, "unroll_layers", False))


def maybe_scan(body, init, xs):
    """lax.scan by default (O(1) HLO in depth); a Python loop in probe
    mode so XLA's cost analysis (which visits while bodies once) sees
    every layer."""
    if not probe_mode():
        return jax.lax.scan(body, init, xs)
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(length):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# Layer initializers
# ---------------------------------------------------------------------------


def _init_dense_layer(key, cfg: ModelConfig, policy: DTypePolicy) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rms_norm(cfg.d_model, policy.param_dtype),
        "attn": attn.init_gqa(k1, cfg, policy),
        "ln2": init_rms_norm(cfg.d_model, policy.param_dtype),
        "mlp": moe_mod.init_mlp(k2, cfg.d_model, cfg.d_ff, policy),
    }


def _init_moe_layer(key, cfg: ModelConfig, policy: DTypePolicy) -> Params:
    k1, k2 = jax.random.split(key)
    layer = {
        "ln1": init_rms_norm(cfg.d_model, policy.param_dtype),
        "ln2": init_rms_norm(cfg.d_model, policy.param_dtype),
        "moe": moe_mod.init_moe(k2, cfg, policy),
    }
    layer["attn"] = (attn.init_mla(k1, cfg, policy) if cfg.use_mla
                     else attn.init_gqa(k1, cfg, policy))
    return layer


def _init_deepseek_dense(key, cfg: ModelConfig, policy: DTypePolicy) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rms_norm(cfg.d_model, policy.param_dtype),
        "attn": attn.init_mla(k1, cfg, policy),
        "ln2": init_rms_norm(cfg.d_model, policy.param_dtype),
        "mlp": moe_mod.init_mlp(k2, cfg.d_model,
                                cfg.dense_d_ff or cfg.d_ff, policy),
    }


def _init_hybrid_group(key, cfg: ModelConfig, policy: DTypePolicy) -> Params:
    """(rglru, rglru, local-attn), each with its own MLP."""
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    def mlp(k):
        return moe_mod.init_mlp(k, d, cfg.d_ff, policy)
    return {
        "rg1": {"ln1": init_rms_norm(d, policy.param_dtype),
                "block": rg_mod.init_rg_block(ks[0], cfg, policy),
                "ln2": init_rms_norm(d, policy.param_dtype),
                "mlp": mlp(ks[1])},
        "rg2": {"ln1": init_rms_norm(d, policy.param_dtype),
                "block": rg_mod.init_rg_block(ks[2], cfg, policy),
                "ln2": init_rms_norm(d, policy.param_dtype),
                "mlp": mlp(ks[3])},
        "attn": {"ln1": init_rms_norm(d, policy.param_dtype),
                 "attn": attn.init_gqa(ks[4], cfg, policy),
                 "ln2": init_rms_norm(d, policy.param_dtype),
                 "mlp": mlp(ks[5])},
    }


def _init_rwkv_layer(key, cfg: ModelConfig, policy: DTypePolicy) -> Params:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "ln1": init_rms_norm(d, policy.param_dtype),
        "tm": rwkv_mod.init_time_mix(k1, cfg, policy),
        "ln2": init_rms_norm(d, policy.param_dtype),
        "cm": rwkv_mod.init_channel_mix(k2, cfg, policy),
    }


def init_model(key, cfg: ModelConfig,
               policy: DTypePolicy = DTypePolicy()) -> Params:
    ke, kl, kh, kt = jax.random.split(key, 4)
    d = cfg.d_model
    params: Params = {
        "embed": normal_init(ke, (cfg.vocab, d), 1.0, policy.param_dtype),
        "final_norm": init_rms_norm(d, policy.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(kh, (d, cfg.vocab), 1.0,
                                        policy.param_dtype)

    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        params["layers"] = stack_layer_params(
            lambda k: _init_dense_layer(k, cfg, policy), kl, cfg.n_layers)
    elif fam == "moe":
        if cfg.moe_every > 1:  # llama4: (dense, moe) groups
            n_groups = cfg.n_layers // cfg.moe_every
            def group(k):
                k1, k2 = jax.random.split(k)
                return {"dense": _init_dense_layer(k1, cfg, policy),
                        "moe": _init_moe_layer(k2, cfg, policy)}
            params["groups"] = stack_layer_params(group, kl, n_groups)
        else:                  # deepseek: leading dense + uniform moe
            n_moe = cfg.n_layers - cfg.first_dense
            if n_moe:
                params["moe_layers"] = stack_layer_params(
                    lambda k: _init_moe_layer(k, cfg, policy), kl, n_moe)
            if cfg.first_dense:
                params["dense_layers"] = stack_layer_params(
                    lambda k: _init_deepseek_dense(k, cfg, policy),
                    kt, cfg.first_dense)
    elif fam == "hybrid":
        pat = len(cfg.block_pattern)
        n_groups = cfg.n_layers // pat
        tail = cfg.n_layers - n_groups * pat
        params["groups"] = stack_layer_params(
            lambda k: _init_hybrid_group(k, cfg, policy), kl, n_groups)
        if tail:
            params["tail"] = stack_layer_params(
                lambda k: {"ln1": init_rms_norm(d, policy.param_dtype),
                           "block": rg_mod.init_rg_block(k, cfg, policy),
                           "ln2": init_rms_norm(d, policy.param_dtype),
                           "mlp": moe_mod.init_mlp(
                               jax.random.fold_in(k, 1), d, cfg.d_ff,
                               policy)},
                kt, tail)
    elif fam == "ssm":
        params["layers"] = stack_layer_params(
            lambda k: _init_rwkv_layer(k, cfg, policy), kl, cfg.n_layers)
    else:
        raise ValueError(f"unknown family {fam}")
    return params


# ---------------------------------------------------------------------------
# Forward (training / evaluation, full sequence)
# ---------------------------------------------------------------------------


def _dense_block(lp, x, positions, cfg, *, causal=True, window=None):
    h = rms_norm(x, lp["ln1"])
    x = x + attn.gqa_forward(lp["attn"], h, positions, cfg,
                             causal=causal, window=window)
    h = rms_norm(x, lp["ln2"])
    return x + moe_mod.mlp_forward(lp["mlp"], h)


def _moe_block(lp, x, positions, cfg):
    h = rms_norm(x, lp["ln1"])
    if cfg.use_mla:
        x = x + attn.mla_forward(lp["attn"], h, positions, cfg)
    else:
        x = x + attn.gqa_forward(lp["attn"], h, positions, cfg)
    h = rms_norm(x, lp["ln2"])
    y = moe_mod.moe_forward(lp["moe"], h, cfg)
    aux = moe_mod.moe_aux_loss(lp["moe"], h, cfg)
    return x + y, aux


def _deepseek_dense_block(lp, x, positions, cfg):
    h = rms_norm(x, lp["ln1"])
    x = x + attn.mla_forward(lp["attn"], h, positions, cfg)
    h = rms_norm(x, lp["ln2"])
    return x + moe_mod.mlp_forward(lp["mlp"], h)


def _rg_sub_block(lp, x, cfg, state=None):
    h = rms_norm(x, lp["ln1"])
    y, new_state = rg_mod.rg_block_forward(lp["block"], h, cfg, state)
    x = x + y
    h = rms_norm(x, lp["ln2"])
    return x + moe_mod.mlp_forward(lp["mlp"], h), new_state


def _rwkv_block(lp, x, cfg, state=None):
    tm_state = None if state is None else (state["tm_x"], state["wkv"])
    cm_state = None if state is None else state["cm_x"]
    h = rms_norm(x, lp["ln1"])
    y, (tm_x, wkv) = rwkv_mod.time_mix_forward(lp["tm"], h, cfg, tm_state)
    x = x + y
    h = rms_norm(x, lp["ln2"])
    y, cm_x = rwkv_mod.channel_mix_forward(lp["cm"], h, cm_state)
    return x + y, {"tm_x": tm_x, "wkv": wkv, "cm_x": cm_x}


def _embed(params, cfg, tokens, embeds):
    if tokens is not None:
        x = params["embed"][tokens]
        if embeds is not None:  # vlm: prefix patch embeddings
            x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    else:
        x = embeds
    return x


def _unembed(params, cfg, x):
    x = rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return jnp.einsum("bsd,dv->bsv", x, head)


def _wrap_body(body, remat: bool):
    """Constrain the residual carry at every layer boundary (Megatron-style
    seq sharding under the active policy) and optionally remat the layer."""
    from repro.distributed import sharding as shd

    def wrapped(carry, lp):
        out, extra = body(carry, lp)
        return shd.constrain_residual(out), extra

    if remat:
        wrapped = jax.checkpoint(
            wrapped, policy=jax.checkpoint_policies.nothing_saveable)
    return wrapped


def forward(params: Params, cfg: ModelConfig,
            tokens: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None,
            return_hidden: bool = False,
            remat: bool = False):
    """Full-sequence forward. Returns (logits, aux_loss)."""
    from repro.distributed import sharding as shd
    _set_unroll(cfg)
    x = _embed(params, cfg, tokens, embeds)
    x = shd.constrain_residual(x)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    aux = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam in ("dense", "vlm", "audio"):
        causal = not cfg.encoder_only
        def body(carry, lp):
            return _dense_block(lp, carry, positions, cfg,
                                causal=causal), None
        x, _ = maybe_scan(_wrap_body(body, remat), x, params["layers"])
    elif fam == "moe":
        if cfg.moe_every > 1:
            def body(carry, lp):
                y = _dense_block(lp["dense"], carry, positions, cfg)
                y, a = _moe_block(lp["moe"], y, positions, cfg)
                return y, a
            x, auxs = maybe_scan(_wrap_body(body, remat), x,
                                   params["groups"])
            aux = auxs.sum()
        else:
            if cfg.first_dense:
                def dbody(carry, lp):
                    return _deepseek_dense_block(lp, carry, positions,
                                                 cfg), None
                x, _ = maybe_scan(_wrap_body(dbody, remat), x,
                                    params["dense_layers"])
            if "moe_layers" in params:
                def body(carry, lp):
                    y, a = _moe_block(lp, carry, positions, cfg)
                    return y, a
                x, auxs = maybe_scan(_wrap_body(body, remat), x,
                                       params["moe_layers"])
                aux = auxs.sum()
    elif fam == "hybrid":
        def body(carry, lp):
            y, _ = _rg_sub_block(lp["rg1"], carry, cfg)
            y, _ = _rg_sub_block(lp["rg2"], y, cfg)
            h = rms_norm(y, lp["attn"]["ln1"])
            y = y + attn.gqa_forward(lp["attn"]["attn"], h, positions, cfg,
                                     causal=True, window=cfg.local_window)
            h = rms_norm(y, lp["attn"]["ln2"])
            y = y + moe_mod.mlp_forward(lp["attn"]["mlp"], h)
            return y, None
        x, _ = maybe_scan(_wrap_body(body, remat), x, params["groups"])
        if "tail" in params:
            def tbody(carry, lp):
                y, _ = _rg_sub_block(lp, carry, cfg)
                return y, None
            x, _ = maybe_scan(_wrap_body(tbody, remat), x, params["tail"])
    elif fam == "ssm":
        def body(carry, lp):
            y, _ = _rwkv_block(lp, carry, cfg)
            return y, None
        x, _ = maybe_scan(_wrap_body(body, remat), x, params["layers"])
    else:
        raise ValueError(fam)

    if return_hidden:
        return x, aux
    return _unembed(params, cfg, x), aux


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy — never materializes (B, S, V) logits)
# ---------------------------------------------------------------------------


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            loss_chunk: int = LOSS_CHUNK, remat: bool = False) -> jnp.ndarray:
    """batch: {"tokens": (B,S) int32, "labels": (B,S) int32, optional
    "embeds": (B,P,D)} — labels already shifted; label -100 is masked."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    hidden, aux = forward(params, cfg, tokens, embeds, return_hidden=True,
                          remat=remat)
    labels = batch["labels"]
    if embeds is not None and tokens is not None:
        hidden = hidden[:, embeds.shape[1]:]        # loss on text positions
    b, s, d = hidden.shape
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    norm_w = params["final_norm"]

    chunk = s if probe_mode() else min(loss_chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=-100)
    nc = hidden.shape[1] // chunk
    hidden = hidden.reshape(b, nc, chunk, d)
    labels = labels.reshape(b, nc, chunk)

    # checkpoint: backward recomputes each chunk's logits instead of
    # keeping the (B, chunk, V) slab per chunk alive across the map;
    # the final norm runs per-chunk for the same reason.
    @jax.checkpoint
    def chunk_loss(args):
        h, l = args
        h = rms_norm(h, norm_w)
        logits = jnp.einsum("bcd,dv->bcv", h, head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1)[..., 0]
        mask = (l >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    losses, counts = jax.lax.map(
        chunk_loss, (jnp.moveaxis(hidden, 1, 0), jnp.moveaxis(labels, 1, 0)))
    ce = jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1.0)
    return ce + MOE_AUX_WEIGHT * aux


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               policy: DTypePolicy = DTypePolicy()) -> Any:
    """Decode-state pytree sized for ``cache_len`` context."""
    dt = policy.compute_dtype
    fam = cfg.family

    def kv(n_layers):
        shape = (n_layers, batch, cache_len, cfg.n_kv_heads, cfg.d_head)
        return (jnp.zeros(shape, dt), jnp.zeros(shape, dt))

    if fam in ("dense", "vlm"):
        return {"kv": kv(cfg.n_layers)}
    if fam == "audio":
        raise ValueError("encoder-only architectures have no decode step")
    if fam == "moe":
        if cfg.moe_every > 1:
            n_groups = cfg.n_layers // cfg.moe_every
            return {"kv_dense": kv(n_groups), "kv_moe": kv(n_groups)}
        if cfg.use_mla:
            n_moe = cfg.n_layers - cfg.first_dense
            def lat(n):
                return (jnp.zeros((n, batch, cache_len, cfg.kv_lora_rank), dt),
                        jnp.zeros((n, batch, cache_len, cfg.qk_rope_head_dim),
                                  dt))
            out = {"latent": lat(n_moe)}
            if cfg.first_dense:
                out["latent_dense"] = lat(cfg.first_dense)
            return out
        return {"kv": kv(cfg.n_layers)}
    if fam == "hybrid":
        w = cfg.rg_lru_width or cfg.d_model
        pat = len(cfg.block_pattern)
        n_groups = cfg.n_layers // pat
        tail = cfg.n_layers - n_groups * pat
        win = min(cfg.local_window, cache_len)
        def rg_state(n):
            return {"conv": jnp.zeros((n, batch, cfg.rg_conv_width - 1, w), dt),
                    "h": jnp.zeros((n, batch, w), jnp.float32)}
        out = {
            "rg1": rg_state(n_groups), "rg2": rg_state(n_groups),
            "kv": (jnp.zeros((n_groups, batch, win, cfg.n_kv_heads,
                              cfg.d_head), dt),
                   jnp.zeros((n_groups, batch, win, cfg.n_kv_heads,
                              cfg.d_head), dt)),
        }
        if tail:
            out["tail"] = rg_state(tail)
        return out
    if fam == "ssm":
        h = rwkv_mod.n_heads(cfg)
        L = cfg.n_layers
        return {
            "wkv": jnp.zeros((L, batch, h, rwkv_mod.HEAD_DIM,
                              rwkv_mod.HEAD_DIM), jnp.float32),
            "tm_x": jnp.zeros((L, batch, cfg.d_model), dt),
            "cm_x": jnp.zeros((L, batch, cfg.d_model), dt),
        }
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Decode step (one new token against the cache)
# ---------------------------------------------------------------------------


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                cache: Any, length: jnp.ndarray):
    """token: (B,) int32; length: (B,) current context lengths.
    Returns (logits (B, V), new cache)."""
    _set_unroll(cfg)
    x = params["embed"][token][:, None]            # (B, 1, D)
    fam = cfg.family

    if fam in ("dense", "vlm") or (fam == "moe" and not cfg.use_mla
                                   and cfg.moe_every == 1):
        def body(carry, inp):
            lp, (ck, cv) = inp
            h = rms_norm(carry, lp["ln1"])
            y, (ck, cv) = attn.gqa_decode(lp["attn"], h, (ck, cv), length,
                                          cfg)
            carry = carry + y
            h = rms_norm(carry, lp["ln2"])
            carry = carry + moe_mod.mlp_forward(lp["mlp"], h)
            return carry, (ck, cv)
        x, new_kv = maybe_scan(body, x, (params["layers"], cache["kv"]))
        new_cache = {"kv": new_kv}
    elif fam == "moe" and cfg.moe_every > 1:       # llama4 groups
        def body(carry, inp):
            lp, (ckd, cvd), (ckm, cvm) = inp
            h = rms_norm(carry, lp["dense"]["ln1"])
            y, (ckd, cvd) = attn.gqa_decode(lp["dense"]["attn"], h,
                                            (ckd, cvd), length, cfg)
            carry = carry + y
            h = rms_norm(carry, lp["dense"]["ln2"])
            carry = carry + moe_mod.mlp_forward(lp["dense"]["mlp"], h)
            h = rms_norm(carry, lp["moe"]["ln1"])
            y, (ckm, cvm) = attn.gqa_decode(lp["moe"]["attn"], h,
                                            (ckm, cvm), length, cfg)
            carry = carry + y
            h = rms_norm(carry, lp["moe"]["ln2"])
            carry = carry + moe_mod.moe_forward(lp["moe"]["moe"], h, cfg, exact=True,
                                                  serving=True)
            return carry, ((ckd, cvd), (ckm, cvm))
        x, (nkd, nkm) = maybe_scan(
            body, x, (params["groups"], cache["kv_dense"], cache["kv_moe"]))
        new_cache = {"kv_dense": nkd, "kv_moe": nkm}
    elif fam == "moe" and cfg.use_mla:             # deepseek
        new_cache = {}
        if cfg.first_dense:
            def dbody(carry, inp):
                lp, lat = inp
                h = rms_norm(carry, lp["ln1"])
                y, lat = attn.mla_decode(lp["attn"], h, lat, length, cfg)
                carry = carry + y
                h = rms_norm(carry, lp["ln2"])
                carry = carry + moe_mod.mlp_forward(lp["mlp"], h)
                return carry, lat
            x, nl = maybe_scan(dbody, x, (params["dense_layers"],
                                            cache["latent_dense"]))
            new_cache["latent_dense"] = nl
        def body(carry, inp):
            lp, lat = inp
            h = rms_norm(carry, lp["ln1"])
            y, lat = attn.mla_decode(lp["attn"], h, lat, length, cfg)
            carry = carry + y
            h = rms_norm(carry, lp["ln2"])
            carry = carry + moe_mod.moe_forward(lp["moe"], h, cfg, exact=True,
                                                  serving=True)
            return carry, lat
        x, nl = maybe_scan(body, x, (params["moe_layers"],
                                       cache["latent"]))
        new_cache["latent"] = nl
    elif fam == "hybrid":
        win = cache["kv"][0].shape[2]
        def body(carry, inp):
            lp, rg1, rg2, (ck, cv) = inp
            carry, rg1 = _rg_decode(lp["rg1"], carry, cfg, rg1)
            carry, rg2 = _rg_decode(lp["rg2"], carry, cfg, rg2)
            h = rms_norm(carry, lp["attn"]["ln1"])
            # ring-buffer window cache: write at length % win
            y, (ck, cv) = _windowed_decode(lp["attn"]["attn"], h, (ck, cv),
                                           length, cfg, win)
            carry = carry + y
            h = rms_norm(carry, lp["attn"]["ln2"])
            carry = carry + moe_mod.mlp_forward(lp["attn"]["mlp"], h)
            return carry, (rg1, rg2, (ck, cv))
        x, (nrg1, nrg2, nkv) = maybe_scan(
            body, x, (params["groups"], cache["rg1"], cache["rg2"],
                      cache["kv"]))
        new_cache = {"rg1": nrg1, "rg2": nrg2, "kv": nkv}
        if "tail" in params:
            def tbody(carry, inp):
                lp, st = inp
                carry, st = _rg_decode(lp, carry, cfg, st)
                return carry, st
            x, nt = maybe_scan(tbody, x, (params["tail"], cache["tail"]))
            new_cache["tail"] = nt
    elif fam == "ssm":
        def body(carry, inp):
            lp, st = inp
            carry, st = _rwkv_decode(lp, carry, cfg, st)
            return carry, st
        x, nst = maybe_scan(
            body, x, (params["layers"],
                      {"tm_x": cache["tm_x"], "wkv": cache["wkv"],
                       "cm_x": cache["cm_x"]}))
        new_cache = nst
    else:
        raise ValueError(fam)

    logits = _unembed(params, cfg, x)[:, 0]
    return logits, new_cache


def _rg_decode(lp, x, cfg, state):
    st = (state["conv"], state["h"].astype(jnp.float32))
    y, (conv, h) = _rg_sub_block(lp, x, cfg, st)
    return y, {"conv": conv, "h": h}


def _rwkv_decode(lp, x, cfg, state):
    return _rwkv_block(lp, x, cfg, state)


def _windowed_decode(p, x1, cache, length, cfg, win):
    """Sliding-window decode with a ring-buffer cache of size ``win``:
    the new KV overwrites slot (length mod win); attention masks slots
    beyond min(length+1, win)."""
    b = x1.shape[0]
    kv_h, dh = cfg.n_kv_heads, cfg.d_head
    g = cfg.n_heads // kv_h
    q, k, v = attn._project_qkv(p, x1, cfg)
    pos = length.astype(jnp.int32)
    q = attn.apply_rope(q.reshape(b, 1, -1, dh), pos[:, None],
                        cfg.rope_theta).reshape(b, 1, kv_h, g, dh)
    k = attn.apply_rope(k, pos[:, None], cfg.rope_theta)
    ck, cv = cache
    slot = pos % win
    onehot = jax.nn.one_hot(slot, win, dtype=ck.dtype)
    ck = ck * (1 - onehot[..., None, None]) + onehot[..., None, None] * k
    cv = cv * (1 - onehot[..., None, None]) + onehot[..., None, None] * v
    valid = jnp.minimum(pos + 1, win)
    out = attn.decode_attention(q[:, 0], ck, cv, length=valid)
    out = out.reshape(b, 1, cfg.n_heads * dh)
    return jnp.einsum("bse,ed->bsd", out, p["wo"]), (ck, cv)


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            cache_len: int,
            policy: DTypePolicy = DTypePolicy()):
    """Run the full prompt, build the decode cache. Returns
    (last-position logits (B, V), cache, lengths (B,))."""
    b, s = tokens.shape
    _set_unroll(cfg)
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    fam = cfg.family

    if fam in ("dense", "vlm") or (fam == "moe" and not cfg.use_mla
                                   and cfg.moe_every == 1):
        def body(carry, lp):
            h = rms_norm(carry, lp["ln1"])
            y, kv = attn.gqa_prefill(lp["attn"], h, positions, cfg,
                                     cache_len)
            carry = carry + y
            h = rms_norm(carry, lp["ln2"])
            carry = carry + moe_mod.mlp_forward(lp["mlp"], h)
            return carry, kv
        x, kvs = maybe_scan(body, x, params["layers"])
        cache = {"kv": kvs}
    elif fam == "moe" and cfg.moe_every > 1:
        def body(carry, lp):
            h = rms_norm(carry, lp["dense"]["ln1"])
            y, kvd = attn.gqa_prefill(lp["dense"]["attn"], h, positions,
                                      cfg, cache_len)
            carry = carry + y
            h = rms_norm(carry, lp["dense"]["ln2"])
            carry = carry + moe_mod.mlp_forward(lp["dense"]["mlp"], h)
            h = rms_norm(carry, lp["moe"]["ln1"])
            y, kvm = attn.gqa_prefill(lp["moe"]["attn"], h, positions,
                                      cfg, cache_len)
            carry = carry + y
            h = rms_norm(carry, lp["moe"]["ln2"])
            carry = carry + moe_mod.moe_forward(lp["moe"]["moe"], h, cfg, exact=True)
            return carry, (kvd, kvm)
        x, (kvd, kvm) = maybe_scan(body, x, params["groups"])
        cache = {"kv_dense": kvd, "kv_moe": kvm}
    elif fam == "moe" and cfg.use_mla:
        cache = {}
        if cfg.first_dense:
            def dbody(carry, lp):
                h = rms_norm(carry, lp["ln1"])
                y, lat = attn.mla_prefill(lp["attn"], h, positions, cfg,
                                          cache_len)
                carry = carry + y
                h = rms_norm(carry, lp["ln2"])
                carry = carry + moe_mod.mlp_forward(lp["mlp"], h)
                return carry, lat
            x, lat = maybe_scan(dbody, x, params["dense_layers"])
            cache["latent_dense"] = lat
        def body(carry, lp):
            h = rms_norm(carry, lp["ln1"])
            y, lat = attn.mla_prefill(lp["attn"], h, positions, cfg,
                                      cache_len)
            carry = carry + y
            h = rms_norm(carry, lp["ln2"])
            carry = carry + moe_mod.moe_forward(lp["moe"], h, cfg, exact=True)
            return carry, lat
        x, lat = maybe_scan(body, x, params["moe_layers"])
        cache["latent"] = lat
    elif fam == "hybrid":
        win = min(cfg.local_window, cache_len)
        def body(carry, lp):
            carry, rg1 = _rg_sub_block(lp["rg1"], carry, cfg)
            carry, rg2 = _rg_sub_block(lp["rg2"], carry, cfg)
            h = rms_norm(carry, lp["attn"]["ln1"])
            y, kv = _windowed_prefill(lp["attn"]["attn"], h, positions,
                                      cfg, win)
            carry = carry + y
            h = rms_norm(carry, lp["attn"]["ln2"])
            carry = carry + moe_mod.mlp_forward(lp["attn"]["mlp"], h)
            return carry, (_rg_to_state(rg1), _rg_to_state(rg2), kv)
        x, (rg1, rg2, kvs) = maybe_scan(body, x, params["groups"])
        cache = {"rg1": rg1, "rg2": rg2, "kv": kvs}
        if "tail" in params:
            def tbody(carry, lp):
                carry, st = _rg_sub_block(lp, carry, cfg)
                return carry, _rg_to_state(st)
            x, tst = maybe_scan(tbody, x, params["tail"])
            cache["tail"] = tst
    elif fam == "ssm":
        def body(carry, lp):
            carry, st = _rwkv_block(lp, carry, cfg)
            return carry, st
        x, sts = maybe_scan(body, x, params["layers"])
        cache = sts
    else:
        raise ValueError(fam)

    logits = _unembed(params, cfg, x[:, -1:])[:, 0]
    lengths = jnp.full((b,), s, jnp.int32)
    return logits, cache, lengths


def _rg_to_state(st):
    conv, h = st
    return {"conv": conv, "h": h}


def _windowed_prefill(p, x, positions, cfg, win):
    """Forward with sliding-window attention; returns the ring-buffer cache
    holding the last ``win`` positions (aligned so slot = pos mod win)."""
    b, s, _ = x.shape
    q, k, v = attn._project_qkv(p, x, cfg)
    q = attn.apply_rope(q.reshape(b, s, -1, cfg.d_head), positions,
                        cfg.rope_theta).reshape(q.shape)
    k = attn.apply_rope(k, positions, cfg.rope_theta)
    out = attn.chunked_attention(q, k, v, causal=True, window=win)
    out = out.reshape(b, s, cfg.n_heads * cfg.d_head)
    y = jnp.einsum("bse,ed->bsd", out, p["wo"])
    # last `win` kv, placed at slots (pos mod win)
    last_k = k[:, -win:]
    last_v = v[:, -win:]
    pos = positions[:, -win:] % win
    ck = jnp.zeros((b, win) + k.shape[2:], k.dtype)
    cv = jnp.zeros((b, win) + v.shape[2:], v.dtype)
    bidx = jnp.arange(b)[:, None]
    ck = ck.at[bidx, pos].set(last_k)
    cv = cv.at[bidx, pos].set(last_v)
    return y, (ck, cv)
