"""Deterministic sharded synthetic-token pipeline.

Offline container: no corpus on disk, so the pipeline synthesizes a
deterministic pseudo-corpus — a counter-based PRNG stream (threefry over
(step, position)) mixed through a fixed n-gram transition sieve so the
stream has learnable low-order structure (loss decreases during the
example runs, which is how the end-to-end driver demonstrates learning).

Determinism contract: batch(step) depends only on (seed, step) — not on
worker count, restart point, or shard layout. That is what makes
checkpoint/restart and elastic rescaling exactly replayable: after a
restart at step k every host recomputes batch(k) identically and slices
out its own shard.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: int = 97     # n-gram sieve modulus (learnable structure)


class SyntheticTokenPipeline:
    """``pipeline.batch(step)`` -> {"tokens", "labels"} global arrays;
    ``pipeline.shard(step, host, n_hosts)`` -> this host's slice."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % 1:
            raise ValueError("global_batch must be integral")

    def _tokens(self, step: int) -> jnp.ndarray:
        c = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        base = jax.random.randint(
            key, (c.global_batch, c.seq_len + 1), 0, c.vocab, jnp.int32)
        # bigram sieve: every odd position is a deterministic function of
        # its (unmixed, even) predecessor -> observably learnable structure
        prev = jnp.roll(base, 1, axis=1)
        pos = jnp.arange(c.seq_len + 1)
        mixed = jnp.where(
            (pos % 2 == 1)[None, :],
            (prev * 31 + 7) % jnp.asarray(min(c.structure, c.vocab)),
            base % c.vocab,
        )
        return mixed.at[:, 0].set(base[:, 0])

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        toks = self._tokens(step)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def shard(self, step: int, host: int, n_hosts: int) -> Dict[str, jnp.ndarray]:
        b = self.batch(step)
        per = self.cfg.global_batch // n_hosts
        sl = slice(host * per, (host + 1) * per)
        return {k: v[sl] for k, v in b.items()}


def make_batch_specs(cfg, shape, *,
                     dtype=jnp.int32) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one global batch of the given shape
    cell — what the dry-run lowers against (no allocation).

    ``cfg`` is a ModelConfig (for frontend stubs), ``shape`` a ShapeCell.
    """
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "audio":
        specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.bfloat16)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), dtype)
    elif cfg.family == "vlm":
        p = cfg.frontend_prefix
        specs["embeds"] = jax.ShapeDtypeStruct((b, p, cfg.d_model),
                                               jnp.bfloat16)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s - p), dtype)
        specs["labels"] = jax.ShapeDtypeStruct((b, s - p), dtype)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), dtype)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), dtype)
    return specs
