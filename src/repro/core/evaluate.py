"""End-to-end PPAC + CFP evaluation of an HI system on a GEMM workload.

Implements the paper's system latency (Eq. 5), energy (Eqs. 12-14), area
(Sec IV-C), dollar cost (Eq. 15), CFP (Eqs. 2-3) and Perf-SI (Eq. 4) on
top of the tiler (Algorithm 1), the analytical systolic model, the
topology-aware D2D model, and the slicing floorplanner.

Modeling note (documented divergence): Sec IV-A's assumed dataflow routes
every chiplet's intermediate results to the *destination* chiplet, while
Sec IV-A's write model makes DRAM write-back split-K dependent. We honor
both: reduction-phase D2D traffic always flows to the destination —
32-bit partial sums when split-K is on (multiple per output region),
8-bit final outputs when off — and write-back is performed by the
destination alone iff split-K is on. This reproduces Fig. 5's non-zero,
topology-dependent D2D latency under x-x-0 mappings and Fig. 12's split-K
bandwidth asymmetry.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core import carbon as carbon_mod
from repro.core import cost as cost_mod
from repro.core import d2d as d2d_mod
from repro.core import scalesim as sim_mod
from repro.core.scalesim import OPERAND_BYTES, PSUM_BYTES, SimCache
from repro.core.system import HISystem
from repro.core.techdb import DEFAULT_DB, TechDB
from repro.core.workload import (
    DEFAULT_TILE,
    GEMMWorkload,
    tile_and_assign,
)


@dataclasses.dataclass(frozen=True)
class Metrics:
    """Everything the SA cost function (Eq. 17) and the analyses consume."""

    latency_s: float
    energy_j: float
    area_mm2: float
    dollar: float
    emb_cfp_kg: float
    ope_cfp_kg: float
    # components, for the figure-level analyses
    l_compute_rd_s: float
    l_d2d_s: float
    l_dram_wr_s: float
    e_compute_j: float
    e_d2d_j: float
    d2d_bits: int
    macs: int

    @property
    def total_cfp(self) -> float:
        return self.emb_cfp_kg + self.ope_cfp_kg

    @property
    def perf_si(self) -> float:
        return carbon_mod.perf_si(self.latency_s, self.total_cfp)


def package_area_mm2(sys: HISystem, topo: d2d_mod.Topology,
                     db: TechDB = DEFAULT_DB) -> float:
    """Area model (Sec IV-C): die area for 2D, base-die area for 3D,
    floorplan bounding box (with white space) for 2.5D / hybrid."""
    if sys.style == "2D":
        return sys.chiplets[0].area_mm2(db)
    if sys.style == "3D":
        assert topo.base_die is not None
        return sys.chiplets[topo.base_die].area_mm2(db)
    assert topo.floorplan is not None
    return topo.floorplan.bbox_area


def evaluate(
    sys: HISystem,
    wl: GEMMWorkload,
    db: TechDB = DEFAULT_DB,
    tile_sizes: Tuple[int, int, int] = DEFAULT_TILE,
    cache: Optional[SimCache] = None,
) -> Metrics:
    cache = cache if cache is not None else SimCache()
    assignments = tile_and_assign(wl, sys.chiplets, sys.mapping, tile_sizes, db)
    topo = d2d_mod.build_topology(sys, db)
    mem = db.memories[sys.memory]

    # -- per-chiplet simulation (cached, Sec V-D) ---------------------------
    sims = [
        cache.simulate(a.tiles, a.core, sys.mapping.dataflow)
        for a in assignments
    ]

    # -- Eq. 5 term 1: max_i (L_compute,i + L_DRAM_RD,i) --------------------
    l_cr = 0.0
    for i, (a, s) in enumerate(zip(assignments, sims)):
        l_comp = sim_mod.compute_latency_s(s, a.core, db)
        bw = topo.effective_dram_bw(i)
        l_rd = s.dram_rd_bits / bw if s.dram_rd_bits else 0.0
        l_cr = max(l_cr, l_comp + l_rd)

    # -- Eq. 5 term 2: reduction-phase D2D ----------------------------------
    src_bits = []
    for i, a in enumerate(assignments):
        if i == topo.dest:
            src_bits.append(0)
            continue
        bits = 0
        for t in a.tiles:
            width = PSUM_BYTES if t.partial else OPERAND_BYTES
            bits += t.m * t.n * width * 8
        src_bits.append(bits)
    d2d = d2d_mod.route_reduction(topo, src_bits)

    # -- Eq. 5 term 3: DRAM write-back (split-K dependent) ------------------
    if sys.mapping.split_k:
        # destination reduces the partials, requantizes, writes once
        wr_bits = wl.M * wl.N * OPERAND_BYTES * 8
        l_wr = wr_bits / topo.effective_dram_bw(topo.dest)
    else:
        l_wr = 0.0
        for i, s in enumerate(sims):
            if s.dram_wr_bits:
                l_wr = max(l_wr, s.dram_wr_bits / topo.effective_dram_bw(i))

    latency = l_cr + d2d.latency_s + l_wr

    # -- energy (Eqs. 12-14) ------------------------------------------------
    e_compute = 0.0
    e_mem_d2d_pj = 0.0
    for i, (a, s) in enumerate(zip(assignments, sims)):
        node = a.core.node
        e_compute += s.dram_rd_bits * mem.energy_pj_bit_rd
        e_compute += s.dram_wr_bits * mem.energy_pj_bit_wr
        e_compute += s.sram_bits * db.sram_energy_pj_bit(node)
        e_compute += s.macs * db.mac_energy_pj(node)
        # compute-memory D2D (3D stacks route DRAM traffic via the base die)
        e_mem_d2d_pj += ((s.dram_rd_bits + s.dram_wr_bits)
                         * topo.dram_path_energy_pj_bit(i))
    e_d2d_pj = d2d.energy_pj + e_mem_d2d_pj
    e_compute_j = e_compute * 1e-12
    e_d2d_j = e_d2d_pj * 1e-12
    # static power burns for the whole system latency — this is the term
    # through which faster execution lowers energy and operational CFP.
    e_static_j = sum(c.static_power_w(db) for c in sys.chiplets) * latency
    energy = e_compute_j + e_d2d_j + e_static_j

    # -- area, cost, carbon ---------------------------------------------------
    # Regional axes (all default-neutral, see repro.core.carbon): the
    # lifetime electricity bill joins the dollar metric (price 0.0 ->
    # +0.0), the regional fab-grid factor scales embodied carbon
    # (factor 1.0 -> x1.0), and operational CFP dots the 24h grid
    # profile with the load profile (flat -> scalar, bit-identical).
    area = package_area_mm2(sys, topo, db)
    cost = cost_mod.system_cost(sys, area, db)
    # Encoded schedule (repro.core.schedule): a (start, shape) design
    # axis overrides the fixed db.load_profile duty weighting for the
    # operational terms. None keeps the legacy path verbatim; the
    # neutral (0, 0) schedule decodes to db.load_profile's own values,
    # so it is bit-identical too.
    if sys.schedule is not None:
        from repro.core.schedule import schedule_load_row
        load = schedule_load_row(sys.schedule, db)
    else:
        load = None
    dollar = cost.total + carbon_mod.operational_cost_usd(energy, db,
                                                          load=load)
    emb = carbon_mod.embodied_cfp(sys, area, db)
    ope = carbon_mod.operational_cfp(energy, latency, db, per_unit=True,
                                     load=load)

    return Metrics(
        latency_s=latency,
        energy_j=energy,
        area_mm2=area,
        dollar=dollar,
        emb_cfp_kg=emb.total * db.emb_factor,
        ope_cfp_kg=ope,
        l_compute_rd_s=l_cr,
        l_d2d_s=d2d.latency_s,
        l_dram_wr_s=l_wr,
        e_compute_j=e_compute_j,
        e_d2d_j=e_d2d_j,
        d2d_bits=d2d.total_bits,
        macs=sum(s.macs for s in sims),
    )
