"""CarbonPATH core: the paper's models and optimization engine.

Public surface:
    TechDB / DEFAULT_DB            technology knobs (Tables II-III + cited data)
    Chiplet / library              the chiplet library (A-T-S notation)
    GEMMWorkload / WORKLOADS       Table IV workloads
    Mapping / tile_and_assign      Algorithm 1
    HISystem / validate            solution vectors + feasibility rules
    evaluate / Metrics             PPAC + CFP evaluation (Eqs. 2-17)
    anneal / SAConfig / Template   the SA engine and T1-T4 templates
    evaluate_chipletgym            the ChipletGym-style baseline flow

Exploration entry point: :mod:`repro.pathfinding` (Pathfinder API v2) —
encoded design space, batched evaluation, pluggable search strategies.
``anneal`` remains as a deprecation shim over it.
"""
from repro.core.chiplet import (
    Chiplet,
    different_chiplet_system,
    identical_chiplet_system,
    library,
)
from repro.core.chipletgym import evaluate_chipletgym
from repro.core.evaluate import Metrics, evaluate
from repro.core.sa import SAConfig, SAResult, anneal, fit_normalizer, random_system
from repro.core.scalesim import SimCache
from repro.core.system import HISystem, InvalidSystem, is_valid, validate
from repro.core.techdb import DEFAULT_DB, TechDB, all_pkg_protocol_pairs
from repro.core.templates import TEMPLATES, Normalizer, Template, sa_cost
from repro.core.workload import (
    ALL_MAPPINGS,
    GEMMWorkload,
    Mapping,
    WORKLOADS,
    tile_and_assign,
    workload,
)

__all__ = [
    "Chiplet", "library", "identical_chiplet_system", "different_chiplet_system",
    "evaluate_chipletgym", "Metrics", "evaluate", "SAConfig", "SAResult",
    "anneal", "fit_normalizer", "random_system", "SimCache", "HISystem",
    "InvalidSystem", "is_valid", "validate", "DEFAULT_DB", "TechDB",
    "all_pkg_protocol_pairs", "TEMPLATES", "Normalizer", "Template", "sa_cost",
    "ALL_MAPPINGS", "GEMMWorkload", "Mapping", "WORKLOADS", "tile_and_assign",
    "workload",
]
