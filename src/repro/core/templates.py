"""Optimization templates (Table V) and the SA cost function (Eq. 17).

SA-Cost = alpha*E + beta*A + gamma*L + theta*M + zeta*C_emb + eta*C_ope,
with each metric min-median normalized over a population of random valid
systems (Sec V-C) so no single term dominates.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence

import numpy as np

from repro.core.evaluate import Metrics

METRIC_FIELDS = ("energy_j", "area_mm2", "latency_s", "dollar",
                 "emb_cfp_kg", "ope_cfp_kg")


@dataclasses.dataclass(frozen=True)
class Template:
    name: str
    alpha: float   # energy
    beta: float    # area
    gamma: float   # latency
    theta: float   # dollar cost
    zeta: float    # embodied CFP
    eta: float     # operational CFP

    @property
    def weights(self):
        return (self.alpha, self.beta, self.gamma,
                self.theta, self.zeta, self.eta)

    def without_carbon(self) -> "Template":
        """The *CarbonPATH w/o carbon* ablation: zeta = eta = 0."""
        return Template(self.name + "-noC", self.alpha, self.beta,
                        self.gamma, self.theta, 0.0, 0.0)


TEMPLATES: Mapping[str, Template] = {
    "T1": Template("T1", 1.0, 1.0, 1.0, 1.0, 1.0, 1.0),
    "T2": Template("T2", 0.8, 0.2, 0.1, 0.1, 0.2, 0.7),
    "T3": Template("T3", 0.1, 0.1, 0.7, 0.7, 0.1, 0.1),
    "T4": Template("T4", 0.6, 0.6, 0.1, 0.1, 0.6, 0.6),
}


@dataclasses.dataclass(frozen=True)
class Normalizer:
    """Min/median normalization fitted on a random-valid-system population
    (the paper uses 10,000 samples): x -> (x - min) / median."""

    mins: Dict[str, float]
    medians: Dict[str, float]

    @classmethod
    def fit(cls, population: Sequence[Metrics]) -> "Normalizer":
        mins: Dict[str, float] = {}
        medians: Dict[str, float] = {}
        for f in METRIC_FIELDS:
            vals = sorted(getattr(m, f) for m in population)
            mins[f] = vals[0]
            medians[f] = _positive_median(vals)
        return cls(mins, medians)

    @classmethod
    def fit_arrays(cls, fields: Mapping[str, "np.ndarray"]) -> "Normalizer":
        """Fit from struct-of-arrays metrics (one array per METRIC_FIELDS
        entry), e.g. a :class:`repro.pathfinding.MetricsBatch`."""
        mins: Dict[str, float] = {}
        medians: Dict[str, float] = {}
        for f in METRIC_FIELDS:
            vals = np.asarray(fields[f], dtype=np.float64)
            mins[f] = float(vals.min())
            medians[f] = _positive_median(sorted(vals.tolist()))
        return cls(mins, medians)

    def normalize(self, m: Metrics) -> Dict[str, float]:
        return {
            f: (getattr(m, f) - self.mins[f]) / self.medians[f]
            for f in METRIC_FIELDS
        }

    def weights_arrays(self):
        """(mins, medians) as float64 vectors in METRIC_FIELDS order, for
        batched cost evaluation."""
        return (np.array([self.mins[f] for f in METRIC_FIELDS]),
                np.array([self.medians[f] for f in METRIC_FIELDS]))


def _positive_median(sorted_vals: Sequence[float]) -> float:
    """True median of a pre-sorted sequence (midpoint average for even
    lengths), floored to 1.0 when non-positive so it can divide."""
    n = len(sorted_vals)
    if n % 2:
        mid = sorted_vals[n // 2]
    else:
        mid = 0.5 * (sorted_vals[n // 2 - 1] + sorted_vals[n // 2])
    return mid if mid > 0 else 1.0


IDENTITY_NORMALIZER = Normalizer(
    {f: 0.0 for f in METRIC_FIELDS}, {f: 1.0 for f in METRIC_FIELDS})


def sa_cost(m: Metrics, t: Template,
            norm: Normalizer = IDENTITY_NORMALIZER) -> float:
    """Eq. 17 on normalized metrics."""
    x = norm.normalize(m)
    w = t.weights
    return (w[0] * x["energy_j"] + w[1] * x["area_mm2"]
            + w[2] * x["latency_s"] + w[3] * x["dollar"]
            + w[4] * x["emb_cfp_kg"] + w[5] * x["ope_cfp_kg"])
