"""Dollar-cost model (Sec IV-D, Eqs. 15-16).

M_system = (sum_i M_chiplet_i + M_interposer + M_pkg) / Y_bonding + M_mem

Chiplet cost = wafer cost / dies-per-wafer / die yield (negative binomial).
Interposer cost applies only to active/passive 2.5D interposers and is
modeled as a 65nm silicon die of the floorplanned package area. Bonding
yield compounds per bonding event and depends on the interconnect type.
"""
from __future__ import annotations

import dataclasses

from repro.core.chiplet import Chiplet
from repro.core.system import HISystem
from repro.core.techdb import DEFAULT_DB, TechDB


def chiplet_cost(ch: Chiplet, db: TechDB = DEFAULT_DB) -> float:
    """Eq. 16."""
    area = ch.area_mm2(db)
    wafer = db.node_wafer_cost[ch.node]
    dpw = db.dies_per_wafer(area)
    y = db.die_yield(area, ch.node)
    return wafer / dpw / y


def bonding_yield(sys: HISystem, db: TechDB = DEFAULT_DB) -> float:
    """Compound bonding yield over all assembly events. 2.5D placements
    each incur one attach; a 3D stack incurs one bond per tier interface."""
    if sys.style == "2D":
        return 1.0
    y = 1.0
    if sys.style in ("2.5D", "2.5D+3D"):
        pkg = db.packages[sys.pkg_25d]
        n_attach = len(sys.planar_indices())
        if sys.style == "2.5D+3D":
            n_attach += 1  # the stack's base die is one planar attach
        y *= pkg.bonding_yield ** n_attach
    if sys.style in ("3D", "2.5D+3D"):
        pkg = db.packages[sys.pkg_3d]
        n_bonds = (len(sys.stack) if sys.style == "2.5D+3D"
                   else sys.n_chiplets) - 1
        y *= pkg.bonding_yield ** max(0, n_bonds)
    return y


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    chiplets: float
    interposer: float
    package: float
    memory: float
    bonding_yield: float

    @property
    def total(self) -> float:
        return ((self.chiplets + self.interposer + self.package)
                / self.bonding_yield + self.memory)


def interposer_cost(area_mm2: float, db: TechDB = DEFAULT_DB) -> float:
    """65nm silicon interposer die of the packaged area [3], [45]."""
    dpw = db.dies_per_wafer(area_mm2)
    y = db.interposer_yield(area_mm2)
    return db.interposer_wafer_cost / dpw / y


def system_cost(sys: HISystem, package_area_mm2: float,
                db: TechDB = DEFAULT_DB) -> CostBreakdown:
    """Eq. 15. ``package_area_mm2`` comes from the area model (floorplan
    bbox for 2.5D/hybrid, base-die area for 3D, die area for 2D)."""
    chiplets = sum(chiplet_cost(c, db) for c in sys.chiplets)
    interposer = 0.0
    if sys.style in ("2.5D", "2.5D+3D") and sys.pkg_25d in ("Passive", "Active"):
        interposer = interposer_cost(package_area_mm2, db)
    # assembly: one attach/bond event per chiplet, priced by interconnect
    assembly = 0.0
    if sys.style == "2D":
        assembly = db.assembly_cost
    if sys.style in ("2.5D", "2.5D+3D"):
        n_planar = len(sys.planar_indices())
        if sys.style == "2.5D+3D":
            n_planar += 1  # the stack base is one planar attach
        assembly += (n_planar * db.assembly_cost
                     * db.packages[sys.pkg_25d].cost_scale)
    if sys.style in ("3D", "2.5D+3D"):
        n_stack = len(sys.stack) if sys.style == "2.5D+3D" else sys.n_chiplets
        assembly += (n_stack * db.assembly_cost
                     * db.packages[sys.pkg_3d].cost_scale)
    package = db.substrate_cost_mm2 * package_area_mm2 + assembly
    memory = db.memories[sys.memory].cost_usd
    return CostBreakdown(chiplets, interposer, package, memory,
                         bonding_yield(sys, db))
