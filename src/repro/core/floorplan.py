"""Bipartitioning slicing floorplanner for 2.5D / 2.5D+3D packages.

Recursively splits the chiplet set into two area-balanced halves with
alternating vertical/horizontal cuts (Sec IV-C, after [3], [43]); the
recursion bottoms out at single chiplets, which are shaped as squares.
Outputs placed rectangles, the package bounding box (with white space),
and the adjacency graph used by the D2D topology model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Set, Tuple


@dataclasses.dataclass
class Rect:
    """A slot of the slicing tree. The slot tiles the package exactly (so
    slot adjacency == interconnect adjacency); ``die_area`` is the true
    silicon area inside the slot, the difference is white space."""

    x: float
    y: float
    w: float
    h: float
    idx: int = -1       # chiplet index; -1 for internal nodes
    die_area: float = 0.0

    @property
    def area(self) -> float:
        return self.w * self.h

    def edge_shared(self, other: "Rect", tol: float = 1e-9) -> float:
        """Length of shared boundary between two rects (0 if not adjacent)."""
        # vertical adjacency (share an x-edge)
        if abs(self.x + self.w - other.x) < tol or abs(other.x + other.w - self.x) < tol:
            lo = max(self.y, other.y)
            hi = min(self.y + self.h, other.y + other.h)
            return max(0.0, hi - lo)
        # horizontal adjacency (share a y-edge)
        if abs(self.y + self.h - other.y) < tol or abs(other.y + other.h - self.y) < tol:
            lo = max(self.x, other.x)
            hi = min(self.x + self.w, other.x + other.w)
            return max(0.0, hi - lo)
        return 0.0


@dataclasses.dataclass
class Floorplan:
    rects: List[Rect]              # one per chiplet, in input order
    width: float
    height: float

    @property
    def bbox_area(self) -> float:
        return self.width * self.height

    @property
    def die_area(self) -> float:
        return sum(r.die_area for r in self.rects)

    @property
    def white_space(self) -> float:
        return self.bbox_area - self.die_area

    def adjacency(self) -> Dict[int, Set[int]]:
        adj: Dict[int, Set[int]] = {r.idx: set() for r in self.rects}
        for i, a in enumerate(self.rects):
            for b in self.rects[i + 1:]:
                if a.edge_shared(b) > 1e-9:
                    adj[a.idx].add(b.idx)
                    adj[b.idx].add(a.idx)
        return adj


def _balanced_bipartition(areas: Sequence[Tuple[int, float]]):
    """Greedy balanced split of (index, area) items into two halves."""
    ordered = sorted(areas, key=lambda t: t[1], reverse=True)
    left: List[Tuple[int, float]] = []
    right: List[Tuple[int, float]] = []
    al = ar = 0.0
    for item in ordered:
        if al <= ar:
            left.append(item)
            al += item[1]
        else:
            right.append(item)
            ar += item[1]
    return left, right, al, ar


def _place(items, x, y, w, h, vertical, out):
    """Recursively place ``items`` (list of (idx, area)) inside the box."""
    if len(items) == 1:
        idx, area = items[0]
        # the chiplet owns the whole slot; slots tile the package exactly,
        # so slot adjacency below is the link topology. Slot area >= die
        # area; the surplus is white space.
        out[idx] = Rect(x, y, w, h, idx, die_area=area)
        return
    left, right, al, ar = _balanced_bipartition(items)
    frac = al / (al + ar)
    if vertical:   # vertical cut -> split width
        wl = w * frac
        _place(left, x, y, wl, h, not vertical, out)
        _place(right, x + wl, y, w - wl, h, not vertical, out)
    else:          # horizontal cut -> split height
        hl = h * frac
        _place(left, x, y, w, hl, not vertical, out)
        _place(right, x, y + hl, w, h - hl, not vertical, out)


def floorplan(areas: Sequence[float], whitespace_frac: float = 0.10) -> Floorplan:
    """Slicing floorplan of chiplets with the given areas (mm^2).

    The bounding box is sized to total area * (1 + whitespace_frac) with a
    square aspect ratio; recursive bipartition assigns each chiplet a slot.
    """
    if not areas:
        raise ValueError("empty chiplet set")
    total = sum(areas) * (1.0 + whitespace_frac)
    side = math.sqrt(total)
    out: Dict[int, Rect] = {}
    _place(list(enumerate(areas)), 0.0, 0.0, side, side, True, out)
    rects = [out[i] for i in range(len(areas))]
    # bbox from actual placements (slots may underfill)
    width = max(r.x + r.w for r in rects)
    height = max(r.y + r.h for r in rects)
    return Floorplan(rects, width, height)


def chain_adjacency(n: int) -> Dict[int, Set[int]]:
    """Adjacency of a vertical 3D stack: tier i touches i-1 and i+1."""
    adj: Dict[int, Set[int]] = {i: set() for i in range(n)}
    for i in range(n - 1):
        adj[i].add(i + 1)
        adj[i + 1].add(i)
    return adj
