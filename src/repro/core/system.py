"""HI-system configuration vector + feasibility rules (Sec V-A).

An :class:`HISystem` is one candidate solution of the search engine: the
chiplet multiset, integration style, package interconnect(s), protocol(s),
system memory, and the workload mapping triple. ``validate`` enforces the
paper's feasibility rules; every SA move goes through it.

For population-scale work, systems have a canonical fixed-width ``int32``
encoding — see :class:`repro.pathfinding.DesignSpace`, whose
``validity_mask`` is the vectorized rendering of :func:`validate` and
whose ``encode``/``decode`` round-trip exactly over valid systems.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.chiplet import Chiplet
from repro.core.techdb import (
    DEFAULT_DB,
    PKG_PROTOCOLS_25D,
    PKG_PROTOCOLS_3D,
    TechDB,
)
from repro.core.workload import Mapping


class InvalidSystem(ValueError):
    """Raised when a configuration violates a feasibility rule."""


@dataclasses.dataclass(frozen=True)
class HISystem:
    chiplets: Tuple[Chiplet, ...]
    style: str                       # 2D | 2.5D | 3D | 2.5D+3D
    memory: str                      # DDR4 | DDR5 | HBM2 | HBM3
    mapping: Mapping
    pkg_25d: Optional[str] = None    # RDL | EMIB | Passive | Active
    proto_25d: Optional[str] = None  # UCIe-S | UCIe-A | AIB | BoW
    pkg_3d: Optional[str] = None     # TSV | uBump | HybBond
    proto_3d: Optional[str] = None   # UCIe-3D
    # Indices of chiplets in the 3D stack (hybrid only; 3D uses all).
    stack: Tuple[int, ...] = ()
    # mesh_noc comm model (repro.core.comm): per-chiplet
    # (mesh_dims_idx, entry_placement_idx) pairs. Empty = legacy pairwise
    # links; (0, 0) per chiplet is the bit-neutral single-tile mesh.
    noc: Tuple[Tuple[int, int], ...] = ()
    # window schedule model (repro.core.schedule): one per-design
    # (start_hour, shape_idx) pair. None = fixed db.load_profile duty
    # weighting; (0, 0) is the bit-neutral always-on schedule.
    schedule: Optional[Tuple[int, int]] = None

    @property
    def n_chiplets(self) -> int:
        return len(self.chiplets)

    def describe(self) -> str:
        """Paper's I-P-M notation."""
        if self.style == "2D":
            return f"2D-NA-{self.memory}"
        if self.style == "2.5D":
            return f"2.5D-{self.pkg_25d}-{self.memory}"
        if self.style == "3D":
            return f"3D-{self.pkg_3d}-{self.memory}"
        return f"2.5D-{self.pkg_25d}-3D-{self.pkg_3d}-{self.memory}"

    # -- canonical 3D stack order: non-increasing area from the base up ----

    def stack_order(self, db: TechDB = DEFAULT_DB) -> Tuple[int, ...]:
        """Chiplet indices ordered base-first (largest area at the bottom)."""
        idx = self.stack if self.style == "2.5D+3D" else tuple(
            range(self.n_chiplets))
        return tuple(sorted(idx, key=lambda i: -self.chiplets[i].area_mm2(db)))

    def planar_indices(self) -> Tuple[int, ...]:
        """Chiplets placed side-by-side in the 2.5D plane. For hybrid
        systems the stack occupies one planar slot (its base die)."""
        if self.style in ("2D", "3D"):
            return ()
        if self.style == "2.5D":
            return tuple(range(self.n_chiplets))
        return tuple(i for i in range(self.n_chiplets) if i not in self.stack)


def validate(sys: HISystem, db: TechDB = DEFAULT_DB,
             max_chiplets: int = 6) -> None:
    """Feasibility checks (Sec V-A). Raises :class:`InvalidSystem`."""
    n = sys.n_chiplets
    if n < 1 or n > max_chiplets:
        raise InvalidSystem(f"chiplet count {n} outside [1, {max_chiplets}]")
    if sys.memory not in db.memories:
        raise InvalidSystem(f"unknown memory {sys.memory}")
    if sys.mapping.dataflow not in ("OS", "WS", "IS"):
        raise InvalidSystem(f"bad dataflow {sys.mapping.dataflow}")
    for c in sys.chiplets:
        if c.node not in db.tech_nodes or c.array not in db.array_sizes:
            raise InvalidSystem(f"chiplet {c.name} outside library")
        if c.sram_kb not in db.sram_sizes_kb[c.array]:
            raise InvalidSystem(f"chiplet {c.name} SRAM not in library")
    if sys.noc:
        from repro.core.comm import validate_noc
        try:
            validate_noc(sys.noc, n)
        except ValueError as e:
            raise InvalidSystem(f"bad noc assignment: {e}") from e
    if sys.schedule is not None:
        from repro.core.schedule import validate_schedule
        try:
            validate_schedule(sys.schedule)
        except ValueError as e:
            raise InvalidSystem(f"bad schedule: {e}") from e

    if sys.style == "2D":
        if n != 1:
            raise InvalidSystem("2D (monolithic) requires exactly 1 chiplet")
        if sys.pkg_25d or sys.pkg_3d:
            raise InvalidSystem("2D carries no package interconnect")
        return

    if n < 2:
        raise InvalidSystem(f"{sys.style} requires >= 2 chiplets")

    if sys.style == "2.5D":
        _check_25d(sys)
        if sys.pkg_3d or sys.proto_3d or sys.stack:
            raise InvalidSystem("2.5D system carries 3D fields")
    elif sys.style == "3D":
        _check_3d(sys)
        if sys.pkg_25d or sys.proto_25d:
            raise InvalidSystem("3D system carries 2.5D fields")
    elif sys.style == "2.5D+3D":
        if n < 3:
            raise InvalidSystem(
                "2.5D+3D misclassification: needs >= 3 chiplets")
        _check_25d(sys)
        _check_3d(sys)
        if len(sys.stack) < 2:
            raise InvalidSystem("hybrid stack needs >= 2 chiplets")
        if len(sys.stack) >= n:
            raise InvalidSystem("hybrid needs >= 1 planar (non-stack) chiplet")
        if len(set(sys.stack)) != len(sys.stack) or any(
                i < 0 or i >= n for i in sys.stack):
            raise InvalidSystem("bad stack indices")
    else:
        raise InvalidSystem(f"unknown integration style {sys.style}")


def _check_25d(sys: HISystem) -> None:
    protos = PKG_PROTOCOLS_25D.get(sys.pkg_25d or "")
    if protos is None:
        raise InvalidSystem(f"unknown 2.5D interconnect {sys.pkg_25d}")
    if sys.proto_25d not in protos:
        raise InvalidSystem(
            f"protocol {sys.proto_25d} incompatible with {sys.pkg_25d}")


def _check_3d(sys: HISystem) -> None:
    protos = PKG_PROTOCOLS_3D.get(sys.pkg_3d or "")
    if protos is None:
        raise InvalidSystem(f"unknown 3D interconnect {sys.pkg_3d}")
    if sys.proto_3d not in protos:
        raise InvalidSystem(
            f"protocol {sys.proto_3d} incompatible with {sys.pkg_3d}")


def is_valid(sys: HISystem, db: TechDB = DEFAULT_DB,
             max_chiplets: int = 6) -> bool:
    try:
        validate(sys, db, max_chiplets)
        return True
    except InvalidSystem:
        return False


def style_for_count(n: int, prefer: str) -> str:
    """Dynamic HI-type adjustment when a chiplet-count move invalidates the
    current style (Sec V-B, chip-architecture moves)."""
    if n == 1:
        return "2D"
    if n == 2 and prefer == "2.5D+3D":
        return "3D"
    if n >= 2 and prefer == "2D":
        return "2.5D"
    return prefer
