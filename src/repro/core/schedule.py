"""Carbon-aware temporal scheduling: "when to run" as an encoded axis.

PR 8 made the 24h grid-intensity profile a runtime column of the fused
program, but the *load* weighting stayed one global, static
``TechDB.load_profile``. This module is the schedule seam — the temporal
twin of ``repro.core.comm``:

* ``fixed``  — the load profile is ``db.load_profile``, a per-db
  constant. The bit-pinned default; every golden was recorded under it.
* ``window`` — each design carries two extra int32 axes: a start-hour
  offset (0..23) and a duty-window *shape* index into the small
  :data:`SCHEDULE_SHAPES` table. The decoded load profile is the shape
  row rolled to the start hour — pure gather arithmetic over trace-time
  constant tables, so schedules are *data*, not shapes, and a whole
  region x workload grid stays ONE fused compile (the ``MESH_DIMS``
  pattern of PR 9).

Shape rows are 24h duty weights summing to exactly 1: the deployment
model keeps total lifetime work fixed (``duty_runs_per_s`` over the
active fraction), so a schedule only moves *when* the energy is drawn,
never how much. Concentrating the same kWh into low-intensity (or
low-price) hours is therefore the Carbon Connect temporal-shifting
lever, co-designed with architecture/mapping/packaging by the search.

Neutrality. ``SCHED_NEUTRAL == (0, 0)`` is the exact neutral element:
:func:`schedule_tables` *replaces* row 0 with ``db.load_profile``, so
the neutral gather reproduces the per-db load values bit-for-bit and
every windowed term reduces to the legacy arithmetic — which is what
lets the forced-on CI lane (``REPRO_SCHEDULE=window``) replay all
legacy goldens through the windowed program.
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.techdb import HOURS_PER_DAY, TechDB, DEFAULT_DB

SCHEDULE_MODELS: Tuple[str, ...] = ("fixed", "window")
DEFAULT_SCHEDULE = "fixed"
# Forces default-constructed DesignSpaces onto the windowed encoding with
# the schedule axes *frozen at neutral* — the CI lane proving the windowed
# program is bit-invisible. Explicit ``DesignSpace(schedule="window")``
# makes the axes live instead.
SCHEDULE_ENV_VAR = "REPRO_SCHEDULE"

# Searchable duty-window shapes. Index 0 is the neutral element — the
# per-db ``load_profile`` itself (see ``schedule_tables``) — so a (0, 0)
# schedule is the bit-exact fixed-schedule limit. Shapes 1+ are
# contiguous always-on windows of W hours (weight 1/W inside, 0 outside,
# anchored at hour 0 before the start-hour roll), summing to exactly 1.
SCHEDULE_WINDOW_HOURS: Tuple[int, ...] = (16, 12, 8, 6, 4)
SCHED_NEUTRAL: Tuple[int, int] = (0, 0)


def resolve_schedule(schedule: Optional[str] = None) -> str:
    """Resolve a schedule-model name; ``None`` consults ``REPRO_SCHEDULE``."""
    if schedule is None:
        schedule = os.environ.get(SCHEDULE_ENV_VAR, "") or DEFAULT_SCHEDULE
    if schedule not in SCHEDULE_MODELS:
        raise ValueError(
            f"unknown schedule model {schedule!r}; "
            f"expected one of {SCHEDULE_MODELS}")
    return schedule


def n_schedule_shapes() -> int:
    """Number of rows in the shape table (neutral row 0 included)."""
    return 1 + len(SCHEDULE_WINDOW_HOURS)


def window_row(hours: int) -> Tuple[float, ...]:
    """A contiguous ``hours``-long duty window anchored at hour 0."""
    if not 1 <= hours <= HOURS_PER_DAY:
        raise ValueError(f"window of {hours}h outside [1, {HOURS_PER_DAY}]")
    w = 1.0 / hours
    return tuple(w if h < hours else 0.0 for h in range(HOURS_PER_DAY))


_TABLES: Dict[Tuple[float, ...], np.ndarray] = {}


def schedule_tables(db: TechDB = DEFAULT_DB) -> np.ndarray:
    """``loads[Si, 24] float64`` duty-weight lookup table for ``db``.

    Row 0 is **replaced by ``db.load_profile``** — the neutral gather
    must reproduce the per-db fixed load bit-for-bit, not a generic
    flat row. Rows 1+ are the :data:`SCHEDULE_WINDOW_HOURS` windows.
    The vectorized engines gather this by the encoded per-design
    ``(start_hour, shape_idx)`` columns — the axes stay runtime data,
    the table is a trace-time constant shared by every windowed program.
    """
    key = tuple(float(x) for x in db.load_profile)
    tab = _TABLES.get(key)
    if tab is None:
        rows = [key] + [window_row(h) for h in SCHEDULE_WINDOW_HOURS]
        tab = np.array(rows, dtype=np.float64)
        tab.setflags(write=False)
        _TABLES[key] = tab
    return tab


def schedule_load_row(schedule: Tuple[int, int],
                      db: TechDB = DEFAULT_DB) -> Tuple[float, ...]:
    """Scalar decoded load profile: the shape row rolled to the start
    hour, ``load[h] = shapes[shape][(h - start) % 24]``. The neutral
    ``(0, 0)`` schedule returns ``db.load_profile``'s values exactly
    (identity roll of the replaced row 0)."""
    start, shape = schedule
    validate_schedule(schedule)
    tab = schedule_tables(db)
    return tuple(float(tab[shape][(h - start) % HOURS_PER_DAY])
                 for h in range(HOURS_PER_DAY))


def validate_schedule(schedule: Tuple[int, int]) -> None:
    """Raise ``ValueError`` unless ``schedule`` is a well-formed
    ``(start_hour, shape_idx)`` pair."""
    if len(schedule) != 2:
        raise ValueError(
            f"schedule carries {len(schedule)} entries, expected "
            f"(start_hour, shape_idx)")
    start, shape = schedule
    if not 0 <= start < HOURS_PER_DAY:
        raise ValueError(
            f"start hour {start} outside [0, {HOURS_PER_DAY})")
    if not 0 <= shape < n_schedule_shapes():
        raise ValueError(
            f"shape index {shape} outside [0, {n_schedule_shapes()})")
