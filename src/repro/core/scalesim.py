"""Analytical systolic-array timing and traffic model (ScaleSim-equivalent).

The paper evaluates compute latency with the cycle-accurate ScaleSim
simulator and hides its cost behind a lookup cache (Sec. V-D). On this
substrate we use the closed-form formulation that ScaleSim's analytical
mode implements — per-dataflow fill/stream/drain pipeline timing over
array-sized tile passes, plus a buffer-fold DRAM-traffic model — which
preserves the relative trends the paper reports (shape-dependent dataflow
ranking, SRAM-size sensitivity) while being cheap enough to batch.

Conventions: operands are 8-bit (the paper's MAC energy is per 8-bit MAC);
partial sums are 32-bit. The array is square (A x A PEs). The chiplet's
SRAM is split into three equal buffers (ifmap / filter / ofmap), matching
the paper's ScaleSim configuration.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.chiplet import Chiplet
from repro.core.techdb import DEFAULT_DB, TechDB
from repro.core.workload import Tile

OPERAND_BYTES = 1      # int8 inputs/weights
PSUM_BYTES = 4         # fp32/int32 accumulators


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Cycles and traffic for one core's assigned tile list."""

    cycles: int                 # total compute cycles on the array
    dram_rd_bits: int           # DRAM -> chiplet operand traffic
    dram_wr_bits: int           # chiplet -> DRAM result traffic
    sram_bits: int              # on-chip buffer traffic (reads+writes)
    macs: int                   # useful MACs executed

    def __add__(self, other: "SimResult") -> "SimResult":
        return SimResult(
            self.cycles + other.cycles,
            self.dram_rd_bits + other.dram_rd_bits,
            self.dram_wr_bits + other.dram_wr_bits,
            self.sram_bits + other.sram_bits,
            self.macs + other.macs,
        )


ZERO = SimResult(0, 0, 0, 0, 0)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def simulate_tile(tile: Tile, core: Chiplet, dataflow: str) -> SimResult:
    """Closed-form systolic timing for one (m, k, n) sub-GEMM on an A x A
    array.

    Per dataflow, the stationary operand is pinned in the PEs and the other
    two stream through; a tile pass costs (stream + 2A - 1) cycles of
    fill/stream/drain pipeline:

      OS: outputs stationary. Passes over ceil(m/A) * ceil(n/A) output
          tiles, each streaming the k dimension.
      WS: weights stationary. Passes over ceil(k/A) * ceil(n/A) weight
          tiles, each streaming m input rows.
      IS: inputs stationary. Passes over ceil(m/A) * ceil(k/A) input
          tiles, each streaming n weight columns.
    """
    a = core.array
    m, k, n = tile.m, tile.k, tile.n
    if dataflow == "OS":
        passes = _ceil_div(m, a) * _ceil_div(n, a)
        stream = k
    elif dataflow == "WS":
        passes = _ceil_div(k, a) * _ceil_div(n, a)
        stream = m
    elif dataflow == "IS":
        passes = _ceil_div(m, a) * _ceil_div(k, a)
        stream = n
    else:
        raise ValueError(f"unknown dataflow {dataflow!r}")
    cycles = passes * (stream + 2 * a - 1)
    traffic = _tile_traffic(tile, core, dataflow)
    return SimResult(cycles, traffic[0], traffic[1], traffic[2], tile.macs)


def _tile_traffic(tile: Tile, core: Chiplet, dataflow: str):
    """Buffer-fold DRAM traffic + naive-streaming SRAM traffic (bits).

    The streamed operands are re-fetched from DRAM once per pass over the
    stationary dimension *unless* the relevant strip fits in its third of
    the SRAM, in which case it is read once and re-served from SRAM. The
    ofmap is written once; under WS/IS partial sums spill per K-fold when
    the output strip does not fit on chip.
    """
    a = core.array
    m, k, n = tile.m, tile.k, tile.n
    buf = core.buffer_bytes_each()
    if_bytes = m * k * OPERAND_BYTES
    w_bytes = k * n * OPERAND_BYTES
    of_bytes = m * n * PSUM_BYTES

    final_wr = m * n * OPERAND_BYTES    # outputs requantized for writeback
    if dataflow == "OS":
        # ifmap strip per output-row tile: A x k ; reused across n tiles
        if_folds = 1 if a * k * OPERAND_BYTES <= buf else _ceil_div(n, a)
        w_folds = 1 if k * a * OPERAND_BYTES <= buf else _ceil_div(m, a)
        rd = if_bytes * if_folds + w_bytes * w_folds
        wr = final_wr
    elif dataflow == "WS":
        # weights read once; ifmap column-slice m x A reused across n tiles
        if_folds = 1 if m * a * OPERAND_BYTES <= buf else _ceil_div(n, a)
        k_folds = _ceil_div(k, a)
        psum_spill = 1 if m * a * PSUM_BYTES <= buf else k_folds
        rd = w_bytes + if_bytes * if_folds + of_bytes * (psum_spill - 1)
        wr = of_bytes * (psum_spill - 1) + final_wr
    else:  # IS
        w_folds = 1 if a * n * OPERAND_BYTES <= buf else _ceil_div(m, a)
        k_folds = _ceil_div(k, a)
        psum_spill = 1 if a * n * PSUM_BYTES <= buf else k_folds
        rd = if_bytes + w_bytes * w_folds + of_bytes * (psum_spill - 1)
        wr = of_bytes * (psum_spill - 1) + final_wr
    # SRAM sees the un-folded streaming traffic: every pass streams its
    # operands through the array edge plus result writes.
    sram = (if_bytes + w_bytes + of_bytes) * 8  # bits, one full pass
    sram += (rd + wr) * 8                        # refills mirrored in SRAM
    return rd * 8, wr * 8, sram


def simulate_assignment(
    tiles: Sequence[Tile], core: Chiplet, dataflow: str,
) -> SimResult:
    """Total cycles/traffic for all tiles assigned to one core. Tiles run
    back-to-back on the array (the scheduler serializes per core)."""
    total = ZERO
    for t in tiles:
        total = total + simulate_tile(t, core, dataflow)
    return total


def compute_latency_s(res: SimResult, core: Chiplet, db: TechDB = DEFAULT_DB) -> float:
    """Cycles -> seconds at the node-scaled clock (1 GHz at 7nm [50])."""
    return res.cycles / (core.freq_ghz(db) * 1e9)


# ---------------------------------------------------------------------------
# Simulation cache (Sec V-D): keyed on everything that changes cycle count.
# ---------------------------------------------------------------------------


class SimCache:
    """Lookup-table simulation cache. A full 'simulation' is only run when
    the (tile list, array size, buffer size, dataflow) key is unseen."""

    def __init__(self) -> None:
        self._store = {}
        self.hits = 0
        self.misses = 0

    def key(self, tiles: Sequence[Tile], core: Chiplet, dataflow: str):
        return (
            tuple((t.m, t.k, t.n) for t in tiles),
            core.array, core.sram_kb, dataflow,
        )

    def simulate(self, tiles: Sequence[Tile], core: Chiplet, dataflow: str) -> SimResult:
        k = self.key(tiles, core, dataflow)
        hit = self._store.get(k)
        if hit is not None:
            self.hits += 1
            return hit
        self.misses += 1
        res = simulate_assignment(tiles, core, dataflow)
        self._store[k] = res
        return res
