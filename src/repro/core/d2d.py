"""Topology-aware die-to-die communication model (Sec IV-A, Eqs. 6-10).

Builds the package topology (floorplan adjacency for 2.5D, a vertical
chain for 3D stacks, the composition for hybrids), assigns per-chiplet
bump budgets from geometry x bump pitch (Eq. 7), derives link bandwidths
as the min of the two endpoints' shares under the protocol's lane rate and
efficiency (Eq. 6), routes every source's reduction traffic to the
destination chiplet along shortest paths with shared links serialized
(Fig. 4), and exposes base-die-mediated DRAM bandwidth for stacked dies
(Eqs. 8-10).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core import comm as comm_mod
from repro.core import floorplan as fp
from repro.core.chiplet import Chiplet
from repro.core.system import HISystem
from repro.core.techdb import DEFAULT_DB, DEFAULT_HOP_LATENCY_S, TechDB

# Back-compat alias: the per-hop switch/PHY latency now lives per protocol
# in ``TechDB.protocols[*].hop_latency_s`` (neutral default = this value).
HOP_LATENCY_S = DEFAULT_HOP_LATENCY_S


@dataclasses.dataclass
class Link:
    a: int
    b: int
    bw_bits_s: float          # effective payload bandwidth (Eq. 6 min)
    energy_pj_bit: float
    kind: str                 # "2.5D" | "3D"
    hop_latency_s: float = DEFAULT_HOP_LATENCY_S

    def key(self) -> Tuple[int, int]:
        return (self.a, self.b) if self.a < self.b else (self.b, self.a)


@dataclasses.dataclass
class Topology:
    """Package-level communication graph plus memory attach points."""

    system: HISystem
    links: Dict[Tuple[int, int], Link]
    adj: Dict[int, Set[int]]
    dest: int                                  # reduction destination
    mem_bw_bits_s: Dict[int, float]            # direct DRAM bw per chiplet
    base_die: Optional[int]                    # 3D/hybrid stack base
    floorplan: Optional[fp.Floorplan]
    stack_order: Tuple[int, ...]
    # comm-model payload (repro.core.comm): per-chiplet mean NoC hop
    # counts (empty = legacy model) plus the TechDB NoC knobs, stashed at
    # build time so ``route_reduction`` keeps its db-free signature.
    noc_hops: Tuple[float, ...] = ()
    noc_hop_latency_s: float = 0.0
    noc_energy_pj_bit: float = 0.0
    # shared per-hop D2D latency when every protocol agrees (the default);
    # None switches route_reduction to the per-link hop-latency sum
    hop_latency_uniform: Optional[float] = DEFAULT_HOP_LATENCY_S

    # -- path helpers -------------------------------------------------------

    def shortest_path(self, src: int, dst: int) -> List[int]:
        """BFS with *sorted* neighbour expansion: ties between equal-length
        paths break deterministically (lowest chiplet index first), so the
        scalar and batched evaluators route identically."""
        if src == dst:
            return [src]
        prev: Dict[int, int] = {src: src}
        q = deque([src])
        while q:
            u = q.popleft()
            for v in sorted(self.adj[u]):
                if v not in prev:
                    prev[v] = u
                    if v == dst:
                        path = [dst]
                        while path[-1] != src:
                            path.append(prev[path[-1]])
                        return path[::-1]
                    q.append(v)
        raise RuntimeError(f"no path {src}->{dst}: topology disconnected")

    def path_links(self, src: int, dst: int) -> List[Link]:
        nodes = self.shortest_path(src, dst)
        out = []
        for u, v in zip(nodes, nodes[1:]):
            out.append(self.links[(u, v) if u < v else (v, u)])
        return out

    def min_path_bw(self, src: int, dst: int) -> float:
        """min-bandwidth-of-path semantics (weakest link dominates)."""
        links = self.path_links(src, dst)
        return min(l.bw_bits_s for l in links) if links else float("inf")

    def effective_dram_bw(self, idx: int) -> float:
        """Eqs. 8-10: stacked dies reach DRAM via the base die; effective
        bandwidth is min(DRAM bw, min-bandwidth of the path down). Routed
        through :meth:`min_path_bw` so the two weakest-link semantics
        cannot drift apart."""
        direct = self.mem_bw_bits_s.get(idx, 0.0)
        if direct > 0.0:
            return direct
        assert self.base_die is not None
        return min(self.mem_bw_bits_s[self.base_die],
                   self.min_path_bw(idx, self.base_die))

    def dram_path_hops(self, idx: int) -> int:
        if self.mem_bw_bits_s.get(idx, 0.0) > 0.0:
            return 0
        assert self.base_die is not None
        return len(self.path_links(idx, self.base_die))

    def dram_path_energy_pj_bit(self, idx: int) -> float:
        """Compute-memory D2D energy per bit (3D stacks only)."""
        if self.mem_bw_bits_s.get(idx, 0.0) > 0.0:
            return 0.0
        assert self.base_die is not None
        return sum(l.energy_pj_bit for l in self.path_links(idx, self.base_die))


# ---------------------------------------------------------------------------
# Bump budgets and link bandwidth (Eqs. 6-7)
# ---------------------------------------------------------------------------


def bump_count(ch: Chiplet, pitch_um: float, three_d: bool,
               db: TechDB = DEFAULT_DB) -> int:
    """Eq. 7 (whole-chiplet budget). 3D spreads bumps across the die area;
    2.5D restricts them to the die edges (perimeter), as D2D PHYs demand
    length-matched escape routing clear of the central power grid."""
    if three_d:
        area_um2 = ch.area_mm2(db) * 1e6
        return max(1, int(area_um2 / (pitch_um * pitch_um)))
    perim_um = ch.perimeter_mm(db) * 1e3
    return max(1, int(perim_um / pitch_um))


def link_bump_count(pitch_um: float, *, edge_mm: Optional[float] = None,
                    area_mm2: Optional[float] = None) -> int:
    """Eq. 7 applied per LINK: a 2.5D link only gets the bumps that fit on
    the shared edge between the two neighbouring dies (the topology-aware
    part of the model); a 3D bond gets the full overlapping face area."""
    if area_mm2 is not None:
        return max(1, int(area_mm2 * 1e6 / (pitch_um * pitch_um)))
    assert edge_mm is not None
    return max(1, int(edge_mm * 1e3 / pitch_um))


def chiplet_d2d_bw_bits(ch: Chiplet, pitch_um: float, proto: str,
                        three_d: bool, db: TechDB = DEFAULT_DB) -> float:
    """Eq. 6: BW = DR x N_bump x eta (bits/s), whole-chiplet budget."""
    spec = db.protocols[proto]
    n = bump_count(ch, pitch_um, three_d, db)
    return spec.data_rate_gbps * 1e9 * n * spec.efficiency


def link_bw_bits(proto: str, pitch_um: float, *,
                 edge_mm: Optional[float] = None,
                 area_mm2: Optional[float] = None,
                 db: TechDB = DEFAULT_DB) -> float:
    spec = db.protocols[proto]
    n = link_bump_count(pitch_um, edge_mm=edge_mm, area_mm2=area_mm2)
    return spec.data_rate_gbps * 1e9 * n * spec.efficiency


# ---------------------------------------------------------------------------
# Topology construction
# ---------------------------------------------------------------------------


def build_topology(sys: HISystem, db: TechDB = DEFAULT_DB) -> Topology:
    n = sys.n_chiplets
    areas = [c.area_mm2(db) for c in sys.chiplets]
    dest = max(range(n), key=lambda i: areas[i])
    mem = db.memories[sys.memory]
    total_mem_bw = mem.bw_gbs_per_channel * mem.max_channels * 8e9  # bits/s
    # comm-model payload: NoC hop counts only exist under mesh_noc systems
    # (empty tuple keeps route_reduction on the literal legacy code path)
    comm_kw = dict(
        noc_hops=comm_mod.system_noc_hops(sys) if sys.noc else (),
        noc_hop_latency_s=db.noc_hop_latency_s,
        noc_energy_pj_bit=db.noc_energy_pj_bit,
        hop_latency_uniform=db.uniform_hop_latency(),
    )

    links: Dict[Tuple[int, int], Link] = {}
    adj: Dict[int, Set[int]] = {i: set() for i in range(n)}
    plan: Optional[fp.Floorplan] = None
    base_die: Optional[int] = None
    stack_order: Tuple[int, ...] = ()
    mem_bw: Dict[int, float] = {}

    def add_link(a: int, b: int, pkg_name: str, proto: str, kind: str,
                 edge_mm: Optional[float] = None):
        pkg = db.packages[pkg_name]
        if kind == "3D":
            # face-to-face bond: bumps over the smaller die's full area
            face = min(sys.chiplets[a].area_mm2(db),
                       sys.chiplets[b].area_mm2(db))
            bw = link_bw_bits(proto, pkg.bump_pitch_um, area_mm2=face, db=db)
        else:
            # side-by-side: bumps limited to the shared floorplan edge,
            # capped by either chiplet's whole-perimeter budget (Eq. 6 min)
            assert edge_mm is not None
            bw = link_bw_bits(proto, pkg.bump_pitch_um, edge_mm=edge_mm,
                              db=db)
            bw = min(bw, chiplet_d2d_bw_bits(
                sys.chiplets[a], pkg.bump_pitch_um, proto, False, db))
            bw = min(bw, chiplet_d2d_bw_bits(
                sys.chiplets[b], pkg.bump_pitch_um, proto, False, db))
        spec = db.protocols[proto]
        key = (a, b) if a < b else (b, a)
        links[key] = Link(key[0], key[1], bw, spec.energy_pj_bit, kind,
                          spec.hop_latency_s)
        adj[a].add(b)
        adj[b].add(a)

    if sys.style == "2D":
        mem_bw[0] = total_mem_bw
        return Topology(sys, links, adj, dest, mem_bw, None, None, (),
                        **comm_kw)

    if sys.style in ("2.5D", "2.5D+3D"):
        planar = list(sys.planar_indices())
        if sys.style == "2.5D+3D":
            stack_order = sys.stack_order(db)
            base_die = stack_order[0]
            planar = planar + [base_die]   # stack sits on its base die slot
        plan_areas = [areas[i] for i in planar]
        plan = fp.floorplan(plan_areas)
        # remap floorplan rect indices back to chiplet indices
        for r in plan.rects:
            r.idx = planar[r.idx]
        plan_adj = plan.adjacency()
        rect_of = {r.idx: r for r in plan.rects}
        for a, nbrs in plan_adj.items():
            for b in nbrs:
                if (min(a, b), max(a, b)) not in links:
                    edge = rect_of[a].edge_shared(rect_of[b])
                    add_link(a, b, sys.pkg_25d, sys.proto_25d, "2.5D",
                             edge_mm=edge)
        if sys.style == "2.5D+3D":
            for lo, hi in zip(stack_order, stack_order[1:]):
                add_link(lo, hi, sys.pkg_3d, sys.proto_3d, "3D")
        # 2.5D memory: channels distributed by chiplet size (Sec IV-A(2));
        # stacked non-base dies get no direct channel.
        total_planar_area = sum(areas[i] for i in planar)
        for i in planar:
            mem_bw[i] = total_mem_bw * areas[i] / total_planar_area
    else:  # pure 3D
        stack_order = sys.stack_order(db)
        base_die = stack_order[0]
        for lo, hi in zip(stack_order, stack_order[1:]):
            add_link(lo, hi, sys.pkg_3d, sys.proto_3d, "3D")
        mem_bw[base_die] = total_mem_bw

    return Topology(sys, links, adj, dest, mem_bw, base_die, plan,
                    stack_order, **comm_kw)


# ---------------------------------------------------------------------------
# D2D reduction-phase latency and traffic (Fig. 4 semantics)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class D2DResult:
    latency_s: float
    total_bits: int                       # payload bits crossing any link
    link_bits: Dict[Tuple[int, int], int]
    energy_pj: float
    hops: int


def route_reduction(topo: Topology, src_bits: Sequence[int]) -> D2DResult:
    """Route ``src_bits[i]`` from every chiplet i to the destination.

    Shared links serialize (their loads add); disjoint links proceed in
    parallel, so the reduction-phase latency is the busiest-link time plus
    per-hop overheads along the slowest path: package-level switch/PHY
    hops (per-protocol ``hop_latency_s``; the uniform default collapses
    to the bit-pinned ``max_hops * h``) plus, under the mesh_noc comm
    model, the source and destination chiplets' mean on-die NoC hop
    latencies. NoC router energy is charged per bit-hop alongside the
    link energy — the traffic-proportional router bill.
    """
    link_bits: Dict[Tuple[int, int], int] = {k: 0 for k in topo.links}
    energy = 0.0
    max_hops = 0
    total = 0
    hop_lat = 0.0
    noc_h = topo.noc_hops
    dest_noc = noc_h[topo.dest] if noc_h else 0.0
    uniform = topo.hop_latency_uniform
    for src, bits in enumerate(src_bits):
        if src == topo.dest or bits <= 0:
            continue
        path = topo.path_links(src, topo.dest)
        max_hops = max(max_hops, len(path))
        path_lat = (len(path) * uniform if uniform is not None
                    else sum(l.hop_latency_s for l in path))
        if noc_h:
            pair_hops = noc_h[src] + dest_noc
            path_lat += pair_hops * topo.noc_hop_latency_s
            energy += bits * pair_hops * topo.noc_energy_pj_bit
        hop_lat = max(hop_lat, path_lat)
        for link in path:
            link_bits[link.key()] += bits
            energy += link.energy_pj_bit * bits
            total += bits
    latency = 0.0
    for key, bits in link_bits.items():
        if bits:
            latency = max(latency, bits / topo.links[key].bw_bits_s)
    latency += hop_lat
    return D2DResult(latency, total, link_bits, energy, max_hops)
