"""Carbon-footprint models (Sec II-B, Eqs. 2-4), after ECO-CHIP [3]/ACT [16].

Embodied CFP: per-chiplet manufacturing carbon (area x node carbon-per-area,
inflated by die-yield scrap) + amortized design carbon + heterogeneous-
integration carbon (packaging interconnect, interposer, substrate, inflated
by bonding-yield scrap).

Operational CFP: Eq. 3. E_system is the per-execution energy of the
workload; the device re-runs it back-to-back for the active fraction of its
lifetime, so the fleet-lifetime emission is
    (E_system / L_system) [W] x active-hours x C_src x N_vol.

Perf-SI (Eq. 4): throughput per unit carbon = 1 / (latency x C_sys).
"""
from __future__ import annotations

import dataclasses

from repro.core.chiplet import Chiplet
from repro.core.system import HISystem
from repro.core.cost import bonding_yield
from repro.core.techdb import DEFAULT_DB, TechDB

SECONDS_PER_YEAR = 365.25 * 24 * 3600


def chiplet_mfg_cfp(ch: Chiplet, db: TechDB = DEFAULT_DB) -> float:
    """C_mfg,i(n): area x CPA(node), divided by die yield — scrapped dies
    waste their embodied carbon."""
    area = ch.area_mm2(db)
    return area * db.node_cpa[ch.node] / db.die_yield(area, ch.node)


def chiplet_design_cfp(ch: Chiplet, db: TechDB = DEFAULT_DB) -> float:
    """C_des,i / N_vol: design/NRE carbon amortized over production volume."""
    return db.node_design_cfp[ch.node] / db.production_volume


@dataclasses.dataclass(frozen=True)
class EmbodiedBreakdown:
    manufacturing: float
    design: float
    packaging: float            # C_HI

    @property
    def total(self) -> float:
        return self.manufacturing + self.design + self.packaging


def packaging_cfp(sys: HISystem, package_area_mm2: float,
                  db: TechDB = DEFAULT_DB) -> float:
    """C_HI: interconnect + interposer + substrate carbon, inflated by the
    bonding-yield scrap of whole assemblies."""
    if sys.style == "2D":
        return db.substrate_cfp_mm2 * package_area_mm2
    cfp = db.substrate_cfp_mm2 * package_area_mm2
    if sys.style in ("2.5D", "2.5D+3D"):
        pkg = db.packages[sys.pkg_25d]
        cfp += pkg.cfp_kg_per_mm2 * package_area_mm2
        if sys.pkg_25d in ("Passive", "Active"):
            cfp += (package_area_mm2 * db.interposer_cpa
                    / db.interposer_yield(package_area_mm2))
    if sys.style in ("3D", "2.5D+3D"):
        pkg = db.packages[sys.pkg_3d]
        order = sys.stack_order(db)
        bonded_area = sum(sys.chiplets[i].area_mm2(db) for i in order[1:])
        cfp += pkg.cfp_kg_per_mm2 * bonded_area
    return cfp / bonding_yield(sys, db)


def embodied_cfp(sys: HISystem, package_area_mm2: float,
                 db: TechDB = DEFAULT_DB) -> EmbodiedBreakdown:
    """Eq. 2."""
    mfg = sum(chiplet_mfg_cfp(c, db) for c in sys.chiplets)
    des = sum(chiplet_design_cfp(c, db) for c in sys.chiplets)
    pkg = packaging_cfp(sys, package_area_mm2, db)
    return EmbodiedBreakdown(mfg, des, pkg)


def operational_cfp(energy_j: float, latency_s: float,
                    db: TechDB = DEFAULT_DB, per_unit: bool = False) -> float:
    """Eq. 3 under a fixed-demand deployment: the system executes the
    workload ``duty_runs_per_s`` times per active second over its lifetime,
    so lifetime emissions scale with per-run energy (which itself carries a
    static-power x latency term added in ``evaluate``). Returns fleet
    lifetime kgCO2e, or per-unit with ``per_unit=True``."""
    del latency_s  # latency enters through the static-energy term upstream
    active_s = db.lifetime_years * SECONDS_PER_YEAR * db.use_fraction
    runs = db.duty_runs_per_s * active_s
    kwh = energy_j * runs / 3.6e6
    volume = 1 if per_unit else db.production_volume
    return kwh * db.carbon_intensity * volume


def perf_si(latency_s: float, total_cfp: float) -> float:
    """Eq. 4 with Performance = 1/latency so that higher is better."""
    return 1.0 / (latency_s * total_cfp)
