"""Carbon-footprint models (Sec II-B, Eqs. 2-4), after ECO-CHIP [3]/ACT [16].

Embodied CFP: per-chiplet manufacturing carbon (area x node carbon-per-area,
inflated by die-yield scrap, plus the per-die share of the wafer's scrapped
edge area, discounted by recycling credits) + amortized design carbon +
heterogeneous-integration carbon (packaging interconnect, interposer,
substrate, router share, inflated by bonding-yield scrap).

ECO-CHIP term map (each function's docstring names its equation):

* ``chiplet_mfg_cfp``   -> ECO-CHIP ``carbon = cpa*area/yield + wastage``
  with the ACT recycling credit ``(1-rcy_mat)(1-rcy_cpa)``.
* ``wasted_die_cfp``    -> ECO-CHIP ``waste_carbon_per_die``: the wafer
  area no whole die fits on still burned CPA energy; amortized per die.
* ``packaging_cfp``     -> ECO-CHIP ``package_costs`` package term
  (Eq. 2's C_HI).
* ``embodied_cfp``      -> Eq. 2 total, adding the ECO-CHIP ``router_c``
  split (``router_area_frac`` of each die's manufacturing carbon is NoC).
* ``operational_cfp``   -> Eq. 3, generalized to a 24h grid-intensity
  profile dotted with a diurnal load profile (Carbon Connect).

Every lifecycle knob defaults to a *neutral* value (0.0 addend, 1.0
multiplier, flat profile): with defaults, all functions reproduce their
pre-lifecycle outputs bit-for-bit.

Operational CFP: Eq. 3. E_system is the per-execution energy of the
workload; the device re-runs it back-to-back for the active fraction of its
lifetime, so the fleet-lifetime emission is
    (E_system / L_system) [W] x active-hours x C_src x N_vol.

Perf-SI (Eq. 4): throughput per unit carbon = 1 / (latency x C_sys).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.chiplet import Chiplet
from repro.core.system import HISystem
from repro.core.cost import bonding_yield
from repro.core.techdb import DEFAULT_DB, TechDB

SECONDS_PER_YEAR = 365.25 * 24 * 3600


def recycling_credit(db: TechDB = DEFAULT_DB) -> float:
    """ACT/ECO-CHIP recycling discount on manufacturing carbon:
    ``(1 - rcy_mat_frac) * (1 - rcy_cpa_frac)``.

    ``rcy_mat_frac`` credits recycled raw material, ``rcy_cpa_frac``
    credits the recycled share of the carbon-per-area energy bill; both
    are clamped to [0, 1] by ``TechDB``. Defaults (0, 0) give a factor
    of exactly 1.0."""
    return (1.0 - db.rcy_mat_frac) * (1.0 - db.rcy_cpa_frac)


def wasted_die_cfp(die_area_mm2: float, node: int,
                   db: TechDB = DEFAULT_DB) -> float:
    """ECO-CHIP ``waste_carbon_per_die``: wafer edge/scrap carbon per die.

    A wafer of area ``pi r^2`` yields ``DPW`` whole dies; the remaining
    ``pi r^2 - DPW * A`` mm^2 still burned CPA(node) energy and is
    amortized over the good dies:

        C_waste = cpa(node) * (wafer_area - DPW * A) / DPW

    scaled by ``db.wasted_die_scale`` (0.0 default = term off, so the
    pre-lifecycle manufacturing carbon is reproduced exactly)."""
    if db.wasted_die_scale == 0.0:
        return 0.0
    dpw = db.dies_per_wafer(die_area_mm2)
    scrap_mm2 = db.wafer_area_mm2() - dpw * die_area_mm2
    return db.wasted_die_scale * db.node_cpa[node] * scrap_mm2 / dpw


def chiplet_mfg_cfp(ch: Chiplet, db: TechDB = DEFAULT_DB) -> float:
    """C_mfg,i(n): ECO-CHIP ``carbon = cpa*area/yield + wastage_extra_cfp``.

    Area x CPA(node), divided by die yield — scrapped dies waste their
    embodied carbon — plus the per-die share of the wafer's scrapped
    area (:func:`wasted_die_cfp`), all discounted by the recycling
    credit (:func:`recycling_credit`). With default knobs this is
    bit-identical to plain ``area * cpa / yield``."""
    area = ch.area_mm2(db)
    mfg = area * db.node_cpa[ch.node] / db.die_yield(area, ch.node)
    mfg = mfg + wasted_die_cfp(area, ch.node, db)
    return mfg * recycling_credit(db)


def chiplet_design_cfp(ch: Chiplet, db: TechDB = DEFAULT_DB) -> float:
    """C_des,i / N_vol: design/NRE carbon amortized over production volume."""
    return db.node_design_cfp[ch.node] / db.production_volume


@dataclasses.dataclass(frozen=True)
class EmbodiedBreakdown:
    manufacturing: float        # incl. wasted-die share and recycling credit
    design: float
    packaging: float            # C_HI incl. the router (NoC) split

    @property
    def total(self) -> float:
        return self.manufacturing + self.design + self.packaging


def packaging_cfp(sys: HISystem, package_area_mm2: float,
                  db: TechDB = DEFAULT_DB) -> float:
    """C_HI: interconnect + interposer + substrate carbon, inflated by the
    bonding-yield scrap of whole assemblies (ECO-CHIP ``package_costs``
    package term).

    The final division deliberately covers the *entire* C_HI — including
    the base substrate term that a 2D system gets yield-free: when a
    2.5D/3D bonding event fails, the whole assembly (substrate included)
    is scrapped, so every packaging gram must be re-spent. This matches
    ECO-CHIP, which scales the full package carbon by assembly yield;
    2D packages undergo no bonding events (``bonding_yield`` == 1.0
    exactly), so the early return is a shortcut, not an asymmetry — the
    scalar and device paths agree bitwise (pinned by the
    ``packaging_cfp`` parity test)."""
    if sys.style == "2D":
        return db.substrate_cfp_mm2 * package_area_mm2
    cfp = db.substrate_cfp_mm2 * package_area_mm2
    if sys.style in ("2.5D", "2.5D+3D"):
        pkg = db.packages[sys.pkg_25d]
        cfp += pkg.cfp_kg_per_mm2 * package_area_mm2
        if sys.pkg_25d in ("Passive", "Active"):
            cfp += (package_area_mm2 * db.interposer_cpa
                    / db.interposer_yield(package_area_mm2))
    if sys.style in ("3D", "2.5D+3D"):
        pkg = db.packages[sys.pkg_3d]
        order = sys.stack_order(db)
        bonded_area = sum(sys.chiplets[i].area_mm2(db) for i in order[1:])
        cfp += pkg.cfp_kg_per_mm2 * bonded_area
    return cfp / bonding_yield(sys, db)


def embodied_cfp(sys: HISystem, package_area_mm2: float,
                 db: TechDB = DEFAULT_DB) -> EmbodiedBreakdown:
    """Eq. 2, with the ECO-CHIP packaging/router carbon split.

    ECO-CHIP's ``package_costs`` returns ``(package_c, router_c)`` and
    charges ``package_c + router_c`` to integration: the on-die routers
    (NoC share ``db.router_area_frac`` of each die) belong to the
    *integration* bill, not the compute bill. Router carbon is the NoC
    share of total manufacturing carbon and — like ECO-CHIP's
    ``router_c`` — does not pay the bonding-yield inflation (routers on
    good dies are not re-spent when a bond fails; the die is recovered
    carbon-wise through the die-yield term). ``router_area_frac=0.0``
    (default) reproduces the pre-split packaging carbon exactly.

    Under the mesh_noc comm model (``sys.noc`` non-empty) each chiplet's
    router share scales with its physical router count ``mx * my`` —
    structure-proportional instead of a flat area fraction. The neutral
    ``(1, 1)`` mesh multiplies by exactly 1.0 per chiplet, reproducing
    the legacy term bit-for-bit."""
    per_chip = [chiplet_mfg_cfp(c, db) for c in sys.chiplets]
    mfg = sum(per_chip)
    des = sum(chiplet_design_cfp(c, db) for c in sys.chiplets)
    pkg = packaging_cfp(sys, package_area_mm2, db)
    if sys.noc:
        from repro.core.comm import system_n_routers
        routers = system_n_routers(sys)
        pkg = pkg + db.router_area_frac * sum(
            m * r for m, r in zip(per_chip, routers))
    else:
        pkg = pkg + db.router_area_frac * mfg
    return EmbodiedBreakdown(mfg, des, pkg)


def effective_intensity(ci: float,
                        profile: Optional[Sequence[float]] = None,
                        load: Optional[Sequence[float]] = None) -> float:
    """Load-weighted effective grid intensity (Carbon Connect).

    With a 24h grid-intensity ``profile`` and a diurnal ``load``
    weighting (entries summing to 1), the effective intensity is

        ci_eff = ci + sum_h (profile[h] - ci) * load[h]

    i.e. the scalar ``ci`` plus a correction that is *exactly* +0.0
    when the profile is flat at ``ci`` (every term is 0.0), so flat
    profiles are bit-identical to the scalar model. This formulation —
    not ``sum(profile * load)`` — is what the device program computes,
    keeping scalar and fused paths aligned."""
    if profile is None:
        return ci
    if load is None:
        load = (1.0 / len(profile),) * len(profile)
    corr = 0.0
    for p, l in zip(profile, load):
        corr += (p - ci) * l
    return ci + corr


def effective_price(price: float,
                    profile: Optional[Sequence[float]] = None,
                    load: Optional[Sequence[float]] = None) -> float:
    """Load-weighted effective electricity price — the dollar-metric twin
    of :func:`effective_intensity`, sharing its ``price + sum((p - price)
    * load)`` formulation so a flat curve contributes exactly +0.0 and a
    ``None`` curve is the scalar price bit-for-bit."""
    return effective_intensity(price, profile, load)


def lifetime_kwh(energy_j: float, db: TechDB = DEFAULT_DB) -> float:
    """Lifetime electrical energy (kWh) of one deployed unit: per-run
    energy x (duty_runs_per_s x active seconds) under the fixed-demand
    deployment model."""
    active_s = db.lifetime_years * SECONDS_PER_YEAR * db.use_fraction
    runs = db.duty_runs_per_s * active_s
    return energy_j * runs / 3.6e6


def operational_cost_usd(energy_j: float, db: TechDB = DEFAULT_DB,
                         load: Optional[Sequence[float]] = None) -> float:
    """Lifetime electricity bill of one unit: lifetime kWh x regional
    effective price. With the default flat ``db.price_profile=None`` the
    effective price *is* ``db.electricity_price`` ($/kWh) bit-for-bit;
    a 24h price curve is load-weighted like the grid intensity
    (:func:`effective_price`), ``load`` overriding ``db.load_profile``
    for schedule-carrying designs. The neutral default price of 0.0
    leaves the manufacturing-only dollar metric unchanged (x + 0.0 is
    bit-identical for finite x)."""
    price = effective_price(db.electricity_price, db.price_profile,
                            db.load_profile if load is None else load)
    return lifetime_kwh(energy_j, db) * price


def operational_cfp(energy_j: float, latency_s: float,
                    db: TechDB = DEFAULT_DB, per_unit: bool = False,
                    load: Optional[Sequence[float]] = None) -> float:
    """Eq. 3 under a fixed-demand deployment: the system executes the
    workload ``duty_runs_per_s`` times per active second over its lifetime,
    so lifetime emissions scale with per-run energy (which itself carries a
    static-power x latency term added in ``evaluate``). The grid intensity
    is the load-weighted :func:`effective_intensity` of ``db.grid_profile``
    (``None`` = flat = the scalar ``db.carbon_intensity``, bit-identical).
    ``load`` overrides ``db.load_profile`` for designs carrying an
    encoded schedule (see :mod:`repro.core.schedule`); ``None`` keeps
    the fixed per-db weighting bit-for-bit.
    Returns fleet lifetime kgCO2e, or per-unit with ``per_unit=True``."""
    del latency_s  # latency enters through the static-energy term upstream
    kwh = lifetime_kwh(energy_j, db)
    ci = effective_intensity(db.carbon_intensity, db.grid_profile,
                             db.load_profile if load is None else load)
    volume = 1 if per_unit else db.production_volume
    return kwh * ci * volume


def perf_si(latency_s: float, total_cfp: float) -> float:
    """Eq. 4 with Performance = 1/latency so that higher is better."""
    return 1.0 / (latency_s * total_cfp)
