"""Technology database for CarbonPATH.

Every constant the paper's models consume lives here, grouped by the design
spaces of Table II / Table III. Values are calibrated knobs sourced from the
paper's citations (ECO-CHIP [3], UCIe [35], AIB/Arvon [36], BoW [37],
CiM-3D [40], HBM/DRAM [41,42], wafer costs [46,52], ASAP7 synthesis [50]).
The paper normalizes all reported results (Sec. VII) — relative trend
fidelity, not absolute point estimates, is the contract; users override any
entry via ``TechDB(overrides={...})``.

Units used throughout the core package:
    area   mm^2        power  W           energy  pJ/bit
    bw     GB/s        freq   GHz         latency s
    pitch  um          cost   USD         carbon  kgCO2e
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Mapping, Optional, Tuple

# ---------------------------------------------------------------------------
# Enumerations of the design space (Table II / Table III)
# ---------------------------------------------------------------------------

TECH_NODES = (7, 10, 14, 22, 28)                       # nm
ARRAY_SIZES = (64, 96, 128, 192)                       # systolic array dim
SRAM_SIZES_KB: Mapping[int, Tuple[int, ...]] = {       # per array size
    64: (256, 512, 768, 1024),
    96: (512, 1024, 1536, 2048),
    128: (1024, 2048, 3072, 4096),
    192: (2048, 4096, 6144, 8192),
}
MEMORY_TYPES = ("DDR4", "DDR5", "HBM2", "HBM3")
INTEGRATION_STYLES = ("2D", "2.5D", "3D", "2.5D+3D")
INTERCONNECTS_25D = ("RDL", "EMIB", "Passive", "Active")
INTERCONNECTS_3D = ("TSV", "uBump", "HybBond")
PROTOCOLS_25D = ("UCIe-S", "UCIe-A", "AIB", "BoW")
PROTOCOLS_3D = ("UCIe-3D",)
DATAFLOWS = ("OS", "WS", "IS")

# Table III — compatible (2.5D interconnect -> protocols)
PKG_PROTOCOLS_25D: Mapping[str, Tuple[str, ...]] = {
    "RDL": ("UCIe-S",),
    "EMIB": ("UCIe-A", "AIB", "BoW"),
    "Passive": ("UCIe-A", "AIB", "BoW"),
    "Active": ("UCIe-A", "AIB", "BoW"),
}
PKG_PROTOCOLS_3D: Mapping[str, Tuple[str, ...]] = {
    "TSV": ("UCIe-3D",),
    "uBump": ("UCIe-3D",),
    "HybBond": ("UCIe-3D",),
}


def valid_pairs_25d() -> Tuple[Tuple[str, str], ...]:
    return tuple(
        (pkg, proto)
        for pkg, protos in PKG_PROTOCOLS_25D.items()
        for proto in protos
    )


def valid_pairs_3d() -> Tuple[Tuple[str, str], ...]:
    return tuple(
        (pkg, proto)
        for pkg, protos in PKG_PROTOCOLS_3D.items()
        for proto in protos
    )


def valid_pairs_hybrid() -> Tuple[Tuple[str, str, str, str], ...]:
    """(2.5D pkg, 2.5D proto, 3D pkg, 3D proto) — 10 x 3 = 30 combos."""
    return tuple(
        (p25, pr25, p3, pr3)
        for (p25, pr25) in valid_pairs_25d()
        for (p3, pr3) in valid_pairs_3d()
    )


def all_pkg_protocol_pairs() -> int:
    """Paper Sec V-A: 10 (2.5D) + 3 (3D) + 30 (hybrid) = 43."""
    return len(valid_pairs_25d()) + len(valid_pairs_3d()) + len(valid_pairs_hybrid())


# ---------------------------------------------------------------------------
# Chiplet library physical characterization (synthesized ASAP7 @ 7nm, scaled)
# ---------------------------------------------------------------------------
# Base area/power at 7 nm per systolic array size (synthesis-calibrated
# placeholders). Area includes the PE array + control; SRAM added per KB.
# 12.5% activity factor is already folded into the dynamic power numbers.

ARRAY_AREA_7NM_MM2: Mapping[int, float] = {   # PE array logic area at 7nm
    64: 1.10, 96: 2.45, 128: 4.30, 192: 9.60,
}
ARRAY_POWER_7NM_W: Mapping[int, float] = {    # at 1 GHz, 12.5% activity
    64: 0.55, 96: 1.22, 128: 2.15, 192: 4.80,
}
SRAM_AREA_7NM_MM2_PER_KB = 0.0018             # high-density 7nm SRAM macro
SRAM_LEAK_W_PER_KB = 2.0e-5

# Node scaling tables (relative to 7nm = 1.0), after [3], [51].
NODE_AREA_SCALE: Mapping[int, float] = {7: 1.00, 10: 1.55, 14: 2.20, 22: 3.55, 28: 4.70}
NODE_POWER_SCALE: Mapping[int, float] = {7: 1.00, 10: 1.25, 14: 1.60, 22: 2.25, 28: 2.80}
NODE_FREQ_GHZ: Mapping[int, float] = {7: 1.00, 10: 0.90, 14: 0.80, 22: 0.65, 28: 0.55}

# Carbon intensity of manufacturing per mm^2 by node (kgCO2e/mm^2), after
# ECO-CHIP [3] / imec [30]: advanced nodes have higher per-area intensity
# (more EUV passes, higher energy litho).
NODE_CPA_KGCO2_MM2: Mapping[int, float] = {
    7: 0.0460, 10: 0.0390, 14: 0.0320, 22: 0.0250, 28: 0.0210,
}
# Defect density per node (defects/mm^2) for negative-binomial yield [47-49]
NODE_DEFECT_MM2: Mapping[int, float] = {
    7: 0.0014, 10: 0.0012, 14: 0.0010, 22: 0.0008, 28: 0.0007,
}
# Wafer cost by node (300 mm wafer, USD) from [46], [52]
NODE_WAFER_COST: Mapping[int, float] = {
    7: 9346.0, 10: 5992.0, 14: 3984.0, 22: 3238.0, 28: 2612.0,
}
# Design (NRE) carbon per chiplet by node (kgCO2e), amortized over volume.
NODE_DESIGN_CFP_KGCO2: Mapping[int, float] = {
    7: 1.8e6, 10: 1.2e6, 14: 0.8e6, 22: 0.5e6, 28: 0.4e6,
}

WAFER_DIAMETER_MM = 300.0
YIELD_CLUSTER_ALPHA = 2.0          # negative binomial clustering parameter

# ---------------------------------------------------------------------------
# Protocols (UCIe [35], AIB [36], BoW [37]) — PHY characteristics
# ---------------------------------------------------------------------------


# Per-hop switch/PHY latency of a package-level D2D link. The neutral
# default matches the pre-refactor module constant ``d2d.HOP_LATENCY_S``
# exactly: with every protocol at this value the routed hop term is
# computed as ``max_hops * h`` — bit-identical to all pinned goldens.
DEFAULT_HOP_LATENCY_S = 2.0e-9


@dataclasses.dataclass(frozen=True)
class ProtocolSpec:
    name: str
    data_rate_gbps: float      # per bump/wire lane
    efficiency: float          # eta_protocol: payload fraction after framing
    energy_pj_bit: float       # D2D link energy per bit
    max_bump_pitch_um: float   # coarsest pitch the PHY tolerates
    hop_latency_s: float = DEFAULT_HOP_LATENCY_S   # per-hop switch/PHY


PROTOCOLS: Mapping[str, ProtocolSpec] = {
    # 2.5D standard-package UCIe: 16 GT/s, ~25um+ pitch
    "UCIe-S": ProtocolSpec("UCIe-S", 16.0, 0.80, 0.50, 130.0),
    # 2.5D advanced-package UCIe: 32 GT/s on fine pitch
    "UCIe-A": ProtocolSpec("UCIe-A", 32.0, 0.83, 0.30, 55.0),
    "AIB": ProtocolSpec("AIB", 6.4, 0.90, 0.50, 55.0),
    "BoW": ProtocolSpec("BoW", 16.0, 0.88, 0.45, 55.0),
    # 3D UCIe: short vertical hops, very low pJ/bit
    "UCIe-3D": ProtocolSpec("UCIe-3D", 4.0, 0.92, 0.05, 10.0),
}

# ---------------------------------------------------------------------------
# Packaging interconnects — bump pitch, bonding yield, carbon, cost
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackageSpec:
    name: str
    style: str                 # "2.5D" | "3D"
    bump_pitch_um: float       # D2D bump/via pitch
    bonding_yield: float       # per bonding event
    cfp_kg_per_mm2: float      # packaging embodied carbon per packaged mm^2
    cost_scale: float          # relative assembly cost multiplier
    wires_per_mm: float        # escape density for edge (2.5D) routing


PACKAGES: Mapping[str, PackageSpec] = {
    # 2.5D family — paper: RDL most mature/highest yield & lowest cost
    "RDL": PackageSpec("RDL", "2.5D", 110.0, 0.999, 0.0045, 1.00, 95.0),
    # EMIB: the dense silicon bridge (~250 wires/mm, fine BEOL layers)
    # carries the highest per-area embodied carbon of the 2.5D options
    "EMIB": PackageSpec("EMIB", "2.5D", 45.0, 0.990, 0.0300, 1.45, 250.0),
    "Passive": PackageSpec("Passive", "2.5D", 40.0, 0.990, 0.0110, 1.60, 220.0),
    "Active": PackageSpec("Active", "2.5D", 36.0, 0.985, 0.0130, 1.85, 240.0),
    # 3D family — paper: TSV cheapest 3D, hybrid bond lowest-yield/highest-cost
    "TSV": PackageSpec("TSV", "3D", 40.0, 0.980, 0.0150, 2.10, 0.0),
    "uBump": PackageSpec("uBump", "3D", 25.0, 0.970, 0.0170, 2.40, 0.0),
    "HybBond": PackageSpec("HybBond", "3D", 6.0, 0.955, 0.0210, 2.95, 0.0),
}

# ---------------------------------------------------------------------------
# Memory systems (JEDEC [39], HBM [41,42])
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MemorySpec:
    name: str
    bw_gbs_per_channel: float
    max_channels: int
    energy_pj_bit_rd: float
    energy_pj_bit_wr: float
    cost_usd: float            # per system memory subsystem
    cfp_kg: float              # embodied carbon of the memory stack


MEMORIES: Mapping[str, MemorySpec] = {
    "DDR4": MemorySpec("DDR4", 25.6, 4, 15.0, 15.0, 35.0, 4.5),
    "DDR5": MemorySpec("DDR5", 51.2, 4, 12.0, 12.0, 55.0, 5.5),
    "HBM2": MemorySpec("HBM2", 307.0, 8, 3.9, 3.9, 160.0, 14.0),
    "HBM3": MemorySpec("HBM3", 819.0, 8, 3.5, 3.5, 240.0, 19.0),
}

# SRAM access energy (pJ/bit) at 7nm from [40]; scales with node power.
SRAM_ENERGY_PJ_BIT_7NM = 0.18
# MAC energy (pJ per 8-bit MAC) at 7nm from synthesis; per-bit convention:
# E_compute is charged per bit processed = MAC energy / 8.
MAC_ENERGY_PJ_7NM = 0.32

# ---------------------------------------------------------------------------
# Operational carbon (Eq. 3)
# ---------------------------------------------------------------------------

CARBON_INTENSITY_KG_PER_KWH = 0.475   # world-average grid [16]
LIFETIME_YEARS = 5.0                  # 3-7y [31-33]
USE_FRACTION = 0.30                   # T_use: active fraction of lifetime
PRODUCTION_VOLUME = 1_000_000         # N_vol (paper Sec VI-A)
# Demand model for Eq. 3: the deployed system serves a fixed request rate
# over its active lifetime, so lifetime operational energy is
# E_system-per-run x (duty_runs_per_s x active seconds). Constant across
# candidates -> cancels under the paper's normalization.
DUTY_RUNS_PER_S = 5000.0
# Static (leakage + clock-tree) power fraction of peak dynamic power; it
# charges energy proportional to latency, which is how shorter execution
# lowers operational CFP (Sec VI-C3).
STATIC_POWER_FRACTION = 0.15

# --- lifecycle / regional axes (ECO-CHIP [3], Carbon Connect) -------------
# All defaults are *neutral*: with them, every model below reproduces the
# pre-lifecycle numbers bit-for-bit (0.0 addends, 1.0 multipliers, flat
# profiles), so goldens pinned before this axis existed stay valid.
HOURS_PER_DAY = 24
# Uniform diurnal duty weighting: the deployed system draws its lifetime
# energy evenly across the day unless a workload says otherwise. Entries
# sum to 1; pairs with a per-region 24h grid-intensity profile to turn
# operational CFP into a profile dot product (Carbon Connect).
FLAT_LOAD_PROFILE: Tuple[float, ...] = (1.0 / HOURS_PER_DAY,) * HOURS_PER_DAY
ELECTRICITY_PRICE_USD_PER_KWH = 0.0   # regional $/kWh; 0 = cost-model-only $
EMBODIED_REGION_FACTOR = 1.0          # regional fab-grid embodied multiplier
RCY_MAT_FRAC = 0.0                    # recycled raw-material fraction [0,1]
RCY_CPA_FRAC = 0.0                    # recycled share of CPA energy [0,1]
WASTED_DIE_SCALE = 0.0                # gate on per-wafer scrap carbon term
ROUTER_AREA_FRAC = 0.0                # on-die router share of chiplet area
# mesh-NoC knobs (repro.core.comm): per-router-hop latency/energy of the
# on-chiplet mesh. Both are multiplied by the mean NoC hop count, which is
# exactly 0.0 at the neutral (1, 1) mesh — legacy results never see them.
NOC_HOP_LATENCY_S = 2.0e-10           # on-die router hop (10x faster than D2D)
NOC_ENERGY_PJ_BIT = 0.05              # on-die router+wire energy per bit-hop

# Interposer: fabricated at 65nm [3],[45]
INTERPOSER_NODE_CPA = 0.0125          # kgCO2e/mm^2 at 65nm
INTERPOSER_DEFECT_MM2 = 0.0004
INTERPOSER_WAFER_COST = 1937.0        # USD, 65nm 300mm wafer
PKG_SUBSTRATE_COST_PER_MM2 = 0.011    # [5]
PKG_SUBSTRATE_CFP_PER_MM2 = 0.0008
# Assembly cost per chiplet attach/bond event, scaled by the interconnect's
# cost_scale (RDL cheapest ... hybrid bonding most expensive) [5], [44].
ASSEMBLY_COST_PER_CHIPLET = 2.0

# ChipletGym baseline constants (Sec VI-B1/B2): fixed D2D latencies and
# constant bonding yield, energy per MAC only.
CHIPLETGYM_D2D_LATENCY_25D_S = 17.2e-12
CHIPLETGYM_D2D_LATENCY_3D_S = 1.6e-12
CHIPLETGYM_BOND_YIELD = 0.99


# ---------------------------------------------------------------------------
# TechDB — the single object models consume; supports overrides
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TechDB:
    """Bundles every knob; ``overrides`` patches any attribute by name.

    ``TechDB(overrides={"carbon_intensity": 0.1})`` is equivalent to
    passing the field directly but composes with call sites that only
    forward a dict; unknown names raise instead of silently creating
    dead attributes. Recycling fractions are clamped to ``[0, 1]``
    after patching (a credit can neither be negative nor exceed the
    whole material/energy bill)."""

    tech_nodes: Tuple[int, ...] = TECH_NODES
    array_sizes: Tuple[int, ...] = ARRAY_SIZES
    sram_sizes_kb: Mapping[int, Tuple[int, ...]] = dataclasses.field(
        default_factory=lambda: dict(SRAM_SIZES_KB))
    memories: Mapping[str, MemorySpec] = dataclasses.field(
        default_factory=lambda: dict(MEMORIES))
    packages: Mapping[str, PackageSpec] = dataclasses.field(
        default_factory=lambda: dict(PACKAGES))
    protocols: Mapping[str, ProtocolSpec] = dataclasses.field(
        default_factory=lambda: dict(PROTOCOLS))
    array_area_7nm: Mapping[int, float] = dataclasses.field(
        default_factory=lambda: dict(ARRAY_AREA_7NM_MM2))
    array_power_7nm: Mapping[int, float] = dataclasses.field(
        default_factory=lambda: dict(ARRAY_POWER_7NM_W))
    node_area_scale: Mapping[int, float] = dataclasses.field(
        default_factory=lambda: dict(NODE_AREA_SCALE))
    node_power_scale: Mapping[int, float] = dataclasses.field(
        default_factory=lambda: dict(NODE_POWER_SCALE))
    node_freq_ghz: Mapping[int, float] = dataclasses.field(
        default_factory=lambda: dict(NODE_FREQ_GHZ))
    node_cpa: Mapping[int, float] = dataclasses.field(
        default_factory=lambda: dict(NODE_CPA_KGCO2_MM2))
    node_defect: Mapping[int, float] = dataclasses.field(
        default_factory=lambda: dict(NODE_DEFECT_MM2))
    node_wafer_cost: Mapping[int, float] = dataclasses.field(
        default_factory=lambda: dict(NODE_WAFER_COST))
    node_design_cfp: Mapping[int, float] = dataclasses.field(
        default_factory=lambda: dict(NODE_DESIGN_CFP_KGCO2))
    sram_area_per_kb: float = SRAM_AREA_7NM_MM2_PER_KB
    sram_energy_pj_bit_7nm: float = SRAM_ENERGY_PJ_BIT_7NM
    mac_energy_pj_7nm: float = MAC_ENERGY_PJ_7NM
    carbon_intensity: float = CARBON_INTENSITY_KG_PER_KWH
    lifetime_years: float = LIFETIME_YEARS
    use_fraction: float = USE_FRACTION
    production_volume: int = PRODUCTION_VOLUME
    duty_runs_per_s: float = DUTY_RUNS_PER_S
    static_power_fraction: float = STATIC_POWER_FRACTION
    yield_alpha: float = YIELD_CLUSTER_ALPHA
    wafer_diameter_mm: float = WAFER_DIAMETER_MM
    interposer_cpa: float = INTERPOSER_NODE_CPA
    interposer_defect: float = INTERPOSER_DEFECT_MM2
    interposer_wafer_cost: float = INTERPOSER_WAFER_COST
    substrate_cost_mm2: float = PKG_SUBSTRATE_COST_PER_MM2
    substrate_cfp_mm2: float = PKG_SUBSTRATE_CFP_PER_MM2
    assembly_cost: float = ASSEMBLY_COST_PER_CHIPLET
    # lifecycle / regional axes — neutral defaults (see module comment)
    electricity_price: float = ELECTRICITY_PRICE_USD_PER_KWH
    emb_factor: float = EMBODIED_REGION_FACTOR
    grid_profile: Optional[Tuple[float, ...]] = None
    price_profile: Optional[Tuple[float, ...]] = None
    load_profile: Tuple[float, ...] = FLAT_LOAD_PROFILE
    rcy_mat_frac: float = RCY_MAT_FRAC
    rcy_cpa_frac: float = RCY_CPA_FRAC
    wasted_die_scale: float = WASTED_DIE_SCALE
    router_area_frac: float = ROUTER_AREA_FRAC
    noc_hop_latency_s: float = NOC_HOP_LATENCY_S
    noc_energy_pj_bit: float = NOC_ENERGY_PJ_BIT
    overrides: Optional[Dict[str, Any]] = None

    def __post_init__(self) -> None:
        if self.overrides:
            field_names = {f.name for f in dataclasses.fields(self)}
            for name, value in self.overrides.items():
                if name == "overrides" or name not in field_names:
                    raise ValueError(f"TechDB has no knob named {name!r}")
                setattr(self, name, value)
        # consumed at construction: a later dataclasses.replace(db, x=...)
        # must not have a stale overrides dict silently undo the change
        self.overrides = None
        # recycling credits are fractions of the bill: clamp to [0, 1]
        self.rcy_mat_frac = min(1.0, max(0.0, float(self.rcy_mat_frac)))
        self.rcy_cpa_frac = min(1.0, max(0.0, float(self.rcy_cpa_frac)))
        for name in ("grid_profile", "price_profile"):
            prof = getattr(self, name)
            if prof is not None:
                prof = tuple(float(x) for x in prof)
                if len(prof) != HOURS_PER_DAY:
                    raise ValueError(
                        f"{name} needs {HOURS_PER_DAY} hourly entries, "
                        f"got {len(prof)}")
                setattr(self, name, prof)
        self.load_profile = tuple(float(x) for x in self.load_profile)
        if len(self.load_profile) != HOURS_PER_DAY:
            raise ValueError(
                f"load_profile needs {HOURS_PER_DAY} hourly entries, "
                f"got {len(self.load_profile)}")
        for size in self.array_sizes:
            if size not in self.sram_sizes_kb:
                raise ValueError(f"no SRAM options for array size {size}")

    # -- convenience lookups used throughout the models --------------------

    def freq_ghz(self, node: int) -> float:
        return self.node_freq_ghz[node]

    def sram_energy_pj_bit(self, node: int) -> float:
        return self.sram_energy_pj_bit_7nm * self.node_power_scale[node]

    def mac_energy_pj(self, node: int) -> float:
        return self.mac_energy_pj_7nm * self.node_power_scale[node]

    def wafer_area_mm2(self) -> float:
        r = self.wafer_diameter_mm / 2.0
        return math.pi * r * r

    def dies_per_wafer(self, die_area_mm2: float) -> int:
        """DPW with edge-loss correction (standard formula, [3]).

        The edge-loss term drives the estimate to zero (and below) as
        the die approaches the wafer — past ``pi r^2 / A =
        pi d / sqrt(2 A)`` (A = r^2/2, i.e. 11250 mm^2 on a 300 mm
        wafer) the formula is meaningless, and silently clamping it to
        "1 die per wafer" would feed garbage into every per-die
        amortization (interposer cost, wasted-die carbon). Such areas
        raise instead; a *positive* fractional estimate below one die
        still clamps to 1 (the die fits, so a wafer yields at least
        one)."""
        if die_area_mm2 <= 0:
            raise ValueError(f"die area must be positive, got {die_area_mm2}")
        r = self.wafer_diameter_mm / 2.0
        dpw = (math.pi * r * r / die_area_mm2
               - math.pi * self.wafer_diameter_mm / math.sqrt(2.0 * die_area_mm2))
        if dpw <= 0.0:
            raise ValueError(
                f"die of {die_area_mm2} mm^2 does not fit a "
                f"{self.wafer_diameter_mm} mm wafer (edge-corrected DPW "
                f"{dpw:.3f} <= 0)")
        return max(1, int(dpw))

    def die_yield(self, die_area_mm2: float, node: int) -> float:
        """Negative binomial yield: (1 + A*D0/alpha)^-alpha [47-49]."""
        d0 = self.node_defect[node]
        a = self.yield_alpha
        return float((1.0 + die_area_mm2 * d0 / a) ** (-a))

    def interposer_yield(self, area_mm2: float) -> float:
        a = self.yield_alpha
        return float((1.0 + area_mm2 * self.interposer_defect / a) ** (-a))

    def uniform_hop_latency(self) -> Optional[float]:
        """The shared per-hop D2D latency if every protocol agrees, else
        ``None``. All three evaluator layers use this to pick the
        bit-pinned ``max_hops * h`` fast path (the default: every stock
        protocol sits at ``DEFAULT_HOP_LATENCY_S``) over the per-kind
        weighted sum needed for heterogeneous hop latencies."""
        lats = {p.hop_latency_s for p in self.protocols.values()}
        return lats.pop() if len(lats) == 1 else None


DEFAULT_DB = TechDB()
