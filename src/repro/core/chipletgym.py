"""ChipletGym-style baseline models [18] (Sec VI-B comparisons).

Reproduces the simplifying assumptions the paper criticizes:
  * fixed D2D latency — 17.2 ps for 2.5D, 1.6 ps for 3D — independent of
    interconnect, topology, chiplet count or size;
  * energy = energy-per-MAC only (no DRAM, SRAM or protocol overheads);
  * constant bonding yield of 0.99 for every packaging type;
  * no area term and no CFP in the optimization objective.

The evaluator exposes the same signature as :func:`repro.core.evaluate.
evaluate`. In the Pathfinder v2 API it is the ``objective="chipletgym"``
backend (``repro.pathfinding.Pathfinder``), which replaces the seed
``evaluate_fn`` swap; batched strategies fall back to per-row scalar
evaluation for this backend.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core import cost as cost_mod
from repro.core import d2d as d2d_mod
from repro.core import scalesim as sim_mod
from repro.core.evaluate import Metrics, package_area_mm2
from repro.core.scalesim import SimCache
from repro.core.system import HISystem
from repro.core.techdb import (
    CHIPLETGYM_BOND_YIELD,
    CHIPLETGYM_D2D_LATENCY_25D_S,
    CHIPLETGYM_D2D_LATENCY_3D_S,
    DEFAULT_DB,
    TechDB,
)
from repro.core.workload import DEFAULT_TILE, GEMMWorkload, tile_and_assign


def evaluate_chipletgym(
    sys: HISystem,
    wl: GEMMWorkload,
    db: TechDB = DEFAULT_DB,
    tile_sizes: Tuple[int, int, int] = DEFAULT_TILE,
    cache: Optional[SimCache] = None,
) -> Metrics:
    cache = cache if cache is not None else SimCache()
    assignments = tile_and_assign(wl, sys.chiplets, sys.mapping, tile_sizes, db)
    topo = d2d_mod.build_topology(sys, db)
    mem = db.memories[sys.memory]
    total_bw = mem.bw_gbs_per_channel * mem.max_channels * 8e9

    sims = [cache.simulate(a.tiles, a.core, sys.mapping.dataflow)
            for a in assignments]

    # compute + DRAM read, with a flat (non-topology) memory bandwidth share
    l_cr = 0.0
    for a, s in zip(assignments, sims):
        l_comp = sim_mod.compute_latency_s(s, a.core, db)
        l_rd = s.dram_rd_bits / (total_bw / max(1, sys.n_chiplets))
        l_cr = max(l_cr, l_comp + l_rd)

    # fixed per-hop D2D latency regardless of traffic or interconnect
    fixed = (CHIPLETGYM_D2D_LATENCY_3D_S if sys.style == "3D"
             else CHIPLETGYM_D2D_LATENCY_25D_S)
    l_d2d = 0.0 if sys.style == "2D" else fixed * (sys.n_chiplets - 1)

    l_wr = 0.0
    for s in sims:
        l_wr = max(l_wr, s.dram_wr_bits / (total_bw / max(1, sys.n_chiplets)))
    latency = l_cr + l_d2d + l_wr

    # energy: MAC energy only
    energy = sum(s.macs * db.mac_energy_pj(a.core.node)
                 for a, s in zip(assignments, sims)) * 1e-12

    area = package_area_mm2(sys, topo, db)
    chiplets = sum(cost_mod.chiplet_cost(c, db) for c in sys.chiplets)
    interposer = 0.0
    if sys.style in ("2.5D", "2.5D+3D") and sys.pkg_25d in ("Passive", "Active"):
        interposer = cost_mod.interposer_cost(area, db)
    package = db.substrate_cost_mm2 * area
    dollar = ((chiplets + interposer + package) / CHIPLETGYM_BOND_YIELD
              + mem.cost_usd)

    return Metrics(
        latency_s=latency,
        energy_j=energy,
        area_mm2=area,
        dollar=dollar,
        emb_cfp_kg=0.0,     # ChipletGym models no CFP
        ope_cfp_kg=0.0,
        l_compute_rd_s=l_cr,
        l_d2d_s=l_d2d,
        l_dram_wr_s=l_wr,
        e_compute_j=energy,
        e_d2d_j=0.0,
        d2d_bits=0,
        macs=sum(s.macs for s in sims),
    )
