"""Regional scenario axes: one cell of a scenario grid, beyond scalar CI.

A :class:`Region` bundles the per-region runtime axes of the scenario
engine (Carbon Connect / ECO-CHIP, see ``repro.core.carbon``):

* ``carbon_intensity`` — scalar grid intensity (kgCO2e/kWh), the PR 4 axis;
* ``grid_profile``     — optional 24h intensity profile; ``None`` = flat at
  ``carbon_intensity`` (bit-identical to the scalar model);
* ``electricity_price``— regional $/kWh, added to the dollar metric as the
  lifetime electricity bill (0.0 = neutral);
* ``emb_factor``       — regional fab-grid embodied-carbon multiplier
  (1.0 = neutral);
* ``price_profile``    — optional 24h $/kWh price curve; ``None`` = flat
  at ``electricity_price`` (bit-identical to the scalar price). Like the
  grid profile it is dotted with the design's decoded load profile, so
  a schedule-axis search can chase cheap hours as well as clean ones.

``ScenarioSweep`` accepts ``{name: Region}`` as well as the historical
``{name: float}`` — :func:`as_region` coerces a bare float to a
neutral-axes region, which reproduces the scalar-CI behavior exactly.
:func:`measured_profile` pulls 24h intensity rows from the checked-in
ElectricityMaps-style dataset (``repro.core.grid_traces``) instead of
the synthetic :func:`diurnal_profile` sinusoid.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple, Union

import numpy as np

from repro.core.techdb import HOURS_PER_DAY


@dataclasses.dataclass(frozen=True)
class Region:
    """Per-region runtime axes of one scenario cell (all but the scalar
    carbon intensity default to their neutral values)."""

    carbon_intensity: float
    electricity_price: float = 0.0
    emb_factor: float = 1.0
    grid_profile: Optional[Tuple[float, ...]] = None
    price_profile: Optional[Tuple[float, ...]] = None

    def __post_init__(self) -> None:
        for field in ("grid_profile", "price_profile"):
            prof = getattr(self, field)
            if prof is not None:
                prof = tuple(float(x) for x in prof)
                if len(prof) != HOURS_PER_DAY:
                    raise ValueError(
                        f"{field} needs {HOURS_PER_DAY} hourly entries, "
                        f"got {len(prof)}")
                object.__setattr__(self, field, prof)

    def profile_array(self) -> np.ndarray:
        """float64[24] grid-intensity row for the device program; a
        ``None`` profile synthesizes the flat row at ``carbon_intensity``
        (whose in-program correction term is exactly +0.0)."""
        if self.grid_profile is None:
            return np.full(HOURS_PER_DAY, np.float64(self.carbon_intensity))
        return np.asarray(self.grid_profile, dtype=np.float64)

    def price_array(self) -> np.ndarray:
        """float64[24] electricity-price row for the device program; a
        ``None`` curve synthesizes the flat row at ``electricity_price``
        (whose in-program correction term is exactly +0.0)."""
        if self.price_profile is None:
            return np.full(HOURS_PER_DAY, np.float64(self.electricity_price))
        return np.asarray(self.price_profile, dtype=np.float64)

    def db_overrides(self) -> dict:
        """Field patch for ``dataclasses.replace(db, **...)`` so the
        scalar path evaluates under this region's axes."""
        return dict(carbon_intensity=self.carbon_intensity,
                    electricity_price=self.electricity_price,
                    emb_factor=self.emb_factor,
                    grid_profile=self.grid_profile,
                    price_profile=self.price_profile)


RegionLike = Union[float, Region]


def as_region(spec: RegionLike) -> Region:
    """Coerce a scenario-cell spec: a bare float is the historical
    scalar-CI region with neutral price/embodied/profile axes."""
    if isinstance(spec, Region):
        return spec
    return Region(carbon_intensity=float(spec))


def diurnal_profile(ci_mean: float, swing: float = 0.3,
                    peak_hour: int = 19) -> Tuple[float, ...]:
    """Synthetic 24h grid-intensity profile: a sinusoid of relative
    amplitude ``swing`` around ``ci_mean`` peaking at ``peak_hour``
    (evening ramp, duck-curve-ish). Mean over the day equals
    ``ci_mean``, so under a flat load profile the effective intensity
    stays close to the scalar model while hourly structure is real."""
    return tuple(
        ci_mean * (1.0 + swing * math.cos(2.0 * math.pi
                                          * (h - peak_hour) / HOURS_PER_DAY))
        for h in range(HOURS_PER_DAY))


def measured_profile(name: str, season: str = "summer",
                     day: str = "weekday") -> Tuple[float, ...]:
    """Measured 24h grid-intensity trace for a reference region
    (ElectricityMaps-style checked-in dataset, see
    :mod:`repro.core.grid_traces`) — the drop-in replacement for the
    synthetic :func:`diurnal_profile` in examples and benchmarks."""
    from repro.core.grid_traces import grid_trace

    return grid_trace(name, season=season, day=day)
