"""SA solution space + hierarchical moves (Sec V), and legacy shims.

Components: (1) the solution space = valid :class:`HISystem` vectors,
(2) hierarchical moves — application-level (mapping) vs lower-level
(chip-architecture / chiplet / package) perturbations with validity repair,
(3) the Eq. 17 cost on min/median-normalized metrics.

The annealing *loop* itself moved to
:class:`repro.pathfinding.SimulatedAnnealing` (Pathfinder API v2);
``anneal`` below is a thin deprecation shim that reproduces the seed
behaviour bit-for-bit. ``fit_normalizer`` remains the scalar reference
loop — prefer :func:`repro.pathfinding.fit_normalizer_batched` for large
populations (>= 5x faster via the array evaluator).

Runtime mitigations from Sec V-D are both present: the ScaleSim-equivalent
simulation cache (shared across the whole anneal — node-only chiplet moves
hit the cache because cycle count is node-independent) and incremental
re-evaluation falls out of the same property.

Schedule (Sec VI-A): T0 = 4000, Tf = 0.001, cooling 0.99, 50 moves/temp.
"""
from __future__ import annotations

import dataclasses
import random
import warnings
from typing import Callable, List, Optional, Tuple

from repro.core import comm as comm_mod
from repro.core.chiplet import Chiplet
from repro.core.evaluate import Metrics, evaluate
from repro.core.scalesim import SimCache
from repro.core.system import HISystem, is_valid, style_for_count
from repro.core.techdb import (
    DEFAULT_DB,
    PKG_PROTOCOLS_25D,
    PKG_PROTOCOLS_3D,
    TechDB,
)
from repro.core.templates import Normalizer, Template
from repro.core.workload import GEMMWorkload, Mapping


@dataclasses.dataclass
class SAConfig:
    t_initial: float = 4000.0
    t_final: float = 0.001
    cooling: float = 0.99
    moves_per_temp: int = 50
    max_chiplets: int = 6
    norm_samples: int = 10_000
    seed: int = 0


@dataclasses.dataclass
class SAResult:
    best: HISystem
    best_metrics: Metrics
    best_cost: float
    history: List[float]
    evaluations: int
    cache: SimCache


# ---------------------------------------------------------------------------
# Multi-objective cost vector (the Fig. 13 / Pareto axes)
# ---------------------------------------------------------------------------

# The three trade-off axes the paper's frontier figures plot: performance
# (latency), system cost (dollars) and carbon footprint (embodied +
# operational). Every scalarized Eq. 17 cost collapses these; the Pareto
# machinery in :mod:`repro.pathfinding.pareto` keeps them separate.
OBJECTIVE_AXES: Tuple[str, str, str] = ("latency_s", "dollar", "total_cfp")


def cost_vector(m: Metrics) -> Tuple[float, float, float]:
    """Per-axis ``(latency_s, dollar, total_cfp)`` objective vector.

    The scalar reference for the batched/device renderings
    (:meth:`repro.pathfinding.Objective.cost_vector_batch` and the fused
    jit program in :mod:`repro.pathfinding.device`): all three must agree
    within 1e-6 relative. All axes are *minimized*; unlike the Eq. 17
    scalar cost the vector is unnormalized (raw metric units), so
    frontiers are comparable across normalizers and templates."""
    return (m.latency_s, m.dollar, m.total_cfp)


# ---------------------------------------------------------------------------
# Random valid system generation
# ---------------------------------------------------------------------------


def random_chiplet(rng: random.Random, db: TechDB) -> Chiplet:
    a = rng.choice(db.array_sizes)
    t = rng.choice(db.tech_nodes)
    s = rng.choice(db.sram_sizes_kb[a])
    return Chiplet(a, t, s)


def random_mapping(rng: random.Random) -> Mapping:
    return Mapping(rng.choice((0, 1)), rng.choice(("OS", "WS", "IS")),
                   rng.choice((0, 1)))


def _pick_25d(rng: random.Random) -> Tuple[str, str]:
    pkg = rng.choice(list(PKG_PROTOCOLS_25D))
    return pkg, rng.choice(PKG_PROTOCOLS_25D[pkg])


def _pick_3d(rng: random.Random) -> Tuple[str, str]:
    pkg = rng.choice(list(PKG_PROTOCOLS_3D))
    return pkg, rng.choice(PKG_PROTOCOLS_3D[pkg])


def _style_fields(style: str, n: int, rng: random.Random):
    """pkg/proto/stack fields consistent with a style and chiplet count."""
    pkg25 = proto25 = pkg3 = proto3 = None
    stack: Tuple[int, ...] = ()
    if style in ("2.5D", "2.5D+3D"):
        pkg25, proto25 = _pick_25d(rng)
    if style in ("3D", "2.5D+3D"):
        pkg3, proto3 = _pick_3d(rng)
    if style == "2.5D+3D":
        size = rng.randint(2, n - 1)
        stack = tuple(sorted(rng.sample(range(n), size)))
    return pkg25, proto25, pkg3, proto3, stack


def random_system(rng: random.Random, db: TechDB = DEFAULT_DB,
                  max_chiplets: int = 6) -> HISystem:
    """Random but *valid* HI system (SA initialization, Sec V-A)."""
    while True:
        n = rng.randint(1, max_chiplets)
        if n == 1:
            style = "2D"
        elif n == 2:
            style = rng.choice(("2.5D", "3D"))
        else:
            style = rng.choice(("2.5D", "3D", "2.5D+3D"))
        pkg25, proto25, pkg3, proto3, stack = _style_fields(style, n, rng)
        sys = HISystem(
            chiplets=tuple(random_chiplet(rng, db) for _ in range(n)),
            style=style,
            memory=rng.choice(list(db.memories)),
            mapping=random_mapping(rng),
            pkg_25d=pkg25, proto_25d=proto25,
            pkg_3d=pkg3, proto_3d=proto3,
            stack=stack,
        )
        if is_valid(sys, db, max_chiplets):
            return sys


# ---------------------------------------------------------------------------
# Hierarchical moves (Sec V-B)
# ---------------------------------------------------------------------------


def _move_application(sys: HISystem, rng: random.Random, db: TechDB) -> HISystem:
    m = sys.mapping
    which = rng.randrange(3)
    if which == 0:    # dataflow
        m = Mapping(m.order,
                    rng.choice([d for d in ("OS", "WS", "IS")
                                if d != m.dataflow]), m.split_k)
    elif which == 1:  # split-K toggle
        m = Mapping(m.order, m.dataflow, 1 - m.split_k)
    else:             # assigning order toggle
        m = Mapping(1 - m.order, m.dataflow, m.split_k)
    return dataclasses.replace(sys, mapping=m)


def _repair_style(sys: HISystem, rng: random.Random, db: TechDB) -> HISystem:
    """Dynamic HI-type adjustment + field repair after a count change."""
    n = sys.n_chiplets
    style = style_for_count(n, sys.style)
    pkg25, proto25 = sys.pkg_25d, sys.proto_25d
    pkg3, proto3 = sys.pkg_3d, sys.proto_3d
    stack = sys.stack
    if style in ("2.5D", "2.5D+3D") and not pkg25:
        pkg25, proto25 = _pick_25d(rng)
    if style in ("3D", "2.5D+3D") and not pkg3:
        pkg3, proto3 = _pick_3d(rng)
    if style != "2.5D+3D":
        stack = ()
    else:
        stack = tuple(i for i in stack if i < n)
        if len(stack) < 2 or len(stack) >= n:
            size = rng.randint(2, n - 1)
            stack = tuple(sorted(rng.sample(range(n), size)))
    if style == "2D":
        pkg25 = proto25 = pkg3 = proto3 = None
    if style == "2.5D":
        pkg3 = proto3 = None
    if style == "3D":
        pkg25 = proto25 = None
    return dataclasses.replace(
        sys, style=style, pkg_25d=pkg25, proto_25d=proto25,
        pkg_3d=pkg3, proto_3d=proto3, stack=stack)


def _move_chip_arch(sys: HISystem, rng: random.Random, db: TechDB,
                    max_chiplets: int) -> HISystem:
    if rng.random() < 0.5:   # grow/shrink chiplet count
        n = sys.n_chiplets
        delta = rng.choice((-1, 1))
        n2 = min(max(n + delta, 1), max_chiplets)
        if n2 == n:
            n2 = min(max(n - delta, 1), max_chiplets)
        chips = list(sys.chiplets)
        noc = list(sys.noc)
        if n2 > n:
            chips.append(random_chiplet(rng, db))
            if noc:   # new chiplet starts at the neutral single-tile mesh
                noc.append(comm_mod.NOC_NEUTRAL)
        else:
            idx = rng.randrange(len(chips))
            chips.pop(idx)
            if noc:
                noc.pop(idx)
        sys = dataclasses.replace(sys, chiplets=tuple(chips),
                                  noc=tuple(noc))
        return _repair_style(sys, rng, db)
    # memory-type move
    mem = rng.choice([m for m in db.memories if m != sys.memory])
    return dataclasses.replace(sys, memory=mem)


def _move_chiplet(sys: HISystem, rng: random.Random, db: TechDB) -> HISystem:
    idx = rng.randrange(sys.n_chiplets)
    chips = list(sys.chiplets)
    new = random_chiplet(rng, db)
    while new == chips[idx]:
        new = random_chiplet(rng, db)
    chips[idx] = new
    return dataclasses.replace(sys, chiplets=tuple(chips))


def _move_noc(sys: HISystem, rng: random.Random, db: TechDB) -> HISystem:
    """mesh_noc comm-model move: re-draw one chiplet's (mesh dims, entry
    placement) pair uniformly, excluding the current assignment."""
    idx = rng.randrange(sys.n_chiplets)
    cur = sys.noc[idx]
    while True:
        cand = (rng.randrange(len(comm_mod.MESH_DIMS)),
                rng.randrange(len(comm_mod.ENTRY_PLACEMENTS)))
        if cand != cur:
            break
    noc = list(sys.noc)
    noc[idx] = cand
    return dataclasses.replace(sys, noc=tuple(noc))


def _move_schedule(sys: HISystem, rng: random.Random,
                   db: TechDB) -> HISystem:
    """window schedule-model move: shift the start hour or re-draw the
    duty-window shape, excluding the current value (rejection-free —
    the offset draw can never land on the current assignment)."""
    from repro.core import schedule as sched_mod

    start, shape = sys.schedule
    if rng.randrange(2) == 0:
        start = (start + 1 + rng.randrange(
            sched_mod.HOURS_PER_DAY - 1)) % sched_mod.HOURS_PER_DAY
    else:
        n = sched_mod.n_schedule_shapes()
        shape = (shape + 1 + rng.randrange(n - 1)) % n
    return dataclasses.replace(sys, schedule=(start, shape))


def seed_schedule(sys: HISystem) -> HISystem:
    """Attach the neutral (0, 0) schedule to a fixed-schedule system.

    The temporal twin of :func:`seed_noc`: strategies searching a *live*
    window :class:`~repro.pathfinding.DesignSpace` call this on their
    random seeds before proposing — ``random_system`` draws no schedule
    axes (keeping its RNG stream legacy-identical) and :func:`propose`
    only fires schedule moves on systems that carry one. Neutral (start
    0, shape 0) decodes to ``db.load_profile`` itself, so the seeded
    system evaluates bit-identically. No RNG draws."""
    if sys.schedule is not None:
        return sys
    from repro.core.schedule import SCHED_NEUTRAL

    return dataclasses.replace(sys, schedule=SCHED_NEUTRAL)


def seed_noc(sys: HISystem) -> HISystem:
    """Attach the neutral per-chiplet NoC assignment to a legacy system.

    Strategies searching a *live* mesh_noc space call this on their
    random seeds before proposing: ``random_system`` draws no NoC axes
    (keeping its RNG stream legacy-identical), and :func:`propose` only
    fires NoC moves on systems that carry them. Neutral = (1x1 mesh,
    corner entry) per chiplet — zero mesh hops, one router — so the
    seeded system evaluates bit-identically to its legacy self. No RNG
    draws."""
    if sys.noc:
        return sys
    return dataclasses.replace(
        sys, noc=(comm_mod.NOC_NEUTRAL,) * sys.n_chiplets)


def _move_package(sys: HISystem, rng: random.Random, db: TechDB) -> HISystem:
    if sys.style == "2D":
        return sys
    options = []
    if sys.style in ("2.5D", "2.5D+3D"):
        options += ["pkg25", "proto25"]
    if sys.style in ("3D", "2.5D+3D"):
        options += ["pkg3"]
    which = rng.choice(options)
    if which == "pkg25":
        pkg = rng.choice([p for p in PKG_PROTOCOLS_25D if p != sys.pkg_25d])
        proto = (sys.proto_25d if sys.proto_25d in PKG_PROTOCOLS_25D[pkg]
                 else rng.choice(PKG_PROTOCOLS_25D[pkg]))
        return dataclasses.replace(sys, pkg_25d=pkg, proto_25d=proto)
    if which == "proto25":
        protos = [p for p in PKG_PROTOCOLS_25D[sys.pkg_25d]
                  if p != sys.proto_25d]
        if not protos:
            return sys
        return dataclasses.replace(sys, proto_25d=rng.choice(protos))
    pkg = rng.choice([p for p in PKG_PROTOCOLS_3D if p != sys.pkg_3d])
    return dataclasses.replace(sys, pkg_3d=pkg, proto_3d="UCIe-3D")


def propose(sys: HISystem, rng: random.Random, db: TechDB = DEFAULT_DB,
            max_chiplets: int = 6, p_application: float = 0.35,
            noc_moves: bool = False,
            schedule_moves: bool = False) -> HISystem:
    """Hierarchical move selection: application level first, then one of
    the lower levels; repair + validity check, retry until valid.

    ``noc_moves=True`` (set by strategies searching a *live* mesh_noc
    :class:`~repro.pathfinding.DesignSpace`) adds the NoC axes as a
    fourth lower level; ``schedule_moves=True`` (live window schedule
    spaces) adds the temporal axis as the next one. The defaults consume
    the exact legacy RNG stream, so legacy and frozen-neutral searches
    are bit-identical."""
    noc_on = bool(noc_moves and sys.noc)
    sched_on = bool(schedule_moves and sys.schedule is not None)
    n_levels = 3 + noc_on + sched_on
    for _ in range(64):
        if rng.random() < p_application:
            cand = _move_application(sys, rng, db)
        else:
            level = rng.randrange(n_levels)
            if level == 0:
                cand = _move_chip_arch(sys, rng, db, max_chiplets)
            elif level == 1:
                cand = _move_chiplet(sys, rng, db)
            elif level == 2:
                cand = _move_package(sys, rng, db)
            elif level == 3 and noc_on:
                cand = _move_noc(sys, rng, db)
            else:
                cand = _move_schedule(sys, rng, db)
        if is_valid(cand, db, max_chiplets):
            return cand
    return sys


# ---------------------------------------------------------------------------
# The annealer
# ---------------------------------------------------------------------------


def fit_normalizer(
    wl: GEMMWorkload,
    db: TechDB = DEFAULT_DB,
    samples: int = 10_000,
    seed: int = 1234,
    cache: Optional[SimCache] = None,
    evaluate_fn: Callable[..., Metrics] = evaluate,
    max_chiplets: int = 6,
) -> Normalizer:
    """Sample random valid systems and fit the min/median normalizer."""
    rng = random.Random(seed)
    cache = cache if cache is not None else SimCache()
    pop = []
    for _ in range(samples):
        s = random_system(rng, db, max_chiplets)
        pop.append(evaluate_fn(s, wl, db, cache=cache))
    return Normalizer.fit(pop)


def anneal(
    wl: GEMMWorkload,
    template: Template,
    db: TechDB = DEFAULT_DB,
    config: Optional[SAConfig] = None,
    norm: Optional[Normalizer] = None,
    cache: Optional[SimCache] = None,
    evaluate_fn: Callable[..., Metrics] = evaluate,
    initial: Optional[HISystem] = None,
) -> SAResult:
    """Deprecation shim over the Pathfinder v2 API.

    The annealing engine now lives in
    :class:`repro.pathfinding.SimulatedAnnealing`; this wrapper preserves
    the seed call signature and, *for a given normalizer*, produces
    bit-identical trajectories (same RNG stream, same moves, same
    evaluations). With ``norm=None`` the auto-fitted normalizer now uses
    the true median (the ``Normalizer.fit`` even-length fix), so
    trajectories can differ slightly from the pre-fix release. Migrate
    to::

        Pathfinder(wl, template, db=db, norm=norm).search(
            strategy=SimulatedAnnealing(config))
    """
    warnings.warn(
        "repro.core.sa.anneal is deprecated; use repro.pathfinding."
        "Pathfinder with the SimulatedAnnealing strategy",
        DeprecationWarning, stacklevel=2)
    from repro.pathfinding import Pathfinder, SimulatedAnnealing

    cfg = config or SAConfig()
    cache = cache if cache is not None else SimCache()
    if norm is None:
        norm = fit_normalizer(wl, db, min(cfg.norm_samples, 2000),
                              cfg.seed + 1, cache, evaluate_fn,
                              cfg.max_chiplets)
    pf = Pathfinder(wl, template, db=db, objective=evaluate_fn, norm=norm,
                    cache=cache, max_chiplets=cfg.max_chiplets)
    # SAResult has no frontier field, so collecting one here would be
    # pure per-move overhead (and would dilute cache-speedup ratios)
    res = pf.search(strategy=SimulatedAnnealing(cfg, initial=initial,
                                                frontier_size=0))
    return SAResult(res.best, res.best_metrics, res.best_cost, res.history,
                    res.evaluations, cache)
