"""GEMM workloads (Table IV) and the workload tiler/assigner (Algorithm 1).

A workload is an (M, K, N) GEMM. Algorithm 1 partitions it into tiles using
base tile sizes (t_M, t_K, t_N) — K is only partitioned when *split-K* is
enabled — and assigns contiguous tile ranges to cores proportionally to
their relative compute throughput, in ascending or descending core order
(*assigning order*).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.core.chiplet import Chiplet
from repro.core.techdb import DEFAULT_DB, TechDB


@dataclasses.dataclass(frozen=True)
class GEMMWorkload:
    name: str
    M: int  # batch dimension
    K: int  # input / reduction dimension
    N: int  # output dimension

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N

    @property
    def flops(self) -> int:
        return 2 * self.macs


# Table IV
WORKLOADS: Tuple[GEMMWorkload, ...] = (
    GEMMWorkload("WL1-GPT2-MLP", 512, 768, 3072),
    GEMMWorkload("WL2-ViT-MLP-b32", 6304, 768, 3072),
    GEMMWorkload("WL3-ViT-MLP-b1", 197, 768, 3072),
    GEMMWorkload("WL4-ResNet50-FC", 128, 2048, 1000),
    GEMMWorkload("WL5-VGG16-FC", 64, 4096, 4096),
    GEMMWorkload("WL6-MobileNetV2", 1316, 24, 144),
)


def workload(idx_or_name) -> GEMMWorkload:
    if isinstance(idx_or_name, int):
        return WORKLOADS[idx_or_name - 1]
    for wl in WORKLOADS:
        if wl.name == idx_or_name or wl.name.startswith(str(idx_or_name)):
            return wl
    raise KeyError(idx_or_name)


@dataclasses.dataclass(frozen=True)
class Tile:
    """One (m, k, n) tile of the GEMM; ``partial`` marks split-K tiles whose
    output is a partial sum that must be reduced on the destination core."""

    m: int
    k: int
    n: int
    partial: bool

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n


@dataclasses.dataclass(frozen=True)
class Mapping:
    """The paper's O-D-K workload-mapping triple."""

    order: int        # 0 = largest-first, 1 = smallest-first (s_A)
    dataflow: str     # OS | WS | IS
    split_k: int      # 0 | 1

    @property
    def name(self) -> str:
        return f"{self.order}-{self.dataflow}-{self.split_k}"

    @classmethod
    def parse(cls, name: str) -> "Mapping":
        o, d, k = name.split("-")
        return cls(int(o), d, int(k))


ALL_MAPPINGS: Tuple[Mapping, ...] = tuple(
    Mapping(o, d, k) for o in (0, 1) for d in ("OS", "WS", "IS") for k in (0, 1)
)  # 12 strategies (Sec V-A)

# Default base tile sizes. Large enough that cross-tile DRAM re-fetch
# amplification stays low (the buffer-fold model handles within-tile
# reuse), small enough that Table-IV workloads still produce more tiles
# than cores; configurable per call.
DEFAULT_TILE = (512, 512, 512)  # (t_M, t_K, t_N)


def _partition(total: int, base: int) -> List[int]:
    """Split ``total`` into chunks of ``base``; the last chunk absorbs the
    remainder (Algorithm 1 line 3: last tiles may exceed base size)."""
    if total <= base:
        return [total]
    count = total // base
    sizes = [base] * count
    rem = total - base * count
    if rem:
        sizes[-1] += rem
    return sizes


@dataclasses.dataclass(frozen=True)
class Assignment:
    """Tile assignment for one core: the core and its tile list."""

    core: Chiplet
    tiles: Tuple[Tile, ...]

    @property
    def macs(self) -> int:
        return sum(t.macs for t in self.tiles)


def tile_and_assign(
    wl: GEMMWorkload,
    cores: Sequence[Chiplet],
    mapping: Mapping,
    tile_sizes: Tuple[int, int, int] = DEFAULT_TILE,
    db: TechDB = DEFAULT_DB,
) -> List[Assignment]:
    """Algorithm 1: partition (M, K, N) into tiles and assign proportionally
    to core compute power, in the order dictated by ``mapping.order``.

    Returns one :class:`Assignment` per core, in the *original* core order
    (so callers can zip against their chiplet list).
    """
    t_m, t_k, t_n = tile_sizes
    b_m, b_n = t_m, t_n
    # line 1; when split-K is on, force at least two K-slices (a base size
    # above K/2 would silently de-activate the split)
    b_k = min(t_k, max(1, wl.K // 2)) if mapping.split_k else wl.K

    order = sorted(
        range(len(cores)),
        key=lambda i: cores[i].compute_power_ratio(db),
        reverse=not mapping.order,                               # line 2
    )

    ms = _partition(wl.M, b_m)                                   # line 3
    ks = _partition(wl.K, b_k)
    ns = _partition(wl.N, b_n)
    split = len(ks) > 1
    tiles = [
        Tile(m, k, n, partial=split)
        for m in ms for k in ks for n in ns                      # line 4
    ]
    total = len(tiles)

    powers = [cores[i].compute_power_ratio(db) for i in order]
    psum = sum(powers)
    ideal = [p / psum * total for p in powers]                   # line 6
    counts = [int(x) for x in ideal]                             # line 7
    remaining = total - sum(counts)
    # line 9: largest fractional parts get the leftovers
    frac_order = sorted(
        range(len(order)), key=lambda i: ideal[i] - counts[i], reverse=True)
    for i in frac_order[:remaining]:
        counts[i] += 1

    assignments: List[Assignment] = [None] * len(cores)          # type: ignore
    start = 0                                                    # lines 10-14
    for pos, core_idx in enumerate(order):
        n_tiles = counts[pos]
        assignments[core_idx] = Assignment(
            cores[core_idx], tuple(tiles[start:start + n_tiles]))
        start += n_tiles
    return assignments


def destination_index(cores: Sequence[Chiplet], db: TechDB = DEFAULT_DB) -> int:
    """The paper designates the largest chiplet as the reduction destination
    (greatest compute capacity and memory bandwidth)."""
    return max(range(len(cores)), key=lambda i: cores[i].area_mm2(db))
