"""Chiplet library: pre-designed systolic-array AI accelerator dies.

Each chiplet is identified by the paper's A-T-S notation (array size -
tech node - SRAM KB), e.g. ``128-7-1024``. Area and power derive from the
synthesis-calibrated 7nm values in :mod:`repro.core.techdb`, scaled per
node. The library enumerates every valid (A, T, S) combination of Table II.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Tuple

from repro.core.techdb import DEFAULT_DB, TechDB


@dataclasses.dataclass(frozen=True)
class Chiplet:
    """A characterized accelerator die drawn from the chiplet library."""

    array: int          # systolic array dimension (array x array PEs)
    node: int           # technology node, nm
    sram_kb: int        # on-chip buffer capacity (split into 3 equal buffers)

    @property
    def name(self) -> str:
        return f"{self.array}-{self.node}-{self.sram_kb}"

    @classmethod
    def parse(cls, name: str) -> "Chiplet":
        a, t, s = name.split("-")
        return cls(int(a), int(t), int(s))

    # -- physical characterization -----------------------------------------

    def area_mm2(self, db: TechDB = DEFAULT_DB) -> float:
        logic = db.array_area_7nm[self.array]
        sram = db.sram_area_per_kb * self.sram_kb
        return (logic + sram) * db.node_area_scale[self.node]

    def power_w(self, db: TechDB = DEFAULT_DB) -> float:
        dyn = db.array_power_7nm[self.array] * db.node_power_scale[self.node]
        leak = 2.0e-5 * self.sram_kb * db.node_power_scale[self.node]
        # power scales with achievable frequency at the node
        return (dyn + leak) * db.freq_ghz(self.node)

    def static_power_w(self, db: TechDB = DEFAULT_DB) -> float:
        """Leakage + clock-tree power burned whenever the system is on;
        charged per second of system latency in the energy model."""
        return db.static_power_fraction * self.power_w(db)

    def freq_ghz(self, db: TechDB = DEFAULT_DB) -> float:
        return db.freq_ghz(self.node)

    def peak_macs_per_s(self, db: TechDB = DEFAULT_DB) -> float:
        return self.array * self.array * self.freq_ghz(db) * 1e9

    @property
    def pe_count(self) -> int:
        return self.array * self.array

    def compute_power_ratio(self, db: TechDB = DEFAULT_DB) -> float:
        """Relative compute throughput p_p used by Algorithm 1 line 6."""
        return self.array * self.array * self.freq_ghz(db)

    def side_mm(self, db: TechDB = DEFAULT_DB) -> float:
        """Assume square dies; side length for bump-count models (Eq. 7)."""
        return math.sqrt(self.area_mm2(db))

    def perimeter_mm(self, db: TechDB = DEFAULT_DB) -> float:
        return 4.0 * self.side_mm(db)

    def buffer_bytes_each(self) -> int:
        """Three equally sized on-chip buffers (ifmap/filter/ofmap)."""
        return (self.sram_kb * 1024) // 3


def library(db: TechDB = DEFAULT_DB) -> Tuple[Chiplet, ...]:
    """Full chiplet library: every valid (A, T, S) from Table II."""
    return tuple(iter_library(db))


def iter_library(db: TechDB = DEFAULT_DB) -> Iterator[Chiplet]:
    for array in db.array_sizes:
        for node in db.tech_nodes:
            for sram in db.sram_sizes_kb[array]:
                yield Chiplet(array, node, sram)


# Named systems used throughout the paper's experiments (Sec VI-A).
def identical_chiplet_system(n: int = 4) -> Tuple[Chiplet, ...]:
    """*identical chiplet system*: n x 128-7-1024."""
    return tuple(Chiplet(128, 7, 1024) for _ in range(n))


def different_chiplet_system() -> Tuple[Chiplet, ...]:
    """*different chiplet system*: 64-7-256, 96-7-512, 128-7-1024, 192-7-2048."""
    return (
        Chiplet(64, 7, 256),
        Chiplet(96, 7, 512),
        Chiplet(128, 7, 1024),
        Chiplet(192, 7, 2048),
    )
