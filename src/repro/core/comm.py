"""Pluggable communication models: legacy pairwise links vs mesh-NoC + NoI.

The package-level communication model used to live in three bit-pinned
copies (scalar ``core/d2d.py``, host-batched ``pathfinding/batch.py``,
fused-device ``pathfinding/device.py``). This module is the single seam
all three share:

* ``legacy`` — the original pairwise-link model: traffic crosses the
  package interconnect only; on-chiplet distribution is free. The
  bit-pinned default; every golden was recorded under it.
* ``mesh_noc`` — each chiplet carries an on-die mesh NoC (dims a new
  design axis) whose traffic funnels through one interposer-NoI entry
  router (placement a new design axis). Per-bit NoC hop counts are
  **closed-form Manhattan index arithmetic** — no graph library, no BFS —
  so the model vectorizes into the fused jit program as pure elementwise
  math over the ``[P, C]`` slot layout.

Mesh hop model. A chiplet's PEs are tiles of an ``mx x my`` mesh; the
NoI entry router sits at integer coordinates ``(ex, ey)``. Traffic is
uniformly sourced across tiles, and XY routing makes the expected hop
count to the entry separable per axis:

    D(m, e) = (sum_{x<=e} (e-x) + sum_{x>e} (x-e)) / m
            = (e(e+1)/2 + (m-1-e)(m-e)/2) / m

    noc_hops(mx, my, ex, ey) = D(mx, ex) + D(my, ey)

Every bit leaving (entering) a chiplet pays its source's (destination's)
mean NoC hop count in router latency (``TechDB.noc_hop_latency_s``) and
router energy (``TechDB.noc_energy_pj_bit``), on top of the unchanged
package-level link model. Embodied router carbon scales with the
physical router count ``mx * my`` per chiplet (ECO-CHIP's ``router_c``
generalized from a flat area fraction), and operational router carbon
rides the traffic-proportional NoC energy term.

Neutrality. ``MESH_DIMS[0] == (1, 1)`` is the exact neutral element:
one tile, zero hops, one router. Every mesh-model term then reduces to
``x + 0.0`` / ``x * 1.0`` — bit-identical to legacy — which is what lets
the forced-on CI lane (``REPRO_COMM_MODEL=mesh_noc``) replay all legacy
goldens through the mesh program.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence, Tuple

import numpy as np

COMM_MODELS: Tuple[str, ...] = ("legacy", "mesh_noc")
DEFAULT_COMM = "legacy"
# Forces default-constructed DesignSpaces onto the mesh_noc encoding with
# the NoC axes *frozen at neutral* — the CI lane proving the mesh program
# is bit-invisible. Explicit ``DesignSpace(comm="mesh_noc")`` makes the
# axes live instead.
COMM_ENV_VAR = "REPRO_COMM_MODEL"

# Searchable mesh dimensions per chiplet. Index 0 is the neutral element
# (single tile: zero hops, one router) — the bit-exact legacy limit.
MESH_DIMS: Tuple[Tuple[int, int], ...] = (
    (1, 1), (2, 2), (4, 2), (4, 4), (8, 4), (8, 8))
# NoI entry-router placements within the mesh.
ENTRY_PLACEMENTS: Tuple[str, ...] = ("corner", "edge", "center")
NOC_NEUTRAL: Tuple[int, int] = (0, 0)


def resolve_comm(comm: Optional[str] = None) -> str:
    """Resolve a comm-model name; ``None`` consults ``REPRO_COMM_MODEL``."""
    if comm is None:
        comm = os.environ.get(COMM_ENV_VAR, "") or DEFAULT_COMM
    if comm not in COMM_MODELS:
        raise ValueError(
            f"unknown comm model {comm!r}; expected one of {COMM_MODELS}")
    return comm


def entry_coords(mx: int, my: int, placement: int) -> Tuple[int, int]:
    """Integer mesh coordinates of the NoI entry router."""
    if placement == 0:                       # corner
        return 0, 0
    if placement == 1:                       # middle of the bottom edge
        return (mx - 1) // 2, 0
    if placement == 2:                       # mesh center
        return (mx - 1) // 2, (my - 1) // 2
    raise ValueError(f"entry placement {placement} outside "
                     f"[0, {len(ENTRY_PLACEMENTS)})")


def axis_mean_hops(m: int, e: int) -> float:
    """Closed-form mean ``|x - e|`` over ``x in [0, m)`` (one mesh axis)."""
    return (e * (e + 1) // 2 + (m - 1 - e) * (m - e) // 2) / m


def mesh_mean_hops(mx: int, my: int, ex: int, ey: int) -> float:
    """Mean XY-routed hop count from a uniform tile to the entry router."""
    return axis_mean_hops(mx, ex) + axis_mean_hops(my, ey)


def noc_hop_count(mesh_idx: int, entry_idx: int) -> float:
    """Mean NoC hops for one chiplet's ``(mesh dims, entry placement)``."""
    mx, my = MESH_DIMS[mesh_idx]
    ex, ey = entry_coords(mx, my, entry_idx)
    return mesh_mean_hops(mx, my, ex, ey)


def n_routers(mesh_idx: int) -> int:
    """Physical router count of the mesh — the embodied-carbon multiplier."""
    mx, my = MESH_DIMS[mesh_idx]
    return mx * my


_TABLES: Optional[Tuple[np.ndarray, np.ndarray]] = None


def noc_tables() -> Tuple[np.ndarray, np.ndarray]:
    """``(hops[Mi, Ei] float64, routers[Mi] float64)`` lookup tables.

    The vectorized engines gather these by the encoded per-slot
    ``(mesh_idx, entry_idx)`` columns — the axes stay runtime data, the
    tables are trace-time constants shared by every mesh program.
    """
    global _TABLES
    if _TABLES is None:
        hops = np.array(
            [[noc_hop_count(mi, ei) for ei in range(len(ENTRY_PLACEMENTS))]
             for mi in range(len(MESH_DIMS))], dtype=np.float64)
        routers = np.array([float(n_routers(mi))
                            for mi in range(len(MESH_DIMS))],
                           dtype=np.float64)
        _TABLES = (hops, routers)
    return _TABLES


# ---------------------------------------------------------------------------
# The scalar CommModel seam (core/evaluate consumes it through d2d/carbon)
# ---------------------------------------------------------------------------


def system_noc_hops(sys) -> Tuple[float, ...]:
    """Per-chiplet mean NoC hop counts; all-zero for legacy systems."""
    if not getattr(sys, "noc", ()):
        return (0.0,) * sys.n_chiplets
    return tuple(noc_hop_count(mi, ei) for mi, ei in sys.noc)


def system_n_routers(sys) -> Tuple[int, ...]:
    """Per-chiplet physical router counts; all-one for legacy systems."""
    if not getattr(sys, "noc", ()):
        return (1,) * sys.n_chiplets
    return tuple(n_routers(mi) for mi, ei in sys.noc)


def validate_noc(noc: Sequence[Tuple[int, int]], n_chiplets: int) -> None:
    """Raise ``ValueError`` unless ``noc`` is a well-formed per-chiplet
    ``(mesh_idx, entry_idx)`` assignment."""
    if len(noc) != n_chiplets:
        raise ValueError(
            f"noc carries {len(noc)} entries for {n_chiplets} chiplets")
    for mi, ei in noc:
        if not 0 <= mi < len(MESH_DIMS):
            raise ValueError(f"mesh index {mi} outside "
                             f"[0, {len(MESH_DIMS)})")
        if not 0 <= ei < len(ENTRY_PLACEMENTS):
            raise ValueError(f"entry placement {ei} outside "
                             f"[0, {len(ENTRY_PLACEMENTS)})")
