"""Checkpoint/resume of segmented device searches.

The device engines (:class:`repro.pathfinding.device.DeviceEvaluator`
and :class:`~repro.pathfinding.device.ScenarioEngine`) no longer run one
monolithic ``lax.scan``: sweeps advance in fixed-size *segments* driven
by a host loop, and at every segment boundary the full search state —
the scan carry (chain populations, costs, incumbent best, RNG key
stream, per-cell sweep counters) plus the host-side
:class:`~repro.pathfinding.pareto.ParetoArchive` contents and the
accepted-cost history — is snapshotted through
:class:`repro.checkpoint.CheckpointManager` (sharded ``.npy`` + atomic
manifest writes). A preempted multi-thousand-cell sweep therefore
resumes from the newest valid boundary instead of restarting from zero,
and because the segmented scan consumes the *same* key stream as the
monolithic one, an interrupted-then-resumed run reproduces the
uninterrupted trajectory bit-for-bit.

This module holds the host-side state plumbing shared by both engines:

* :func:`search_fingerprint` — a digest of everything that defines the
  search (engine kind, seed, ladder, weight rows, normalizer rows,
  segment size, ...). It is stored inside every checkpoint; restoring
  under a different configuration raises instead of silently continuing
  a different search. :func:`segment_fingerprint` names the field set
  the segmented tempering engines share.
* :class:`SearchCheckpointer` — the thin engine-facing wrapper:
  ``save(sweep_done, carry, archives, history, fingerprint)`` at segment
  boundaries, ``restore(...)`` on entry (returns ``None`` when no valid
  checkpoint exists; archives are reloaded *in place* so the caller's
  references stay live).
* :func:`run_segmented` — the restore-or-init / advance-in-chunks /
  snapshot-at-boundaries host loop itself, shared by
  ``DeviceEvaluator.parallel_tempering`` and
  ``ScenarioEngine.parallel_tempering`` (the engines supply only the
  carry packing and output absorption).

The user surface lives one layer up: ``checkpoint_dir=`` / ``resume=``
on :class:`~repro.pathfinding.strategies.ParallelTempering`,
:class:`~repro.pathfinding.pareto.ScalarizationSweep`,
:meth:`~repro.pathfinding.pareto.ScenarioSweep.run` and
:meth:`~repro.pathfinding.pathfinder.Pathfinder.run_scenarios`.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.checkpoint import ELASTIC, CheckpointManager

# bump when the checkpoint tree layout changes incompatibly: the version
# participates in the fingerprint, so old trees are rejected, not
# misread
STATE_VERSION = 1


def search_fingerprint(kind: str, **parts: Any) -> np.ndarray:
    """``uint64[1]`` digest of a search configuration.

    ``parts`` values are arrays/scalars/None; the digest covers dtype,
    shape and exact bytes, so any change to the seed population, ladder,
    weight rows, normalizer rows, RNG seed or segmentation produces a
    different fingerprint. The total sweep count is deliberately *not*
    part of it: resuming may extend a finished run's budget."""
    h = hashlib.sha256()
    h.update(kind.encode())
    h.update(str(STATE_VERSION).encode())
    for name in sorted(parts):
        v = parts[name]
        h.update(name.encode())
        if v is None:
            h.update(b"\x00none")
            continue
        a = np.asarray(v)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    return np.frombuffer(h.digest()[:8], dtype=np.uint64).copy()


def segment_fingerprint(kind: str, *, v0, temps, swap_every, seed, mins,
                        medians, weights, pair_mask, ci,
                        segment: Optional[int], collect: bool,
                        **extra: Any) -> np.ndarray:
    """:func:`search_fingerprint` over the fields every segmented
    tempering engine shares (seed population, ladder, weight rows,
    normalizer rows, exchange gates, carbon intensity, segmentation).

    The *user-facing* ``segment`` knob is hashed (-1 = None), not the
    derived chunk size, so a finished ``segment=None`` run can be resumed
    with a larger sweep budget — the documented extension use case.
    Engine-specific fields (e.g. the scenario grid's workload ids) ride
    in ``extra``.

    The regional lifecycle axes — per-cell ``price``, ``embf`` and the
    24h grid-intensity ``profile`` — DO enter the fingerprint (via
    ``extra``, from every engine): they are search *inputs* that change
    the cost surface, so a checkpoint written under one regional grid
    must not resume under another. Neutral columns are materialized
    before hashing (0.0 / 1.0 / flat-at-ci), which means checkpoints
    written before the axes existed do not fingerprint-match and are
    ignored rather than mis-resumed.

    The schedule policy is the one exception to that materialize-first
    rule: the ``schedule`` model name (and the 24h ``pprofile`` price
    curve on the serving path) enters the fingerprint **only when
    non-neutral** — a ``"window"`` bucket hashes its schedule bytes, a
    ``"fixed"`` one hashes exactly the pre-scheduling field set. The
    neutral ``(0, 0)`` schedule is bit-invisible to the search, so a
    pre-scheduling checkpoint must stay byte-identical and keep
    resuming; a windowed search, whose encoded rows are wider and whose
    cost surface moves with the duty table, must never resume from a
    fixed-schedule snapshot (and vice versa).

    The kernel fast path is deliberately *outside* the fingerprint: the
    Pallas gather (``use_pallas`` / ``REPRO_PATHFINDER_PALLAS``) is an
    execution detail of the same search, exact on the integer prefix
    tables, so a checkpoint written with the kernel on resumes under the
    jnp path (and vice versa) — only float fusion noise (~1e-16), never
    the key stream or sweep indices, can differ across the switch."""
    return search_fingerprint(
        kind, v0=v0, temps=temps, swap_every=np.int64(swap_every),
        seed=np.int64(seed), mins=mins, medians=medians, weights=weights,
        pair_mask=pair_mask, ci=ci,
        segment=np.int64(-1 if segment is None else segment),
        collect=np.int64(bool(collect)), **extra)


def check_not_shrunk(done: int, sweeps: int) -> None:
    """Shared resume guard of both segmented engines: a checkpoint
    further along than the requested sweep count must raise, not
    silently hand back the over-run state."""
    if done > sweeps:
        raise ValueError(
            f"checkpoint is {done} sweeps in but this run asks for only "
            f"{sweeps}: shrinking a resumed search would silently "
            "over-run its budget — raise sweeps/budget or start a fresh "
            "checkpoint_dir")


@dataclasses.dataclass
class RestoredSearch:
    """What :meth:`SearchCheckpointer.restore` hands back to the engine."""

    sweep_done: int                     # completed sweeps (min over cells)
    sweep_done_per_cell: np.ndarray     # int64, 0-d (PT) or [S] (scenario)
    carry: Dict[str, np.ndarray]        # the scan carry at the boundary
    history: np.ndarray                 # accepted-cost history so far


class SearchCheckpointer:
    """Segment-boundary snapshot/restore for the device search engines.

    State is tiny (a few KB of chain rows + archive contents), so shards
    default to 1 file per leaf; ``keep`` rotates old boundaries away.
    Pass one instance per search — the directory is the unit of
    resumption."""

    def __init__(self, directory: str, keep: int = 3, n_shards: int = 1):
        self.directory = directory
        self.manager = CheckpointManager(directory, keep=keep,
                                         n_shards=n_shards)

    # -- engine-facing API --------------------------------------------------

    def save(self, sweep_done: Union[int, np.ndarray],
             carry: Dict[str, np.ndarray],
             archives: Union[None, object, Sequence[object]],
             history: np.ndarray, fingerprint: np.ndarray) -> str:
        """Snapshot one segment boundary (atomic; step = sweeps done)."""
        done = np.asarray(sweep_done, dtype=np.int64)
        tree = {
            "carry": {k: np.asarray(v) for k, v in carry.items()},
            "archives": self._archive_list(archives),
            "history": np.asarray(history, dtype=np.float64),
            "sweep_done": done,
            "fingerprint": np.asarray(fingerprint, dtype=np.uint64),
        }
        return self.manager.save(int(done.min()), tree)

    def restore(self, carry_like: Dict[str, np.ndarray],
                archives: Union[None, object, Sequence[object]],
                fingerprint: np.ndarray) -> Optional[RestoredSearch]:
        """Restore the newest boundary *of this search*, or ``None``
        when the directory holds no checkpoint yet. Archives are
        reloaded in place.

        Snapshots written by a different configuration are skipped (and
        left on disk — they belong to another search, e.g. survivors of
        a ``resume=False`` restart sharing the directory); corrupt ones
        are pruned like :meth:`CheckpointManager.restore` does. Only
        when the directory holds snapshots but *none* match does this
        raise ``ValueError`` — the config changed under an existing
        checkpoint_dir."""
        import shutil

        from repro.checkpoint import CorruptCheckpointError, load_checkpoint

        arch_list = self._archive_list(archives)
        like = {
            "carry": {k: np.asarray(v) for k, v in carry_like.items()},
            "archives": arch_list,
            "history": ELASTIC,
            "sweep_done": ELASTIC,
            "fingerprint": np.zeros(1, dtype=np.uint64),
        }
        want = np.asarray(fingerprint, dtype=np.uint64)
        tree = None
        mismatched = 0
        for s in reversed(self.manager.all_steps()):
            path = self.manager.step_path(s)
            try:
                _, t = load_checkpoint(path, like)
            except CorruptCheckpointError:
                shutil.rmtree(path, ignore_errors=True)
                continue
            except (KeyError, ValueError):
                # structurally incompatible = written by a different
                # search shape (e.g. another chain count): foreign, not
                # corrupt — skip it, keep looking for our own snapshot
                mismatched += 1
                continue
            if not np.array_equal(
                    np.asarray(t["fingerprint"], dtype=np.uint64), want):
                mismatched += 1
                continue
            tree = t
            break
        if tree is None:
            if mismatched:
                raise ValueError(
                    f"checkpoint in {self.directory} was written by a "
                    "different search configuration (seed / ladder / "
                    "weights / normalizer / segment size changed) — "
                    "point checkpoint_dir at a fresh directory or pass "
                    "resume=False")
            return None
        for dst, src in zip(arch_list, tree["archives"]):
            dst.load_checkpoint_arrays(src.checkpoint_arrays())
        done = np.asarray(tree["sweep_done"], dtype=np.int64)
        return RestoredSearch(
            sweep_done=int(done.min()),
            sweep_done_per_cell=done,
            carry={k: np.asarray(v) for k, v in tree["carry"].items()},
            history=np.asarray(tree["history"], dtype=np.float64))

    @staticmethod
    def _archive_list(archives) -> List[object]:
        if archives is None:
            return []
        if isinstance(archives, (list, tuple)):
            return list(archives)
        return [archives]


def run_segmented(*, sweeps: int, seg_size: int, checkpoint, resume: bool,
                  fingerprint: Optional[np.ndarray],
                  archives: Union[None, object, Sequence[object]],
                  carry_like: Optional[Dict[str, np.ndarray]],
                  fresh: Callable[[], Any],
                  from_restored: Callable[[RestoredSearch], Any],
                  run_segment: Callable[[Any, int, int], Tuple[Any, Any]],
                  absorb: Callable[[Any, int], None],
                  carry_np: Callable[[Any], Dict[str, np.ndarray]],
                  history_np: Callable[[], np.ndarray],
                  sweep_counter: Callable[[int], Union[int, np.ndarray]],
                  flush_seed: Callable[[], None]) -> Tuple[Any, int]:
    """The host segment loop shared by both device tempering engines
    (restore-or-init / advance-in-chunks / snapshot-at-boundaries).

    :meth:`DeviceEvaluator.parallel_tempering
    <repro.pathfinding.device.DeviceEvaluator.parallel_tempering>` and
    :meth:`ScenarioEngine.parallel_tempering
    <repro.pathfinding.device.ScenarioEngine.parallel_tempering>` differ
    only in what the carry *is* (single-cell vs stacked, one RNG key vs a
    per-cell key matrix), how a segment's outputs are absorbed (flat
    history + one archive vs per-cell histories + per-cell archives) and
    what the checkpoint's sweep counter looks like (scalar vs per-cell
    vector); the control flow — which is what checkpoint correctness
    hangs on — is this one function:

    1. With ``checkpoint``/``resume``, restore the newest matching
       snapshot; otherwise initialize fresh state via ``fresh()``
       (``from_restored(r)`` rebuilds the device carry; a restored run
       further along than ``sweeps`` raises via
       :func:`check_not_shrunk`).
    2. Advance in chunks: ``run_segment(carry, done, seg)`` invokes the
       engine's compiled scan for ``seg = min(seg_size, sweeps - done)``
       sweeps; ``absorb(ys, seg)`` feeds history/archives (including the
       engine's lazily-prepended seed block).
    3. After every chunk, snapshot ``(sweep_counter(done),
       carry_np(carry), archives, history_np(), fingerprint)``.
    4. ``flush_seed()`` covers the zero-sweep / resumed-complete edge
       where the loop body never ran to consume the seed block.

    Returns ``(carry, done)``. Bit-exactness contract: this drives the
    exact same call sequence as the historical in-engine loops, so the
    goldens in ``tests/test_resume.py`` pin it unchanged."""
    restored = None
    if checkpoint is not None and resume:
        restored = checkpoint.restore(carry_like, archives, fingerprint)
    if restored is None:
        carry = fresh()
        done = 0
    else:
        carry = from_restored(restored)
        done = restored.sweep_done
        check_not_shrunk(done, sweeps)
    while done < sweeps:
        seg = min(seg_size, sweeps - done)
        carry, ys = run_segment(carry, done, seg)
        absorb(ys, seg)
        done += seg
        if checkpoint is not None:
            checkpoint.save(sweep_counter(done), carry_np(carry),
                            archives, history_np(), fingerprint)
    # a zero-sweep run (or a resumed-complete one) never feeds the seed
    # population through the loop
    flush_seed()
    return carry, done
