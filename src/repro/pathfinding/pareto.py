"""Pareto-frontier pathfinding: multi-objective archive + frontier sweeps.

CarbonPATH's central claim is the *trade-off* between performance, cost
and carbon — not any single scalarization of it. This module makes the
frontier a first-class search output instead of an ad-hoc rescan:

* :func:`non_dominated_mask` / :func:`non_dominated_mask_jnp` — exact
  host reference and vectorized ``jax.numpy`` renderings of the
  non-dominated (minimization) filter. Both use exact float comparisons,
  so they agree *exactly* on any input (asserted over 1k random fronts by
  ``benchmarks/pareto_frontier.py``).
* :class:`ParetoArchive` — a bounded archive of non-dominated
  ``(encoded design, objective vector)`` pairs over the
  :data:`repro.core.sa.OBJECTIVE_AXES` axes ``(latency_s, dollar,
  total_cfp)``. Inserts are chunked (pairwise filtering stays cheap),
  storage order is canonical (lexicographic), duplicates are dropped, and
  the archive is pruned to ``max_size`` by NSGA-II crowding distance —
  all deterministic, so inserting an archive into itself is a no-op.
* :func:`hypervolume` — exact 2-D/3-D dominated hypervolume w.r.t. a
  reference point (the frontier-quality scalar the benchmark tracks
  against evaluation budget).
* :class:`ScalarizationSweep` — K scalarization directions x N
  parallel-tempering chains in **one** batched device program: per-chain
  Eq. 17 weight rows and a replica-exchange pair mask keep each
  direction's temperature ladder independent inside a single fused
  ``lax.scan`` (reusing the PR-2 engine). Every evaluation feeds the
  archive, so one call maps the frontier.
* :class:`ScenarioSweep` — the deployment axis: a grid of
  ``TechDB.carbon_intensity`` values (regions) x workloads (Table IV
  GEMMs or MLP GEMMs derived from ``repro/configs`` model configs via
  :func:`workloads_from_configs`). On the device path the whole grid is
  one stacked program (:class:`repro.pathfinding.device.ScenarioEngine`:
  a single compile, per-cell ``fold_in``-derived keys, total budget
  split across cells, optional scenario-axis sharding).

Every search strategy now returns its archive through
``SearchResult.frontier``::

    from repro.core import TEMPLATES, workload
    from repro.pathfinding import Pathfinder, ScalarizationSweep

    pf = Pathfinder(workload(1), TEMPLATES["T1"])
    res = pf.search(ScalarizationSweep(directions=16, n_chains=4,
                                       sweeps=60))
    lat, cost, cfp = res.frontier.vectors.T     # the Pareto points
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.regions import Region, RegionLike, as_region
from repro.core.sa import OBJECTIVE_AXES, random_system
from repro.core.techdb import DEFAULT_DB, TechDB
from repro.core.templates import TEMPLATES, Template
from repro.core.workload import GEMMWorkload
from repro.pathfinding.space import DesignSpace

N_AXES = len(OBJECTIVE_AXES)

# pairwise-filter block size: chunked inserts keep the O(n^2) dominance
# comparison bounded at (chunk + max_size)^2 regardless of how many
# samples a sweep feeds in; total work scales as n_samples * chunk, so
# smaller chunks are *cheaper* for bulk feeds (each chunk is pre-filtered
# on its own before the merge — search batches are mostly dominated)
_INSERT_CHUNK = 512


# ---------------------------------------------------------------------------
# Non-dominated filtering: exact host reference + vectorized jnp rendering
# ---------------------------------------------------------------------------


def non_dominated_mask(points: np.ndarray) -> np.ndarray:
    """Exact host reference: boolean mask of non-dominated rows.

    Minimization on every axis. Row ``j`` is dominated iff some row ``i``
    is <= on all axes and < on at least one; exact duplicates do not
    dominate each other (both survive — dedup is the archive's job)."""
    p = np.atleast_2d(np.asarray(points, dtype=np.float64))
    if p.shape[0] == 0:
        return np.zeros(0, dtype=bool)
    le = np.all(p[:, None, :] <= p[None, :, :], axis=2)   # i <= j per pair
    lt = np.any(p[:, None, :] < p[None, :, :], axis=2)    # i < j somewhere
    return ~(le & lt).any(axis=0)


def non_dominated_mask_jnp(points) -> np.ndarray:
    """Vectorized ``jax.numpy`` non-dominated filter.

    Same exact comparisons as :func:`non_dominated_mask` (float64 under
    ``enable_x64``), so the two agree bit-for-bit on any front. Supports
    leading batch dimensions: ``[..., n, d] -> [..., n]``."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    with enable_x64():
        p = jnp.asarray(np.asarray(points, dtype=np.float64))
        if p.shape[-2] == 0:
            return np.zeros(p.shape[:-1], dtype=bool)
        le = jnp.all(p[..., :, None, :] <= p[..., None, :, :], axis=-1)
        lt = jnp.any(p[..., :, None, :] < p[..., None, :, :], axis=-1)
        return np.asarray(~jnp.any(le & lt, axis=-2))


def crowding_distance(points: np.ndarray) -> np.ndarray:
    """NSGA-II crowding distance per row (boundary rows get ``inf``).

    Deterministic: per-axis sorting is stable, so exact ties contribute
    identically regardless of input order."""
    p = np.atleast_2d(np.asarray(points, dtype=np.float64))
    n, d = p.shape
    dist = np.zeros(n)
    if n <= 2:
        return np.full(n, np.inf)
    for a in range(d):
        order = np.argsort(p[:, a], kind="stable")
        v = p[order, a]
        span = v[-1] - v[0]
        dist[order[0]] = dist[order[-1]] = np.inf
        if span > 0:
            gaps = (v[2:] - v[:-2]) / span
            np.add.at(dist, order[1:-1], gaps)
    return dist


def hypervolume(points: np.ndarray, ref: Sequence[float]) -> float:
    """Exact dominated hypervolume (minimization) w.r.t. ``ref``.

    Supports 1/2/3 objectives — 3-D uses slicing along the last axis
    (each z-slab contributes its active points' 2-D area). Points not
    strictly better than ``ref`` on every axis contribute nothing."""
    p = np.atleast_2d(np.asarray(points, dtype=np.float64))
    r = np.asarray(ref, dtype=np.float64)
    if p.shape[0] == 0:
        return 0.0
    p = p[np.all(p < r, axis=1)]
    if p.shape[0] == 0:
        return 0.0
    d = p.shape[1]
    if d == 1:
        return float(r[0] - p[:, 0].min())
    if d == 2:
        return _hv2(p, r)
    if d == 3:
        order = np.argsort(p[:, 2], kind="stable")
        p = p[order]
        zs = np.unique(p[:, 2])
        uppers = np.append(zs[1:], r[2])
        hv = 0.0
        for z, hi in zip(zs, uppers):
            hv += _hv2(p[p[:, 2] <= z, :2], r[:2]) * (hi - z)
        return float(hv)
    raise NotImplementedError(f"hypervolume supports <= 3 axes, got {d}")


def _hv2(p: np.ndarray, r: np.ndarray) -> float:
    """2-D dominated area: sweep x ascending with a falling y staircase."""
    p = p[np.lexsort((p[:, 1], p[:, 0]))]
    hv, y_best = 0.0, r[1]
    for x, y in p:
        if y < y_best:
            hv += (r[0] - x) * (y_best - y)
            y_best = y
    return float(hv)


def simplex_directions(k: int, d: int = N_AXES) -> np.ndarray:
    """``k`` deterministic weight directions on the ``d``-simplex.

    Simplex-lattice design: the smallest resolution ``H`` whose lattice
    has >= ``k`` points, thinned to exactly ``k`` by even index spacing
    (lexicographic order), so every call with the same ``k`` returns the
    same spread — corners (single-objective directions) always included."""
    if k < 1:
        raise ValueError(f"need k >= 1 directions, got {k}")
    h = 1
    while _lattice_size(h, d) < k:
        h += 1
    grid = np.array([c for c in _lattice(h, d)], dtype=np.float64) / h
    idx = np.unique(np.round(np.linspace(0, len(grid) - 1, k)).astype(int))
    # rounding collisions can drop below k: backfill with unused indices
    if len(idx) < k:
        unused = np.setdiff1d(np.arange(len(grid)), idx)
        idx = np.sort(np.concatenate([idx, unused[:k - len(idx)]]))
    return grid[idx]


def _lattice_size(h: int, d: int) -> int:
    from math import comb

    return comb(h + d - 1, d - 1)


def _lattice(h: int, d: int):
    if d == 1:
        yield (h,)
        return
    for i in range(h + 1):
        for rest in _lattice(h - i, d - 1):
            yield (i,) + rest


# ---------------------------------------------------------------------------
# The archive
# ---------------------------------------------------------------------------


class ParetoArchive:
    """Bounded deterministic archive of non-dominated designs.

    Stores ``(encoded row, objective vector)`` pairs; every insert
    re-filters to the non-dominated set (``backend="jnp"`` uses the
    vectorized filter, ``"numpy"`` the exact host reference — they agree
    exactly), drops duplicate rows, prunes to ``max_size`` by largest
    crowding distance (stable index tie-break) and canonicalizes storage
    to lexicographic ``(vector, encoding)`` order.

    Determinism: the same insert sequence always yields the identical
    archive, re-inserting the archive into itself is a no-op, and while
    the bound is not hit the contents are independent of insertion order
    entirely. Once crowding pruning engages, chunked feeds may retain a
    (deterministic) subset that differs from a single-shot insert —
    pruning is greedy and pruned points cannot return."""

    def __init__(self, max_size: int = 256, n_axes: int = N_AXES,
                 width: Optional[int] = None, backend: str = "numpy"):
        if max_size < 1:
            raise ValueError(f"max_size must be >= 1, got {max_size}")
        if backend not in ("numpy", "jnp"):
            raise ValueError(f"unknown backend {backend!r}")
        self.max_size = max_size
        self.n_axes = n_axes
        self.backend = backend
        self._vec = np.zeros((0, n_axes), dtype=np.float64)
        self._enc = np.zeros((0, 0 if width is None else width),
                             dtype=np.int32)

    # -- views --------------------------------------------------------------

    def __len__(self) -> int:
        return self._vec.shape[0]

    def __repr__(self) -> str:
        return (f"ParetoArchive(size={len(self)}/{self.max_size}, "
                f"axes={OBJECTIVE_AXES[:self.n_axes]})")

    @property
    def vectors(self) -> np.ndarray:
        """``[m, n_axes]`` objective vectors, canonical order."""
        return self._vec.copy()

    @property
    def encoded(self) -> np.ndarray:
        """``[m, width]`` encoded design rows, canonical order."""
        return self._enc.copy()

    def systems(self, space: DesignSpace) -> List:
        return space.decode_many(self._enc)

    # -- mutation -----------------------------------------------------------

    def insert(self, encoded: np.ndarray, vectors: np.ndarray) -> int:
        """Insert a batch; returns the archive size afterwards."""
        enc = np.atleast_2d(np.asarray(encoded, dtype=np.int32))
        vec = np.atleast_2d(np.asarray(vectors, dtype=np.float64))
        if enc.shape[0] != vec.shape[0]:
            raise ValueError(
                f"{enc.shape[0]} encodings vs {vec.shape[0]} vectors")
        if vec.shape[1] != self.n_axes:
            raise ValueError(
                f"expected {self.n_axes} axes, got {vec.shape[1]}")
        if self._enc.shape[1] == 0 and enc.shape[1] > 0:
            self._enc = np.zeros((0, enc.shape[1]), dtype=np.int32)
        if enc.shape[1] != self._enc.shape[1]:
            raise ValueError(
                f"row width {enc.shape[1]} != archive {self._enc.shape[1]}")
        for lo in range(0, enc.shape[0], _INSERT_CHUNK):
            self._insert_chunk(enc[lo:lo + _INSERT_CHUNK],
                               vec[lo:lo + _INSERT_CHUNK])
        return len(self)

    def merge(self, other: "ParetoArchive") -> int:
        return self.insert(other._enc, other._vec)

    def _insert_chunk(self, enc: np.ndarray, vec: np.ndarray) -> None:
        if vec.shape[0] > 64:
            # pre-reduce the incoming block alone: dominated rows can
            # never enter the archive, and dropping them first keeps the
            # merge pairwise tiny
            pre = (non_dominated_mask_jnp(vec) if self.backend == "jnp"
                   else non_dominated_mask(vec))
            enc, vec = enc[pre], vec[pre]
        all_enc = np.vstack([self._enc, enc])
        all_vec = np.vstack([self._vec, vec])
        # canonical order + exact-duplicate dedup in one pass (int32
        # encodings are exact in float64, so the combined key is lossless)
        key = np.hstack([all_vec, all_enc.astype(np.float64)])
        # np.unique returns first-occurrence indices in sorted-key order:
        # dedup + canonical lexicographic order in one pass
        _, uniq = np.unique(key, axis=0, return_index=True)
        all_enc, all_vec = all_enc[uniq], all_vec[uniq]
        mask = (non_dominated_mask_jnp(all_vec) if self.backend == "jnp"
                else non_dominated_mask(all_vec))
        all_enc, all_vec = all_enc[mask], all_vec[mask]
        if all_vec.shape[0] > self.max_size:
            cd = crowding_distance(all_vec)
            keep = np.argsort(-cd, kind="stable")[:self.max_size]
            keep.sort()
            all_enc, all_vec = all_enc[keep], all_vec[keep]
        self._enc, self._vec = all_enc, all_vec

    # -- checkpointing ------------------------------------------------------
    # the repro.checkpoint protocol: archives ride inside checkpoint
    # pytrees as first-class objects (their row count is elastic across
    # restore, so a resumed search continues the exact frontier)

    def checkpoint_arrays(self) -> Dict[str, np.ndarray]:
        """The archive's full state as plain arrays (row widths and
        counts are restored from the checkpoint, not the template).

        Returns references, not copies: mutation always rebinds
        ``_enc``/``_vec`` wholesale (see ``_insert_chunk``), so a
        returned snapshot can never be corrupted in place."""
        return {"enc": self._enc, "vec": self._vec}

    def from_checkpoint_arrays(self, arrays: Dict[str, np.ndarray]
                               ) -> "ParetoArchive":
        """New archive with this one's bounds/backend and the saved
        contents (the restore half of the checkpoint protocol)."""
        out = ParetoArchive(max_size=self.max_size, n_axes=self.n_axes,
                            backend=self.backend)
        out.load_checkpoint_arrays(arrays)
        return out

    def load_checkpoint_arrays(self, arrays: Dict[str, np.ndarray]) -> None:
        """Overwrite contents in place from :meth:`checkpoint_arrays`."""
        enc = np.atleast_2d(np.asarray(arrays["enc"], dtype=np.int32))
        vec = np.atleast_2d(np.asarray(arrays["vec"], dtype=np.float64))
        if enc.shape[0] != vec.shape[0]:
            raise ValueError(
                f"{enc.shape[0]} encodings vs {vec.shape[0]} vectors")
        self._enc, self._vec = enc, vec

    # -- analysis -----------------------------------------------------------

    def reference_point(self, margin: float = 0.1) -> np.ndarray:
        """Nadir + ``margin`` * range per axis (a usable default HV ref)."""
        if len(self) == 0:
            return np.ones(self.n_axes)
        lo, hi = self._vec.min(axis=0), self._vec.max(axis=0)
        span = np.where(hi > lo, hi - lo, np.maximum(np.abs(hi), 1.0))
        return hi + margin * span

    def hypervolume(self, ref: Optional[Sequence[float]] = None) -> float:
        return hypervolume(self._vec,
                           self.reference_point() if ref is None else ref)

    def project(self, axes: Sequence[int]) -> np.ndarray:
        """Re-filtered 2-D (or 1-D) front over a subset of axes — e.g.
        ``project((1, 2))`` is the Fig. 13 CFP-vs-cost frontier."""
        sub = self._vec[:, list(axes)]
        return sub[non_dominated_mask(sub)]


class FrontierFeed:
    """Buffered (encoded, vector) accumulator in front of an archive.

    Scalar strategies evaluate one candidate at a time; inserting rows
    singly would re-run the dominance filter per evaluation. The feed
    buffers rows and flushes in blocks. ``size=0`` disables collection
    (``archive`` stays ``None``)."""

    def __init__(self, size: int = 256, chunk: int = 512):
        self.archive = ParetoArchive(max_size=size) if size > 0 else None
        self._enc: List[np.ndarray] = []
        self._vec: List[np.ndarray] = []
        self._chunk = chunk
        self._pending = 0

    def add(self, encoded: np.ndarray, vectors: np.ndarray) -> None:
        if self.archive is None:
            return
        enc = np.atleast_2d(np.asarray(encoded, dtype=np.int32))
        self._enc.append(enc)
        self._vec.append(np.atleast_2d(np.asarray(vectors)))
        self._pending += enc.shape[0]
        if self._pending >= self._chunk:
            self._flush()

    def _flush(self) -> None:
        if self._pending:
            self.archive.insert(np.vstack(self._enc), np.vstack(self._vec))
            self._enc, self._vec, self._pending = [], [], 0

    def done(self) -> Optional[ParetoArchive]:
        if self.archive is not None:
            self._flush()
        return self.archive


# ---------------------------------------------------------------------------
# ScalarizationSweep: K directions x N chains in one device program
# ---------------------------------------------------------------------------


def directions_to_weights(w3: np.ndarray) -> np.ndarray:
    """Map ``[K, 3]`` (latency, cost, CFP) simplex directions to ``[K, 6]``
    Eq. 17 weight rows (METRIC_FIELDS order): latency -> gamma, dollar ->
    theta, and the CFP weight applied in full to *both* zeta (embodied)
    and eta (operational) — total CFP is their sum, so weighting each
    component by the full direction weight scalarizes ``w * total_cfp``;
    energy/area weights stay 0 so the scalarization moves only along the
    frontier axes."""
    w3 = np.atleast_2d(np.asarray(w3, dtype=np.float64))
    w6 = np.zeros((w3.shape[0], 6))
    w6[:, 2] = w3[:, 0]            # gamma: latency_s
    w6[:, 3] = w3[:, 1]            # theta: dollar
    w6[:, 4] = w3[:, 2]            # zeta: emb_cfp_kg
    w6[:, 5] = w3[:, 2]            # eta:  ope_cfp_kg
    return w6


@dataclasses.dataclass
class ScalarizationSweep:
    """K scalarization directions x N tempering chains, one fused scan.

    Each direction is an Eq. 17 weight row (from
    :func:`simplex_directions` over the latency/cost/CFP axes, or
    ``weights`` for custom rows); each runs its own ``n_chains``-wide
    geometric temperature ladder. On a device-capable objective all
    ``K * N`` chains advance in a single ``lax.scan`` — per-chain weight
    rows ride through the fused evaluate+cost program, and the
    replica-exchange pair mask blocks swaps across direction boundaries,
    so ladders stay independent without leaving the device. Every
    proposal (plus the seed population) feeds the returned
    ``SearchResult.frontier`` archive.

    ``budget`` caps total evaluations: sweeps are truncated to whole
    multiples of ``K * N``. The scalar/host fallback runs one
    :class:`~repro.pathfinding.strategies.ParallelTempering` per
    direction and merges the frontiers.

    Unlike the single-objective strategies, ``frontier_size=0`` is
    rejected here: the frontier archive *is* this strategy's output
    (``best`` is re-derived from it)."""

    directions: int = 16
    n_chains: int = 4
    sweeps: int = 100
    swap_every: int = 5
    # Eq. 17 costs are O(1) after min/median normalization, so the sweep
    # ladder defaults to an *exploitative* range (the SA schedule's 4000
    # top is for cooling to 1e-3 over thousands of moves; at a fixed hot
    # ladder every chain is a pure random walk and the scalarization
    # directions never bite)
    t_max: float = 5.0
    t_min: float = 0.005
    frontier_size: int = 256
    weights: Optional[np.ndarray] = None   # [K, 6] override
    # checkpoint/resume of the fused scan (device path only): advance in
    # host-driven segments of `segment` sweeps, snapshotting carry +
    # archive at each boundary under `checkpoint_dir`; `resume` restores
    # the newest valid snapshot (bit-identical continuation)
    segment: Optional[int] = None
    checkpoint_dir: Optional[str] = None
    resume: bool = True

    def weight_rows(self) -> np.ndarray:
        if self.weights is not None:
            w = np.atleast_2d(np.asarray(self.weights, dtype=np.float64))
            if w.shape[1] != 6:
                raise ValueError(f"weights must be [K, 6], got {w.shape}")
            return w
        return directions_to_weights(simplex_directions(self.directions))

    # per-chain layouts, shared verbatim by the single-cell device path
    # and ScenarioSweep's stacked grid (one definition => no drift)

    def ladder(self) -> np.ndarray:
        """Geometric ``n_chains`` temperature ladder t_max -> t_min."""
        n = self.n_chains
        ratio = (self.t_min / self.t_max) ** (1.0 / max(1, n - 1))
        return np.array([self.t_max * ratio ** i for i in range(n)])

    def chain_temps(self, k: int) -> np.ndarray:
        """``[k * n_chains]`` temperatures: the ladder repeated per
        direction."""
        return np.tile(self.ladder(), k)

    def chain_weights(self, w6: np.ndarray) -> np.ndarray:
        """``[K * n_chains, 6]`` per-chain Eq. 17 rows from ``[K, 6]``
        direction rows."""
        return np.repeat(w6, self.n_chains, axis=0)

    def chain_pair_mask(self, total: int) -> np.ndarray:
        """Replica-exchange gate: block swaps across direction
        boundaries — pair (j, j+1) may swap only when both chains share
        a direction ladder."""
        if total <= 1:
            return np.ones(1, dtype=bool)
        return (np.arange(total - 1) + 1) % self.n_chains != 0

    def search(self, space: DesignSpace, objective, budget=None, key=None):
        from repro.pathfinding.strategies import (
            ParallelTempering,
            SearchResult,
            _check_budget,
            _check_checkpointable,
            _resolve_key,
            budget_sweeps,
        )

        _check_budget(budget)
        _check_checkpointable(self.checkpoint_dir, objective)
        key = _resolve_key(key)
        if self.frontier_size < 1:
            raise ValueError(
                "ScalarizationSweep requires frontier_size >= 1: the "
                "frontier archive is the strategy's output (best is "
                f"re-derived from it), got {self.frontier_size}")
        w6 = self.weight_rows()
        k, n = w6.shape[0], self.n_chains
        total = k * n
        sweeps = budget_sweeps(
            self.sweeps, total, budget,
            detail=f" ({k} directions x {n} chains)")

        if objective.device:
            return self._search_device(space, objective, w6, sweeps, key)

        # host fallback: one PT run per direction, frontiers merged
        archive = ParetoArchive(max_size=self.frontier_size)
        evals = 0
        history: List[float] = []
        base = key
        for i in range(k):
            obj_i = dataclasses.replace(
                objective,
                template=Template(f"dir{i}", *w6[i]))
            pt = ParallelTempering(
                n_chains=n, t_max=self.t_max, t_min=self.t_min,
                sweeps=sweeps, swap_every=self.swap_every,
                frontier_size=self.frontier_size)
            res = pt.search(space, obj_i, None, key=base * 7919 + i)
            evals += res.evaluations
            history.append(res.best_cost)
            if res.frontier is not None:
                archive.merge(res.frontier)
        return self._finalize(space, objective, archive, history, evals)

    def _search_device(self, space: DesignSpace, objective, w6,
                       sweeps: int, key):
        from repro.pathfinding.device import get_device_evaluator
        from repro.pathfinding.strategies import SearchResult  # noqa: F401

        k, n = w6.shape[0], self.n_chains
        total = k * n
        rng = random.Random(key)
        chains = [random_system(rng, objective.db, space.max_chiplets)
                  for _ in range(total)]
        temps = self.chain_temps(k)
        weights = self.chain_weights(w6)                      # [K*N, 6]
        pair_ok = self.chain_pair_mask(total)
        dev = get_device_evaluator(objective.wl, objective.db, space=space)
        archive = ParetoArchive(max_size=self.frontier_size)
        from repro.pathfinding.strategies import _checkpointer

        res = dev.parallel_tempering(
            space.encode_many(chains), temps, sweeps, self.swap_every,
            seed=key, norm=objective.norm,
            template=objective.template, weights=weights,
            pair_mask=np.asarray(pair_ok, dtype=bool),
            segment=self.segment, archive=archive,
            checkpoint=_checkpointer(self.checkpoint_dir),
            resume=self.resume)
        return self._finalize(space, objective, archive,
                              res.history, res.evaluations)

    def _finalize(self, space, objective, archive, history, evals):
        """Best-by-template from the archive (one batched re-evaluation of
        <= max_size frontier rows — not counted against the budget, like
        the PT winner re-materialization)."""
        from repro.pathfinding.strategies import SearchResult

        if len(archive) == 0:
            raise RuntimeError("scalarization sweep produced no samples")
        mb, cost = objective.eval_cost_encoded(archive.encoded, space)
        i = int(np.argmin(cost))
        best = space.decode(archive.encoded[i])
        return SearchResult(best, mb.row(i), float(cost[i]),
                            list(history), evals, objective.cache,
                            frontier=archive)


# ---------------------------------------------------------------------------
# ScenarioSweep: frontier x deployment region x workload
# ---------------------------------------------------------------------------

# representative grid carbon intensities, kg CO2 / kWh (world-average
# default matches techdb.CARBON_INTENSITY_KG_PER_KWH)
REGION_INTENSITIES: Dict[str, float] = {
    "hydro": 0.024,        # e.g. NO/IS grids
    "nuclear-heavy": 0.085,
    "eu-avg": 0.276,
    "world-avg": 0.475,
    "coal-heavy": 0.820,
}


def workloads_from_configs(names: Sequence[str],
                           tokens: int = 512) -> List[GEMMWorkload]:
    """MLP up-projection GEMMs (``tokens x d_model x d_ff``) for model
    configs from :mod:`repro.configs` — the dominant GEMM shape of each
    architecture, usable anywhere a Table IV workload is."""
    from repro.configs import get_config

    out = []
    for name in names:
        cfg = get_config(name)
        out.append(GEMMWorkload(f"{cfg.name}-mlp{tokens}", tokens,
                                cfg.d_model, cfg.d_ff))
    return out


def fold_cell_key(base: int, idx: int) -> int:
    """Deterministic per-cell search key: ``jax.random.fold_in`` of the
    cell index into the base key, reduced to a Python int.

    Distinct (workload, region) cells therefore explore with distinct,
    reproducible proposal streams — previously every cell received the
    *same* key and walked the identical stream. The stacked device scan
    applies the same fold on-device; the host fallback (and per-cell
    seed populations) use this helper."""
    import jax

    folded = jax.random.fold_in(jax.random.PRNGKey(base), idx)
    key_data = getattr(jax.random, "key_data", None)
    data = key_data(folded) if key_data is not None else folded
    a, b = (int(x) for x in np.ravel(np.asarray(data))[-2:])
    # 63-bit result: folded keys are themselves valid PRNGKey seeds
    return ((a << 32) | b) & 0x7FFF_FFFF_FFFF_FFFF


def fold_job_key(base: int, job_id: str) -> int:
    """Deterministic per-job search key for the serving layer.

    The job's *name* (not its slot index) is hashed to a 32-bit index
    and folded into the base key via :func:`fold_cell_key`. The key
    therefore depends only on ``(base, job_id)`` — never on which slot
    the scheduler packs the job into or which co-tenants share the
    batch — which is what makes a job's trajectory bit-identical solo
    vs packed (the per-slot ``fold_in`` inside the engine's
    ``_init_fn`` would break exactly this, so serving must not use it)."""
    import hashlib

    idx = int.from_bytes(
        hashlib.sha256(str(job_id).encode()).digest()[:4], "big")
    return fold_cell_key(base, idx)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One (workload, deployment region) cell of a sweep.

    ``spec`` carries the full regional axes (price, embodied factor,
    24h grid profile); ``carbon_intensity`` stays a plain float for
    backward-compatible reporting (it equals ``spec.carbon_intensity``)."""

    workload: GEMMWorkload
    region: str
    carbon_intensity: float
    spec: Optional[Region] = None

    @property
    def key(self) -> Tuple[str, str]:
        return (self.workload.name, self.region)


@dataclasses.dataclass
class ScenarioFrontier:
    """Results of a :class:`ScenarioSweep`: one ``SearchResult`` (and
    frontier archive) per scenario."""

    scenarios: List[Scenario]
    results: Dict[Tuple[str, str], "object"]   # key -> SearchResult

    def frontier(self, workload_name: str, region: str) -> ParetoArchive:
        return self.results[(workload_name, region)].frontier

    def merged(self, workload_name: str,
               max_size: int = 512) -> ParetoArchive:
        """Union frontier across regions for one workload (the envelope a
        deployment-portfolio planner optimizes against)."""
        out = ParetoArchive(max_size=max_size)
        for s in self.scenarios:
            if s.workload.name == workload_name:
                out.merge(self.results[s.key].frontier)
        return out

    def rows(self):
        """Flat (workload, region, ci, latency, dollar, cfp) rows for
        CSV/JSON reporting."""
        for s in self.scenarios:
            arch = self.results[s.key].frontier
            for v in arch.vectors:
                yield (s.workload.name, s.region, s.carbon_intensity,
                       float(v[0]), float(v[1]), float(v[2]))


@dataclasses.dataclass
class ScenarioSweep:
    """Map the Pareto frontier across deployment regions and workloads.

    Each (workload, region) cell runs the inner
    :class:`ScalarizationSweep` under the region's axes — scalar grid
    carbon intensity, and optionally (via :class:`repro.core.regions.
    Region` values in ``regions``) a 24h grid-intensity profile, a
    regional electricity price and an embodied-carbon factor.
    Operational CFP, the dollar metric and embodied CFP all shift with
    them, so both the frontier *and* the region-fitted normalizer
    shift. Every cell gets a distinct key (``fold_cell_key``). Bare
    float region values stay the historical scalar-CI cells,
    bit-identical to the pre-Region sweep.

    On the device path the whole grid is **one stacked program**: the
    per-cell carbon intensities, normalizer rows, Eq. 17 weight rows and
    ``fold_in``-derived keys all ride through the single ``lax.scan`` of
    :class:`repro.pathfinding.device.ScenarioEngine`, so a 5-region x
    2-workload sweep compiles the fused program exactly once (the
    per-cell path re-built a ``Pathfinder``/``DeviceEvaluator`` and paid
    a full retrace per region even though only one scalar changed).
    Normalizer fits batch the same way: one ``evaluate_batch`` per
    workload plus an exact per-region ``ope`` rescale
    (:func:`repro.pathfinding.batch.fit_region_normalizers`).

    ``budget`` is the *total* evaluation budget of the sweep, split
    evenly across cells (``budget // n_cells`` each; the remainder is
    left unspent — previously each cell silently consumed the full
    budget). ``shard="auto"`` shards the scenario axis over the local
    devices when more than one exists
    (:func:`repro.distributed.sharding.scenario_mesh`); ``True`` forces
    a mesh, ``False`` keeps everything on one device."""

    strategy: ScalarizationSweep = dataclasses.field(
        default_factory=lambda: ScalarizationSweep(directions=8,
                                                   n_chains=4, sweeps=40))
    regions: Dict[str, RegionLike] = dataclasses.field(
        default_factory=lambda: dict(REGION_INTENSITIES))
    norm_samples: int = 400
    norm_seed: int = 1234
    shard: Union[bool, str] = "auto"
    # communication model of the searched DesignSpace (None = the
    # REPRO_COMM_MODEL-resolved default; "mesh_noc" adds the per-chiplet
    # mesh-dims / NoI-entry axes to every cell's search)
    comm: Optional[str] = None
    # schedule model of the searched DesignSpace (None = the
    # REPRO_SCHEDULE-resolved default; "window" adds the per-design
    # start-hour / duty-shape axes so every cell co-optimizes *when*
    # its designs run against the region's 24h grid profile)
    schedule: Optional[str] = None

    def run(self, workloads: Union[GEMMWorkload, Sequence[GEMMWorkload],
                                   "ScenarioSpec"],
            template: Union[str, Template] = "T1",
            db: TechDB = DEFAULT_DB, device: bool = True,
            budget: Optional[int] = None,
            key: Optional[int] = None,
            checkpoint_dir: Optional[str] = None,
            resume: bool = True,
            segment: Optional[int] = None) -> ScenarioFrontier:
        """``checkpoint_dir`` makes the stacked grid scan interruptible:
        it advances in ``segment``-sweep chunks (default: one chunk) and
        snapshots the scan carry (per-cell populations, costs,
        incumbents, RNG streams and sweep counters) plus every per-cell
        frontier archive at each boundary; ``resume=True`` restores the
        newest valid snapshot, continuing bit-identically to the
        uninterrupted run. Device path only.

        ``workloads`` also accepts a
        :class:`~repro.pathfinding.scenario.ScenarioSpec` — the unified
        frozen description of the whole sweep. The spec then supplies
        the workloads, regions, comm/schedule models and the
        budget/segment/checkpoint knobs; passing any of those loose
        kwargs alongside a spec is an error (one source of truth)."""
        from repro.pathfinding.batch import fit_region_normalizers
        from repro.pathfinding.pathfinder import Pathfinder
        from repro.pathfinding.scenario import ScenarioSpec
        from repro.pathfinding.strategies import _check_budget, _resolve_key

        if isinstance(workloads, ScenarioSpec):
            spec = workloads
            if (budget is not None or checkpoint_dir is not None
                    or segment is not None):
                raise ValueError(
                    "budget/segment/checkpoint_dir ride inside the "
                    "ScenarioSpec; don't also pass them to run()")
            sweep = dataclasses.replace(
                self, regions=spec.region_map(),
                comm=spec.comm if spec.comm is not None else self.comm,
                schedule=(spec.schedule if spec.schedule is not None
                          else self.schedule))
            return sweep.run(
                list(spec.workloads), template=template, db=db,
                device=device, budget=spec.budget, key=key,
                checkpoint_dir=spec.checkpoint_dir, resume=spec.resume,
                segment=spec.segment)
        _check_budget(budget)
        if checkpoint_dir is not None and not device:
            raise ValueError(
                "checkpoint_dir requires the device path "
                "(ScenarioSweep.run(device=True)); the per-cell host "
                "fallback cannot checkpoint")
        if isinstance(workloads, GEMMWorkload):
            workloads = [workloads]
        workloads = list(workloads)
        tpl = TEMPLATES[template] if isinstance(template, str) else template
        base = _resolve_key(key)
        # regions accept floats (historical scalar-CI cells) or Region
        # specs carrying the price/embodied/profile axes; a float is a
        # neutral-axes Region, bit-identical to the pre-Region sweep
        regions = [(name, as_region(spec))
                   for name, spec in self.regions.items()]
        # cell-major grid: workloads outer, regions inner (the historical
        # iteration order — cell index = wi * len(regions) + ri)
        cells = [(wi, wl, region, reg)
                 for wi, wl in enumerate(workloads)
                 for region, reg in regions]
        cell_budget = None
        if budget is not None:
            cell_budget = budget // len(cells)
            if cell_budget < 1:
                raise ValueError(
                    f"total budget {budget} < one evaluation per cell "
                    f"({len(cells)} cells)")
        # fail fast on inputs the inner ScalarizationSweep would reject
        # per cell anyway — *before* paying the normalizer fits
        strat = self.strategy
        if hasattr(strat, "weight_rows"):
            if strat.frontier_size < 1:
                raise ValueError(
                    "ScenarioSweep requires frontier_size >= 1 on its "
                    "inner ScalarizationSweep (the per-cell frontier "
                    "archives are the sweep's output), got "
                    f"{strat.frontier_size}")
            k = strat.weight_rows().shape[0]
            nc = k * strat.n_chains
            if cell_budget is not None and cell_budget < nc:
                raise ValueError(
                    f"per-cell budget {cell_budget} < one chain "
                    f"population {nc} ({k} directions x {strat.n_chains} "
                    f"chains); total budget must be >= "
                    f"{nc * len(cells)}")
        space = DesignSpace(db, comm=self.comm, schedule=self.schedule)
        norm_of: Dict[Tuple[int, str], object] = {}
        for wi, wl in enumerate(workloads):
            fitted = fit_region_normalizers(
                wl, [reg for _, reg in regions], db,
                samples=self.norm_samples, seed=self.norm_seed, space=space)
            for (region, _), nz in zip(regions, fitted):
                norm_of[(wi, region)] = nz
        if device:
            return self._run_device(cells, workloads, tpl, db, space,
                                    norm_of, cell_budget, base,
                                    checkpoint_dir, resume, segment)

        # host fallback: one Pathfinder per cell, distinct folded keys,
        # split budget, pre-fitted region normalizers
        scenarios: List[Scenario] = []
        results: Dict[Tuple[str, str], object] = {}
        for idx, (wi, wl, region, reg) in enumerate(cells):
            db_s = dataclasses.replace(db, **reg.db_overrides())
            pf = Pathfinder(wl, tpl, db=db_s, device=False,
                            norm=norm_of[(wi, region)],
                            space=DesignSpace(db_s, comm=self.comm,
                                              schedule=self.schedule))
            res = pf.search(strategy=self.strategy, budget=cell_budget,
                            key=fold_cell_key(base, idx))
            sc = Scenario(wl, region, reg.carbon_intensity, reg)
            scenarios.append(sc)
            results[sc.key] = res
        return ScenarioFrontier(scenarios, results)

    def _mesh(self):
        if self.shard is False:
            return None
        from repro.distributed.sharding import scenario_mesh

        return scenario_mesh(min_devices=1 if self.shard is True else 2)

    def _run_device(self, cells, workloads, tpl, db, space, norm_of,
                    cell_budget, base, checkpoint_dir=None, resume=True,
                    segment=None) -> ScenarioFrontier:
        from repro.core.evaluate import evaluate
        from repro.core.scalesim import SimCache
        from repro.pathfinding.device import get_scenario_engine
        from repro.pathfinding.strategies import (
            SearchResult,
            _checkpointer,
            budget_sweeps,
        )

        strat = self.strategy
        w6 = strat.weight_rows()
        k = w6.shape[0]
        nc = k * strat.n_chains
        # run() already rejected cell_budget < nc with grid context
        sweeps = budget_sweeps(strat.sweeps, nc, cell_budget)
        S = len(cells)
        # per-chain layouts come from the inner strategy itself, so the
        # stacked grid and the single-cell device path cannot drift
        temps = np.tile(strat.chain_temps(k), (S, 1))
        weights = np.tile(strat.chain_weights(w6)[None], (S, 1, 1))
        pair = np.tile(strat.chain_pair_mask(nc), (S, 1))
        mm = [norm_of[(wi, region)].weights_arrays()
              for (wi, _, region, _) in cells]
        mins = np.stack([a for a, _ in mm])
        medians = np.stack([b for _, b in mm])
        ci = np.array([reg.carbon_intensity for *_, reg in cells],
                      dtype=np.float64)
        price = np.array([reg.electricity_price for *_, reg in cells],
                         dtype=np.float64)
        embf = np.array([reg.emb_factor for *_, reg in cells],
                        dtype=np.float64)
        profile = np.stack([reg.profile_array() for *_, reg in cells])
        pprofile = np.stack([reg.price_array() for *_, reg in cells])
        widx = np.array([wi for wi, *_ in cells], dtype=np.int32)
        v0 = np.stack([
            space.encode_many([
                random_system(random.Random(fold_cell_key(base, idx)),
                              db, space.max_chiplets)
                for _ in range(nc)])
            for idx in range(S)])
        engine = get_scenario_engine(tuple(workloads), db, space=space)
        archives = [ParetoArchive(max_size=strat.frontier_size)
                    for _ in range(S)]
        res = engine.parallel_tempering(
            v0, temps, sweeps, strat.swap_every, seed=base, mins=mins,
            medians=medians, weights=weights, pair_mask=pair, ci=ci,
            widx=widx, price=price, embf=embf, profile=profile,
            pprofile=pprofile, mesh=self._mesh(), segment=segment,
            archives=archives, checkpoint=_checkpointer(checkpoint_dir),
            resume=resume)
        # best-by-template per cell: ONE stacked re-evaluation of the
        # (padded) archives — not counted against the budget, like the PT
        # winner re-materialization
        m = max(len(a) for a in archives)
        enc_f = np.stack([
            a.encoded if len(a) == m else np.concatenate(
                [a.encoded, np.repeat(a.encoded[:1], m - len(a), axis=0)])
            for a in archives])
        wt = np.tile(np.asarray(tpl.weights, dtype=np.float64), (S, 1))
        cost_f, _ = engine.evaluate_cost(enc_f, mins, medians, wt, ci,
                                         widx, price=price, embf=embf,
                                         profile=profile,
                                         pprofile=pprofile)
        cache = SimCache()
        evals_cell = nc * (1 + sweeps)
        scenarios: List[Scenario] = []
        results: Dict[Tuple[str, str], object] = {}
        for s, (wi, wl, region, reg) in enumerate(cells):
            arch = archives[s]
            cc = cost_f[s, :len(arch)]
            i = int(np.argmin(cc))
            best = space.decode(arch.encoded[i])
            db_s = dataclasses.replace(db, **reg.db_overrides())
            best_m = evaluate(best, wl, db_s, cache=cache)
            sc = Scenario(wl, region, reg.carbon_intensity, reg)
            scenarios.append(sc)
            results[sc.key] = SearchResult(
                best, best_m, float(cc[i]), res.history[s].tolist(),
                evals_cell, cache, frontier=arch)
        return ScenarioFrontier(scenarios, results)
