"""The Pathfinder facade — single public entry point for exploration.

Bundles workload + template + TechDB + objective backend + normalizer and
drives any :class:`SearchStrategy`::

    from repro.pathfinding import Pathfinder, SimulatedAnnealing

    pf = Pathfinder(workload(1), TEMPLATES["T1"])
    result = pf.search(strategy=SimulatedAnnealing(SAConfig()))

Objective backends replace the seed API's ``evaluate_fn`` swap by name:
``"carbonpath"`` (full Eqs. 2-17 models, batched evaluation) and
``"chipletgym"`` (the Sec VI-B baseline assumptions, scalar fallback). A
callable with the ``evaluate(sys, wl, db, cache=...)`` signature is also
accepted for custom models.
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import numpy as np

from repro.core.chipletgym import evaluate_chipletgym
from repro.core.evaluate import Metrics, evaluate
from repro.core.scalesim import SimCache
from repro.core.system import HISystem
from repro.core.techdb import DEFAULT_DB, TechDB
from repro.core.templates import (
    IDENTITY_NORMALIZER,
    TEMPLATES,
    Normalizer,
    Template,
)
from repro.core.workload import GEMMWorkload
from repro.pathfinding.batch import (
    MetricsBatch,
    evaluate_batch,
    fit_normalizer_batched,
)
from repro.pathfinding.space import DesignSpace
from repro.pathfinding.strategies import (
    Objective,
    SearchResult,
    SearchStrategy,
    SimulatedAnnealing,
)

OBJECTIVES = {
    "carbonpath": evaluate,
    "chipletgym": evaluate_chipletgym,
}


class Pathfinder:
    """Carbon-aware design-space exploration over one workload."""

    def __init__(self, wl: GEMMWorkload,
                 template: Union[Template, str] = "T1",
                 db: TechDB = DEFAULT_DB,
                 objective: Union[str, Callable] = "carbonpath",
                 norm: Optional[Normalizer] = None,
                 cache: Optional[SimCache] = None,
                 max_chiplets: int = 6,
                 space: Optional[DesignSpace] = None,
                 device: bool = True):
        """``device=True`` (default) routes batched strategies through the
        jitted fused evaluator + lax.scan tempering engine of
        :mod:`repro.pathfinding.device`. It only takes effect for the
        CarbonPATH backend — scalar-only backends (e.g. ``chipletgym``)
        always use the host fallback, as does ``device=False``."""
        self.wl = wl
        self.template = (TEMPLATES[template] if isinstance(template, str)
                         else template)
        self.db = db
        self.space = space or DesignSpace(db, max_chiplets)
        if callable(objective):
            self.evaluate_fn = objective
        else:
            self.evaluate_fn = OBJECTIVES[objective]
        self.batched = self.evaluate_fn is evaluate
        self.device = bool(device) and self.batched
        self.cache = cache if cache is not None else SimCache()
        self._norm = norm

    # -- normalizer ---------------------------------------------------------

    def fit_normalizer(self, samples: int = 2000, seed: int = 1234,
                       method: Optional[str] = None) -> Normalizer:
        """Fit the Eq. 17 min/median normalizer. ``method="batched"``
        (default for the CarbonPATH backend) samples and evaluates the
        population through the array evaluator; ``method="scalar"``
        reproduces the seed ``sa.fit_normalizer`` loop exactly (same RNG,
        same per-system evaluation), which the table benchmarks use for
        bit-stable baselines."""
        if method is None:
            method = "batched" if self.batched else "scalar"
        if method == "batched":
            if not self.batched:
                raise ValueError(
                    "batched normalizer fitting requires the carbonpath "
                    "objective backend")
            self._norm = fit_normalizer_batched(
                self.wl, self.db, samples, seed, space=self.space)
        elif method == "scalar":
            from repro.core.sa import fit_normalizer
            self._norm = fit_normalizer(
                self.wl, self.db, samples, seed, self.cache,
                self.evaluate_fn, self.space.max_chiplets)
        else:
            raise ValueError(f"unknown normalizer method {method!r}")
        return self._norm

    @property
    def norm(self) -> Normalizer:
        if self._norm is None:
            self.fit_normalizer()
        return self._norm

    # -- evaluation ---------------------------------------------------------

    def evaluate(self, sys: HISystem) -> Metrics:
        """Scalar single-system evaluation under this objective backend."""
        return self.evaluate_fn(sys, self.wl, self.db, cache=self.cache)

    def evaluate_batch(self, encoded: np.ndarray) -> MetricsBatch:
        """Batched evaluation of an encoded population. Does not need (or
        trigger fitting of) a normalizer — metrics are raw."""
        if self.batched:
            return evaluate_batch(encoded, self.wl, self.db,
                                  space=self.space)
        obj = Objective(self.wl, self.template,
                        self._norm or IDENTITY_NORMALIZER, self.db,
                        self.evaluate_fn, self.cache, self.batched,
                        self.device)
        return obj.evaluate_encoded(encoded, self.space)

    def objective(self) -> Objective:
        return Objective(self.wl, self.template, self.norm, self.db,
                         self.evaluate_fn, self.cache, self.batched,
                         self.device)

    def evaluate_cost_vector(self, encoded: np.ndarray):
        """Metrics + Eq. 17 cost + ``(latency, dollar, total_cfp)``
        objective vectors for an encoded population (fused on device)."""
        return self.objective().eval_cost_vector_encoded(encoded,
                                                         self.space)

    # -- search -------------------------------------------------------------

    def search(self, strategy: Optional[SearchStrategy] = None,
               budget: Optional[int] = None,
               key: Optional[int] = None) -> SearchResult:
        strategy = strategy or SimulatedAnnealing()
        return strategy.search(self.space, self.objective(), budget, key)

    def pareto_front(self, strategy: Optional[SearchStrategy] = None,
                     budget: Optional[int] = None,
                     key: Optional[int] = None):
        """Run a search and return its Pareto archive directly (see
        :mod:`repro.pathfinding.pareto`). Defaults to a
        :class:`~repro.pathfinding.pareto.ScalarizationSweep`."""
        if strategy is None:
            from repro.pathfinding.pareto import ScalarizationSweep

            strategy = ScalarizationSweep()
        return self.search(strategy, budget, key).frontier

    def run_scenarios(self, sweep=None, workloads=None, regions=None,
                      budget: Optional[int] = None,
                      key: Optional[int] = None,
                      checkpoint_dir: Optional[str] = None,
                      resume: bool = True,
                      segment: Optional[int] = None):
        """Map frontiers across deployment regions (and optionally extra
        workloads) with this Pathfinder's template/TechDB — a
        :class:`~repro.pathfinding.pareto.ScenarioSweep` whose whole
        region x workload grid runs as one stacked device program on the
        device path (one compile; see
        :class:`repro.pathfinding.device.ScenarioEngine`).

        ``sweep`` accepts either a :class:`ScenarioSweep` (search knobs)
        or a :class:`~repro.pathfinding.scenario.ScenarioSpec` — the
        unified frozen description of the whole run (workloads, regions,
        comm/schedule models, budget/segment/checkpoint knobs). With a
        spec, this Pathfinder contributes only its workload default (a
        spec without workloads is impossible), template, TechDB and
        device flag; passing the loose ``workloads``/``regions``/
        ``budget``/``checkpoint_dir``/``segment`` kwargs alongside a
        spec is an error. The loose ``regions=`` mapping keeps working
        bit-identically but is deprecated in favor of the spec.

        ``budget`` is the sweep's *total* evaluation budget, split evenly
        across cells. ``checkpoint_dir`` makes the sweep interruptible:
        the grid scan advances in ``segment``-sweep chunks and snapshots
        its carry + per-cell frontier archives at every boundary;
        ``resume=True`` (default) restores the newest valid snapshot and
        continues bit-identically to an uninterrupted run. Returns a
        :class:`~repro.pathfinding.pareto.ScenarioFrontier`."""
        import dataclasses

        from repro.pathfinding.pareto import ScenarioSweep
        from repro.pathfinding.scenario import ScenarioSpec

        if not self.batched:
            raise ValueError(
                "run_scenarios requires the carbonpath objective backend: "
                "ScenarioSweep rebuilds per-cell objectives from the "
                "TechDB and cannot carry a custom or chipletgym "
                "evaluate_fn")
        if isinstance(sweep, ScenarioSpec):
            if (workloads is not None or regions is not None
                    or budget is not None or checkpoint_dir is not None
                    or segment is not None):
                raise ValueError(
                    "a ScenarioSpec already carries the workloads, "
                    "regions and budget/segment/checkpoint knobs; don't "
                    "also pass them to run_scenarios()")
            return ScenarioSweep().run(
                sweep, template=self.template, db=self.db,
                device=self.device, key=key)
        sweep = sweep or ScenarioSweep()
        if regions is not None:
            import warnings

            warnings.warn(
                "run_scenarios(regions=...) is deprecated: pass a "
                "repro.pathfinding.scenario.ScenarioSpec (unified "
                "workloads + {name: Region} + run knobs) as the first "
                "argument instead",
                DeprecationWarning, stacklevel=2)
            sweep = dataclasses.replace(sweep, regions=dict(regions))
        wls = [self.wl] if workloads is None else list(workloads)
        return sweep.run(wls, template=self.template, db=self.db,
                         device=self.device, budget=budget, key=key,
                         checkpoint_dir=checkpoint_dir, resume=resume,
                         segment=segment)
