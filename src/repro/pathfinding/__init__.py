"""CarbonPATH pathfinding — Pathfinder API v2.

The public exploration surface of the repo: an encoded design space
(:class:`DesignSpace`), a batched struct-of-arrays evaluator
(:func:`evaluate_batch`, parity-guaranteed against the scalar
:func:`repro.core.evaluate.evaluate`), a device-resident engine
(:mod:`repro.pathfinding.device`: jitted fused evaluate+cost, vectorized
hierarchical moves, and a ``lax.scan`` parallel-tempering loop — the
default for batched strategies via ``Pathfinder(device=True)``),
pluggable search strategies behind the :class:`Pathfinder` facade, and
first-class multi-objective frontiers (:mod:`repro.pathfinding.pareto`:
a bounded :class:`ParetoArchive` over the ``(latency, dollar,
total_cfp)`` axes fed by every strategy through
``SearchResult.frontier``, plus :class:`ScalarizationSweep` /
:class:`ScenarioSweep` for frontier mapping across weight directions,
deployment regions and workloads).

Quickstart::

    from repro.core import SAConfig, TEMPLATES, workload
    from repro.pathfinding import Pathfinder, SimulatedAnnealing

    pf = Pathfinder(workload(1), TEMPLATES["T1"])
    res = pf.search(strategy=SimulatedAnnealing(SAConfig()))
    print(res.best.describe(), res.best_metrics.total_cfp)

Migration from the seed API: ``anneal(wl, template, ...)`` is now
``Pathfinder(wl, template, ...).search(SimulatedAnnealing(config))``;
``fit_normalizer`` is ``Pathfinder.fit_normalizer`` (batched by default,
``method="scalar"`` for the seed loop); the ``evaluate_fn`` swap is the
``objective="carbonpath" | "chipletgym"`` backend name. The seed entry
points keep working as thin deprecation shims for one release.
"""
from repro.pathfinding.batch import (
    BatchEvaluator,
    MetricsBatch,
    evaluate_batch,
    fit_normalizer_batched,
    fit_region_normalizers,
    get_evaluator,
)
from repro.pathfinding.device import (
    DeviceEvaluator,
    ScenarioEngine,
    evaluate_batch_device,
    get_device_evaluator,
    get_scenario_engine,
    propose_batch,
)
from repro.pathfinding.pareto import (
    ParetoArchive,
    ScalarizationSweep,
    ScenarioSweep,
    crowding_distance,
    fold_cell_key,
    fold_job_key,
    hypervolume,
    non_dominated_mask,
    non_dominated_mask_jnp,
    simplex_directions,
    workloads_from_configs,
)
from repro.pathfinding.pathfinder import OBJECTIVES, Pathfinder
from repro.pathfinding.scenario import ScenarioSpec
from repro.pathfinding.resume import (
    SearchCheckpointer,
    run_segmented,
    search_fingerprint,
    segment_fingerprint,
)
from repro.pathfinding.space import DesignSpace
from repro.pathfinding.strategies import (
    GridSweep,
    Objective,
    ParallelTempering,
    RandomSearch,
    SearchResult,
    SearchStrategy,
    SimulatedAnnealing,
)

__all__ = [
    "BatchEvaluator", "DeviceEvaluator", "MetricsBatch", "ScenarioEngine",
    "evaluate_batch", "evaluate_batch_device", "fit_normalizer_batched",
    "fit_region_normalizers", "fold_cell_key", "fold_job_key",
    "get_device_evaluator",
    "get_evaluator", "get_scenario_engine", "propose_batch", "OBJECTIVES",
    "Pathfinder", "DesignSpace", "GridSweep", "Objective",
    "ParallelTempering", "ParetoArchive", "RandomSearch",
    "ScalarizationSweep", "ScenarioSpec", "ScenarioSweep",
    "SearchCheckpointer",
    "SearchResult", "SearchStrategy", "run_segmented",
    "search_fingerprint", "segment_fingerprint",
    "SimulatedAnnealing", "crowding_distance", "hypervolume",
    "non_dominated_mask", "non_dominated_mask_jnp", "simplex_directions",
    "workloads_from_configs",
]
