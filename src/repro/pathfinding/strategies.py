"""Pluggable search strategies over the encoded HI design space.

Every strategy implements the :class:`SearchStrategy` protocol::

    search(space, objective, budget, key) -> SearchResult

where ``space`` is a :class:`~repro.pathfinding.space.DesignSpace`,
``objective`` bundles the workload / cost template / normalizer and the
evaluation backend (CarbonPATH or ChipletGym models), ``budget`` caps the
number of evaluations (None = strategy default schedule) and ``key``
seeds the strategy's RNG.

Strategies:

* :class:`SimulatedAnnealing` — the paper's hierarchical-move annealer
  (Sec V), moved verbatim from the seed ``repro.core.sa.anneal`` so
  results are bit-identical for equal seeds/config.
* :class:`ParallelTempering` — N concurrent chains on a geometric
  temperature ladder, evaluated per sweep through the *batched* evaluator
  with periodic replica-exchange swaps.
* :class:`RandomSearch` — batched uniform sampling of valid systems.
* :class:`GridSweep` — deterministic sweep of package x protocol x
  memory x mapping for a fixed chiplet multiset (the Sec V-A 43-combo
  enumeration the figure benchmarks use).
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import List, Optional, Protocol, Sequence, Tuple

import numpy as np

from repro.core.chiplet import Chiplet, different_chiplet_system
from repro.core.evaluate import Metrics, evaluate
from repro.core.scalesim import SimCache
from repro.core.system import HISystem
from repro.core.techdb import DEFAULT_DB, TechDB, valid_pairs_25d, valid_pairs_3d
from repro.core.templates import (
    METRIC_FIELDS,
    Normalizer,
    Template,
    sa_cost,
)
from repro.core.workload import ALL_MAPPINGS, GEMMWorkload
from repro.pathfinding.batch import MetricsBatch, evaluate_batch
from repro.pathfinding.space import DesignSpace


@dataclasses.dataclass
class SearchResult:
    """What every strategy returns (superset of the seed ``SAResult``).

    ``frontier`` is the Pareto archive of every design the strategy
    evaluated, over the :data:`repro.core.sa.OBJECTIVE_AXES` axes
    ``(latency_s, dollar, total_cfp)`` — ``None`` only when collection
    was disabled (``frontier_size=0``)."""

    best: HISystem
    best_metrics: Metrics
    best_cost: float
    history: List[float]
    evaluations: int
    cache: Optional[SimCache] = None
    frontier: Optional["object"] = None   # ParetoArchive

    def __repr__(self) -> str:
        front = "none" if self.frontier is None else len(self.frontier)
        return (f"SearchResult(best_cost={self.best_cost:.6g}, "
                f"evaluations={self.evaluations}, "
                f"history={len(self.history)} pts, frontier={front})")


@dataclasses.dataclass
class Objective:
    """Workload + Eq. 17 cost + evaluation backend, scalar and batched."""

    wl: GEMMWorkload
    template: Template
    norm: Normalizer
    db: TechDB = DEFAULT_DB
    evaluate_fn: object = evaluate          # scalar backend
    cache: SimCache = dataclasses.field(default_factory=SimCache)
    # None -> derived: only the CarbonPATH scalar reference has a
    # parity-guaranteed batched twin; every other backend falls back
    batched: Optional[bool] = None
    # None -> follows ``batched``: the jitted device evaluator is the
    # same CarbonPATH math, so any batched-capable objective can use it
    device: Optional[bool] = None

    def __post_init__(self):
        if self.batched is None:
            self.batched = self.evaluate_fn is evaluate
        if self.device is None:
            self.device = self.batched
        self.device = self.device and self.batched
        # hoisted out of cost_batch: the dict -> array restacking ran on
        # every sweep
        mins, medians = self.norm.weights_arrays()
        self._cost_mins = mins
        self._cost_medians = medians
        self._cost_w = np.asarray(self.template.weights, dtype=np.float64)

    def evaluate(self, sys: HISystem) -> Metrics:
        return self.evaluate_fn(sys, self.wl, self.db, cache=self.cache)

    def cost(self, m: Metrics) -> float:
        return sa_cost(m, self.template, self.norm)

    # -- multi-objective vector (OBJECTIVE_AXES order) ----------------------

    def cost_vector(self, m: Metrics) -> np.ndarray:
        """Scalar-path ``(latency_s, dollar, total_cfp)`` vector."""
        from repro.core.sa import cost_vector

        return np.asarray(cost_vector(m), dtype=np.float64)

    def cost_vector_batch(self, mb: MetricsBatch) -> np.ndarray:
        """``[P, 3]`` objective vectors for a batch (raw metric units —
        normalizer/template independent, so frontiers merge across
        scalarization directions)."""
        return mb.objective_vectors()

    def eval_cost_vector_encoded(self, encoded: np.ndarray,
                                 space: DesignSpace
                                 ) -> Tuple[MetricsBatch, np.ndarray,
                                            np.ndarray]:
        """Metrics + Eq. 17 cost + objective vectors in one call; on the
        device path all three come out of the same fused jit program."""
        if self.device:
            from repro.pathfinding.device import get_device_evaluator

            dev = get_device_evaluator(self.wl, self.db, space=space)
            return dev.evaluate_cost_vector(encoded, self.norm,
                                            self.template)
        mb = self.evaluate_encoded(encoded, space)
        return mb, self.cost_batch(mb), self.cost_vector_batch(mb)

    def evaluate_encoded(self, encoded: np.ndarray,
                         space: DesignSpace) -> MetricsBatch:
        if self.batched:
            return evaluate_batch(encoded, self.wl, self.db, space=space)
        # non-vectorized backends (e.g. ChipletGym) fall back to the
        # scalar model per row but keep the struct-of-arrays interface
        ms = [self.evaluate(s) for s in space.decode_many(encoded)]
        return MetricsBatch(**{
            f.name: np.array([getattr(m, f.name) for m in ms])
            for f in dataclasses.fields(MetricsBatch)})

    def eval_cost_encoded(self, encoded: np.ndarray, space: DesignSpace
                          ) -> Tuple[MetricsBatch, np.ndarray]:
        """Metrics + Eq. 17 cost in one call. On the device path this is
        a single fused jitted program (metrics never leave the device
        between evaluation and cost)."""
        if self.device:
            from repro.pathfinding.device import get_device_evaluator

            dev = get_device_evaluator(self.wl, self.db, space=space)
            return dev.evaluate_cost(encoded, self.norm, self.template)
        mb = self.evaluate_encoded(encoded, space)
        return mb, self.cost_batch(mb)

    def cost_batch(self, mb: MetricsBatch) -> np.ndarray:
        x = np.stack([mb.fields()[f] for f in METRIC_FIELDS], axis=1)
        return ((x - self._cost_mins) / self._cost_medians
                * self._cost_w).sum(axis=1)


class SearchStrategy(Protocol):
    def search(self, space: DesignSpace, objective: Objective,
               budget: Optional[int] = None,
               key: Optional[int] = None) -> SearchResult:
        ...


# ``key=None`` resolves to this fixed default instead of 0, so passing
# ``key=0`` is a *distinct*, fully valid seed (previously both collapsed
# onto the same RNG stream). Any fixed constant works; this one is the
# 32-bit golden-ratio mix constant, far from hand-typed seeds.
DEFAULT_SEARCH_KEY = 0x9E3779B9


def _resolve_key(key: Optional[int]) -> int:
    return DEFAULT_SEARCH_KEY if key is None else key


def _check_budget(budget: Optional[int]) -> None:
    """Every strategy's first line: ``budget`` is None (strategy default
    schedule) or a positive integer evaluation cap. 0/negative budgets
    and non-integers (a float silently truncates in slicing/floordiv
    arithmetic) are rejected up front."""
    if budget is None:
        return
    if isinstance(budget, bool) or not isinstance(budget, (int, np.integer)):
        raise TypeError(
            f"budget must be an int or None, got {type(budget).__name__}")
    if budget < 1:
        raise ValueError(f"budget must be >= 1 or None, got {budget}")


def budget_sweeps(sweeps: int, population: int,
                  budget: Optional[int], *, detail: str = "") -> int:
    """Clamp a sweep count to a *total* evaluation budget.

    One chain population costs ``population`` evaluations to seed and
    ``population`` more per sweep, so ``budget`` evaluations pay for at
    most ``(budget - population) // population`` whole sweeps. A budget
    below one population cannot seed the chains at all and is rejected
    loudly (``detail`` extends the message with caller context).

    This is the :class:`~repro.pathfinding.pareto.ScalarizationSweep`
    total-split semantics — shared by the scenario grid (per-cell
    budgets) and the serving layer (per-job budgets). Note
    :class:`ParallelTempering` keeps its own, different accounting
    (best-effort truncation instead of a loud reject)."""
    if budget is None:
        return sweeps
    if budget < population:
        raise ValueError(
            f"budget {budget} < one chain population {population}{detail}")
    return min(sweeps, (budget - population) // population)


def _checkpointer(checkpoint_dir: Optional[str]):
    """A :class:`~repro.pathfinding.resume.SearchCheckpointer` for the
    directory, or ``None`` when checkpointing is off."""
    if checkpoint_dir is None:
        return None
    from repro.pathfinding.resume import SearchCheckpointer

    return SearchCheckpointer(checkpoint_dir)


def _check_checkpointable(checkpoint_dir: Optional[str],
                          objective: "Objective") -> None:
    """Checkpoint/resume lives in the segmented device engines; the
    scalar host fallbacks have no snapshot-able carry, so asking for
    both is a configuration error, not a silent no-op."""
    if checkpoint_dir is not None and not objective.device:
        raise ValueError(
            "checkpoint_dir requires the device engine "
            "(Pathfinder(device=True) with the carbonpath backend); the "
            "scalar host fallback cannot checkpoint")


# ---------------------------------------------------------------------------
# Simulated annealing (Sec V) — the seed annealer behind the v2 protocol
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimulatedAnnealing:
    """The paper's SA engine. For a given config/seed this reproduces the
    seed ``anneal(...)`` trajectory exactly (same RNG stream, same moves,
    same scalar evaluations through the shared SimCache).

    Unlike the other strategies, ``key=None`` defers to ``config.seed``
    (the explicit, golden-pinned SA default) rather than
    :data:`DEFAULT_SEARCH_KEY` — so with the default ``SAConfig(seed=0)``
    an explicit ``key=0`` is the same stream; pass a config seed or an
    explicit key to vary it."""

    config: "SAConfig" = None  # type: ignore[assignment]
    initial: Optional[HISystem] = None
    frontier_size: int = 256

    def search(self, space: DesignSpace, objective: Objective,
               budget: Optional[int] = None,
               key: Optional[int] = None) -> SearchResult:
        from repro.core.sa import (
            SAConfig,
            propose,
            random_system,
            seed_noc,
            seed_schedule,
        )
        from repro.pathfinding.pareto import FrontierFeed

        _check_budget(budget)
        cfg = self.config or SAConfig(max_chiplets=space.max_chiplets)
        db = objective.db
        rng = random.Random(cfg.seed if key is None else key)
        feed = FrontierFeed(self.frontier_size)

        collect = feed.archive is not None

        cur = self.initial or random_system(rng, db, cfg.max_chiplets)
        if space.noc_live:
            cur = seed_noc(cur)
        if space.sched_live:
            cur = seed_schedule(cur)
        cur_m = objective.evaluate(cur)
        cur_c = objective.cost(cur_m)
        if collect:
            feed.add(space.encode(cur), objective.cost_vector(cur_m))
        best, best_m, best_c = cur, cur_m, cur_c
        history = [cur_c]
        evals = 1

        t = cfg.t_initial
        while t > cfg.t_final:
            for _ in range(cfg.moves_per_temp):
                if budget is not None and evals >= budget:
                    break
                cand = propose(cur, rng, db, cfg.max_chiplets,
                               noc_moves=space.noc_live,
                               schedule_moves=space.sched_live)
                if cand is cur:
                    continue
                m = objective.evaluate(cand)
                c = objective.cost(m)
                evals += 1
                if collect:
                    feed.add(space.encode(cand), objective.cost_vector(m))
                delta = c - cur_c
                if delta <= 0 or rng.random() < math.exp(
                        -delta / max(t, 1e-12)):
                    cur, cur_m, cur_c = cand, m, c
                    if c < best_c:
                        best, best_m, best_c = cand, m, c
            history.append(cur_c)
            t *= cfg.cooling
            if budget is not None and evals >= budget:
                break
        return SearchResult(best, best_m, best_c, history, evals,
                            objective.cache, frontier=feed.done())


# ---------------------------------------------------------------------------
# Parallel tempering: batched chains + replica exchange
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ParallelTempering:
    """N simultaneous SA chains on a geometric temperature ladder. Every
    sweep proposes one hierarchical move per chain and evaluates all
    candidates in a single batched call; every ``swap_every`` sweeps
    adjacent-temperature replicas attempt a Metropolis exchange, letting
    hot chains tunnel solutions down to cold ones.

    With a device-capable objective (``Pathfinder(device=True)``, the
    default for the CarbonPATH backend) the whole sweep loop — propose,
    evaluate, Metropolis accept, replica exchange — runs as a fused
    ``jax.lax.scan`` on the device (:mod:`repro.pathfinding.device`),
    advanced in host-driven segments of ``segment`` sweeps (default: one
    segment). Segmentation never changes the trajectory — same key
    stream, same sweep indices — but gives the search its checkpoint
    boundaries: with ``checkpoint_dir`` set, the scan carry + frontier
    archive + history snapshot atomically at every boundary
    (:mod:`repro.pathfinding.resume`), and ``resume=True`` (default)
    restores the newest valid snapshot so an interrupted search
    reproduces the uninterrupted run bit-for-bit. The host path below is
    preserved as the scalar fallback and as the replayable reference
    (checkpointing requires the device engine)."""

    n_chains: int = 8
    t_max: float = 4000.0
    t_min: float = 1.0
    sweeps: int = 500
    swap_every: int = 5
    frontier_size: int = 256
    segment: Optional[int] = None
    checkpoint_dir: Optional[str] = None
    resume: bool = True

    def search(self, space: DesignSpace, objective: Objective,
               budget: Optional[int] = None,
               key: Optional[int] = None) -> SearchResult:
        from repro.core.sa import propose, random_system
        from repro.pathfinding.pareto import FrontierFeed

        _check_budget(budget)
        _check_checkpointable(self.checkpoint_dir, objective)
        key = _resolve_key(key)
        db = objective.db
        rng = random.Random(key)
        # the initial population costs one evaluation per chain, so a
        # tiny budget bounds the ladder width itself
        n = self.n_chains if budget is None else min(self.n_chains, budget)
        ratio = (self.t_min / self.t_max) ** (1.0 / max(1, n - 1))
        temps = [self.t_max * ratio ** i for i in range(n)]

        chains = [random_system(rng, db, space.max_chiplets)
                  for _ in range(n)]
        if space.noc_live:
            from repro.core.sa import seed_noc

            chains = [seed_noc(s) for s in chains]
        if space.sched_live:
            from repro.core.sa import seed_schedule

            chains = [seed_schedule(s) for s in chains]
        if objective.device:
            return self._search_device(space, objective, budget, key,
                                       chains, temps)
        feed = FrontierFeed(self.frontier_size)
        enc0 = space.encode_many(chains)
        mb = objective.evaluate_encoded(enc0, space)
        costs = objective.cost_batch(mb).tolist()
        feed.add(enc0, objective.cost_vector_batch(mb))
        evals = n
        bi = int(np.argmin(costs))
        best, best_m, best_c = chains[bi], mb.row(bi), costs[bi]
        history = [best_c]

        for sweep in range(self.sweeps):
            # honor the budget exactly: a final partial sweep evaluates
            # only as many chains as evaluations remain
            k = n if budget is None else min(n, budget - evals)
            if k <= 0:
                break
            cands = [propose(chains[i], rng, db, space.max_chiplets,
                             noc_moves=space.noc_live,
                             schedule_moves=space.sched_live)
                     for i in range(k)]
            enc = space.encode_many(cands)
            mb = objective.evaluate_encoded(enc, space)
            ccosts = objective.cost_batch(mb).tolist()
            feed.add(enc, objective.cost_vector_batch(mb))
            evals += k
            for i in range(k):
                delta = ccosts[i] - costs[i]
                if delta <= 0 or rng.random() < math.exp(
                        -delta / max(temps[i], 1e-12)):
                    chains[i], costs[i] = cands[i], ccosts[i]
                    if ccosts[i] < best_c:
                        best, best_m, best_c = cands[i], mb.row(i), ccosts[i]
            if sweep % self.swap_every == 0:
                _replica_exchange(temps, chains, costs, rng)
            history.append(costs[-1])  # coldest chain
        return SearchResult(best, best_m, best_c, history, evals,
                            objective.cache, frontier=feed.done())

    def _search_device(self, space: DesignSpace, objective: Objective,
                       budget: Optional[int], key: Optional[int],
                       chains, temps) -> SearchResult:
        """The fused lax.scan path. Proposals come from the device move
        generator (same hierarchical distribution, jax.random stream), so
        trajectories are deterministic per key but differ from the host
        Python-RNG path; with a budget, only whole sweeps run (search
        evaluations stay <= budget). Re-materializing the winner's
        Metrics costs one scalar evaluation of an already-searched row
        (through the shared SimCache, outside the budget accounting)."""
        from repro.pathfinding.device import get_device_evaluator
        from repro.pathfinding.pareto import ParetoArchive

        n = len(chains)
        dev = get_device_evaluator(objective.wl, objective.db, space=space)
        sweeps = self.sweeps
        if budget is not None:
            sweeps = min(sweeps, max(0, budget - n) // n)
        archive = (ParetoArchive(max_size=self.frontier_size)
                   if self.frontier_size > 0 else None)
        res = dev.parallel_tempering(
            space.encode_many(chains), np.asarray(temps), sweeps,
            self.swap_every, seed=key,
            norm=objective.norm, template=objective.template,
            collect_samples=self.frontier_size > 0,
            segment=self.segment, archive=archive,
            checkpoint=_checkpointer(self.checkpoint_dir),
            resume=self.resume)
        best = space.decode(res.best_enc)
        # one scalar evaluation beats paying a fresh bucket compile of
        # the fused evaluator just to materialize the winning row
        return SearchResult(best, objective.evaluate(best),
                            res.best_cost, res.history, res.evaluations,
                            objective.cache, frontier=archive)


def _replica_exchange(temps: Sequence[float], chains: list, costs: list,
                      rng: random.Random) -> None:
    """Metropolis swap between adjacent replicas (detailed balance):
    accept with min(1, exp[(beta_i - beta_j)(E_i - E_j)]). ``temps`` is
    descending, so when the hotter chain i holds the lower cost the
    exponent is positive and the swap is certain — better solutions
    always flow toward the cold end."""
    for i in range(len(temps) - 1):
        d = ((1.0 / temps[i] - 1.0 / temps[i + 1])
             * (costs[i] - costs[i + 1]))
        if d >= 0 or rng.random() < math.exp(d):
            chains[i], chains[i + 1] = chains[i + 1], chains[i]
            costs[i], costs[i + 1] = costs[i + 1], costs[i]


# ---------------------------------------------------------------------------
# Random search + grid sweep (batched baselines)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RandomSearch:
    """Uniform sampling of valid systems, evaluated in batches."""

    batch_size: int = 512
    frontier_size: int = 256

    def search(self, space: DesignSpace, objective: Objective,
               budget: Optional[int] = None,
               key: Optional[int] = None) -> SearchResult:
        from repro.pathfinding.pareto import FrontierFeed

        _check_budget(budget)
        budget = budget if budget is not None else 2048
        rng = np.random.default_rng(_resolve_key(key))
        feed = FrontierFeed(self.frontier_size)
        best = best_m = None
        best_c = math.inf
        history: List[float] = []
        evals = 0
        while evals < budget:
            k = min(self.batch_size, budget - evals)
            enc = space.sample(k, key=rng)
            mb, costs, vec = objective.eval_cost_vector_encoded(enc, space)
            feed.add(enc, vec)
            evals += k
            i = int(np.argmin(costs))
            if costs[i] < best_c:
                best, best_m, best_c = (space.decode(enc[i]), mb.row(i),
                                        float(costs[i]))
            history.append(best_c)
        return SearchResult(best, best_m, best_c, history, evals,
                            objective.cache, frontier=feed.done())


@dataclasses.dataclass
class GridSweep:
    """Deterministic sweep: every package-protocol combination (the
    paper's 10 + 3 + 30 = 43, Sec V-A) x memory x mapping for a fixed
    chiplet multiset. Hybrid combos stack the ``stack`` indices."""

    chiplets: Optional[Tuple[Chiplet, ...]] = None
    memories: Optional[Sequence[str]] = None
    mappings: Sequence = ALL_MAPPINGS
    stack: Tuple[int, ...] = (1, 2)
    frontier_size: int = 256

    def systems(self, db: TechDB) -> List[HISystem]:
        chips = tuple(self.chiplets or different_chiplet_system())
        mems = list(self.memories or db.memories)
        out = []
        for mem in mems:
            for mapping in self.mappings:
                for pkg, proto in valid_pairs_25d():
                    out.append(HISystem(chips, "2.5D", mem, mapping,
                                        pkg_25d=pkg, proto_25d=proto))
                for pkg, proto in valid_pairs_3d():
                    out.append(HISystem(chips, "3D", mem, mapping,
                                        pkg_3d=pkg, proto_3d=proto))
                for p25, pr25 in valid_pairs_25d():
                    for p3, pr3 in valid_pairs_3d():
                        out.append(HISystem(
                            chips, "2.5D+3D", mem, mapping, pkg_25d=p25,
                            proto_25d=pr25, pkg_3d=p3, proto_3d=pr3,
                            stack=self.stack))
        return out

    def search(self, space: DesignSpace, objective: Objective,
               budget: Optional[int] = None,
               key: Optional[int] = None) -> SearchResult:
        from repro.pathfinding.pareto import FrontierFeed

        _check_budget(budget)
        systems = self.systems(objective.db)
        if budget is not None:
            systems = systems[:budget]
        enc = space.encode_many(systems)
        mb, costs, vec = objective.eval_cost_vector_encoded(enc, space)
        feed = FrontierFeed(self.frontier_size)
        feed.add(enc, vec)
        i = int(np.argmin(costs))
        running = np.minimum.accumulate(costs)
        return SearchResult(systems[i], mb.row(i), float(costs[i]),
                            running.tolist(), len(systems), objective.cache,
                            frontier=feed.done())
