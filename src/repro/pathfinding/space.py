"""Encoded design space for CarbonPATH pathfinding (Pathfinder API v2).

The discrete HI design space of Sec V-A — chiplet multiset x integration
style x package interconnect/protocol x memory x mapping — is canonically
enumerated from a :class:`TechDB` and represented as fixed-width ``int32``
vectors so whole populations can be validated, sampled and evaluated as
arrays (see :mod:`repro.pathfinding.batch`).

Vector layout (one row per system, width ``9 + 3 * max_chiplets``)::

    [0] n_chiplets      [1] style_idx     [2] memory_idx
    [3] order           [4] dataflow_idx  [5] split_k
    [6] pair25_idx      (index into valid_pairs_25d(), -1 if none)
    [7] pair3_idx       (index into valid_pairs_3d(),  -1 if none)
    [8] stack_mask      (bitmask of 3D-stacked chiplet indices, 0 if none)
    [9 + 3i .. 11 + 3i] per-chiplet (array_idx, node_idx, sram_idx)
                        for i < n_chiplets; -1 padding beyond.

Under ``comm="mesh_noc"`` (see :mod:`repro.core.comm`) the row grows two
per-chiplet NoC columns appended after the chiplet block (total width
``9 + 5 * max_chiplets``)::

    [noc_col + 2i]      mesh_dims_idx  (index into comm.MESH_DIMS)
    [noc_col + 2i + 1]  entry_idx      (index into comm.ENTRY_PLACEMENTS)
                        for i < n_chiplets; -1 padding beyond.

Under ``schedule="window"`` (see :mod:`repro.core.schedule`) the row
grows two whole-design schedule columns appended after every per-chiplet
block::

    [sched_col]      start_hour (0..23)
    [sched_col + 1]  shape_idx  (index into the SCHEDULE_SHAPES table)

Legacy vectors round-trip unchanged: the NoC columns exist only when the
space's ``comm`` resolves to ``mesh_noc``, the schedule columns only
when ``schedule`` resolves to ``window``. When either model is forced
through its env var (``REPRO_COMM_MODEL`` / ``REPRO_SCHEDULE``) rather
than requested explicitly, the axes are *frozen* at their bit-neutral
``(0, 0)`` values — sampling fills neutral values without consuming RNG
draws and move generators skip the corresponding moves — so legacy
searches replay identically through the widened program.

``encode``/``decode`` round-trip exactly for every valid system (the
stack tuple is canonicalized to sorted order, which is what the SA move
generator produces anyway).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import comm as comm_mod
from repro.core import schedule as sched_mod
from repro.core.chiplet import Chiplet
from repro.core.system import HISystem, is_valid
from repro.core.techdb import (
    DATAFLOWS,
    DEFAULT_DB,
    INTEGRATION_STYLES,
    PKG_PROTOCOLS_25D,
    PKG_PROTOCOLS_3D,
    PROTOCOLS_25D,
    TechDB,
    valid_pairs_25d,
    valid_pairs_3d,
)
from repro.core.workload import Mapping

# column indices of the encoding
COL_N, COL_STYLE, COL_MEM, COL_ORDER, COL_DATAFLOW, COL_SPLITK = range(6)
COL_PAIR25, COL_PAIR3, COL_STACK = 6, 7, 8
COL_CHIP = 9  # first per-chiplet column

S_2D, S_25D, S_3D, S_HYBRID = range(4)  # indices into INTEGRATION_STYLES


DEFAULT_MAX_CHIPLETS = 6  # paper Sec V-A chiplet-count bound


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """Canonical enumeration of the discrete HI space from a TechDB."""

    db: TechDB = DEFAULT_DB
    max_chiplets: int = DEFAULT_MAX_CHIPLETS
    # Communication model ("legacy" | "mesh_noc"). None resolves through
    # the REPRO_COMM_MODEL env var (default "legacy"). An env-forced
    # mesh_noc keeps the NoC axes *frozen* at the neutral mesh
    # (noc_live False): legacy searches replay bit-identically through
    # the mesh program. Passing comm="mesh_noc" explicitly makes the
    # axes live search dimensions.
    comm: Optional[str] = None
    # Schedule model ("fixed" | "window"). None resolves through the
    # REPRO_SCHEDULE env var (default "fixed"). Same freeze semantics as
    # comm: env-forced window keeps the (start_hour, shape) axes frozen
    # at the neutral (0, 0) schedule (sched_live False); passing
    # schedule="window" explicitly makes them live search dimensions.
    schedule: Optional[str] = None

    def __post_init__(self):
        db = self.db
        set_ = object.__setattr__
        explicit = self.comm
        set_(self, "comm", comm_mod.resolve_comm(explicit))
        set_(self, "noc_live",
             self.comm == "mesh_noc" and explicit == "mesh_noc")
        explicit_sched = self.schedule
        set_(self, "schedule", sched_mod.resolve_schedule(explicit_sched))
        set_(self, "sched_live",
             self.schedule == "window" and explicit_sched == "window")
        set_(self, "arrays", tuple(db.array_sizes))
        set_(self, "nodes", tuple(db.tech_nodes))
        set_(self, "memories", tuple(db.memories))
        set_(self, "pairs_25d", valid_pairs_25d())
        set_(self, "pairs_3d", valid_pairs_3d())
        set_(self, "array_index", {a: i for i, a in enumerate(self.arrays)})
        set_(self, "node_index", {t: i for i, t in enumerate(self.nodes)})
        set_(self, "memory_index", {m: i for i, m in enumerate(self.memories)})
        set_(self, "dataflow_index", {d: i for i, d in enumerate(DATAFLOWS)})
        set_(self, "style_index",
             {s: i for i, s in enumerate(INTEGRATION_STYLES)})
        set_(self, "pair25_index",
             {p: i for i, p in enumerate(self.pairs_25d)})
        set_(self, "pair3_index", {p: i for i, p in enumerate(self.pairs_3d)})
        set_(self, "sram_index",
             {a: {s: i for i, s in enumerate(db.sram_sizes_kb[a])}
              for a in self.arrays})
        # sram option count per array (vector for validity checks)
        set_(self, "n_sram",
             np.array([len(db.sram_sizes_kb[a]) for a in self.arrays],
                      dtype=np.int32))
        # hierarchical package draw, mirroring sa.random_system: first a
        # package uniform, then a protocol uniform within the package
        set_(self, "pkg25_pairs",
             tuple(tuple(self.pair25_index[(pkg, pr)] for pr in protos)
                   for pkg, protos in PKG_PROTOCOLS_25D.items()))
        set_(self, "pkg3_pairs",
             tuple(tuple(self.pair3_index[(pkg, pr)] for pr in protos)
                   for pkg, protos in PKG_PROTOCOLS_3D.items()))

    # -- flat lookup tables for vectorized (device) hierarchical moves ------

    def move_tables(self) -> dict:
        """Flat ``int32`` tables that let :mod:`repro.pathfinding.device`
        mirror the hierarchical package/protocol draws of
        :func:`repro.core.sa.propose` with pure gathers:

        * ``p25_off``/``p25_cnt``/``p25_flat`` — CSR layout of pair-25D ids
          grouped by package (draw a package uniformly, then a protocol
          uniformly within it);
        * ``pair25_pkg``/``pair25_local``/``pair25_proto`` — reverse maps
          from a pair id to its package, its position within the package
          and its global protocol index;
        * ``pair25_by_pkg_proto`` — pair id for (package, protocol) or -1
          when incompatible (the "keep the protocol if the new package
          supports it" rule of ``_move_package``);
        * ``pair3_pkg``/``pair3_of_pkg`` — the 3D equivalents (every 3D
          package carries exactly UCIe-3D).
        """
        cached = getattr(self, "_move_tables", None)
        if cached is not None:
            return cached
        n25 = len(self.pairs_25d)
        pair_pkg = np.empty(n25, dtype=np.int32)
        pair_local = np.empty(n25, dtype=np.int32)
        pair_proto = np.empty(n25, dtype=np.int32)
        by_pkg_proto = np.full(
            (len(PKG_PROTOCOLS_25D), len(PROTOCOLS_25D)), -1, dtype=np.int32)
        off, cnt, flat = [0], [], []
        for pi, (pkg, protos) in enumerate(PKG_PROTOCOLS_25D.items()):
            for li, proto in enumerate(protos):
                pid = self.pair25_index[(pkg, proto)]
                gp = PROTOCOLS_25D.index(proto)
                pair_pkg[pid] = pi
                pair_local[pid] = li
                pair_proto[pid] = gp
                by_pkg_proto[pi, gp] = pid
                flat.append(pid)
            cnt.append(len(protos))
            off.append(len(flat))
        pair3_pkg = np.empty(len(self.pairs_3d), dtype=np.int32)
        pair3_of_pkg = np.empty(len(PKG_PROTOCOLS_3D), dtype=np.int32)
        for pi, pkg in enumerate(PKG_PROTOCOLS_3D):
            pid = self.pair3_index[(pkg, "UCIe-3D")]
            pair3_pkg[pid] = pi
            pair3_of_pkg[pi] = pid
        tables = dict(
            p25_off=np.asarray(off, dtype=np.int32),
            p25_cnt=np.asarray(cnt, dtype=np.int32),
            p25_flat=np.asarray(flat, dtype=np.int32),
            pair25_pkg=pair_pkg, pair25_local=pair_local,
            pair25_proto=pair_proto, pair25_by_pkg_proto=by_pkg_proto,
            pair3_pkg=pair3_pkg, pair3_of_pkg=pair3_of_pkg,
        )
        object.__setattr__(self, "_move_tables", tables)
        return tables

    # -- geometry -----------------------------------------------------------

    @property
    def width(self) -> int:
        w = COL_CHIP + 3 * self.max_chiplets
        if self.comm == "mesh_noc":
            w += 2 * self.max_chiplets
        if self.schedule == "window":
            w += 2
        return w

    @property
    def noc_col(self) -> int:
        """First NoC column (mesh_noc spaces only)."""
        return COL_CHIP + 3 * self.max_chiplets

    @property
    def sched_col(self) -> int:
        """First schedule column (window spaces only) — after every
        per-chiplet block, so NoC-bearing and legacy layouts both append
        the schedule pair at the tail."""
        col = COL_CHIP + 3 * self.max_chiplets
        if self.comm == "mesh_noc":
            col += 2 * self.max_chiplets
        return col

    def chip_cols(self, i: int):
        base = COL_CHIP + 3 * i
        return base, base + 1, base + 2

    def noc_cols(self, i: int):
        base = self.noc_col + 2 * i
        return base, base + 1

    def chiplet_choices(self) -> int:
        """Distinct chiplets in the library (Table II: 80 by default)."""
        return sum(len(self.db.sram_sizes_kb[a]) for a in self.arrays) * len(
            self.nodes)

    def bounds(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-column ``(lo, hi)`` inclusive int bounds of the encoding.

        Loose bounds: every valid row satisfies them, but not every row
        inside them is valid (e.g. the SRAM index bound is the max across
        arrays, and pair/stack columns depend on the style). Useful for
        cheap in-bounds assertions over move-generator outputs — the
        tight check remains :meth:`validity_mask`."""
        lo = np.full(self.width, -1, dtype=np.int64)
        hi = np.empty(self.width, dtype=np.int64)
        hi[COL_N] = self.max_chiplets
        lo[COL_N] = 1
        hi[COL_STYLE] = len(INTEGRATION_STYLES) - 1
        lo[COL_STYLE] = 0
        hi[COL_MEM] = len(self.memories) - 1
        lo[COL_MEM] = 0
        hi[COL_ORDER] = 1
        lo[COL_ORDER] = 0
        hi[COL_DATAFLOW] = len(DATAFLOWS) - 1
        lo[COL_DATAFLOW] = 0
        hi[COL_SPLITK] = 1
        lo[COL_SPLITK] = 0
        hi[COL_PAIR25] = len(self.pairs_25d) - 1
        hi[COL_PAIR3] = len(self.pairs_3d) - 1
        hi[COL_STACK] = (1 << self.max_chiplets) - 1
        lo[COL_STACK] = 0
        n_sram_max = int(self.n_sram.max())
        for i in range(self.max_chiplets):
            ca, ct, cs = self.chip_cols(i)
            hi[ca] = len(self.arrays) - 1
            hi[ct] = len(self.nodes) - 1
            hi[cs] = n_sram_max - 1
        if self.comm == "mesh_noc":
            for i in range(self.max_chiplets):
                cm, ce = self.noc_cols(i)
                hi[cm] = len(comm_mod.MESH_DIMS) - 1
                hi[ce] = len(comm_mod.ENTRY_PLACEMENTS) - 1
        if self.schedule == "window":
            sc = self.sched_col
            lo[sc] = lo[sc + 1] = 0   # whole-design axes: never padded
            hi[sc] = sched_mod.HOURS_PER_DAY - 1
            hi[sc + 1] = sched_mod.n_schedule_shapes() - 1
        return lo, hi

    # -- encode / decode ----------------------------------------------------

    def encode(self, sys: HISystem) -> np.ndarray:
        vec = np.full(self.width, -1, dtype=np.int32)
        n = sys.n_chiplets
        if n > self.max_chiplets:
            raise ValueError(
                f"{n} chiplets exceeds space max_chiplets={self.max_chiplets}")
        vec[COL_N] = n
        vec[COL_STYLE] = self.style_index[sys.style]
        vec[COL_MEM] = self.memory_index[sys.memory]
        vec[COL_ORDER] = sys.mapping.order
        vec[COL_DATAFLOW] = self.dataflow_index[sys.mapping.dataflow]
        vec[COL_SPLITK] = sys.mapping.split_k
        vec[COL_PAIR25] = (self.pair25_index[(sys.pkg_25d, sys.proto_25d)]
                           if sys.pkg_25d else -1)
        vec[COL_PAIR3] = (self.pair3_index[(sys.pkg_3d, sys.proto_3d)]
                          if sys.pkg_3d else -1)
        stack = sys.stack if sys.style == "2.5D+3D" else ()
        vec[COL_STACK] = sum(1 << i for i in stack)
        for i, c in enumerate(sys.chiplets):
            ca, ct, cs = self.chip_cols(i)
            vec[ca] = self.array_index[c.array]
            vec[ct] = self.node_index[c.node]
            vec[cs] = self.sram_index[c.array][c.sram_kb]
        if self.comm == "mesh_noc":
            noc = sys.noc or (comm_mod.NOC_NEUTRAL,) * n
            for i, (mi, ei) in enumerate(noc):
                cm, ce = self.noc_cols(i)
                vec[cm] = mi
                vec[ce] = ei
        elif sys.noc:
            raise ValueError(
                "system carries NoC assignments but the space is "
                "comm='legacy'; build the DesignSpace with comm='mesh_noc'")
        if self.schedule == "window":
            sched = sys.schedule or sched_mod.SCHED_NEUTRAL
            sc = self.sched_col
            vec[sc], vec[sc + 1] = sched
        elif sys.schedule is not None:
            raise ValueError(
                "system carries a schedule but the space is "
                "schedule='fixed'; build the DesignSpace with "
                "schedule='window'")
        return vec

    def encode_many(self, systems: Sequence[HISystem]) -> np.ndarray:
        out = np.empty((len(systems), self.width), dtype=np.int32)
        for i, s in enumerate(systems):
            out[i] = self.encode(s)
        return out

    def decode(self, vec: np.ndarray) -> HISystem:
        vec = np.asarray(vec)
        n = int(vec[COL_N])
        style = INTEGRATION_STYLES[int(vec[COL_STYLE])]
        chips = []
        for i in range(n):
            ca, ct, cs = self.chip_cols(i)
            array = self.arrays[int(vec[ca])]
            chips.append(Chiplet(array, self.nodes[int(vec[ct])],
                                 self.db.sram_sizes_kb[array][int(vec[cs])]))
        pkg25 = proto25 = pkg3 = proto3 = None
        if int(vec[COL_PAIR25]) >= 0:
            pkg25, proto25 = self.pairs_25d[int(vec[COL_PAIR25])]
        if int(vec[COL_PAIR3]) >= 0:
            pkg3, proto3 = self.pairs_3d[int(vec[COL_PAIR3])]
        mask = int(vec[COL_STACK])
        stack = tuple(i for i in range(n) if (mask >> i) & 1)
        noc = ()
        if self.comm == "mesh_noc":
            noc = tuple((int(vec[self.noc_col + 2 * i]),
                         int(vec[self.noc_col + 2 * i + 1]))
                        for i in range(n))
        schedule = None
        if self.schedule == "window":
            sc = self.sched_col
            schedule = (int(vec[sc]), int(vec[sc + 1]))
        return HISystem(
            chiplets=tuple(chips),
            style=style,
            memory=self.memories[int(vec[COL_MEM])],
            mapping=Mapping(int(vec[COL_ORDER]),
                            DATAFLOWS[int(vec[COL_DATAFLOW])],
                            int(vec[COL_SPLITK])),
            pkg_25d=pkg25, proto_25d=proto25,
            pkg_3d=pkg3, proto_3d=proto3,
            stack=stack,
            noc=noc,
            schedule=schedule,
        )

    def decode_many(self, batch: np.ndarray) -> List[HISystem]:
        return [self.decode(row) for row in np.asarray(batch)]

    # -- vectorized validity (Sec V-A feasibility rules) --------------------

    def validity_mask(self, batch: np.ndarray) -> np.ndarray:
        """Boolean mask of rows that encode *valid* systems — the batched
        rendering of :func:`repro.core.system.validate`."""
        v = np.atleast_2d(np.asarray(batch, dtype=np.int64))
        n, style = v[:, COL_N], v[:, COL_STYLE]
        p25, p3, stack = v[:, COL_PAIR25], v[:, COL_PAIR3], v[:, COL_STACK]

        ok = (n >= 1) & (n <= self.max_chiplets)
        ok &= (style >= 0) & (style < len(INTEGRATION_STYLES))
        ok &= (v[:, COL_MEM] >= 0) & (v[:, COL_MEM] < len(self.memories))
        ok &= (v[:, COL_ORDER] >= 0) & (v[:, COL_ORDER] <= 1)
        ok &= (v[:, COL_DATAFLOW] >= 0) & (v[:, COL_DATAFLOW] < len(DATAFLOWS))
        ok &= (v[:, COL_SPLITK] >= 0) & (v[:, COL_SPLITK] <= 1)

        for i in range(self.max_chiplets):
            ca, ct, cs = self.chip_cols(i)
            active = i < n
            a, t, s = v[:, ca], v[:, ct], v[:, cs]
            a_ok = (a >= 0) & (a < len(self.arrays))
            chip_ok = (a_ok & (t >= 0) & (t < len(self.nodes)) & (s >= 0)
                       & (s < self.n_sram[np.where(a_ok, a, 0)]))
            ok &= np.where(active, chip_ok, True)

        if self.comm == "mesh_noc":
            for i in range(self.max_chiplets):
                cm, ce = self.noc_cols(i)
                m, e = v[:, cm], v[:, ce]
                noc_ok = ((m >= 0) & (m < len(comm_mod.MESH_DIMS))
                          & (e >= 0) & (e < len(comm_mod.ENTRY_PLACEMENTS)))
                ok &= np.where(i < n, noc_ok, True)

        if self.schedule == "window":
            sc = self.sched_col
            st, sh = v[:, sc], v[:, sc + 1]
            ok &= ((st >= 0) & (st < sched_mod.HOURS_PER_DAY)
                   & (sh >= 0) & (sh < sched_mod.n_schedule_shapes()))

        popcount = sum((stack >> i) & 1 for i in range(self.max_chiplets))
        no3d, no25d, nostack = p3 == -1, p25 == -1, stack == 0
        has25 = (p25 >= 0) & (p25 < len(self.pairs_25d))
        has3 = (p3 >= 0) & (p3 < len(self.pairs_3d))
        in_range = stack < (1 << np.minimum(n, 63))

        ok &= np.where(style == S_2D, (n == 1) & no25d & no3d & nostack, True)
        ok &= np.where(style == S_25D, (n >= 2) & has25 & no3d & nostack, True)
        ok &= np.where(style == S_3D, (n >= 2) & has3 & no25d & nostack, True)
        ok &= np.where(style == S_HYBRID,
                       (n >= 3) & has25 & has3 & (popcount >= 2)
                       & (popcount < n) & in_range & (stack >= 0), True)
        return ok

    # -- batched random sampling -------------------------------------------

    def sample(self, count: int,
               key: Union[int, np.random.Generator] = 0) -> np.ndarray:
        """Draw ``count`` random *valid* encoded systems.

        Mirrors :func:`repro.core.sa.random_system`'s hierarchical draw
        (uniform chiplet count -> style for that count -> package uniform,
        protocol uniform within the package) but vectorized: systems are
        valid by construction, no rejection loop.
        """
        rng = (key if isinstance(key, np.random.Generator)
               else np.random.default_rng(key))
        C = self.max_chiplets
        v = np.full((count, self.width), -1, dtype=np.int32)

        n = rng.integers(1, C + 1, count)
        # style per count: n=1 -> 2D; n=2 -> {2.5D, 3D}; n>=3 -> all three
        style = np.where(
            n == 1, S_2D,
            np.where(n == 2, rng.integers(S_25D, S_3D + 1, count),
                     rng.integers(S_25D, S_HYBRID + 1, count)))
        v[:, COL_N] = n
        v[:, COL_STYLE] = style
        v[:, COL_MEM] = rng.integers(0, len(self.memories), count)
        v[:, COL_ORDER] = rng.integers(0, 2, count)
        v[:, COL_DATAFLOW] = rng.integers(0, len(DATAFLOWS), count)
        v[:, COL_SPLITK] = rng.integers(0, 2, count)

        v[:, COL_PAIR25] = np.where(
            (style == S_25D) | (style == S_HYBRID),
            self._draw_pairs(rng, self.pkg25_pairs, count), -1)
        v[:, COL_PAIR3] = np.where(
            (style == S_3D) | (style == S_HYBRID),
            self._draw_pairs(rng, self.pkg3_pairs, count), -1)

        # chiplets: uniform (array, node, sram-option) per active slot
        a = rng.integers(0, len(self.arrays), (count, C))
        t = rng.integers(0, len(self.nodes), (count, C))
        s = (rng.random((count, C))
             * self.n_sram[a]).astype(np.int32)  # uniform over options
        active = np.arange(C)[None, :] < n[:, None]
        for i in range(C):
            ca, ct, cs = self.chip_cols(i)
            v[:, ca] = np.where(active[:, i], a[:, i], -1)
            v[:, ct] = np.where(active[:, i], t[:, i], -1)
            v[:, cs] = np.where(active[:, i], s[:, i], -1)

        # hybrid stacks: size uniform in [2, n-1], members uniform
        hyb = style == S_HYBRID
        size = np.where(n > 2, 2 + (rng.random(count)
                                    * np.maximum(n - 2, 1)).astype(np.int64),
                        2)
        scores = rng.random((count, C))
        scores[~active] = np.inf
        picked_order = np.argsort(scores, axis=1)
        ranks = np.empty_like(picked_order)
        np.put_along_axis(ranks, picked_order,
                          np.arange(C)[None, :].repeat(count, 0), axis=1)
        member = (ranks < size[:, None]).astype(np.int64)
        mask = (member << np.arange(C)[None, :]).sum(axis=1)
        v[:, COL_STACK] = np.where(hyb, mask, 0)

        if self.comm == "mesh_noc":
            if self.noc_live:
                # live axes: uniform (mesh_dims, entry) per active slot
                m = rng.integers(0, len(comm_mod.MESH_DIMS), (count, C))
                e = rng.integers(0, len(comm_mod.ENTRY_PLACEMENTS),
                                 (count, C))
            else:
                # frozen (env-forced) axes: neutral mesh, no RNG draws,
                # so the legacy sampling stream is untouched
                m = np.zeros((count, C), dtype=np.int64)
                e = np.zeros((count, C), dtype=np.int64)
            for i in range(C):
                cm, ce = self.noc_cols(i)
                v[:, cm] = np.where(active[:, i], m[:, i], -1)
                v[:, ce] = np.where(active[:, i], e[:, i], -1)

        if self.schedule == "window":
            sc = self.sched_col
            if self.sched_live:
                # live axes: uniform (start_hour, shape) per design
                v[:, sc] = rng.integers(0, sched_mod.HOURS_PER_DAY, count)
                v[:, sc + 1] = rng.integers(
                    0, sched_mod.n_schedule_shapes(), count)
            else:
                # frozen (env-forced) axes: neutral always-on schedule,
                # no RNG draws, so the legacy sampling stream is untouched
                v[:, sc] = 0
                v[:, sc + 1] = 0
        return v

    @staticmethod
    def _draw_pairs(rng, pkg_pairs, count: int) -> np.ndarray:
        pkg = rng.integers(0, len(pkg_pairs), count)
        out = np.empty(count, dtype=np.int64)
        for i, protos in enumerate(pkg_pairs):
            sel = pkg == i
            out[sel] = np.asarray(protos)[
                rng.integers(0, len(protos), int(sel.sum()))]
        return out

    def sample_systems(self, count: int,
                       key: Union[int, np.random.Generator] = 0
                       ) -> List[HISystem]:
        return self.decode_many(self.sample(count, key))

    def is_valid_scalar(self, sys: HISystem) -> bool:
        return is_valid(sys, self.db, self.max_chiplets)
