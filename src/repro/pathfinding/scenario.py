"""Unified scenario configuration: one value for *what to sweep*.

Scenario inputs used to travel as loose kwargs — a workload list here, a
``{name: carbon_intensity}`` mapping there, comm model on the sweep,
budget/checkpoint knobs on ``run(...)`` — and the serving layer carried
a third spelling (scalar ``carbon_intensity`` + ``electricity_price`` +
``emb_factor`` + ``grid_profile`` fields on ``JobSpec``).
:class:`ScenarioSpec` is the single frozen, hashable description all of
them accept:

* :meth:`repro.pathfinding.pareto.ScenarioSweep.run` takes a spec in
  place of its loose ``workloads`` argument,
* :meth:`repro.pathfinding.pathfinder.Pathfinder.run_scenarios` takes a
  spec in place of a sweep,
* :class:`repro.serving.jobs.JobSpec` collapses its loose regional
  fields into one :class:`~repro.core.regions.Region` (``region=``).

The old spellings keep working bit-identically (deprecation shims warn
once per call site); only the *packaging* of the inputs changed, never
the math, the RNG streams, or the checkpoint fingerprints.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

from repro.core.comm import COMM_MODELS
from repro.core.regions import Region, RegionLike, as_region
from repro.core.schedule import SCHEDULE_MODELS
from repro.core.workload import GEMMWorkload


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """What to sweep: workloads x deployment regions, plus the design
    axes (comm / schedule models) and the run knobs (budget, segment
    size, checkpointing) that used to arrive as loose kwargs.

    ``regions`` accepts a ``{name: Region-or-float}`` mapping (floats
    are historical scalar-CI regions) and normalizes it to a sorted-free,
    insertion-ordered tuple of ``(name, Region)`` pairs so the spec is
    hashable — usable directly as a cache key. ``comm`` / ``schedule``
    of ``None`` defer to the environment-resolved defaults
    (``REPRO_COMM_MODEL`` / ``REPRO_SCHEDULE``), exactly like the loose
    kwargs did."""

    workloads: Tuple[GEMMWorkload, ...]
    regions: Tuple[Tuple[str, Region], ...]
    comm: Optional[str] = None
    schedule: Optional[str] = None
    budget: Optional[int] = None
    segment: Optional[int] = None
    checkpoint_dir: Optional[str] = None
    resume: bool = True

    def __post_init__(self) -> None:
        wls = self.workloads
        if isinstance(wls, GEMMWorkload):
            wls = (wls,)
        wls = tuple(wls)
        if not wls or not all(isinstance(w, GEMMWorkload) for w in wls):
            raise ValueError(
                "ScenarioSpec.workloads needs >= 1 GEMMWorkload")
        object.__setattr__(self, "workloads", wls)
        regs = self.regions
        items = regs.items() if isinstance(regs, dict) else regs
        norm = tuple((str(name), as_region(spec)) for name, spec in items)
        if not norm:
            raise ValueError("ScenarioSpec.regions needs >= 1 region")
        object.__setattr__(self, "regions", norm)
        if self.comm is not None and self.comm not in COMM_MODELS:
            raise ValueError(
                f"unknown comm model {self.comm!r}; "
                f"options: {sorted(COMM_MODELS)}")
        if self.schedule is not None \
                and self.schedule not in SCHEDULE_MODELS:
            raise ValueError(
                f"unknown schedule model {self.schedule!r}; "
                f"options: {sorted(SCHEDULE_MODELS)}")

    def region_map(self) -> Dict[str, Region]:
        """The ``{name: Region}`` view (insertion order preserved)."""
        return dict(self.regions)


#: what sweep entry points accept where a region mapping is expected
RegionsLike = Union[Dict[str, RegionLike],
                    Tuple[Tuple[str, Region], ...]]
