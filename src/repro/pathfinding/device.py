"""Device-resident pathfinding: jitted fused evaluate+cost, vectorized
moves, and a ``lax.scan`` parallel-tempering engine.

PR 1's :func:`repro.pathfinding.batch.evaluate_batch` vectorized the
metric *arithmetic* but kept the search loop host-bound: a per-row Python
topology pass (``_topo_one``), an un-jitted ``jax.numpy`` stage 3, Python
``propose()`` per chain and a host<->device round-trip every sweep. This
module moves the whole explore -> evaluate -> accept loop onto the device:

* :class:`DeviceEvaluator` — a single ``jax.jit``-compiled
  ``evaluate_cost`` that fuses stages 1-3 of the batched evaluator *and*
  the Eq. 17 ``sa_cost`` into one XLA program. The per-row Python
  floorplan/BFS pass is replaced by an exact vectorized rendering (the
  slicing-floorplan recursion unrolled level-by-level over fixed
  ``max_chiplets`` slots, BFS with queue-order tie-breaking as a masked
  fixed-point, link tables in a fixed ``(C*(C-1)/2 + C-1)``-slot layout),
  so stage 2 becomes gathers + elementwise arithmetic with no data-
  dependent Python. Populations are padded to power-of-two buckets
  (>= 64) and the encoded buffer is donated, so repeated sweeps of any
  size hit the jit compile cache and never re-trace.
* :func:`propose_batch` / :meth:`DeviceEvaluator.propose` — the
  hierarchical move distribution of :func:`repro.core.sa.propose`
  (application / chip-architecture / chiplet / package levels, style
  repair, hierarchical package-then-protocol draws) applied to encoded
  ``int32`` rows with ``jax.random``; candidates that fail the vectorized
  validity rules keep the incumbent row (the batched rendering of the
  scalar retry loop).
* :meth:`DeviceEvaluator.parallel_tempering` — the full ParallelTempering
  sweep (propose, evaluate, Metropolis accept, sequential adjacent-pair
  replica exchange) fused into ``jax.lax.scan`` chunks advanced by a
  host loop (``segment=`` sweeps per chunk; default one chunk). The
  chunking is bit-invisible — same key stream, same sweep indices — and
  its boundaries are where long searches snapshot carry + frontier
  archive for checkpoint/resume (:mod:`repro.pathfinding.resume`).
  ``record_trace=True`` additionally returns every proposal and uniform
  draw so a host reference can replay the exact trajectory (the
  trajectory-equivalence tests).

Numerics: everything runs in float64 (``jax.experimental.enable_x64``
scoped to this module's entry points) and replicates the host evaluator's
operation order wherever floating-point ties matter (greedy floorplan
accumulation order, Algorithm 1's sorted-order power summation), so the
jitted path stays within the 1e-6 relative parity contract of the scalar
:func:`repro.core.evaluate.evaluate` — in practice ~1e-15.

* :class:`ScenarioEngine` — the stacked twin for deployment grids: the
  grid carbon intensity, per-cell normalizer/weight rows and the
  per-workload tile totals are *runtime* data of the same fused program
  (tile prefix tables ride in a bucket-padded per-workload stack), so a
  whole region x workload :class:`~repro.pathfinding.pareto
  .ScenarioSweep` runs in one ``lax.scan`` with one XLA compile,
  ``fold_in``-derived per-cell keys, and optional scenario-axis sharding
  over local devices.

The hottest stage-3 inner loop (prefix-table gather + per-chiplet-slot
segment reduction) can optionally run through the Pallas kernel in
:mod:`repro.kernels.prefix_gather` (``use_pallas=True`` or
``REPRO_PATHFINDER_PALLAS=1``; default auto = TPU backends only — on CPU
the kernel executes in interpreter mode, which is exact but slow).

The scalar fallback (``Pathfinder(device=False)`` or any non-CarbonPATH
objective backend, e.g. ChipletGym) preserves the PR-1 host path.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import comm as comm_mod
from repro.core import schedule as sched_mod
from repro.core.carbon import SECONDS_PER_YEAR
from repro.core.scalesim import OPERAND_BYTES
from repro.core.techdb import DEFAULT_DB, HOURS_PER_DAY, TechDB
from repro.core.templates import Normalizer, Template
from repro.core.workload import DEFAULT_TILE, GEMMWorkload
from repro.pathfinding.batch import (
    MetricsBatch,
    _SIM_METRICS,
    get_evaluator,
)
from repro.pathfinding.space import (
    COL_CHIP,
    COL_DATAFLOW,
    COL_MEM,
    COL_N,
    COL_ORDER,
    COL_PAIR25,
    COL_PAIR3,
    COL_SPLITK,
    COL_STACK,
    COL_STYLE,
    DEFAULT_MAX_CHIPLETS,
    DesignSpace,
    S_25D,
    S_2D,
    S_3D,
    S_HYBRID,
)

P_APPLICATION = 0.35  # sa.propose's application-level move probability


@dataclasses.dataclass(frozen=True)
class _Cfg:
    """Static (trace-time) constants baked into the jitted programs."""

    C: int            # max chiplet slots
    W: int            # encoded row width
    A: int            # array-size options
    T_nodes: int      # tech-node options
    S: int            # max SRAM options
    M: int            # memory options
    n_pairs25: int
    n_pairs3: int
    n_pkg25: int
    n_pkg3: int
    L: int            # fixed link slots: C*(C-1)/2 plane + C-1 chain
    T0: int           # tiles without split-K
    T1: int           # tiles with split-K
    wr_bits: float    # wl.M * wl.N * OPERAND_BYTES * 8
    acost: float
    substrate_cost_mm2: float
    substrate_cfp_mm2: float
    interposer_cpa: float
    interposer_defect: float
    interposer_wafer_cost: float
    yield_alpha: float
    wafer_diameter_mm: float
    lifetime_years: float
    use_fraction: float
    duty_runs_per_s: float
    router_area_frac: float           # NoC share of die mfg carbon -> C_HI
    comm: str                         # communication model (repro.core.comm)
    noc_col: int                      # first NoC column (mesh_noc layouts)
    n_mesh: int                       # len(comm.MESH_DIMS)
    n_entry: int                      # len(comm.ENTRY_PLACEMENTS)
    noc_hop_latency_s: float
    noc_energy_pj_bit: float
    # shared per-hop package latency when every protocol agrees (the
    # bit-pinned hops * h form); None switches the hop term to the
    # per-link-kind split using the p25_hl/p3_hl tables
    hop_uniform: Optional[float]
    noc_live: bool                    # NoC axes searchable (not frozen)
    # temporal scheduling seam (repro.core.schedule): the 24h duty
    # weighting rides in the trace-constant tb["sched_tab"] lookup —
    # fixed spaces gather its row 0 (= db.load_profile verbatim),
    # window spaces gather per-design (start, shape) columns
    schedule: str                     # schedule model (fixed | window)
    sched_col: int                    # first schedule column (window)
    n_sched: int                      # schedule-shape table rows
    sched_live: bool                  # schedule axes searchable
    use_pallas: bool


def _popcount(x, bits: int):
    import jax.numpy as jnp

    out = jnp.zeros_like(x)
    for i in range(bits):
        out = out + ((x >> i) & 1)
    return out


# ---------------------------------------------------------------------------
# Stage 1: Algorithm 1 tile assignment (exact jnp port of batch._assign)
# ---------------------------------------------------------------------------


def _assign_jax(powers, nmask, order, total, cfg: _Cfg):
    import jax.numpy as jnp

    C = cfg.C
    key = jnp.where((order == 0)[:, None], -powers, powers)
    key = jnp.where(nmask, key, jnp.inf)  # padding sorts last either way
    pos = jnp.argsort(key, axis=1)  # stable
    p_sorted = jnp.take_along_axis(powers, pos, axis=1)
    # sequential fold in sorted order: equal-power cores make the
    # fractional parts ulp-level ties, so summation order is part of the
    # parity contract with the scalar/np assigner
    psum = jnp.zeros(powers.shape[0])
    for c in range(C):
        psum = psum + p_sorted[:, c]
    psum = jnp.where(psum > 0, psum, 1.0)
    ideal = p_sorted / psum[:, None] * total.astype(jnp.float64)[:, None]
    counts = jnp.floor(ideal)
    csum = jnp.zeros_like(psum)
    for c in range(C):
        csum = csum + counts[:, c]
    remaining = (total.astype(jnp.int64) - csum.astype(jnp.int64))
    frac = ideal - counts
    frac_pos = jnp.argsort(-frac, axis=1)  # stable
    rank = jnp.argsort(frac_pos, axis=1)   # exact inverse permutation
    counts_i = counts.astype(jnp.int64) + (rank < remaining[:, None])
    starts = jnp.concatenate(
        [jnp.zeros_like(counts_i[:, :1]),
         jnp.cumsum(counts_i[:, :-1], axis=1)], axis=1)
    inv = jnp.argsort(pos, axis=1)
    start = jnp.take_along_axis(starts, inv, axis=1)
    count = jnp.take_along_axis(counts_i, inv, axis=1)
    return start, count


# ---------------------------------------------------------------------------
# Stage 2: vectorized topology (exact rendering of batch._topo_one /
# batch._topology, incl. the slicing floorplan and sorted-BFS routes)
# ---------------------------------------------------------------------------


def _topology_jax(v, areas, tb, cfg: _Cfg):
    import jax.numpy as jnp
    from jax import lax

    C, L = cfg.C, cfg.L
    P = v.shape[0]
    rows = jnp.arange(P)
    slot = jnp.arange(C, dtype=jnp.int32)

    n = v[:, COL_N].astype(jnp.int32)
    style = v[:, COL_STYLE]
    is2d = style == S_2D
    is25 = style == S_25D
    is3d = style == S_3D
    ishyb = style == S_HYBRID
    active = slot[None, :] < n[:, None]

    memtot = tb["m_bw"][jnp.clip(v[:, COL_MEM], 0, cfg.M - 1)]
    p25i = jnp.clip(v[:, COL_PAIR25], 0, cfg.n_pairs25 - 1)
    p3i = jnp.clip(v[:, COL_PAIR3], 0, cfg.n_pairs3 - 1)
    p25row = tb["p25"][p25i]  # one gather for all 7 package fields
    pitch25, y25, cfp25, scale25, rate25, eta25, ebit25 = [
        p25row[:, i] for i in range(7)]
    interp25 = tb["p25_interp"][p25i]
    p3row = tb["p3"][p3i]
    pitch3, y3, cfp3, scale3, rate3, eta3, ebit3 = [
        p3row[:, i] for i in range(7)]

    # -- 3D chain: members sorted by non-increasing area, ties by index ----
    member = ((v[:, COL_STACK][:, None] >> slot[None, :]) & 1) == 1
    member = jnp.where(ishyb[:, None], member & active,
                       jnp.where(is3d[:, None], active, False))
    chain_len = member.sum(axis=1).astype(jnp.int32)
    chain_slots = jnp.argsort(
        jnp.where(member, -areas, jnp.inf), axis=1).astype(jnp.int32)
    a_chain = jnp.take_along_axis(areas, chain_slots, axis=1)
    base_slot = chain_slots[:, 0]
    tier = jnp.arange(C)
    tmask = (tier[None, :] >= 1) & (tier[None, :] < chain_len[:, None])
    # Eq. 7 per bond: bumps over the (smaller) upper die's face
    face = jnp.minimum(a_chain[:, :-1], a_chain[:, 1:])
    nb3 = jnp.maximum(1.0, jnp.trunc(face * 1e6 / (pitch3 * pitch3)[:, None]))
    cbw = rate3[:, None] * 1e9 * nb3 * eta3[:, None]
    bond_exists = ((jnp.arange(C - 1)[None, :] + 1 < chain_len[:, None])
                   & (is3d | ishyb)[:, None])

    # -- planar set in floorplan input order: non-members asc + base -------
    planar_mask = active & ~member
    porder = jnp.argsort(
        jnp.where(planar_mask, slot[None, :], C + 1), axis=1
    ).astype(jnp.int32)
    n_nonmem = planar_mask.sum(axis=1).astype(jnp.int32)
    porder = jnp.where(ishyb[:, None] & (slot[None, :] == n_nonmem[:, None]),
                       base_slot[:, None], porder)
    m_planar = n_nonmem + ishyb.astype(jnp.int32)
    pvalid = slot[None, :] < m_planar[:, None]
    ar_p = jnp.where(pvalid, jnp.take_along_axis(areas, porder, axis=1), 0.0)

    # planar-order sequential sums (parity with Python sum())
    tot = jnp.zeros(P)
    for j in range(C):
        tot = tot + ar_p[:, j]
    side = jnp.sqrt(tot * (1.0 + 0.10))

    # -- slicing floorplan, recursion unrolled level by level --------------
    # the greedy iteration order (area desc, ties by input position) is
    # invariant across levels: children receive items already sorted.
    # groups are tiny (<= C members), so all per-group accumulation is
    # expressed as pairwise same-group comparisons — pure fusable
    # elementwise chains, no scatters (the dominant cost on CPU)
    sorder = jnp.argsort(jnp.where(pvalid, -ar_p, jnp.inf),
                         axis=1).astype(jnp.int32)
    inv_sorder = jnp.argsort(sorder, axis=1)
    a_s = jnp.take_along_axis(ar_p, sorder, axis=1)       # sorted areas
    v_s = jnp.take_along_axis(pvalid, sorder, axis=1)
    contrib = [jnp.where(v_s[:, t], a_s[:, t], 0.0) for t in range(C)]
    g = jnp.zeros((P, C), dtype=jnp.int32)
    bx = jnp.zeros((P, C))
    by = jnp.zeros((P, C))
    bwid = jnp.broadcast_to(side[:, None], (P, C))
    bhei = jnp.broadcast_to(side[:, None], (P, C))
    for level in range(max(C - 1, 1)):
        g_s = jnp.take_along_axis(g, sorder, axis=1)
        # greedy pass in sorted order: left iff al <= ar of the item's
        # group so far (prefix sums in the exact scalar iteration order)
        left_s = []
        for t in range(C):
            al_t = jnp.zeros(P)
            ar_t = jnp.zeros(P)
            for t2 in range(t):
                same = g_s[:, t2] == g_s[:, t]
                al_t = al_t + jnp.where(same & left_s[t2], contrib[t2], 0.0)
                ar_t = ar_t + jnp.where(same & ~left_s[t2], contrib[t2],
                                        0.0)
            left_s.append(al_t <= ar_t)
        # final per-group totals / counts, accumulated per original
        # position in the same sorted order as the scalar greedy
        # (skipped other-group items add 0.0, which is exact)
        frac_cols, split_cols = [], []
        for j in range(C):
            gj = g[:, j]
            al_j = jnp.zeros(P)
            ar_j = jnp.zeros(P)
            cnt_j = jnp.zeros(P, dtype=jnp.int32)
            for t2 in range(C):
                same = g_s[:, t2] == gj
                al_j = al_j + jnp.where(same & left_s[t2], contrib[t2], 0.0)
                ar_j = ar_j + jnp.where(same & ~left_s[t2], contrib[t2],
                                        0.0)
                cnt_j = cnt_j + (same & v_s[:, t2]).astype(jnp.int32)
            den = al_j + ar_j
            frac_cols.append(al_j / jnp.where(den > 0, den, 1.0))
            split_cols.append(cnt_j >= 2)
        frac_j = jnp.stack(frac_cols, axis=1)
        split_j = jnp.stack(split_cols, axis=1) & pvalid
        goleft = jnp.take_along_axis(jnp.stack(left_s, axis=1),
                                     inv_sorder, axis=1)
        if level % 2 == 0:  # vertical cut, alternating by depth
            wl_ = bwid * frac_j
            bx = jnp.where(split_j & ~goleft, bx + wl_, bx)
            bwid = jnp.where(split_j,
                             jnp.where(goleft, wl_, bwid - wl_), bwid)
        else:
            hl_ = bhei * frac_j
            by = jnp.where(split_j & ~goleft, by + hl_, by)
            bhei = jnp.where(split_j,
                             jnp.where(goleft, hl_, bhei - hl_), bhei)
        g = jnp.where(split_j, g * 2 + (~goleft).astype(jnp.int32), g * 2)
    width = jnp.max(jnp.where(pvalid, bx + bwid, -jnp.inf), axis=1)
    height = jnp.max(jnp.where(pvalid, by + bhei, -jnp.inf), axis=1)
    bbox = width * height

    # -- links in a fixed slot layout: plane pairs then chain bonds --------
    # per-link values are computed as fusable elementwise [P] chains and
    # scattered into the slot-space adjacency/link tables in one batched
    # op each (valid links never collide: plane links have at most one
    # stacked endpoint — the base — while chain bonds have two)
    pairs = [(j1, j2) for j1 in range(C) for j2 in range(j1 + 1, C)]
    plane_row = is25 | ishyb
    tol = 1e-9
    j1v = jnp.asarray([j1 for j1, _ in pairs], dtype=jnp.int32)
    j2v = jnp.asarray([j2 for _, j2 in pairs], dtype=jnp.int32)
    x1, y1, w1, h1 = bx[:, j1v], by[:, j1v], bwid[:, j1v], bhei[:, j1v]
    x2, y2, w2, h2 = bx[:, j2v], by[:, j2v], bwid[:, j2v], bhei[:, j2v]
    cond_v = (jnp.abs(x1 + w1 - x2) < tol) | (jnp.abs(x2 + w2 - x1) < tol)
    lo_v = jnp.where(y1 > y2, y1, y2)
    hi_v = jnp.minimum(y1 + h1, y2 + h2)
    edge_v = jnp.where(hi_v > lo_v, hi_v - lo_v, 0.0)
    cond_h = (jnp.abs(y1 + h1 - y2) < tol) | (jnp.abs(y2 + h2 - y1) < tol)
    lo_h = jnp.where(x1 > x2, x1, x2)
    hi_h = jnp.minimum(x1 + w1, x2 + w2)
    edge_h = jnp.where(hi_h > lo_h, hi_h - lo_h, 0.0)
    edge = jnp.where(cond_v, edge_v, jnp.where(cond_h, edge_h, 0.0))
    r25 = (rate25 * 1e9)[:, None]
    e25 = eta25[:, None]
    pit25 = pitch25[:, None]
    bwk = r25 * jnp.maximum(1.0, jnp.trunc(edge * 1e3 / pit25)) * e25
    for aa in (ar_p[:, j1v], ar_p[:, j2v]):  # Eq. 6 endpoint perimeter cap
        perim = 4.0 * jnp.sqrt(aa)
        bwk = jnp.minimum(
            bwk, r25 * jnp.maximum(1.0, jnp.trunc(perim * 1e3 / pit25))
            * e25)
    s1a = jnp.concatenate([porder[:, j1v], chain_slots[:, :C - 1]], axis=1)
    s2a = jnp.concatenate([porder[:, j2v], chain_slots[:, 1:]], axis=1)
    exa = jnp.concatenate(
        [plane_row[:, None] & (j2v[None, :] < m_planar[:, None])
         & (edge > 1e-9), bond_exists], axis=1)
    link_bw = jnp.where(exa, jnp.concatenate([bwk, cbw], axis=1), jnp.inf)
    link_e = jnp.where(
        exa, jnp.concatenate(
            [jnp.broadcast_to(ebit25[:, None], bwk.shape),
             jnp.broadcast_to(ebit3[:, None], cbw.shape)], axis=1), 0.0)
    # one-hot reduction instead of scatters (cheaper than scatter thunks
    # on CPU; valid links never collide, so the sum packs exact link ids)
    pm_half = ((s1a[:, :, None] == slot[None, None, :])[:, :, :, None]
               & (s2a[:, :, None] == slot[None, None, :])[:, :, None, :]
               & exa[:, :, None, None])                 # [P, L, C, C]
    kplus1 = jnp.arange(1, L + 1, dtype=jnp.int32)[None, :, None, None]
    lid_half = jnp.sum(pm_half * kplus1, axis=1)
    lid = lid_half + jnp.swapaxes(lid_half, 1, 2) - 1
    adj = lid >= 0

    # -- DRAM attach: planar shares, base-die-mediated chain (Eqs. 8-10) ---
    # both scatters target permutations (porder / chain_slots), so a
    # single batched .add per table is collision-free
    share = memtot[:, None] * ar_p / jnp.where(tot > 0, tot, 1.0)[:, None]
    base_share = jnp.take_along_axis(share, n_nonmem[:, None], axis=1)[:, 0]
    base_bw0 = jnp.where(ishyb, base_share, memtot)
    cmin = lax.cummin(jnp.where(bond_exists, cbw, jnp.inf), axis=1)
    eff_chain = jnp.minimum(base_bw0[:, None], cmin)
    plane_val = jnp.where(pvalid & plane_row[:, None], share, 0.0)
    chain_val = jnp.concatenate(
        [jnp.where((chain_len > 0) & is3d, memtot, 0.0)[:, None],
         jnp.where(tmask[:, 1:] & (is3d | ishyb)[:, None],
                   eff_chain, 0.0)], axis=1)
    rl1 = rows[:, None]
    eff_bw = (jnp.zeros((P, C)).at[rl1, porder].add(plane_val)
              .at[rl1, chain_slots].add(chain_val))
    dram_val = jnp.where(tmask & (is3d | ishyb)[:, None],
                         jnp.arange(C)[None, :] * ebit3[:, None], 0.0)
    dram_e = jnp.zeros((P, C)).at[rl1, chain_slots].add(dram_val)
    eff_bw = eff_bw.at[:, 0].set(jnp.where(is2d, memtot, eff_bw[:, 0]))

    # -- reduction routes: BFS per source, queue-order tie-breaking --------
    dest = jnp.argmax(jnp.where(active, areas, -1.0), axis=1
                      ).astype(jnp.int32)
    INF_I = jnp.int32(10 ** 6)
    eye = jnp.eye(C, dtype=bool)[None]
    ordv = jnp.where(eye, 0, jnp.full((P, C, C), INF_I, dtype=jnp.int32))
    prev = jnp.where(eye, slot[None, :, None],
                     jnp.full((P, C, C), -1, dtype=jnp.int32))
    counter = jnp.ones((P, C), dtype=jnp.int32)
    # step k processes the (unique) node with discovery rank k — exactly
    # the scalar queue pop order. C-1 steps suffice: a node with rank k
    # is found while processing rank k-1 <= C-2, so the last rank
    # discovers nothing
    for k in range(max(C - 1, 1)):
        at_k = ordv == k
        u = jnp.argmax(at_k, axis=2).astype(jnp.int32)
        valid_u = jnp.any(at_k, axis=2)
        adj_u = adj[rows[:, None], u]  # [P, src, node]
        # expand u's neighbours in ascending slot order: discovery rank
        # within this expansion is the exclusive prefix count of newly
        # discovered nodes (identical to the scalar queue-append order)
        newly = valid_u[..., None] & adj_u & (ordv == INF_I)
        ni = newly.astype(jnp.int32)
        offs = jnp.cumsum(ni, axis=2) - ni
        prev = jnp.where(newly, u[..., None], prev)
        ordv = jnp.where(newly, counter[..., None] + offs, ordv)
        counter = counter + jnp.sum(ni, axis=2)

    srcs = jnp.broadcast_to(slot[None, :], (P, C))
    route_on = (~is2d)[:, None] & active & (srcs != dest[:, None])
    node = jnp.broadcast_to(dest[:, None], (P, C)).astype(jnp.int32)
    hops = jnp.zeros((P, C), dtype=jnp.int64)
    hops3 = jnp.zeros((P, C), dtype=jnp.int64)
    n_plane = C * (C - 1) // 2  # link ids >= n_plane are 3D chain bonds
    inc_s = jnp.zeros((P, C, L))
    for _ in range(C - 1):
        pu = jnp.take_along_axis(prev, node[..., None], axis=2)[..., 0]
        go = route_on & (node != srcs) & (pu >= 0)
        lk = lid[rows[:, None], jnp.where(go, pu, 0), node]
        inc_s = inc_s + ((jnp.arange(L)[None, None, :] == lk[..., None])
                         & go[..., None]).astype(jnp.float64)
        hops = hops + go
        if cfg.hop_uniform is None:
            hops3 = hops3 + (go & (lk >= n_plane))
        node = jnp.where(go, pu, node)
    inc = jnp.swapaxes(inc_s, 1, 2)  # [P, link, src]

    # -- bonding yield / assembly / carbon rates (Eqs. 15-16, 2) -----------
    n_f = n.astype(jnp.float64)
    m_f = m_planar.astype(jnp.float64)
    cl_f = chain_len.astype(jnp.float64)
    bond_y = jnp.where(
        is2d, 1.0,
        jnp.where(is25, y25 ** n_f,
                  jnp.where(is3d, y3 ** (n_f - 1.0),
                            (y25 ** m_f) * (y3 ** (cl_f - 1.0)))))
    assembly = jnp.where(
        is2d, cfg.acost,
        jnp.where(is25, n_f * cfg.acost * scale25,
                  jnp.where(is3d, n_f * cfg.acost * scale3,
                            m_f * cfg.acost * scale25
                            + cl_f * cfg.acost * scale3)))
    p3_bonded = jnp.where(is3d | ishyb,
                          cfp3 * jnp.sum(jnp.where(tmask, a_chain, 0.0),
                                         axis=1), 0.0)
    pkg_area = jnp.where(is2d, areas[:, 0],
                         jnp.where(is3d, a_chain[:, 0], bbox))
    return dict(
        eff_bw=eff_bw, dram_e=dram_e, hops=hops, hops3=hops3,
        link_bw=link_bw,
        link_e=link_e, inc=inc, pkg_area=pkg_area, bond_y=bond_y,
        assembly=assembly, interp=(is25 | ishyb) & interp25,
        p25_rate=jnp.where(is25 | ishyb, cfp25, 0.0),
        p3_bonded=p3_bonded, is2d=is2d)


# ---------------------------------------------------------------------------
# Stage 3 + cost: the fused jitted evaluator
# ---------------------------------------------------------------------------


def _gather_sims(v, a_idx, s_idx, di, start, end, tb, cfg: _Cfg, rt=None):
    """Prefix-table gathers for both split-K tables + per-row select.

    With ``cfg.use_pallas`` the whole stage — both split-K gathers for
    all five sim metrics, the per-row clip to the true tile totals, the
    split select and the per-slot segment reduction — is one fused
    Pallas launch (:func:`repro.kernels.prefix_gather.
    prefix_select_gather`); otherwise plain jnp gathers (the bit-pinned
    reference path). ``rt`` (the stacked scenario engine's per-cell
    runtime constants) switches the kernel to the workload-stacked
    ``[(Wk*A*S*3), T_bucket+1]`` tables: the row index picks up the
    per-workload offset ``wi*A*S*3`` and the clip bounds come from the
    traced per-cell tile totals instead of ``cfg``.
    """
    import jax.numpy as jnp

    split1 = (v[:, COL_SPLITK] == 1)[:, None]
    sims = {}
    if cfg.use_pallas:
        from repro.kernels.prefix_gather import prefix_select_gather

        P = v.shape[0]
        ridx = ((a_idx * cfg.S + s_idx) * 3 + di).astype(jnp.int32)
        if rt is None:
            p0f, p1f = tb["pref0_flat"], tb["pref1_flat"]
            t0v = jnp.full((P,), cfg.T0, dtype=jnp.int32)
            t1v = jnp.full((P,), cfg.T1, dtype=jnp.int32)
        else:
            p0f, p1f = tb["pref0_flatw"], tb["pref1_flatw"]
            ridx = ridx + jnp.int32(cfg.A * cfg.S * 3) * \
                rt["wi"].astype(jnp.int32)
            t0v = jnp.broadcast_to(rt["T0"].astype(jnp.int32), (P,))
            t1v = jnp.broadcast_to(rt["T1"].astype(jnp.int32), (P,))
        sel, _ = prefix_select_gather(p0f, p1f, ridx, start, end,
                                      v[:, COL_SPLITK], t0v, t1v)
        for fi, f in enumerate(_SIM_METRICS):
            sims[f] = sel[..., fi]
    else:
        s0 = jnp.clip(start, 0, cfg.T0)
        e0 = jnp.clip(end, 0, cfg.T0)
        s1 = jnp.clip(start, 0, cfg.T1)
        e1 = jnp.clip(end, 0, cfg.T1)
        # tables carry the 5 sim metrics in the trailing axis, so each
        # (split, bound) pair is a single gather of [P, C, 5]
        t0, t1 = tb["pref0"], tb["pref1"]
        g0 = t0[a_idx, s_idx, di, e0] - t0[a_idx, s_idx, di, s0]
        g1 = t1[a_idx, s_idx, di, e1] - t1[a_idx, s_idx, di, s1]
        sel = jnp.where(split1[..., None], g1, g0)
        for fi, f in enumerate(_SIM_METRICS):
            sims[f] = sel[..., fi]
    mn0 = tb["mn0"][jnp.clip(end, 0, cfg.T0)] - tb["mn0"][
        jnp.clip(start, 0, cfg.T0)]
    mn1 = tb["mn1"][jnp.clip(end, 0, cfg.T1)] - tb["mn1"][
        jnp.clip(start, 0, cfg.T1)]
    mn_bits = jnp.where(split1, mn1, mn0)
    return sims, mn_bits


def _metrics_jax(v, tb, cfg: _Cfg, ci, price, embf, profile, pprofile,
                 rt=None):
    """The 13 MetricsBatch arrays for an encoded population, fully jitted.

    Mirrors ``BatchEvaluator.__call__`` stage by stage (same operation
    order where floating-point ties matter).

    ``ci`` is the grid carbon intensity as a *runtime* scalar (or
    per-row vector): region sweeps ride through the compiled program as
    data instead of forcing a retrace per region. ``price`` ($/kWh),
    ``embf`` (regional embodied multiplier), ``profile`` (24h grid
    intensity row) and ``pprofile`` (24h electricity-price row) are the
    remaining regional axes, runtime data too; their neutral values
    (0.0, 1.0, flat-at-ci, flat-at-price) reproduce the scalar model
    bit-for-bit — operational CFP uses
    ``ci + sum((profile - ci) * load)`` and the lifetime bill
    ``price + sum((pprofile - price) * load)``, whose correction terms
    are exactly +0.0 for flat rows. The ``load`` weights come from the
    trace-constant ``tb["sched_tab"]``: fixed-schedule programs read
    row 0 (= ``db.load_profile`` verbatim), window programs gather the
    per-design encoded (start_hour, shape_idx) columns — schedules are
    data, not shapes. ``rt`` optionally
    overrides the per-workload compile-time constants (``T0``/``T1``
    tile totals, ``wr_bits``) with traced values — the stacked scenario
    engine's workload axis; ``cfg.T0``/``cfg.T1`` then only bound the
    (padded) prefix-table gathers."""
    import jax.numpy as jnp

    C = cfg.C
    P = v.shape[0]
    slot = jnp.arange(C, dtype=jnp.int32)
    n = v[:, COL_N]
    nmask = slot[None, :] < n[:, None]
    chip = v[:, COL_CHIP:COL_CHIP + 3 * C].reshape(P, C, 3)
    a_idx = jnp.where(nmask, chip[:, :, 0], 0)
    t_idx = jnp.where(nmask, chip[:, :, 1], 0)
    s_idx = jnp.where(nmask, chip[:, :, 2], 0)

    cphys = tb["chiplet"][a_idx, t_idx, s_idx]  # [P, C, 4] physicals
    areas = jnp.where(nmask, cphys[:, :, 0], 0.0)
    dest = jnp.argmax(jnp.where(nmask, areas, -1.0), axis=1)

    powers = jnp.where(nmask, tb["t_power"][a_idx, t_idx], 0.0)
    split = v[:, COL_SPLITK]
    t0 = cfg.T0 if rt is None else rt["T0"]
    t1 = cfg.T1 if rt is None else rt["T1"]
    total = jnp.where(split == 1, t1, t0)
    start, count = _assign_jax(powers, nmask, v[:, COL_ORDER], total, cfg)
    end = start + count
    di = jnp.broadcast_to(v[:, COL_DATAFLOW][:, None], (P, C))
    sims, mn_bits = _gather_sims(v, a_idx, s_idx, di, start, end, tb, cfg,
                                 rt)

    topo = _topology_jax(v, areas, tb, cfg)

    f8 = lambda x: jnp.asarray(x, dtype=jnp.float64)  # noqa: E731
    mask = nmask
    cyc, rd, wr = f8(sims["cycles"]), f8(sims["rd"]), f8(sims["wr"])
    sram_b, macs = f8(sims["sram"]), f8(sims["macs"])
    nphys = tb["node"][t_idx]  # [P, C, 4] node-scaled rates
    freq = jnp.where(mask, nphys[:, :, 0], 1.0)
    eff_bw = topo["eff_bw"]
    den_bw = jnp.where(eff_bw > 0, eff_bw, 1.0)

    # Eq. 5 term 1: max_i (L_compute,i + L_DRAM_RD,i)
    l_comp = cyc / (freq * 1e9)
    l_rd = jnp.where(rd > 0, rd / den_bw, 0.0)
    l_cr = jnp.max(l_comp + l_rd, axis=1)

    # Eq. 5 term 2: reduction-phase D2D over shared links (Fig. 4)
    sbits = jnp.where(slot[None, :] == dest[:, None], 0.0, f8(mn_bits))
    loads = jnp.einsum("plc,pc->pl", topo["inc"], sbits)
    l_link = jnp.max(loads / topo["link_bw"], axis=1)
    # per-source path latency: package hops x per-hop latency. With a
    # uniform hop latency the product commutes with the masked max
    # bit-exactly (h > 0 is monotone and the winning element is the
    # same), so the legacy hops * HOP_LATENCY_S program is reproduced
    # verbatim; heterogeneous protocol latencies split the hop count by
    # link kind (2.5D plane vs 3D bond) instead.
    mesh_on = cfg.comm == "mesh_noc"
    if mesh_on:
        nocv = v[:, cfg.noc_col:cfg.noc_col + 2 * C].reshape(P, C, 2)
        mi = jnp.where(nmask, nocv[:, :, 0], 0)
        ei = jnp.where(nmask, nocv[:, :, 1], 0)
        noc_h = jnp.where(nmask, tb["noc_hops"][mi, ei], 0.0)
        noc_r = jnp.where(nmask, tb["noc_routers"][mi], 1.0)
    if cfg.hop_uniform is not None:
        path_lat = f8(topo["hops"]) * cfg.hop_uniform
    else:
        h25 = tb["p25_hl"][jnp.maximum(v[:, COL_PAIR25], 0)]
        h3 = tb["p3_hl"][jnp.maximum(v[:, COL_PAIR3], 0)]
        path_lat = (f8(topo["hops"] - topo["hops3"]) * h25[:, None]
                    + f8(topo["hops3"]) * h3[:, None])
    if mesh_on:
        # on-chiplet mesh traversal: source egress + destination ingress
        # mean hop counts (closed-form Manhattan distances to the NoI
        # entry router), per NoC hop latency
        noc_dest = jnp.take_along_axis(noc_h, dest[:, None], axis=1)
        pair_noc = noc_h + noc_dest
        path_lat = path_lat + pair_noc * cfg.noc_hop_latency_s
    hop_term = jnp.max(jnp.where(sbits > 0, path_lat, 0.0), axis=1)
    l_d2d = l_link + hop_term

    # Eq. 5 term 3: DRAM write-back (split-K dependent)
    eff_dest = jnp.take_along_axis(eff_bw, dest[:, None], axis=1)[:, 0]
    wr_bits = cfg.wr_bits if rt is None else rt["wr_bits"]
    wr_split = wr_bits / eff_dest
    wr_direct = jnp.max(jnp.where(wr > 0, wr / den_bw, 0.0), axis=1)
    l_wr = jnp.where(split == 1, wr_split, wr_direct)
    latency = l_cr + l_d2d + l_wr

    # energy (Eqs. 12-14)
    mem_idx = jnp.clip(v[:, COL_MEM], 0, cfg.M - 1)
    mrow = tb["mem3"][mem_idx]  # [P, 3]: rd/wr energy + cost
    m_rd = mrow[:, 0][:, None]
    m_wr = mrow[:, 1][:, None]
    sram_e = nphys[:, :, 1]
    mac_e = nphys[:, :, 2]
    e_comp_pj = jnp.sum(rd * m_rd + wr * m_wr + sram_b * sram_e
                        + macs * mac_e, axis=1)
    e_mem_d2d_pj = jnp.sum((rd + wr) * topo["dram_e"], axis=1)
    e_link_pj = jnp.sum(loads * topo["link_e"], axis=1)
    if mesh_on:
        # NoC traversal energy: routed reduction bits x mesh hops x pJ/bit
        e_link_pj = e_link_pj + (jnp.sum(sbits * pair_noc, axis=1)
                                 * cfg.noc_energy_pj_bit)
    e_compute_j = e_comp_pj * 1e-12
    e_d2d_j = (e_link_pj + e_mem_d2d_pj) * 1e-12
    static_w = jnp.where(mask, cphys[:, :, 1], 0.0)
    e_static_j = jnp.sum(static_w, axis=1) * latency
    energy = e_compute_j + e_d2d_j + e_static_j

    # area, dollar cost (Eqs. 15-16)
    area = topo["pkg_area"]
    chip_cost = jnp.sum(jnp.where(mask, cphys[:, :, 2], 0.0), axis=1)
    icost = jnp.where(topo["interp"], _interposer_cost(area, cfg), 0.0)
    package = cfg.substrate_cost_mm2 * area + topo["assembly"]
    bond_y = topo["bond_y"]
    active_s = cfg.lifetime_years * SECONDS_PER_YEAR * cfg.use_fraction
    runs = cfg.duty_runs_per_s * active_s
    # decoded duty weights: window spaces roll the gathered shape row to
    # the per-design start hour; fixed spaces read the shared row 0
    # (= the legacy static load_profile values). Both branches shape
    # the weights [P, 24] — a scalar-vs-vector effective intensity
    # would let XLA reassociate the operational products differently
    # between the fixed and window programs, an ulp of cross-program
    # drift the neutral-schedule bit-invisibility contract forbids.
    if cfg.schedule == "window":
        sc = cfg.sched_col
        s_start = v[:, sc]
        s_shape = jnp.clip(v[:, sc + 1], 0, cfg.n_sched - 1)
        hrs = jnp.arange(HOURS_PER_DAY, dtype=jnp.int32)
        roll = (hrs[None, :] - s_start[:, None]) % HOURS_PER_DAY
        load = jnp.take_along_axis(tb["sched_tab"][s_shape], roll,
                                   axis=-1)
    else:
        load = jnp.broadcast_to(tb["sched_tab"][0], (P, HOURS_PER_DAY))
    eff_price = price + jnp.sum((pprofile - price) * load, axis=-1)
    dollar = ((chip_cost + icost + package) / bond_y + mrow[:, 2]
              + energy * runs / 3.6e6 * eff_price)

    # embodied + operational CFP (Eqs. 2-3)
    mfg_pc = jnp.where(mask, cphys[:, :, 3], 0.0)
    mfg = jnp.sum(mfg_pc, axis=1)
    des = jnp.sum(jnp.where(mask, nphys[:, :, 3], 0.0), axis=1)
    icfp = jnp.where(
        topo["interp"],
        area * cfg.interposer_cpa / _nb_yield(
            area, cfg.interposer_defect, cfg.yield_alpha), 0.0)
    pkg_cfp_multi = (cfg.substrate_cfp_mm2 * area
                     + topo["p25_rate"] * area + icfp
                     + topo["p3_bonded"]) / bond_y
    pkg_cfp = jnp.where(topo["is2d"], cfg.substrate_cfp_mm2 * area,
                        pkg_cfp_multi)
    if mesh_on:
        # router carbon scales with each die's physical router count
        # (mx * my) instead of the flat per-die share
        pkg_cfp = pkg_cfp + cfg.router_area_frac * jnp.sum(
            mfg_pc * noc_r, axis=1)
    else:
        pkg_cfp = pkg_cfp + cfg.router_area_frac * mfg
    emb = (mfg + des + pkg_cfp) * embf
    eff_ci = ci + jnp.sum((profile - ci) * load, axis=-1)
    ope = energy * runs / 3.6e6 * eff_ci

    return (latency, energy, area, dollar, emb, ope, l_cr, l_d2d, l_wr,
            e_compute_j, e_d2d_j, jnp.sum(loads, axis=1),
            jnp.sum(macs, axis=1))


def _interposer_cost(area, cfg: _Cfg):
    import jax.numpy as jnp
    import math

    r = cfg.wafer_diameter_mm / 2.0
    dpw = (math.pi * r * r / area
           - math.pi * cfg.wafer_diameter_mm / jnp.sqrt(2.0 * area))
    dpw = jnp.maximum(1.0, jnp.trunc(dpw))
    y = _nb_yield(area, cfg.interposer_defect, cfg.yield_alpha)
    return cfg.interposer_wafer_cost / dpw / y


def _nb_yield(area, d0: float, alpha: float):
    return (1.0 + area * d0 / alpha) ** (-alpha)


def _eval_cost_jax(v, mins, medians, w, ci, price, embf, profile,
                   pprofile, tb, cfg: _Cfg, rt=None):
    """Fused metrics + Eq. 17 cost (METRIC_FIELDS column order) + the
    ``OBJECTIVE_AXES`` vector ``(latency_s, dollar, total_cfp)``.

    ``w`` is either a single ``[6]`` weight row or a per-row ``[P, 6]``
    matrix (the scalarization-sweep case: every chain scalarizes with
    its own direction inside the same program). ``ci``/``price``/
    ``embf``/``profile``/``pprofile``/``rt`` are the runtime
    region/workload knobs of :func:`_metrics_jax`."""
    import jax.numpy as jnp

    mets = _metrics_jax(v, tb, cfg, ci, price, embf, profile, pprofile,
                        rt)
    x = jnp.stack([mets[1], mets[2], mets[0], mets[3], mets[4], mets[5]],
                  axis=1)
    cost = ((x - mins[None, :]) / medians[None, :]
            * jnp.atleast_2d(w)).sum(axis=1)
    vec = jnp.stack([mets[0], mets[3], mets[4] + mets[5]], axis=1)
    return mets, cost, vec


# ---------------------------------------------------------------------------
# Vectorized hierarchical moves (device rendering of sa.propose)
# ---------------------------------------------------------------------------


def _validity_jax(v, tb, cfg: _Cfg):
    """jnp port of :meth:`DesignSpace.validity_mask`."""
    import jax.numpy as jnp

    C = cfg.C
    n = v[:, COL_N]
    style = v[:, COL_STYLE]
    p25, p3, stck = v[:, COL_PAIR25], v[:, COL_PAIR3], v[:, COL_STACK]
    ok = (n >= 1) & (n <= C)
    ok &= (style >= 0) & (style < 4)
    ok &= (v[:, COL_MEM] >= 0) & (v[:, COL_MEM] < cfg.M)
    ok &= (v[:, COL_ORDER] >= 0) & (v[:, COL_ORDER] <= 1)
    ok &= (v[:, COL_DATAFLOW] >= 0) & (v[:, COL_DATAFLOW] < 3)
    ok &= (v[:, COL_SPLITK] >= 0) & (v[:, COL_SPLITK] <= 1)
    chip = v[:, COL_CHIP:COL_CHIP + 3 * C].reshape(-1, C, 3)
    active = jnp.arange(C, dtype=jnp.int32)[None, :] < n[:, None]
    a, t, s = chip[:, :, 0], chip[:, :, 1], chip[:, :, 2]
    a_ok = (a >= 0) & (a < cfg.A)
    chip_ok = (a_ok & (t >= 0) & (t < cfg.T_nodes) & (s >= 0)
               & (s < tb["n_sram"][jnp.where(a_ok, a, 0)]))
    ok &= jnp.all(chip_ok | ~active, axis=1)
    if cfg.comm == "mesh_noc":
        nocv = v[:, cfg.noc_col:cfg.noc_col + 2 * C].reshape(-1, C, 2)
        mi, ei = nocv[:, :, 0], nocv[:, :, 1]
        noc_ok = ((mi >= 0) & (mi < cfg.n_mesh)
                  & (ei >= 0) & (ei < cfg.n_entry))
        ok &= jnp.all(noc_ok | ~active, axis=1)
    if cfg.schedule == "window":
        st_ = v[:, cfg.sched_col]
        sh_ = v[:, cfg.sched_col + 1]
        ok &= ((st_ >= 0) & (st_ < HOURS_PER_DAY)
               & (sh_ >= 0) & (sh_ < cfg.n_sched))
    pc = _popcount(stck, C)
    no3d, no25, nostk = p3 == -1, p25 == -1, stck == 0
    has25 = (p25 >= 0) & (p25 < cfg.n_pairs25)
    has3 = (p3 >= 0) & (p3 < cfg.n_pairs3)
    in_range = stck < jnp.left_shift(1, jnp.minimum(n, 30))
    ok &= jnp.where(style == S_2D, (n == 1) & no25 & no3d & nostk, True)
    ok &= jnp.where(style == S_25D, (n >= 2) & has25 & no3d & nostk, True)
    ok &= jnp.where(style == S_3D, (n >= 2) & has3 & no25 & nostk, True)
    ok &= jnp.where(style == S_HYBRID,
                    (n >= 3) & has25 & has3 & (pc >= 2) & (pc < n)
                    & in_range & (stck >= 0), True)
    return ok


def _propose_jax(key, v, tb, cfg: _Cfg, noc_on=None, sched_on=None):
    """One hierarchical move per encoded row, mirroring the level/branch
    distribution of :func:`repro.core.sa.propose` with ``jax.random``.

    Chiplet redraw-until-different uses two resamples instead of an
    unbounded loop (residual collision probability ~ (1/80)^3); rows whose
    candidate fails validity keep the incumbent (the batched rendering of
    the scalar retry loop).

    Under the mesh_noc comm model a fourth move level redraws one
    chiplet's (mesh dims, entry placement) pair, fed by a ``fold_in``
    side-stream so the base draw matrix — and with it every legacy
    move's randomness — is untouched. ``noc_on`` (0.0/1.0, traced
    scalar) widens the level draw to include it; ``None`` falls back to
    the static ``cfg.noc_live`` (frozen mesh spaces keep the exact
    3-level legacy distribution).

    Under the window schedule model one more level perturbs the design's
    (start_hour, shape_idx) schedule pair, fed by its own ``fold_in``
    side-stream (the temporal twin of the NoC level); ``sched_on``
    (0.0/1.0, traced scalar) gates it the same way, with ``None``
    falling back to the static ``cfg.sched_live`` — forced-neutral
    window spaces consume no extra base draws and replay the legacy
    level distribution exactly."""
    import jax
    import jax.numpy as jnp

    C = cfg.C
    P = v.shape[0]
    slot = jnp.arange(C, dtype=jnp.int32)
    mesh = cfg.comm == "mesh_noc"
    win = cfg.schedule == "window"
    # one threefry pass supplies every draw of the sweep: row i is the
    # i-th logical random stream (uniform ints come from floor(u * m))
    U = jax.random.uniform(key, (31 + C, P), dtype=jnp.float64)

    def uni(i):
        return U[i]

    def ri(i, maxv):
        return jnp.floor(U[i] * maxv).astype(jnp.int32)

    n = v[:, COL_N]
    style = v[:, COL_STYLE]
    mem = v[:, COL_MEM]
    order = v[:, COL_ORDER]
    df = v[:, COL_DATAFLOW]
    sk = v[:, COL_SPLITK]
    p25 = v[:, COL_PAIR25]
    p3 = v[:, COL_PAIR3]
    stck = v[:, COL_STACK]
    chip = v[:, COL_CHIP:COL_CHIP + 3 * C].reshape(P, C, 3)

    # -- application level: dataflow | split-K | order ----------------------
    which = ri(0, 3)
    cand_app = (
        v.at[:, COL_DATAFLOW].set(
            jnp.where(which == 0, (df + 1 + ri(1, 2)) % 3, df))
        .at[:, COL_SPLITK].set(jnp.where(which == 1, 1 - sk, sk))
        .at[:, COL_ORDER].set(jnp.where(which == 2, 1 - order, order)))

    # -- memory move --------------------------------------------------------
    cand_mem = v.at[:, COL_MEM].set((mem + 1 + ri(2, cfg.M - 1)) % cfg.M)

    # -- chiplet replacement ------------------------------------------------
    def draw_chiplet(ia, it, iu):
        a = ri(ia, cfg.A)
        t = ri(it, cfg.T_nodes)
        s = jnp.floor(uni(iu)
                      * tb["n_sram"][a].astype(jnp.float64)).astype(jnp.int32)
        return jnp.stack([a, t, s], axis=1)

    r_rep = jnp.floor(uni(3) * n.astype(jnp.float64)).astype(jnp.int32)
    old = jnp.take_along_axis(
        chip, jnp.broadcast_to(r_rep[:, None, None], (P, 1, 3)),
        axis=1)[:, 0]
    new = draw_chiplet(4, 5, 6)
    for ia, it, iu in ((7, 8, 9), (10, 11, 12)):
        new = jnp.where(jnp.all(new == old, axis=1)[:, None],
                        draw_chiplet(ia, it, iu), new)
    chip_rep = jnp.where(slot[None, :, None] == r_rep[:, None, None],
                         new[:, None, :], chip)
    cand_rep = v.at[:, COL_CHIP:COL_CHIP + 3 * C].set(
        chip_rep.reshape(P, -1).astype(jnp.int32))

    # -- chip-architecture: grow / shrink + dynamic HI-type repair ----------
    dlt = jnp.where(uni(13) < 0.5, -1, 1).astype(jnp.int32)
    n2a = jnp.clip(n + dlt, 1, C)
    n2 = jnp.where(n2a == n, jnp.clip(n - dlt, 1, C), n2a)
    grow = n2 > n
    r_del = jnp.floor(uni(14) * n.astype(jnp.float64)).astype(jnp.int32)
    idx_shift = jnp.minimum(
        slot[None, :] + (slot[None, :] >= r_del[:, None]), C - 1)
    chip_shr = jnp.take_along_axis(
        chip, jnp.broadcast_to(idx_shift[:, :, None], (P, C, 3)), axis=1)
    chip_grow = jnp.where(slot[None, :, None] == n[:, None, None],
                          draw_chiplet(15, 16, 17)[:, None, :], chip)
    chip_gs = jnp.where(grow[:, None, None], chip_grow, chip_shr)
    chip_gs = jnp.where((slot[None, :] < n2[:, None])[:, :, None],
                        chip_gs, -1)
    style2 = jnp.where(
        n2 == 1, S_2D,
        jnp.where((n2 == 2) & (style == S_HYBRID), S_3D,
                  jnp.where((n2 >= 2) & (style == S_2D), S_25D, style)))
    need25 = (style2 == S_25D) | (style2 == S_HYBRID)
    need3 = (style2 == S_3D) | (style2 == S_HYBRID)
    pkg_d = ri(18, cfg.n_pkg25)
    pr_d = jnp.floor(
        uni(19) * tb["p25_cnt"][pkg_d].astype(jnp.float64)).astype(jnp.int32)
    pair25_draw = tb["p25_flat"][tb["p25_off"][pkg_d] + pr_d]
    pair3_draw = tb["pair3_of_pkg"][ri(20, cfg.n_pkg3)]
    p25_2 = jnp.where(need25, jnp.where(p25 < 0, pair25_draw, p25), -1)
    p3_2 = jnp.where(need3, jnp.where(p3 < 0, pair3_draw, p3), -1)
    keep = stck & (jnp.left_shift(1, n2) - 1)
    pc = _popcount(keep, C)
    bad = (pc < 2) | (pc >= n2)
    size = jnp.where(
        n2 > 2,
        2 + jnp.floor(uni(21)
                      * (n2 - 2).astype(jnp.float64)).astype(jnp.int32), 2)
    scores = jnp.where(slot[None, :] < n2[:, None],
                       U[31:31 + C].T, jnp.inf)
    rank = jnp.argsort(jnp.argsort(scores, axis=1), axis=1)
    mask_new = jnp.sum(
        (rank < size[:, None]).astype(jnp.int32) << slot[None, :], axis=1)
    stack2 = jnp.where(style2 == S_HYBRID,
                       jnp.where(bad, mask_new, keep), 0)
    head = jnp.stack([n2, style2, mem, order, df, sk, p25_2, p3_2, stack2],
                     axis=1)
    if mesh:
        # mirror the chiplet-slot shift/append on the NoC columns: grown
        # slots seed the neutral (1x1, corner) = (0, 0) pair — exactly
        # sa._move_chip_arch's NOC_NEUTRAL append
        noc = v[:, cfg.noc_col:cfg.noc_col + 2 * C].reshape(P, C, 2)
        noc_shr = jnp.take_along_axis(
            noc, jnp.broadcast_to(idx_shift[:, :, None], (P, C, 2)),
            axis=1)
        noc_grow = jnp.where(slot[None, :, None] == n[:, None, None],
                             0, noc)
        noc_gs = jnp.where(grow[:, None, None], noc_grow, noc_shr)
        noc_gs = jnp.where((slot[None, :] < n2[:, None])[:, :, None],
                           noc_gs, -1)
        gs_parts = [head, chip_gs.reshape(P, -1), noc_gs.reshape(P, -1)]
    else:
        gs_parts = [head, chip_gs.reshape(P, -1)]
    if win:
        # whole-design schedule columns ride through grow/shrink intact
        gs_parts.append(v[:, cfg.sched_col:cfg.sched_col + 2])
    cand_gs = jnp.concatenate(gs_parts, axis=1).astype(jnp.int32)

    # -- package level ------------------------------------------------------
    cur_pkg25 = tb["pair25_pkg"][jnp.maximum(p25, 0)]
    new_pkg25 = (cur_pkg25 + 1 + ri(23, cfg.n_pkg25 - 1)) % cfg.n_pkg25
    kept = tb["pair25_by_pkg_proto"][new_pkg25,
                                     tb["pair25_proto"][jnp.maximum(p25, 0)]]
    cnt_np = tb["p25_cnt"][new_pkg25]
    rnd_pair = tb["p25_flat"][
        tb["p25_off"][new_pkg25]
        + jnp.floor(uni(24) * cnt_np.astype(jnp.float64)).astype(jnp.int32)]
    pkg25_res = jnp.where(kept >= 0, kept, rnd_pair)
    cnt_cur = tb["p25_cnt"][cur_pkg25]
    others = cnt_cur - 1
    loc = tb["pair25_local"][jnp.maximum(p25, 0)]
    j_o = jnp.floor(
        uni(25) * jnp.maximum(others, 1).astype(jnp.float64)
    ).astype(jnp.int32)
    proto25_res = tb["p25_flat"][
        tb["p25_off"][cur_pkg25]
        + (loc + 1 + j_o) % jnp.maximum(cnt_cur, 1)]
    cur_pkg3 = tb["pair3_pkg"][jnp.maximum(p3, 0)]
    pkg3_res = tb["pair3_of_pkg"][
        (cur_pkg3 + 1 + ri(26, cfg.n_pkg3 - 1)) % cfg.n_pkg3]
    n_opts = jnp.where(style == S_25D, 2,
                       jnp.where(style == S_HYBRID, 3, 1))
    pick = jnp.floor(uni(27) * n_opts.astype(jnp.float64)).astype(jnp.int32)
    has_plane = (style == S_25D) | (style == S_HYBRID)
    sel_pkg25 = has_plane & (pick == 0)
    sel_proto25 = has_plane & (pick == 1) & (others > 0)
    sel_pkg3 = (style == S_3D) | ((style == S_HYBRID) & (pick == 2))
    cand_pkg = (
        v.at[:, COL_PAIR25].set(
            jnp.where(sel_pkg25, pkg25_res,
                      jnp.where(sel_proto25, proto25_res, p25)))
        .at[:, COL_PAIR3].set(jnp.where(sel_pkg3, pkg3_res, p3)))

    # -- NoC level: redraw one chiplet's (mesh dims, entry) pair ------------
    if mesh:
        # side-stream so the base U matrix (= the legacy draw stream) is
        # byte-identical whether or not NoC moves are enabled
        Un = jax.random.uniform(jax.random.fold_in(key, 7), (5, P),
                                dtype=jnp.float64)
        r_noc = jnp.floor(Un[0] * n.astype(jnp.float64)).astype(jnp.int32)

        def draw_noc(im, ie):
            m_ = jnp.floor(Un[im] * cfg.n_mesh).astype(jnp.int32)
            e_ = jnp.floor(Un[ie] * cfg.n_entry).astype(jnp.int32)
            return jnp.stack([m_, e_], axis=1)

        old_noc = jnp.take_along_axis(
            noc, jnp.broadcast_to(r_noc[:, None, None], (P, 1, 2)),
            axis=1)[:, 0]
        new_noc = draw_noc(1, 2)
        new_noc = jnp.where(jnp.all(new_noc == old_noc, axis=1)[:, None],
                            draw_noc(3, 4), new_noc)
        noc_mv = jnp.where(slot[None, :, None] == r_noc[:, None, None],
                           new_noc[:, None, :], noc)
        cand_noc = v.at[:, cfg.noc_col:cfg.noc_col + 2 * C].set(
            noc_mv.reshape(P, -1).astype(jnp.int32))

    # -- schedule level: nudge start hour or redraw the window shape --------
    if win:
        # own fold_in side-stream (8), mirroring the NoC stream (7): the
        # base U matrix and the NoC draws stay byte-identical whether or
        # not schedule moves exist, so forced-neutral window spaces
        # replay legacy/mesh trajectories bit-for-bit
        Us = jax.random.uniform(jax.random.fold_in(key, 8), (3, P),
                                dtype=jnp.float64)
        sc = cfg.sched_col
        s_start = v[:, sc]
        s_shape = v[:, sc + 1]
        start2 = (s_start + 1 + jnp.floor(
            Us[1] * (HOURS_PER_DAY - 1)).astype(jnp.int32)) % HOURS_PER_DAY
        shape2 = (s_shape + 1 + jnp.floor(
            Us[2] * (cfg.n_sched - 1)).astype(jnp.int32)) % cfg.n_sched
        s_coin = Us[0] < 0.5  # start-hour nudge vs shape redraw
        cand_sched = (
            v.at[:, sc].set(jnp.where(s_coin, start2, s_start))
            .at[:, sc + 1].set(jnp.where(s_coin, s_shape, shape2)))

    # -- hierarchical branch selection + validity gate ----------------------
    is_app = uni(28) < P_APPLICATION
    coin = uni(30)
    if mesh or win:
        # noc_on/sched_on in {0.0, 1.0} widen the uniform level draw
        # from 3 to up-to-5 options as runtime data: floor(u * 3.0) ==
        # the legacy ri(29, 3) exactly, so frozen-axis cells replay the
        # 3-level distribution
        noc_on_f = ((noc_on if noc_on is not None
                     else (1.0 if cfg.noc_live else 0.0))
                    if mesh else None)
        sched_on_f = ((sched_on if sched_on is not None
                       else (1.0 if cfg.sched_live else 0.0))
                      if win else None)
        n_levels = 3.0
        if mesh:
            n_levels = n_levels + noc_on_f
        if win:
            n_levels = n_levels + sched_on_f
        level = jnp.floor(U[29] * n_levels).astype(jnp.int32)
        if mesh and win:
            # runtime mapping: the schedule level sits after the NoC
            # level iff NoC moves are on for this row/cell
            noc_i = jnp.floor(noc_on_f).astype(jnp.int32)
            is_noc = (level == 3) & (noc_i == 1)
            lower = jnp.where(
                (level == 1)[:, None], cand_rep,
                jnp.where((level == 2)[:, None], cand_pkg,
                          jnp.where(is_noc[:, None], cand_noc,
                                    cand_sched)))
        elif mesh:
            lower = jnp.where(
                (level == 1)[:, None], cand_rep,
                jnp.where((level == 2)[:, None], cand_pkg, cand_noc))
        else:
            lower = jnp.where(
                (level == 1)[:, None], cand_rep,
                jnp.where((level == 2)[:, None], cand_pkg, cand_sched))
    else:
        level = ri(29, 3)
        lower = jnp.where((level == 1)[:, None], cand_rep, cand_pkg)
    cand = jnp.where(
        is_app[:, None], cand_app,
        jnp.where((level == 0)[:, None],
                  jnp.where((coin < 0.5)[:, None], cand_gs, cand_mem),
                  lower))
    ok = _validity_jax(cand, tb, cfg)
    return jnp.where(ok[:, None], cand, v).astype(jnp.int32)


def _exchange_fn(inv_t, us, pair_ok):
    """Adjacent-pair replica-exchange step for ``lax.fori_loop``, shared
    verbatim by the single-scenario scan and the stacked scenario engine
    (one definition => the two cannot drift apart).

    ``d >= 0`` short-circuits in the host loop, so only exp of
    non-positive ``d`` is ever compared; ``pair_ok`` gates swaps across
    independent ladders (scalarization-direction / cell boundaries)."""
    import jax.numpy as jnp

    def ex_body(j, vc):
        vv, cc = vc
        c_i, c_j = cc[j], cc[j + 1]
        d = (inv_t[j] - inv_t[j + 1]) * (c_i - c_j)
        sw = pair_ok[j] & (
            (d >= 0) | (us[j] < jnp.exp(jnp.minimum(d, 0.0))))
        cc = cc.at[j].set(jnp.where(sw, c_j, c_i)) \
               .at[j + 1].set(jnp.where(sw, c_i, c_j))
        v_i, v_j = vv[j], vv[j + 1]
        vv = vv.at[j].set(jnp.where(sw, v_j, v_i)) \
               .at[j + 1].set(jnp.where(sw, v_i, v_j))
        return (vv, cc)

    return ex_body


def _key_to_np(key) -> np.ndarray:
    """Raw PRNG key data as a host array (typed-key safe) — the carry's
    RNG stream position is checkpointed as plain uint32 words."""
    import jax

    try:
        return np.asarray(key)
    except TypeError:
        return np.asarray(jax.random.key_data(key))


def _key_from_np(data: np.ndarray, like_key):
    """Rebuild a key usable by ``jax.random`` from saved raw words,
    matching the flavor (raw/typed) of ``like_key``."""
    import jax
    import jax.numpy as jnp

    try:
        np.asarray(like_key)
        return jnp.asarray(data)
    except TypeError:
        return jax.random.wrap_key_data(jnp.asarray(data))


# trailing shapes of the per-sweep trace fields (the zero-sweep edge)
_TRACE_TAILS = (
    lambda n, w: (n, w),             # proposals
    lambda n, w: (n,),               # proposal_costs
    lambda n, w: (n,),               # u_accept
    lambda n, w: (max(n - 1, 1),),   # u_swap
    lambda n, w: (n,),               # accepted
    lambda n, w: (n,),               # costs
    lambda n, w: (),                 # best_per_sweep
)


# ---------------------------------------------------------------------------
# Compile accounting + shared table/cfg builders
# ---------------------------------------------------------------------------

# program-family name -> number of traces. A jit-wrapped Python function
# body runs exactly once per fresh XLA compile (shape/dtype/sharding
# cache misses) and never on cache hits, so counting calls from inside
# the wrapped function is a faithful compile counter — the hook the
# one-compile regression tests and benchmarks read via trace_count().
_TRACE_COUNTS: Dict[str, int] = {}


def _count_trace(name: str) -> None:
    _TRACE_COUNTS[name] = _TRACE_COUNTS.get(name, 0) + 1


def trace_count(name: str) -> int:
    """Traces (= XLA compiles) of the named fused-program family in this
    process: ``"eval_cost"`` (fused evaluate+cost), ``"pt"`` (the
    single-scenario tempering scan — one compile per distinct segment
    length), ``"pt_init"`` (its seed-population eval),
    ``"scenario_pt"`` / ``"scenario_init"`` (the stacked scenario
    twins), ``"scenario_eval"`` (the stacked one-shot eval)."""
    return _TRACE_COUNTS.get(name, 0)


def _base_cfg(sp: DesignSpace, db: TechDB, T0: int, T1: int,
              wr_bits: float, use_pallas: bool) -> _Cfg:
    """The static trace-time constants shared by every fused program over
    one (TechDB, DesignSpace) — tile bounds and wr_bits vary per engine."""
    return _Cfg(
        C=sp.max_chiplets, W=sp.width, A=len(sp.arrays),
        T_nodes=len(sp.nodes), S=int(sp.n_sram.max()),
        M=len(sp.memories), n_pairs25=len(sp.pairs_25d),
        n_pairs3=len(sp.pairs_3d),
        n_pkg25=len(sp.pkg25_pairs), n_pkg3=len(sp.pkg3_pairs),
        L=sp.max_chiplets * (sp.max_chiplets - 1) // 2
        + sp.max_chiplets - 1,
        T0=T0, T1=T1, wr_bits=wr_bits,
        acost=db.assembly_cost,
        substrate_cost_mm2=db.substrate_cost_mm2,
        substrate_cfp_mm2=db.substrate_cfp_mm2,
        interposer_cpa=db.interposer_cpa,
        interposer_defect=db.interposer_defect,
        interposer_wafer_cost=db.interposer_wafer_cost,
        yield_alpha=db.yield_alpha,
        wafer_diameter_mm=db.wafer_diameter_mm,
        lifetime_years=db.lifetime_years,
        use_fraction=db.use_fraction,
        duty_runs_per_s=db.duty_runs_per_s,
        router_area_frac=db.router_area_frac,
        comm=sp.comm,
        noc_col=sp.noc_col,
        n_mesh=len(comm_mod.MESH_DIMS),
        n_entry=len(comm_mod.ENTRY_PLACEMENTS),
        noc_hop_latency_s=db.noc_hop_latency_s,
        noc_energy_pj_bit=db.noc_energy_pj_bit,
        hop_uniform=db.uniform_hop_latency(),
        noc_live=sp.noc_live,
        schedule=sp.schedule,
        sched_col=sp.sched_col if sp.schedule == "window" else -1,
        n_sched=sched_mod.n_schedule_shapes(),
        sched_live=sp.sched_live,
        use_pallas=use_pallas,
    )


def _shared_tables(host, sp: DesignSpace) -> dict:
    """Workload-independent jnp tables (chiplet physicals, node rates,
    memory energies, package info, move tables) — identical for every
    workload and every deployment region over one (db, space), so the
    single-workload evaluator and the stacked scenario engine share the
    same builder. Call under ``enable_x64``."""
    import jax.numpy as jnp

    mt = sp.move_tables()
    noc_h, noc_r = comm_mod.noc_tables()
    return dict(
        # per-chiplet physicals / node rates / memory energies are
        # stacked along a trailing axis: one gather per site
        chiplet=jnp.asarray(np.stack(
            [host.t_area, host.t_static, host.t_cost, host.t_mfg],
            axis=-1)),
        node=jnp.asarray(np.stack(
            [host.t_freq, host.t_sram_e, host.t_mac_e, host.t_des],
            axis=-1)),
        mem3=jnp.asarray(np.stack(
            [host.m_rd, host.m_wr, host.m_cost], axis=-1)),
        t_power=jnp.asarray(host.t_power),
        m_bw=jnp.asarray(host.m_bw),
        p25=jnp.asarray([i[:7] for i in host.p25_info]),
        p25_interp=jnp.asarray([i[7] for i in host.p25_info]),
        p3=jnp.asarray([i[:7] for i in host.p3_info]),
        # per-pair hop latencies (the heterogeneous-latency hop split)
        # and the closed-form mesh-NoC lookup tables — tiny constants,
        # carried unconditionally; legacy programs never gather them
        p25_hl=jnp.asarray(host.p25_hl),
        p3_hl=jnp.asarray(host.p3_hl),
        noc_hops=jnp.asarray(noc_h),
        noc_routers=jnp.asarray(noc_r),
        # duty-weight shape table (row 0 = db.load_profile verbatim):
        # fixed-schedule programs gather row 0, window programs gather
        # the encoded per-design (start, shape) columns against it
        sched_tab=jnp.asarray(sched_mod.schedule_tables(host.db)),
        n_sram=jnp.asarray(sp.n_sram),
        **{k: jnp.asarray(a) for k, a in mt.items()},
    )


def _tile_tables(host) -> dict:
    """Per-workload prefix-sum tables. Call under ``enable_x64``."""
    import jax.numpy as jnp

    return dict(
        # [A, S, 3, T+1, 5]: the 5 sim metrics ride in the trailing
        # axis so one gather fetches all of them
        pref0=jnp.asarray(np.stack(
            [host.tiles[0]["pref"][f] for f in _SIM_METRICS], axis=-1)),
        pref1=jnp.asarray(np.stack(
            [host.tiles[1]["pref"][f] for f in _SIM_METRICS], axis=-1)),
        mn0=jnp.asarray(host.tiles[0]["mn_pref"]),
        mn1=jnp.asarray(host.tiles[1]["mn_pref"]),
    )


def _pallas_tables(host) -> dict:
    """Flattened [5, (A*S*3), T+1] native-dtype (int64) copies for the
    Pallas kernel. Interpret mode subtracts in int64 exactly like the
    jnp reference gathers, so the kernel path is bit-identical on CPU;
    the compiled TPU path needs rebased float32 tables instead (see the
    kernel module docstring)."""
    import jax.numpy as jnp

    out = {}
    for sk, name in ((0, "pref0_flat"), (1, "pref1_flat")):
        pref = np.stack(
            [host.tiles[sk]["pref"][f] for f in _SIM_METRICS])
        out[name] = jnp.asarray(
            pref.reshape(len(_SIM_METRICS), -1, pref.shape[-1]))
    return out


def _pallas_stacked_tables(hosts, tb0: int, tb1: int) -> dict:
    """Workload-stacked flattened ``[5, (Wk*A*S*3), T_bucket+1]`` float64
    tables for the fused Pallas kernel: each workload's per-metric prefix
    tables are edge-padded to the shared tile bucket and concatenated
    along the row axis, so the kernel indexes
    ``row = ((wi*A + a)*S + s)*3 + d`` with per-cell clip bounds at the
    true (unpadded) tile totals. Native (int64) dtype like
    :func:`_pallas_tables`, for bit-exact interpret-mode subtraction.
    Call under ``enable_x64``."""
    import jax.numpy as jnp

    out = {}
    for sk, bucket, name in ((0, tb0, "pref0_flatw"),
                             (1, tb1, "pref1_flatw")):
        mats = []
        for h in hosts:
            pref = np.stack(
                [h.tiles[sk]["pref"][f] for f in _SIM_METRICS])
            pref = _pad_tiles(pref, bucket, axis=-1)
            mats.append(pref.reshape(pref.shape[0], -1, bucket + 1))
        out[name] = jnp.asarray(np.concatenate(mats, axis=1))
    return out


# ---------------------------------------------------------------------------
# The device evaluator + lax.scan tempering engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DevicePTResult:
    """Output of the fused parallel-tempering scan."""

    best_enc: np.ndarray          # encoded best row
    best_cost: float
    history: List[float]          # [initial best] + coldest-chain per sweep
    evaluations: int
    final_enc: np.ndarray         # [n_chains, width] final population
    final_costs: np.ndarray
    trace: Optional[Dict[str, np.ndarray]] = None
    # every evaluated design + its OBJECTIVE_AXES vector (seed population
    # first): enc [1 + sweeps, n, width], vec [1 + sweeps, n, 3] — the
    # Pareto archive's input
    samples: Optional[Dict[str, np.ndarray]] = None


_PALLAS_ENV_WARNED = False


def _resolve_pallas(use_pallas: Optional[bool]) -> bool:
    """Resolve the kernel fast-path switch.

    An explicit ``use_pallas`` argument wins. Otherwise the
    ``REPRO_PATHFINDER_PALLAS`` environment variable decides: ``1`` (or
    ``true``/``yes``) forces the Pallas path, ``0`` (``false``/``no``)
    forces plain jnp, and ``auto`` (the default) enables the kernel on
    TPU backends only. Any other value warns once per process and falls
    back to ``auto``.
    """
    global _PALLAS_ENV_WARNED
    if use_pallas is not None:
        return use_pallas
    env = os.environ.get("REPRO_PATHFINDER_PALLAS", "auto").lower()
    if env in ("1", "true", "yes"):
        return True
    if env in ("0", "false", "no"):
        return False
    if env != "auto" and not _PALLAS_ENV_WARNED:
        _PALLAS_ENV_WARNED = True
        warnings.warn(
            f"unrecognized REPRO_PATHFINDER_PALLAS value {env!r}; accepted "
            "values are 0/1/auto (aliases: false/no and true/yes) — "
            "falling back to auto (Pallas on TPU backends only)",
            RuntimeWarning, stacklevel=2)
    import jax

    return jax.default_backend() == "tpu"


def _db_region_cols(db: TechDB) -> Tuple[np.float64, np.float64,
                                         np.ndarray, np.ndarray]:
    """The (price, embf, profile, pprofile) runtime region columns a
    single-region evaluator synthesizes from its TechDB. A ``None`` grid
    (price) profile becomes the flat row at ``carbon_intensity``
    (``electricity_price``) — the in-program corrections
    ``sum((profile - ci) * load)`` / ``sum((pprofile - price) * load)``
    are then exactly +0.0, so the default columns are bit-neutral."""
    price = np.float64(db.electricity_price)
    embf = np.float64(db.emb_factor)
    if db.grid_profile is None:
        profile = np.full(len(db.load_profile),
                          np.float64(db.carbon_intensity))
    else:
        profile = np.asarray(db.grid_profile, dtype=np.float64)
    if db.price_profile is None:
        pprofile = np.full(len(db.load_profile), price)
    else:
        pprofile = np.asarray(db.price_profile, dtype=np.float64)
    return price, embf, profile, pprofile


class DeviceEvaluator:
    """Jit-compiled fused evaluate+cost + scan engine for one workload.

    Reuses the host :class:`~repro.pathfinding.batch.BatchEvaluator`'s
    numpy tables (chiplet physicals, tile prefix sums, package info) and
    re-expresses stages 2-3 as a single jitted XLA program.
    """

    def __init__(self, wl: GEMMWorkload, db: TechDB = DEFAULT_DB,
                 tile_sizes: Tuple[int, int, int] = DEFAULT_TILE,
                 space: Optional[DesignSpace] = None,
                 use_pallas: Optional[bool] = None):
        import jax
        from jax.experimental import enable_x64

        self.wl, self.db, self.tile_sizes = wl, db, tile_sizes
        host = get_evaluator(wl, db, tile_sizes, space)
        self.host = host
        self.space = host.space
        sp = self.space
        use_pallas = _resolve_pallas(use_pallas)
        self.cfg = _base_cfg(
            sp, db, T0=host.tiles[0]["T"], T1=host.tiles[1]["T"],
            wr_bits=float(wl.M * wl.N * OPERAND_BYTES * 8),
            use_pallas=use_pallas)
        with enable_x64():
            tb = {**_shared_tables(host, sp), **_tile_tables(host)}
            if use_pallas:
                tb.update(_pallas_tables(host))
        self.tables = tb
        cfg = self.cfg
        # donate the padded population buffer (no-op on CPU, where XLA
        # cannot reuse host-backed int buffers and would warn)
        donate = () if jax.default_backend() == "cpu" else (0,)

        def _eval_fn(v, mins, med, w, ci, price, embf, profile, pprofile):
            _count_trace("eval_cost")
            return _eval_cost_jax(v, mins, med, w, ci, price, embf,
                                  profile, pprofile, tb, cfg)

        self._eval_cost_jit = jax.jit(_eval_fn, donate_argnums=donate)
        self._propose_jit = jax.jit(
            lambda key, v: _propose_jax(key, v, tb, cfg))
        self._pt_cache: Dict[tuple, object] = {}

    # -- bucketed fused evaluation -----------------------------------------

    @staticmethod
    def _pad(encoded: np.ndarray) -> Tuple[np.ndarray, int]:
        v = np.atleast_2d(np.asarray(encoded, dtype=np.int32))
        n_real = v.shape[0]
        bucket = max(64, 1 << (n_real - 1).bit_length())
        if bucket != n_real:
            v = np.vstack(
                [v, np.zeros((bucket - n_real, v.shape[1]), dtype=v.dtype)])
        return v, n_real

    def evaluate_cost(self, encoded: np.ndarray, norm: Normalizer,
                      template: Template
                      ) -> Tuple[MetricsBatch, np.ndarray]:
        """Fused metrics + Eq. 17 cost for an encoded population.

        Pads to a power-of-two bucket (>= 64) so repeated calls of any
        size reuse a handful of compiled programs; the padded buffer is
        donated to the program."""
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        mb, cost, _ = self.evaluate_cost_vector(encoded, norm, template)
        return mb, cost

    def evaluate_cost_vector(self, encoded: np.ndarray, norm: Normalizer,
                             template: Template
                             ) -> Tuple[MetricsBatch, np.ndarray,
                                        np.ndarray]:
        """Fused metrics + cost + ``(latency, dollar, total_cfp)`` vectors
        — all three outputs of one jitted program."""
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        with enable_x64():
            v, n_real = self._pad(encoded)
            mins, medians = norm.weights_arrays()
            price, embf, profile, pprofile = _db_region_cols(self.db)
            mets, cost, vec = self._eval_cost_jit(
                jnp.asarray(v), jnp.asarray(mins), jnp.asarray(medians),
                jnp.asarray(np.asarray(template.weights, dtype=np.float64)),
                jnp.asarray(np.float64(self.db.carbon_intensity)),
                jnp.asarray(price), jnp.asarray(embf), jnp.asarray(profile),
                jnp.asarray(pprofile))
            arrs = [np.asarray(m)[:n_real] for m in mets]
            return (MetricsBatch(*arrs), np.asarray(cost)[:n_real],
                    np.asarray(vec)[:n_real])

    def metrics(self, encoded: np.ndarray) -> MetricsBatch:
        """Raw metrics through the jitted path (identity normalizer)."""
        from repro.core.templates import IDENTITY_NORMALIZER, TEMPLATES

        return self.evaluate_cost(encoded, IDENTITY_NORMALIZER,
                                  TEMPLATES["T1"])[0]

    def propose(self, encoded: np.ndarray, seed: int = 0) -> np.ndarray:
        """One vectorized hierarchical move per row (valid rows only)."""
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        with enable_x64():
            v = np.atleast_2d(np.asarray(encoded, dtype=np.int32))
            out = self._propose_jit(jax.random.PRNGKey(seed),
                                    jnp.asarray(v))
            return np.asarray(out)

    # -- the fused tempering engine ----------------------------------------
    #
    # The sweep loop is *segmented*: a host loop advances the scan in
    # fixed-size chunks (default: one chunk covering every sweep), with
    # the carry round-tripping between jit calls. Segment boundaries are
    # where long searches snapshot their state (see
    # :mod:`repro.pathfinding.resume`) — and because the per-sweep body,
    # the key stream (carried through the scan) and the sweep indices
    # (``sweep0 + arange(seg)``) are identical to the monolithic scan,
    # segmentation does not change a single bit of the trajectory. Each
    # distinct segment length compiles once ("pt" in trace_count); the
    # seed-population evaluation is its own tiny program ("pt_init").

    def _pt_init_fn(self, n: int):
        key_t = ("init", n)
        fn = self._pt_cache.get(key_t)
        if fn is not None:
            return fn
        import jax

        tb, cfg = self.tables, self.cfg

        def init(v0, mins, med, w, ci, price, embf, profile, pprofile):
            _count_trace("pt_init")
            _, cost0, vec0 = _eval_cost_jax(v0, mins, med, w, ci, price,
                                            embf, profile, pprofile,
                                            tb, cfg)
            return cost0, vec0

        fn = jax.jit(init)
        self._pt_cache[key_t] = fn
        return fn

    def _pt_fn(self, n: int, seg: int, swap_every: int,
               record_trace: bool, collect_samples: bool):
        key_t = (n, seg, swap_every, record_trace, collect_samples)
        fn = self._pt_cache.get(key_t)
        if fn is not None:
            return fn
        import jax
        import jax.numpy as jnp

        tb, cfg = self.tables, self.cfg

        def run(v0, costs0, best_v0, best_c0, key, sweep0, temps, mins,
                med, w, pair_ok, ci, price, embf, profile, pprofile):
            _count_trace("pt")
            inv_t = 1.0 / temps

            def body(carry, sweep):
                v, costs, best_v, best_c, key = carry
                key, kp, ka, ksw = jax.random.split(key, 4)
                prop = _propose_jax(kp, v, tb, cfg)
                _, pcost, pvec = _eval_cost_jax(prop, mins, med, w, ci,
                                                price, embf, profile,
                                                pprofile, tb, cfg)
                u = jax.random.uniform(ka, (n,), dtype=jnp.float64)
                delta = pcost - costs
                accept = (delta <= 0) | (
                    u < jnp.exp(-delta / jnp.maximum(temps, 1e-12)))
                v = jnp.where(accept[:, None], prop, v)
                costs = jnp.where(accept, pcost, costs)
                acc = jnp.where(accept, pcost, jnp.inf)
                i = jnp.argmin(acc)
                better = acc[i] < best_c
                best_c = jnp.where(better, acc[i], best_c)
                best_v = jnp.where(better, prop[i], best_v)
                us = jax.random.uniform(ksw, (max(n - 1, 1),),
                                        dtype=jnp.float64)
                do_swap = (sweep % swap_every) == 0
                ex_body = _exchange_fn(inv_t, us, pair_ok)
                v, costs = jax.lax.cond(
                    do_swap,
                    lambda vc: jax.lax.fori_loop(0, n - 1, ex_body, vc),
                    lambda vc: vc, (v, costs))
                ys = (costs[-1], best_c)
                if collect_samples:
                    ys = ys + (prop, pvec)
                if record_trace:
                    ys = ys + (prop, pcost, u, us, accept, costs)
                return (v, costs, best_v, best_c, key), ys

            carry, ys = jax.lax.scan(
                body, (v0, costs0, best_v0, best_c0, key),
                sweep0 + jnp.arange(seg))
            return carry, ys

        fn = jax.jit(run)
        self._pt_cache[key_t] = fn
        return fn

    def parallel_tempering(self, v0: np.ndarray, temps, sweeps: int,
                           swap_every: int, seed: int, norm: Normalizer,
                           template: Template,
                           record_trace: bool = False,
                           weights: Optional[np.ndarray] = None,
                           pair_mask: Optional[np.ndarray] = None,
                           collect_samples: bool = True,
                           segment: Optional[int] = None,
                           checkpoint=None, resume: bool = True,
                           archive=None) -> DevicePTResult:
        """Run the fused propose/evaluate/accept/exchange scan.

        ``v0`` is the encoded seed population (one row per chain, coldest
        chain last as in the host strategy); ``temps`` the matching
        temperature ladder.

        ``weights`` (``[n, 6]``) gives every chain its own Eq. 17
        scalarization row (default: ``template.weights`` for all) and
        ``pair_mask`` (``[max(n-1, 1)]`` bool) disables replica exchange
        across selected adjacent pairs — together they run K independent
        scalarization ladders in one program (the
        :class:`~repro.pathfinding.pareto.ScalarizationSweep` engine).
        ``collect_samples`` returns every evaluated design + its
        objective vector in ``.samples`` for Pareto-archive feeding.

        ``segment`` chops the scan into host-driven chunks of that many
        sweeps (default: one chunk); the chunking is invisible in the
        results — same key stream, same sweep indices, bit-identical
        trajectory. ``archive`` (a
        :class:`~repro.pathfinding.pareto.ParetoArchive`) is fed each
        segment's samples in place of returning ``.samples``, and
        ``checkpoint`` (a
        :class:`~repro.pathfinding.resume.SearchCheckpointer`) snapshots
        carry + archive + history at every boundary; with ``resume=True``
        the newest valid snapshot is restored and the run continues to
        ``sweeps`` (``record_trace`` cannot be combined with
        checkpointing)."""
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        with enable_x64():
            v0 = np.atleast_2d(np.asarray(v0, dtype=np.int32))
            n, width = v0.shape
            sweeps = int(sweeps)
            if segment is not None and int(segment) < 1:
                raise ValueError(f"segment must be >= 1, got {segment}")
            seg_size = max(1, sweeps) if segment is None else int(segment)
            if checkpoint is not None and record_trace:
                raise ValueError(
                    "record_trace records host-replay state for the full "
                    "run and cannot be checkpointed/resumed")
            if checkpoint is not None and collect_samples and archive is None:
                raise ValueError(
                    "checkpointing with collect_samples requires an "
                    "archive= to feed: bulk .samples live only in process "
                    "memory and would be lost across a resume")
            mins, medians = norm.weights_arrays()
            if weights is None:
                w = np.tile(np.asarray(template.weights, np.float64), (n, 1))
            else:
                w = np.asarray(weights, np.float64)
                if w.shape != (n, 6):
                    raise ValueError(
                        f"weights must be [{n}, 6], got {w.shape}")
            if pair_mask is None:
                pair_ok = np.ones(max(n - 1, 1), dtype=bool)
            else:
                pair_ok = np.asarray(pair_mask, dtype=bool)
                if pair_ok.shape != (max(n - 1, 1),):
                    raise ValueError(
                        f"pair_mask must be [{max(n - 1, 1)}], "
                        f"got {pair_ok.shape}")
            temps_np = np.asarray(temps, np.float64)
            ci = np.float64(self.db.carbon_intensity)
            price, embf, profile, pprofile = _db_region_cols(self.db)
            key0 = jax.random.PRNGKey(seed)
            args = (jnp.asarray(temps_np), jnp.asarray(mins),
                    jnp.asarray(medians), jnp.asarray(w),
                    jnp.asarray(pair_ok), jnp.asarray(ci),
                    jnp.asarray(price), jnp.asarray(embf),
                    jnp.asarray(profile), jnp.asarray(pprofile))

            from repro.pathfinding.resume import (
                run_segmented,
                segment_fingerprint,
            )

            fp = None
            carry_like = None
            if checkpoint is not None:
                extra = {}
                if self.cfg.comm != "legacy":
                    # non-legacy comm reshapes the encoding + the fused
                    # program: pre-NoC checkpoints must mismatch cleanly
                    extra["comm"] = np.frombuffer(
                        self.cfg.comm.encode(), dtype=np.uint8)
                if self.cfg.schedule != "fixed":
                    # the window encoding reshapes the row: pre-schedule
                    # checkpoints must mismatch cleanly (fixed-schedule
                    # fingerprints stay byte-identical to pre-PR ones)
                    extra["schedule"] = np.frombuffer(
                        self.cfg.schedule.encode(), dtype=np.uint8)
                if not np.all(pprofile == price):
                    extra["pprofile"] = pprofile
                fp = segment_fingerprint(
                    "device_pt", v0=v0, temps=temps_np,
                    swap_every=swap_every, seed=seed, mins=mins,
                    medians=medians, weights=w, pair_mask=pair_ok, ci=ci,
                    segment=segment, collect=collect_samples,
                    price=price, embf=embf, profile=profile, **extra)
                carry_like = dict(
                    v=np.zeros((n, width), np.int32),
                    costs=np.zeros(n, np.float64),
                    best_v=np.zeros(width, np.int32),
                    best_c=np.zeros((), np.float64),
                    key=_key_to_np(key0))

            # mutable host state the shared driver's hooks close over
            st = dict(history=None, seed_block=None, cost0_np=None)
            enc_parts, vec_parts, trace_parts = [], [], []

            def fresh():
                cost0, vec0 = self._pt_init_fn(n)(
                    jnp.asarray(v0), args[1], args[2], args[3], args[5],
                    args[6], args[7], args[8], args[9])
                cost0_np = np.asarray(cost0)
                st["cost0_np"] = cost0_np
                bi = int(np.argmin(cost0_np))
                st["history"] = [float(cost0_np.min())]
                if collect_samples:
                    st["seed_block"] = (v0[None], np.asarray(vec0)[None])
                return (jnp.asarray(v0), cost0, jnp.asarray(v0[bi]),
                        cost0[bi], key0)

            def from_restored(r):
                c = r.carry
                st["history"] = r.history.tolist()
                return (jnp.asarray(c["v"]), jnp.asarray(c["costs"]),
                        jnp.asarray(c["best_v"]), jnp.asarray(c["best_c"]),
                        _key_from_np(c["key"], key0))

            def run_segment(carry, done, seg):
                fn = self._pt_fn(n, seg, int(swap_every),
                                 bool(record_trace), bool(collect_samples))
                return fn(*carry, np.int64(done), *args)

            def absorb(ys, seg):
                st["history"].extend(np.asarray(ys[0]).tolist())
                off = 2
                if collect_samples:
                    enc_s = np.asarray(ys[off])
                    vec_s = np.asarray(ys[off + 1])
                    off += 2
                    if archive is not None:
                        if st["seed_block"] is not None:
                            enc_s = np.concatenate(
                                [st["seed_block"][0], enc_s])
                            vec_s = np.concatenate(
                                [st["seed_block"][1], vec_s])
                            st["seed_block"] = None
                        archive.insert(enc_s.reshape(-1, width),
                                       vec_s.reshape(-1, vec_s.shape[-1]))
                    else:
                        enc_parts.append(enc_s)
                        vec_parts.append(vec_s)
                if record_trace:
                    trace_parts.append(
                        tuple(np.asarray(y) for y in ys[off:off + 6])
                        + (np.asarray(ys[1]),))

            def carry_np(carry):
                return dict(v=np.asarray(carry[0]),
                            costs=np.asarray(carry[1]),
                            best_v=np.asarray(carry[2]),
                            best_c=np.asarray(carry[3]),
                            key=_key_to_np(carry[4]))

            def flush_seed():
                if st["seed_block"] is not None and archive is not None:
                    archive.insert(
                        st["seed_block"][0].reshape(-1, width),
                        st["seed_block"][1].reshape(
                            -1, st["seed_block"][1].shape[-1]))
                    st["seed_block"] = None

            carry, _ = run_segmented(
                sweeps=sweeps, seg_size=seg_size, checkpoint=checkpoint,
                resume=resume, fingerprint=fp, archives=archive,
                carry_like=carry_like, fresh=fresh,
                from_restored=from_restored, run_segment=run_segment,
                absorb=absorb, carry_np=carry_np,
                history_np=lambda: np.asarray(st["history"], np.float64),
                sweep_counter=lambda done: done, flush_seed=flush_seed)
            history, seed_block = st["history"], st["seed_block"]

            v_fin, costs_fin, best_v, best_c, _ = carry
            samples = None
            if collect_samples and archive is None:
                blocks_e = ([seed_block[0]] if seed_block is not None
                            else []) + enc_parts
                blocks_v = ([seed_block[1]] if seed_block is not None
                            else []) + vec_parts
                if blocks_e:
                    samples = dict(enc=np.concatenate(blocks_e),
                                   vec=np.concatenate(blocks_v))
            trace = None
            if record_trace:
                fields = ("proposals", "proposal_costs", "u_accept",
                          "u_swap", "accepted", "costs", "best_per_sweep")
                cat = [np.concatenate([p[i] for p in trace_parts])
                       if trace_parts else
                       np.zeros((0,) + _TRACE_TAILS[i](n, width))
                       for i in range(len(fields))]
                trace = dict(zip(fields, cat))
                trace["initial_costs"] = st["cost0_np"]
            return DevicePTResult(
                best_enc=np.asarray(best_v), best_cost=float(best_c),
                history=history, evaluations=n + n * sweeps,
                final_enc=np.asarray(v_fin),
                final_costs=np.asarray(costs_fin), trace=trace,
                samples=samples)


# ---------------------------------------------------------------------------
# The stacked scenario engine: one compile for a region x workload grid
# ---------------------------------------------------------------------------


def _tile_bucket(t: int) -> int:
    """Power-of-two tile-count bucket (>= 64): workload sets whose max
    tile counts land in the same bucket produce identically shaped
    stacked programs (the scenario twin of the population `_pad`)."""
    return max(64, 1 << (int(t) - 1).bit_length())


def _pad_tiles(a: np.ndarray, bucket: int, axis: int) -> np.ndarray:
    """Edge-pad a prefix table's T+1 axis to bucket+1 slots. Tile-range
    gathers never index past the true per-workload total (starts/ends
    sum to it), and edge replication makes any clipped tail slot
    difference to exactly zero anyway."""
    cur = a.shape[axis]
    if cur == bucket + 1:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, bucket + 1 - cur)
    return np.pad(a, pad, mode="edge")


@dataclasses.dataclass
class ScenarioPTResult:
    """Per-cell outputs of the stacked scenario tempering scan (leading
    axis = scenario cell everywhere)."""

    best_enc: np.ndarray          # [S, width]
    best_cost: np.ndarray         # [S]
    history: np.ndarray           # [S, 1 + sweeps] coldest-chain costs
    evaluations: int              # total across all cells
    final_enc: np.ndarray         # [S, n, width]
    final_costs: np.ndarray       # [S, n]
    # every evaluated design + its OBJECTIVE_AXES vector, seed population
    # first: enc [1 + sweeps, S, n, width], vec [1 + sweeps, S, n, 3]
    samples: Optional[Dict[str, np.ndarray]] = None


class ScenarioEngine:
    """One fused program for a whole scenario grid.

    The per-cell knobs of a (workload x deployment region) sweep are all
    runtime data of the fused evaluate+cost program: the grid carbon
    intensity (a scalar multiplier of operational CFP), the per-cell
    normalizer rows and Eq. 17 weight rows, and the per-workload tile
    totals / DRAM write-back bits (their prefix tables ride in a stacked,
    tile-bucket-padded lookup indexed by a per-cell workload id). One
    ``lax.scan`` over a ``vmap``-ped per-cell tempering step therefore
    sweeps the full grid in a *single* XLA compile — where the PR-3 path
    paid a fresh ``DeviceEvaluator`` build plus full program retrace per
    region even though only one scalar changed.

    Per-cell RNG: the scan folds the cell index into the base key
    (``jax.random.fold_in``), so every cell gets a distinct,
    deterministic proposal stream that depends only on (seed, cell
    index) — not on the grid's size or order.

    The scenario axis can be sharded across local devices with a mesh
    from :func:`repro.distributed.sharding.scenario_mesh` (pass it as
    ``mesh=``); inputs are placed with their leading axis split over the
    mesh's data axes and XLA partitions the scan accordingly.

    Like the single-workload engine, the grid scan is *segmented*
    (``segment=`` sweeps per host-driven chunk, bit-invisible) so a
    multi-thousand-cell sweep checkpoints at boundaries and resumes
    bit-identically (:mod:`repro.pathfinding.resume`).

    Kernel fast path: like :class:`DeviceEvaluator`, the stacked engine
    takes ``use_pallas`` (default: the ``REPRO_PATHFINDER_PALLAS``
    resolution, see :func:`_resolve_pallas`). When enabled, the gather +
    split-select + segment-reduce stage of every cell's tempering step
    runs through the fused :func:`repro.kernels.prefix_gather.
    prefix_select_gather` kernel on workload-stacked flattened tables —
    its ``custom_vmap`` rule folds the scenario-cell axis into the
    kernel grid, so the whole ``[S, n]`` population tile is one launch
    per sweep. The jnp path stays the bit-pinned reference; the same
    ``scenario_pt``/``scenario_init`` programs are emitted either way,
    so segmentation, checkpoints and serving replay are unaffected."""

    def __init__(self, workloads: Sequence[GEMMWorkload],
                 db: TechDB = DEFAULT_DB,
                 tile_sizes: Tuple[int, int, int] = DEFAULT_TILE,
                 space: Optional[DesignSpace] = None,
                 use_pallas: Optional[bool] = None):
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        self.workloads = tuple(workloads)
        if not self.workloads:
            raise ValueError("ScenarioEngine needs >= 1 workload")
        self.db, self.tile_sizes = db, tile_sizes
        hosts = [get_evaluator(wl, db, tile_sizes, space)
                 for wl in self.workloads]
        self.hosts = hosts
        self.space = hosts[0].space
        sp = self.space
        t0s = [h.tiles[0]["T"] for h in hosts]
        t1s = [h.tiles[1]["T"] for h in hosts]
        tb0, tb1 = _tile_bucket(max(t0s)), _tile_bucket(max(t1s))
        use_pallas = _resolve_pallas(use_pallas)
        self.cfg = _base_cfg(sp, db, T0=tb0, T1=tb1, wr_bits=0.0,
                             use_pallas=use_pallas)
        with enable_x64():
            tb = _shared_tables(hosts[0], sp)
            if use_pallas:
                tb.update(_pallas_stacked_tables(hosts, tb0, tb1))
            tb.update(
                pref0w=jnp.asarray(np.stack([
                    _pad_tiles(np.stack(
                        [h.tiles[0]["pref"][f] for f in _SIM_METRICS],
                        axis=-1), tb0, axis=-2) for h in hosts])),
                pref1w=jnp.asarray(np.stack([
                    _pad_tiles(np.stack(
                        [h.tiles[1]["pref"][f] for f in _SIM_METRICS],
                        axis=-1), tb1, axis=-2) for h in hosts])),
                mn0w=jnp.asarray(np.stack(
                    [_pad_tiles(h.tiles[0]["mn_pref"], tb0, axis=0)
                     for h in hosts])),
                mn1w=jnp.asarray(np.stack(
                    [_pad_tiles(h.tiles[1]["mn_pref"], tb1, axis=0)
                     for h in hosts])),
                t0w=jnp.asarray(np.asarray(t0s, dtype=np.int32)),
                t1w=jnp.asarray(np.asarray(t1s, dtype=np.int32)),
                wrw=jnp.asarray(np.asarray(
                    [float(wl.M * wl.N * OPERAND_BYTES * 8)
                     for wl in self.workloads])),
            )
        self.tables = tb
        self._fn_cache: Dict[tuple, object] = {}

    # -- per-cell table/runtime slices (wi is a traced scalar) -------------

    def _cell_tables(self, wi):
        tb = self.tables
        tbc = dict(tb, pref0=tb["pref0w"][wi], pref1=tb["pref1w"][wi],
                   mn0=tb["mn0w"][wi], mn1=tb["mn1w"][wi])
        rt = dict(T0=tb["t0w"][wi], T1=tb["t1w"][wi],
                  wr_bits=tb["wrw"][wi], wi=wi)
        return tbc, rt

    # -- one-shot stacked evaluation (normalizer fits, finalization) -------

    def _eval_fn(self, S: int, m: int):
        key_t = ("eval", S, m)
        fn = self._fn_cache.get(key_t)
        if fn is not None:
            return fn
        import jax

        cfg = self.cfg

        def run(v, mins, med, w, ci, price, embf, profile, pprofile,
                widx):
            _count_trace("scenario_eval")

            def cell(v_s, mins_s, med_s, w_s, ci_s, price_s, embf_s,
                     profile_s, pprofile_s, wi):
                tbc, rt = self._cell_tables(wi)
                _, cost, vec = _eval_cost_jax(v_s, mins_s, med_s, w_s,
                                              ci_s, price_s, embf_s,
                                              profile_s, pprofile_s,
                                              tbc, cfg, rt)
                return cost, vec

            return jax.vmap(cell)(v, mins, med, w, ci, price, embf,
                                  profile, pprofile, widx)

        fn = jax.jit(run)
        self._fn_cache[key_t] = fn
        return fn

    @staticmethod
    def _region_cols(S: int, ci: np.ndarray, price=None, embf=None,
                     profile=None, pprofile=None
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray]:
        """Normalize/synthesize the per-cell region columns: ``price``
        [S] (default zeros), ``embf`` [S] (default ones), ``profile``
        [S, 24] (default flat-at-ci rows, whose in-program correction
        is exactly +0.0) and ``pprofile`` [S, 24] (default
        flat-at-price rows, correction +0.0 too). Always materialized
        so the jitted programs have ONE signature — legacy scalar-CI
        callers and full six-axis callers share the same compile."""
        ci = np.asarray(ci, np.float64).reshape(S)
        price = (np.zeros(S, np.float64) if price is None
                 else np.asarray(price, np.float64).reshape(S))
        embf = (np.ones(S, np.float64) if embf is None
                else np.asarray(embf, np.float64).reshape(S))
        profile = (np.repeat(ci[:, None], HOURS_PER_DAY, axis=1)
                   if profile is None
                   else np.asarray(profile, np.float64).reshape(
                       S, HOURS_PER_DAY))
        pprofile = (np.repeat(price[:, None], HOURS_PER_DAY, axis=1)
                    if pprofile is None
                    else np.asarray(pprofile, np.float64).reshape(
                        S, HOURS_PER_DAY))
        return price, embf, profile, pprofile

    def evaluate_cost(self, encoded: np.ndarray, mins: np.ndarray,
                      medians: np.ndarray, weights: np.ndarray,
                      ci: np.ndarray, widx: np.ndarray,
                      price: Optional[np.ndarray] = None,
                      embf: Optional[np.ndarray] = None,
                      profile: Optional[np.ndarray] = None,
                      pprofile: Optional[np.ndarray] = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Fused cost + objective vectors for a stacked ``[S, m, width]``
        population (per-cell ``[S, 6]`` normalizer rows / weight rows,
        ``[S]`` carbon intensities and workload ids, plus the optional
        regional axes ``price`` [S], ``embf`` [S], ``profile`` [S, 24]
        and ``pprofile`` [S, 24] — omitted axes synthesize their
        neutral columns).
        Returns ``(cost [S, m], vec [S, m, 3])``; the row axis is
        padded to a power-of-two bucket so repeated calls share one
        program."""
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        with enable_x64():
            v = np.asarray(encoded, dtype=np.int32)
            S, m, _ = v.shape
            mb = max(64, 1 << (m - 1).bit_length())
            if mb != m:
                v = np.concatenate(
                    [v, np.repeat(v[:, :1], mb - m, axis=1)], axis=1)
            ci_a = np.asarray(ci, np.float64).reshape(S)
            price_a, embf_a, profile_a, pprofile_a = self._region_cols(
                S, ci_a, price, embf, profile, pprofile)
            fn = self._eval_fn(S, mb)
            cost, vec = fn(
                jnp.asarray(v),
                jnp.asarray(np.asarray(mins, np.float64).reshape(S, 6)),
                jnp.asarray(np.asarray(medians, np.float64).reshape(S, 6)),
                jnp.asarray(np.asarray(weights, np.float64).reshape(S, 6)),
                jnp.asarray(ci_a), jnp.asarray(price_a),
                jnp.asarray(embf_a), jnp.asarray(profile_a),
                jnp.asarray(pprofile_a),
                jnp.asarray(np.asarray(widx, np.int32).reshape(S)))
            return np.asarray(cost)[:, :m], np.asarray(vec)[:, :m]

    # -- the stacked tempering scan ----------------------------------------
    #
    # Segmented exactly like :class:`DeviceEvaluator`: a host loop
    # advances the grid scan in fixed-size chunks with the carry (per-cell
    # populations, costs, incumbents and fold_in-derived key streams)
    # round-tripping between jit calls, so a multi-thousand-cell sweep
    # checkpoints at segment boundaries and resumes bit-identically.
    # "scenario_init" evaluates the seed populations + folds the per-cell
    # keys; each distinct segment length compiles one "scenario_pt".

    def _eval_cell_fn(self):
        cfg = self.cfg

        def eval_cell(v_s, mins_s, med_s, w_s, ci_s, price_s, embf_s,
                      profile_s, pprofile_s, wi):
            tbc, rt = self._cell_tables(wi)
            _, cost, vec = _eval_cost_jax(v_s, mins_s, med_s, w_s, ci_s,
                                          price_s, embf_s, profile_s,
                                          pprofile_s, tbc, cfg, rt)
            return cost, vec

        return eval_cell

    def _init_fn(self, S: int, n: int):
        key_t = ("init", S, n)
        fn = self._fn_cache.get(key_t)
        if fn is not None:
            return fn
        import jax
        import jax.numpy as jnp

        eval_cell = self._eval_cell_fn()

        def init(v0, mins, med, w, ci, price, embf, profile, pprofile,
                 widx, key):
            _count_trace("scenario_init")
            keys0 = jax.vmap(
                lambda i: jax.random.fold_in(key, i))(jnp.arange(S))
            cost0, vec0 = jax.vmap(eval_cell)(v0, mins, med, w, ci,
                                              price, embf, profile,
                                              pprofile, widx)
            return keys0, cost0, vec0

        fn = jax.jit(init)
        self._fn_cache[key_t] = fn
        return fn

    def _pt_fn(self, S: int, n: int, seg: int, swap_every: int,
               collect_samples: bool):
        key_t = ("pt", S, n, seg, swap_every, collect_samples)
        fn = self._fn_cache.get(key_t)
        if fn is not None:
            return fn
        import jax
        import jax.numpy as jnp

        tb, cfg = self.tables, self.cfg
        eval_cell = self._eval_cell_fn()
        mesh_comm = cfg.comm == "mesh_noc"
        win_sched = cfg.schedule == "window"

        def cell_step(key_s, v_s, costs_s, temps_s, inv_s, mins_s, med_s,
                      w_s, pair_s, ci_s, price_s, embf_s, profile_s,
                      pprofile_s, wi, noc_s, sched_s, sweep):
            key_s, kp, ka, ksw = jax.random.split(key_s, 4)
            prop = _propose_jax(kp, v_s, tb, cfg,
                                noc_on=noc_s if mesh_comm else None,
                                sched_on=sched_s if win_sched else None)
            pcost, pvec = eval_cell(prop, mins_s, med_s, w_s, ci_s,
                                    price_s, embf_s, profile_s,
                                    pprofile_s, wi)
            u = jax.random.uniform(ka, (n,), dtype=jnp.float64)
            delta = pcost - costs_s
            accept = (delta <= 0) | (
                u < jnp.exp(-delta / jnp.maximum(temps_s, 1e-12)))
            v_s = jnp.where(accept[:, None], prop, v_s)
            costs_s = jnp.where(accept, pcost, costs_s)
            acc = jnp.where(accept, pcost, jnp.inf)
            i = jnp.argmin(acc)
            cand_c, cand_v = acc[i], prop[i]
            us = jax.random.uniform(ksw, (max(n - 1, 1),),
                                    dtype=jnp.float64)
            do_swap = (sweep % swap_every) == 0
            ex_body = _exchange_fn(inv_s, us, pair_s)
            v_s, costs_s = jax.lax.cond(
                do_swap,
                lambda vc: jax.lax.fori_loop(0, n - 1, ex_body, vc),
                lambda vc: vc, (v_s, costs_s))
            return key_s, v_s, costs_s, cand_v, cand_c, prop, pvec

        def _run(v0, costs0, best_v0, best_c0, keys0, sweep0, temps, mins,
                 med, w, pair_ok, ci, price, embf, profile, pprofile,
                 widx, noc_on, sched_on):
            # ``sweep0`` is a per-cell [S] vector of job-local sweep
            # counters: every cell keeps its own swap schedule, so a
            # serving job that joins the batch mid-stream sees the same
            # sweep indices it would solo. Lockstep callers pass
            # ``done * ones(S)`` and get the exact pre-vector program
            # semantics (the swap cond is per-lane either way).
            # ``noc_on``/``sched_on`` are the per-cell [S] NoC-move /
            # schedule-move gates (mesh_noc / window engines only; dead
            # inputs elsewhere).
            _count_trace("scenario_pt")
            inv_t = 1.0 / temps

            def body(carry, t):
                v, costs, best_v, best_c, keys = carry
                keys, v, costs, cand_v, cand_c, prop, pvec = jax.vmap(
                    cell_step,
                    in_axes=(0,) * 18,
                )(keys, v, costs, temps, inv_t, mins, med, w, pair_ok,
                  ci, price, embf, profile, pprofile, widx, noc_on,
                  sched_on, sweep0 + t)
                better = cand_c < best_c
                best_c = jnp.where(better, cand_c, best_c)
                best_v = jnp.where(better[:, None], cand_v, best_v)
                ys = (costs[:, -1], best_c)
                if collect_samples:
                    ys = ys + (prop, pvec)
                return (v, costs, best_v, best_c, keys), ys

            carry, ys = jax.lax.scan(
                body, (v0, costs0, best_v0, best_c0, keys0),
                jnp.arange(seg))
            return carry, ys

        # the public replay contract (the serving layer's) is exactly 17
        # positional args, plus a trailing ``noc_on`` iff mesh_noc and a
        # trailing ``sched_on`` iff window — neutral gates for absent
        # axes are dead inputs the compiler strips, so every engine
        # whose optional axes are off emits the same program it did
        # before those axes existed
        if mesh_comm and win_sched:
            run = _run
        elif mesh_comm:
            def run(v0, costs0, best_v0, best_c0, keys0, sweep0, temps,
                    mins, med, w, pair_ok, ci, price, embf, profile,
                    pprofile, widx, noc_on):
                return _run(v0, costs0, best_v0, best_c0, keys0, sweep0,
                            temps, mins, med, w, pair_ok, ci, price,
                            embf, profile, pprofile, widx, noc_on,
                            jnp.zeros_like(ci))
        elif win_sched:
            def run(v0, costs0, best_v0, best_c0, keys0, sweep0, temps,
                    mins, med, w, pair_ok, ci, price, embf, profile,
                    pprofile, widx, sched_on):
                return _run(v0, costs0, best_v0, best_c0, keys0, sweep0,
                            temps, mins, med, w, pair_ok, ci, price,
                            embf, profile, pprofile, widx,
                            jnp.zeros_like(ci), sched_on)
        else:
            def run(v0, costs0, best_v0, best_c0, keys0, sweep0, temps,
                    mins, med, w, pair_ok, ci, price, embf, profile,
                    pprofile, widx):
                return _run(v0, costs0, best_v0, best_c0, keys0, sweep0,
                            temps, mins, med, w, pair_ok, ci, price,
                            embf, profile, pprofile, widx,
                            jnp.zeros_like(ci), jnp.zeros_like(ci))

        fn = jax.jit(run)
        self._fn_cache[key_t] = fn
        return fn

    def segment_runner(self, S: int, n: int, seg: int, swap_every: int,
                       collect_samples: bool = False):
        """Public handle on the fused segment program.

        The serving layer (``repro.serving``) drives one segment at a
        time from its own scheduler, so it needs the compiled program
        without the host loop in :meth:`parallel_tempering`. The
        returned callable has signature ``run(v, costs, best_v, best_c,
        keys, sweep0, temps, mins, med, w, pair_ok, ci, price, embf,
        profile, pprofile, widx)`` — ``price``/``embf`` are the
        per-cell [S] regional price and embodied-factor columns,
        ``profile`` the [S, 24] grid-intensity rows and ``pprofile``
        the [S, 24] electricity-price rows (neutral cells pass 0.0 /
        1.0 / flat-at-ci / flat-at-price); mesh_noc engines take an
        extra trailing ``noc_on`` [S] column and window-schedule
        engines a trailing ``sched_on`` [S] column (0.0/1.0 per-cell
        move gates) — where ``sweep0`` is the per-cell [S] vector of
        job-local sweep counters; calling it twice with the same static
        shape tuple reuses the cached jit program
        (``trace_count("scenario_pt")`` does not move)."""
        return self._pt_fn(int(S), int(n), int(seg), int(swap_every),
                           bool(collect_samples))

    def parallel_tempering(self, v0: np.ndarray, temps, sweeps: int,
                           swap_every: int, seed: int, mins, medians,
                           weights, pair_mask, ci, widx,
                           price=None, embf=None, profile=None,
                           pprofile=None, noc_on=None, sched_on=None,
                           collect_samples: bool = True,
                           mesh=None, segment: Optional[int] = None,
                           checkpoint=None, resume: bool = True,
                           archives: Optional[Sequence] = None
                           ) -> ScenarioPTResult:
        """Run the whole scenario grid in one fused scan.

        ``v0`` is ``[S, n, width]`` (cell-major seed populations),
        ``temps``/``weights``/``pair_mask`` the per-cell ladder / Eq. 17
        rows / exchange gates, ``mins``/``medians`` the per-cell
        normalizer rows, ``ci`` the per-cell grid carbon intensities and
        ``widx`` the per-cell workload indices into this engine's
        workload tuple. ``price``/``embf``/``profile``/``pprofile``
        are the optional per-cell regional axes ([S] electricity
        prices, [S] embodied factors, [S, 24] grid-intensity profiles,
        [S, 24] electricity-price profiles); omitted axes synthesize
        their neutral columns (0.0 / 1.0 / flat-at-ci /
        flat-at-price), so legacy scalar-CI grids compile and run the
        exact same program — the columns are always part of the jitted
        signature and ``trace_count("scenario_pt")`` stays flat across
        axis mixes. ``noc_on`` ([S], mesh_noc engines only) gates the
        per-cell NoC move level as runtime data (default: all-on for
        live-NoC spaces, all-off for frozen ones) and ``sched_on``
        ([S], window-schedule engines only) gates the per-cell
        schedule move level the same way, so mixed legacy-replay and
        axis-searching cells share one compile.
        ``mesh`` (optional) shards the scenario axis.

        ``segment``/``checkpoint``/``resume``/``archives`` mirror
        :meth:`DeviceEvaluator.parallel_tempering`: the grid scan runs in
        host-driven chunks whose carry (including the per-cell sweep
        counters and fold_in-derived key streams) plus the per-cell
        archives snapshot at every boundary, and the chunking never
        changes a bit of any cell's trajectory. ``archives`` is one
        :class:`~repro.pathfinding.pareto.ParetoArchive` per cell, fed
        in place of returning ``.samples``."""
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        with enable_x64():
            v0 = np.asarray(v0, dtype=np.int32)
            if v0.ndim != 3:
                raise ValueError(f"v0 must be [S, n, width], got {v0.shape}")
            S, n, width = v0.shape
            sweeps = int(sweeps)
            if segment is not None and int(segment) < 1:
                raise ValueError(f"segment must be >= 1, got {segment}")
            seg_size = max(1, sweeps) if segment is None else int(segment)
            if checkpoint is not None and collect_samples \
                    and archives is None:
                raise ValueError(
                    "checkpointing with collect_samples requires "
                    "archives= to feed: bulk .samples live only in "
                    "process memory and would be lost across a resume")
            if archives is not None and len(archives) != S:
                raise ValueError(
                    f"need one archive per cell: {len(archives)} != {S}")
            widx_a = np.asarray(widx, dtype=np.int32).reshape(S)
            if widx_a.min(initial=0) < 0 or \
                    widx_a.max(initial=0) >= len(self.workloads):
                raise ValueError(
                    f"widx out of range for {len(self.workloads)} workloads")
            ci_a = np.asarray(ci, np.float64).reshape(S)
            price_a, embf_a, profile_a, pprofile_a = self._region_cols(
                S, ci_a, price, embf, profile, pprofile)
            mesh_comm = self.cfg.comm == "mesh_noc"
            noc_a = None
            if mesh_comm:
                noc_a = (np.full(
                    S, 1.0 if self.space.noc_live else 0.0, np.float64)
                    if noc_on is None
                    else np.asarray(noc_on, np.float64).reshape(S))
            elif noc_on is not None:
                raise ValueError(
                    "noc_on is only meaningful for mesh_noc engines")
            win_sched = self.cfg.schedule == "window"
            sched_a = None
            if win_sched:
                sched_a = (np.full(
                    S, 1.0 if self.space.sched_live else 0.0, np.float64)
                    if sched_on is None
                    else np.asarray(sched_on, np.float64).reshape(S))
            elif sched_on is not None:
                raise ValueError(
                    "sched_on is only meaningful for window-schedule "
                    "engines")
            arrays = dict(
                v0=v0,
                temps=np.asarray(temps, np.float64).reshape(S, n),
                mins=np.asarray(mins, np.float64).reshape(S, 6),
                med=np.asarray(medians, np.float64).reshape(S, 6),
                w=np.asarray(weights, np.float64).reshape(S, n, 6),
                pair_ok=np.asarray(pair_mask, bool).reshape(
                    S, max(n - 1, 1)),
                ci=ci_a,
                price=price_a,
                embf=embf_a,
                profile=profile_a,
                pprofile=pprofile_a,
                widx=widx_a,
            )
            if mesh_comm:
                arrays["noc_on"] = noc_a
            if win_sched:
                arrays["sched_on"] = sched_a
            if mesh is not None:
                from repro.distributed.sharding import shard_scenarios

                arrays = shard_scenarios(arrays, mesh)
            key0 = jax.random.PRNGKey(seed)
            args = (jnp.asarray(arrays["temps"]), jnp.asarray(arrays["mins"]),
                    jnp.asarray(arrays["med"]), jnp.asarray(arrays["w"]),
                    jnp.asarray(arrays["pair_ok"]),
                    jnp.asarray(arrays["ci"]), jnp.asarray(arrays["price"]),
                    jnp.asarray(arrays["embf"]),
                    jnp.asarray(arrays["profile"]),
                    jnp.asarray(arrays["pprofile"]),
                    jnp.asarray(arrays["widx"]))
            if mesh_comm:
                args = args + (jnp.asarray(arrays["noc_on"]),)
            if win_sched:
                args = args + (jnp.asarray(arrays["sched_on"]),)

            from repro.pathfinding.resume import (
                run_segmented,
                segment_fingerprint,
            )

            fp = None
            carry_like = None
            if checkpoint is not None:
                key_np = _key_to_np(key0)
                extra = {}
                if self.cfg.comm != "legacy":
                    # non-legacy comm reshapes the encoding + the fused
                    # program: pre-NoC checkpoints must mismatch cleanly
                    extra["comm"] = np.frombuffer(
                        self.cfg.comm.encode(), dtype=np.uint8)
                    extra["noc_on"] = noc_a
                if self.cfg.schedule != "fixed":
                    # the window encoding reshapes the row the same way:
                    # pre-schedule checkpoints must mismatch cleanly,
                    # while fixed-schedule fingerprints stay byte-
                    # identical to pre-PR ones
                    extra["schedule"] = np.frombuffer(
                        self.cfg.schedule.encode(), dtype=np.uint8)
                    extra["sched_on"] = sched_a
                if not np.all(pprofile_a == price_a[:, None]):
                    extra["pprofile"] = pprofile_a
                fp = segment_fingerprint(
                    "scenario_pt", v0=v0, temps=arrays["temps"],
                    swap_every=swap_every, seed=seed,
                    mins=arrays["mins"], medians=arrays["med"],
                    weights=arrays["w"], pair_mask=arrays["pair_ok"],
                    ci=arrays["ci"], segment=segment,
                    collect=collect_samples, widx=widx_a,
                    price=price_a, embf=embf_a, profile=profile_a,
                    **extra)
                carry_like = dict(
                    v=np.zeros((S, n, width), np.int32),
                    costs=np.zeros((S, n), np.float64),
                    best_v=np.zeros((S, width), np.int32),
                    best_c=np.zeros(S, np.float64),
                    keys=np.zeros((S,) + key_np.shape, key_np.dtype))

            st = dict(hist_parts=None, seed_block=None,
                      sweep_done=np.zeros(S, dtype=np.int64))
            enc_parts, vec_parts = [], []

            def feed_cells(enc_s, vec_s):
                for s in range(S):
                    archives[s].insert(
                        enc_s[:, s].reshape(-1, width),
                        vec_s[:, s].reshape(-1, vec_s.shape[-1]))

            def fresh():
                keys0, cost0, vec0 = self._init_fn(S, n)(
                    jnp.asarray(arrays["v0"]), args[1], args[2], args[3],
                    args[5], args[6], args[7], args[8], args[9],
                    args[10], key0)
                bi0 = jnp.argmin(cost0, axis=1)
                best_v0 = jnp.take_along_axis(
                    jnp.asarray(arrays["v0"]), bi0[:, None, None],
                    axis=1)[:, 0]
                best_c0 = jnp.take_along_axis(
                    cost0, bi0[:, None], axis=1)[:, 0]
                st["hist_parts"] = [
                    np.min(np.asarray(cost0), axis=1)[:, None]]
                if collect_samples:
                    st["seed_block"] = (v0[None], np.asarray(vec0)[None])
                return (jnp.asarray(arrays["v0"]), cost0, best_v0,
                        best_c0, keys0)

            def from_restored(r):
                c = dict(r.carry)
                if mesh is not None:
                    # the fresh path's carry inherits the scenario-axis
                    # sharding from `arrays`; the restored one comes from
                    # host numpy and must be re-placed, or the first
                    # post-resume segment jits a second (unsharded)
                    # program signature
                    from repro.distributed.sharding import shard_scenarios

                    c = shard_scenarios(c, mesh)
                st["sweep_done"] = np.asarray(
                    r.sweep_done_per_cell, dtype=np.int64).reshape(S)
                st["hist_parts"] = [r.history.reshape(S, -1)]
                return (jnp.asarray(c["v"]), jnp.asarray(c["costs"]),
                        jnp.asarray(c["best_v"]), jnp.asarray(c["best_c"]),
                        _key_from_np(c["keys"], key0))

            def run_segment(carry, done, seg):
                fn = self._pt_fn(S, n, seg, int(swap_every),
                                 bool(collect_samples))
                return fn(*carry, jnp.asarray(st["sweep_done"]), *args)

            def absorb(ys, seg):
                st["hist_parts"].append(np.asarray(ys[0]).T)
                if collect_samples:
                    enc_s, vec_s = np.asarray(ys[2]), np.asarray(ys[3])
                    if st["seed_block"] is not None:
                        enc_s = np.concatenate(
                            [st["seed_block"][0], enc_s])
                        vec_s = np.concatenate(
                            [st["seed_block"][1], vec_s])
                        st["seed_block"] = None
                    if archives is not None:
                        feed_cells(enc_s, vec_s)
                    else:
                        enc_parts.append(enc_s)
                        vec_parts.append(vec_s)
                st["sweep_done"] = st["sweep_done"] + seg

            def carry_np(carry):
                return dict(v=np.asarray(carry[0]),
                            costs=np.asarray(carry[1]),
                            best_v=np.asarray(carry[2]),
                            best_c=np.asarray(carry[3]),
                            keys=_key_to_np(carry[4]))

            def flush_seed():
                if st["seed_block"] is not None and archives is not None:
                    feed_cells(*st["seed_block"])
                    st["seed_block"] = None

            carry, _ = run_segmented(
                sweeps=sweeps, seg_size=seg_size, checkpoint=checkpoint,
                resume=resume, fingerprint=fp, archives=archives,
                carry_like=carry_like, fresh=fresh,
                from_restored=from_restored, run_segment=run_segment,
                absorb=absorb, carry_np=carry_np,
                history_np=lambda: np.concatenate(
                    st["hist_parts"], axis=1),
                sweep_counter=lambda done: st["sweep_done"],
                flush_seed=flush_seed)
            hist_parts, seed_block = st["hist_parts"], st["seed_block"]

            v_fin, costs_fin, best_v, best_c, _ = carry
            samples = None
            if collect_samples and archives is None:
                blocks_e = ([seed_block[0]] if seed_block is not None
                            else []) + enc_parts
                blocks_v = ([seed_block[1]] if seed_block is not None
                            else []) + vec_parts
                if blocks_e:
                    samples = dict(enc=np.concatenate(blocks_e),
                                   vec=np.concatenate(blocks_v))
            return ScenarioPTResult(
                best_enc=np.asarray(best_v),
                best_cost=np.asarray(best_c),
                history=np.concatenate(hist_parts, axis=1),
                evaluations=S * n * (1 + sweeps),
                final_enc=np.asarray(v_fin),
                final_costs=np.asarray(costs_fin),
                samples=samples)


_SCENARIO_ENGINES: Dict[tuple, Tuple[TechDB, "ScenarioEngine"]] = {}
_SCENARIO_ENGINE_CACHE_MAX = 4


def get_scenario_engine(workloads: Sequence[GEMMWorkload],
                        db: TechDB = DEFAULT_DB,
                        tile_sizes: Tuple[int, int, int] = DEFAULT_TILE,
                        space: Optional[DesignSpace] = None
                        ) -> ScenarioEngine:
    """Cached :class:`ScenarioEngine` per (workload tuple, db, tiles,
    chiplet bound) — the stacked twin of :func:`get_device_evaluator`.

    Like that twin, the resolved Pallas setting is part of the key, so
    flipping ``REPRO_PATHFINDER_PALLAS`` mid-process builds a fresh
    engine instead of silently returning the cached other-path one.

    The db's ``_Cfg``-static lifecycle knobs (``load_profile``,
    ``router_area_frac``) are default-resolved into the key as values:
    two TechDBs that differ only in those knobs can never alias onto
    one cached engine even if ``id()`` is recycled after a gc (the
    ``hit[0] is db`` identity check in ``cached_evaluator`` guards the
    rest of the db)."""
    from repro.pathfinding.batch import cached_evaluator

    use_pallas = _resolve_pallas(None)
    key = (tuple(workloads), id(db), tile_sizes,
           space.max_chiplets if space is not None else
           DEFAULT_MAX_CHIPLETS, use_pallas,
           tuple(db.load_profile), db.router_area_frac,
           (space.comm, space.noc_live) if space is not None else
           (comm_mod.resolve_comm(None), False),
           (space.schedule, space.sched_live) if space is not None else
           (sched_mod.resolve_schedule(None), False))
    return cached_evaluator(
        _SCENARIO_ENGINES, key, db,
        lambda: ScenarioEngine(workloads, db, tile_sizes, space,
                               use_pallas),
        _SCENARIO_ENGINE_CACHE_MAX)


# ---------------------------------------------------------------------------
# module-level evaluator cache + functional entry points
# ---------------------------------------------------------------------------

_DEVICE_EVALUATORS: Dict[tuple, Tuple[TechDB, DeviceEvaluator]] = {}
_DEVICE_EVALUATOR_CACHE_MAX = 8


def get_device_evaluator(wl: GEMMWorkload, db: TechDB = DEFAULT_DB,
                         tile_sizes: Tuple[int, int, int] = DEFAULT_TILE,
                         space: Optional[DesignSpace] = None
                         ) -> DeviceEvaluator:
    """Cached :class:`DeviceEvaluator` (jit warmup is expensive — share
    one per (workload, db, tiles, chiplet bound) like ``get_evaluator``).

    The resolved Pallas setting is part of the key, so flipping
    ``REPRO_PATHFINDER_PALLAS`` mid-process builds a fresh evaluator
    instead of silently returning the cached other-path one."""
    from repro.pathfinding.batch import cached_evaluator, evaluator_cache_key

    use_pallas = _resolve_pallas(None)
    key = evaluator_cache_key(wl, db, tile_sizes, space) + (use_pallas,)
    return cached_evaluator(
        _DEVICE_EVALUATORS, key, db,
        lambda: DeviceEvaluator(wl, db, tile_sizes, space, use_pallas),
        _DEVICE_EVALUATOR_CACHE_MAX)


def evaluate_batch_device(encoded: np.ndarray, wl: GEMMWorkload,
                          db: TechDB = DEFAULT_DB,
                          tile_sizes: Tuple[int, int, int] = DEFAULT_TILE,
                          space: Optional[DesignSpace] = None
                          ) -> MetricsBatch:
    """Jitted counterpart of :func:`repro.pathfinding.evaluate_batch`."""
    return get_device_evaluator(wl, db, tile_sizes, space).metrics(encoded)


def propose_batch(encoded: np.ndarray, wl: GEMMWorkload,
                  db: TechDB = DEFAULT_DB,
                  space: Optional[DesignSpace] = None,
                  seed: int = 0) -> np.ndarray:
    """Vectorized hierarchical moves over encoded rows (see
    :func:`_propose_jax`); invalid candidates keep the incumbent row."""
    return get_device_evaluator(wl, db, space=space).propose(encoded, seed)
